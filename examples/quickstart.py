"""Quickstart: train a small LM with the paper's FP8 recipe in ~1 minute.

  PYTHONPATH=src python examples/quickstart.py

Covers the whole public API surface: config -> model -> FP8 quantized
training step (enhanced loss scaling, FP16 master weights, stochastic
rounding) -> metrics.
"""
import jax
import numpy as np

from repro.core.loss_scale import LossScaler
from repro.data import DataConfig, synthetic_lm_batches
from repro.models.registry import build_config
from repro.models.transformer import init_lm
from repro.train.step import make_optimizer_for, make_train_step

VOCAB = 256


def main():
    # 1. An architecture from the registry, reduced for CPU.
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=VOCAB, remat=False)
    print(f"arch={cfg.arch}  params~{cfg.param_count():,}  "
          f"FP8 recipe: {cfg.policy.quant.fwd_format} fwd / "
          f"{cfg.policy.quant.bwd_format} bwd, "
          f"master={cfg.policy.master_weight_dtype}")

    # 2. Mixed-precision optimizer with the paper's enhanced loss scaling.
    opt = make_optimizer_for(cfg, name="adam", learning_rate=3e-3,
                             scaler=LossScaler(mode="enhanced",
                                               init_scale=1024.0,
                                               min_scale_schedule=()))
    step = jax.jit(make_train_step(cfg, opt))

    # 3. Deterministic synthetic data with learnable bigram structure.
    data = synthetic_lm_batches(DataConfig(vocab_size=VOCAB, seq_len=64,
                                           batch_size=16, seed=0))

    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    print(f"unigram entropy (no learning) = {np.log(VOCAB):.3f} nats")
    for i in range(60):
        state, m = step(state, next(data),
                        jax.random.fold_in(jax.random.PRNGKey(1), i))
        if i % 10 == 0 or i == 59:
            print(f"step {i:3d}  loss={float(m['loss']):.4f}  "
                  f"scale={float(m['loss_scale']):.0f}  "
                  f"finite={bool(m['grads_finite'])}")
    assert float(m["loss"]) < np.log(VOCAB), "FP8 training failed to learn"
    print("OK: FP8 training learned the synthetic structure.")


if __name__ == "__main__":
    main()
