"""The paper's convnet workload: FP8 ResNet training with loss-scale sweep
and RNE-vs-stochastic-rounding comparison (Figs. 2a/3/4 at CIFAR scale).

  PYTHONPATH=src python examples/resnet_fp8.py
"""
import numpy as np

from benchmarks.common import train_convnet
from repro.core.loss_scale import convnet_scaler
from repro.core.precision_policy import BASELINE, PAPER_FP8, PAPER_FP8_RNE


def main():
    print("== paper Fig. 2a: constant loss-scale sweep (FP8 convnet) ==")
    for scale in [1.0, 10_000.0]:
        h = train_convnet(quant=PAPER_FP8, scaler=convnet_scaler(scale),
                          steps=100, eval_every=25, track_underflow=True)
        print(f"  scale={scale:>7.0f}: val_acc={h['val_acc'][-1]:.3f} "
              f"underflow_frac={np.mean(h['underflow_frac']):.4f}")

    print("== paper Fig. 3/4: rounding mode vs generalization ==")
    for name, q in [("fp32", BASELINE), ("fp8+RNE", PAPER_FP8_RNE),
                    ("fp8+SR", PAPER_FP8)]:
        sc = convnet_scaler(1.0 if name == "fp32" else 10_000.0)
        h = train_convnet(quant=q, scaler=sc, steps=100, eval_every=25)
        print(f"  {name:8s}: val_acc={h['val_acc'][-1]:.3f} "
              f"L2_final={h['l2_loss'][-1]:.4f} "
              f"gap={h['val_nll'][-1] - h['train_nll'][-1]:+.3f}")
    print("OK")


if __name__ == "__main__":
    main()
