"""End-to-end training driver: ~100M-parameter LM, few hundred steps, with
the full production loop — FP8 recipe, enhanced loss scaling, checkpointing/
restart, preemption handling, straggler detection, metrics jsonl.

  PYTHONPATH=src python examples/train_lm.py --steps 300          # full
  PYTHONPATH=src python examples/train_lm.py --steps 30 --small   # quick

The default config is a ~100M-parameter qwen2-family model (d=512, 12L,
vocab 32k). Use --small for a CI-scale run. Kill the process with SIGTERM
and re-run to watch checkpoint/restart resume exactly where it stopped.
"""
import argparse

import jax

from repro.core.loss_scale import LossScaler
from repro.data import DataConfig, synthetic_lm_batches
from repro.models.registry import build_config
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import make_optimizer_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--baseline", action="store_true",
                    help="FP32/BF16 baseline instead of FP8")
    args = ap.parse_args()

    if args.small:
        cfg = build_config(args.arch, smoke=True).replace(
            n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
            vocab_size=512, remat=False)
        batch, seq = 8, 64
    else:
        # ~100M params: 12L x d512 x ff2048, 32k vocab.
        cfg = build_config(args.arch, smoke=True).replace(
            n_layers=12, d_model=512, n_heads=8, n_kv_heads=2, d_ff=2048,
            vocab_size=32768, max_seq_len=512)
        batch, seq = 8, 256
    if args.baseline:
        from repro.core.precision_policy import BASELINE_POLICY
        cfg = cfg.replace(policy=BASELINE_POLICY)
    print(f"training {cfg.arch}-family model, ~{cfg.param_count():,} params, "
          f"fp8={'off' if args.baseline else 'on'}")

    opt = make_optimizer_for(cfg, name="adam", learning_rate=1e-3,
                             scaler=LossScaler(mode="enhanced",
                                               init_scale=2.0**13,
                                               min_scale_schedule=((100, 64.0),)))
    data = synthetic_lm_batches(DataConfig(vocab_size=cfg.vocab_size,
                                           seq_len=seq, batch_size=batch,
                                           seed=0))
    loop = TrainLoop(cfg, opt, data,
                     LoopConfig(total_steps=args.steps, checkpoint_every=50,
                                checkpoint_dir=args.ckpt, log_every=10,
                                metrics_path=f"{args.ckpt}/metrics.jsonl"),
                     seed=0)
    loop.install_signal_handlers()
    out = loop.run()
    print(f"done at step {out['last_step']}: loss="
          f"{out['metrics'].get('loss'):.4f} "
          f"(stragglers={out['stragglers']})")


if __name__ == "__main__":
    main()
