"""Batched serving example: continuous batching with an FP8 KV cache.

  PYTHONPATH=src python examples/serve_batched.py

Eight requests stream through a 4-slot engine; slots recycle as sequences
finish. The same prompts are decoded once with a bf16 KV cache and once with
the FP8 (e5m2) cache to show the beyond-paper KV compression is
quality-neutral at greedy decoding.
"""
import dataclasses

import jax
import numpy as np

from repro.models.registry import build_config
from repro.models.transformer import init_lm
from repro.serve import ServeConfig, ServeEngine


def main():
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512)
    params = init_lm(jax.random.PRNGKey(0), cfg)

    prompts = [np.arange(5 + i) % cfg.vocab_size for i in range(8)]

    def run(kv_fmt):
        pol = dataclasses.replace(cfg.policy, kv_cache_format=kv_fmt)
        eng = ServeEngine(cfg.replace(policy=pol), params,
                          ServeConfig(max_batch=4, max_len=64))
        outs = {}
        pending = list(enumerate(prompts))
        while pending or any(eng.slots):
            while pending and eng.free_slots():
                i, p = pending.pop(0)
                uid = eng.add_request(p, max_new_tokens=8)
                outs[uid] = i
            for uid, toks in eng.step().items():
                print(f"  [{kv_fmt or 'bf16':5s}] request {outs[uid]} "
                      f"done: {toks}")
        return outs

    print("bf16 KV cache:")
    run(None)
    print("FP8 (e5m2) KV cache — half the decode bandwidth:")
    run("e5m2")
    print("OK")


if __name__ == "__main__":
    main()
