"""Batched serving example: the paged production engine.

  PYTHONPATH=src python examples/serve_batched.py

Eight requests stream through the paged engine — chunked prefill and
decode interleave in ONE jitted fixed-shape step, KV lives in a shared
page pool (memory scales with tokens in flight, not max_batch * max_len),
sampling happens on device, and repeated prompts hit the exact prefix
cache. The same workload then runs through the legacy fixed-slot engine
to show the streams are bit-identical (the differential-parity contract),
and once more with temperature sampling to show reproducible stochastic
decoding.
"""
import jax
import numpy as np

from repro.models.registry import build_config
from repro.models.transformer import init_lm
from repro.serve import (PagedServeConfig, PagedServeEngine, ServeConfig,
                         ServeEngine)


def main():
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512)
    params = init_lm(jax.random.PRNGKey(0), cfg)

    # request 7 repeats request 0's prompt (longer than one 8-token page)
    # -> exact prefix-cache hit splices request 0's full prompt pages
    prompts = [np.arange(9 + i) % cfg.vocab_size for i in range(7)]
    prompts.append(prompts[0].copy())

    def run(engine):
        outs, order = {}, {}
        pending = list(enumerate(prompts))
        while pending or any(s is not None for s in engine.slots):
            while pending and engine.free_slots():
                i, p = pending.pop(0)
                order[engine.add_request(p, max_new_tokens=8)] = i
            for uid, toks in engine.step().items():
                outs[order[uid]] = toks
        return outs

    print("paged engine (chunked prefill, page pool, on-device sampling):")
    paged = PagedServeEngine(cfg, params, PagedServeConfig(
        max_batch=4, max_len=64, n_pages=32, page_size=8, chunk_size=8))
    got = run(paged)
    for i in sorted(got):
        print(f"  request {i} done: {got[i]}")
    s = paged.stats()
    print(f"  page occupancy now {s['page_occupancy']:.2f}, prefix-cache "
          f"hit rate {s['prefix_cache_hit_rate']:.2f}")

    print("legacy fixed-slot engine (the parity oracle):")
    ref = run(ServeEngine(cfg, params, ServeConfig(max_batch=4, max_len=64)))
    assert all(got[i] == ref[i] for i in ref), "streams diverged!"
    print("  all 8 token streams bit-identical to the paged engine")

    print("temperature sampling (on device, per-request PRNG streams):")
    sampled = PagedServeEngine(cfg, params, PagedServeConfig(
        max_batch=4, max_len=64, n_pages=32, page_size=8, chunk_size=8,
        temperature=0.8, top_p=0.95, seed=7))
    for i, toks in sorted(run(sampled).items()):
        print(f"  request {i} sampled: {toks}")
    print("OK")


if __name__ == "__main__":
    main()
