"""End-to-end delayed per-tensor scaling: train -> calibrate -> serve.

Demonstrates the scaling/ subsystem with the HYBRID format recipe:
 1. discover the site registry with an abstract trace,
 2. train a tiny LM with QuantConfig(recipe="hybrid", scaling="delayed") —
    e4m3 W/A + e5m2 E/G, per-site scales from amax history, no inline amax
    reductions in the hot path. The precision recipe per tensor class:

        class          format  rounding  overflow
        W weights      e4m3    rne       saturate (+-448)
        A activations  e4m3    sr        saturate (+-448)
        E errors       e5m2    sr        -> inf (loss scaler backs off)
        G weight-grads e5m2    sr        -> inf

    (print it from code: QuantConfig(recipe="hybrid").recipe_table())
 3. calibrate + freeze scales — recording the FORMAT each scale was
    calibrated under — and
 4. run bitwise-deterministic FP8 serving (incl. FP8 KV cache) from the
    frozen scales; the engine refuses scales whose calibration format does
    not match its serving config.

Run: PYTHONPATH=src python examples/delayed_scaling.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision_policy import PrecisionPolicy, QuantConfig
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm
from repro.scaling import (DelayedScaling, calibrate, discover_lm_sites,
                           freeze_with_formats)
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.step import make_optimizer_for, make_train_step


def main():
    quant = QuantConfig(recipe="hybrid", scaling="delayed")
    print("precision recipe:", quant.recipe_table())
    policy = PrecisionPolicy(quant=quant, kv_cache_format="e5m2")
    cfg = ModelConfig(arch="demo", n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=2, d_ff=128, vocab_size=256, max_seq_len=64,
                      policy=policy, scan_layers=False)
    params = init_lm(jax.random.PRNGKey(0), cfg)

    # 1. site registry from one abstract trace (no FLOPs)
    B, S = 2, 16
    proto = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    registry = discover_lm_sites(cfg, params, proto)
    print(f"{len(registry)} scale sites, e.g. {registry.keys[0]}")

    # 2. delayed-scaling training: ScaleState threads through the step
    ds = DelayedScaling(registry, qcfg=quant)
    opt = make_optimizer_for(cfg, learning_rate=1e-3)
    step = jax.jit(make_train_step(cfg, opt, scaling=ds))
    state, scale_state = opt.init(params), ds.init()
    rng = np.random.default_rng(0)
    for i in range(10):
        toks = jnp.asarray(rng.integers(0, 256, (B, S)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        (state, scale_state), m = step(state, scale_state, batch,
                                       jax.random.PRNGKey(i))
    print(f"trained 10 steps, loss={float(m['loss']):.3f}, "
          f"{int((np.asarray(scale_state.scale) != 1.0).sum())} scales live")

    # 3. calibrate on held-out batches and freeze — scales AND the formats
    #    they were calibrated under (e4m3 for W/A sites under the hybrid
    #    recipe, e5m2 for the KV cache here)
    trained = opt.compute_params(state)
    calib = [{"tokens": jnp.asarray(rng.integers(0, 256, (B, S)), jnp.int32)}
             for _ in range(4)]
    ds2, cal_state = calibrate(trained, cfg, calib)
    frozen, formats = freeze_with_formats(ds2, cal_state, cfg)
    kv = {k: v for k, v in frozen.items() if "kv/" in k}
    print(f"frozen {len(frozen)} scales ({len(kv)} KV-cache sites), "
          f"formats: { {f: sum(1 for v in formats.values() if v == f) for f in sorted(set(formats.values()))} }")

    # 4. deterministic calibrated serving; frozen_formats makes the engine
    #    verify its serving config quantizes each site in the SAME format
    #    the scale was calibrated for
    eng = ServeEngine(cfg, trained, ServeConfig(max_batch=2, max_len=48),
                      frozen_scales=frozen, frozen_formats=formats)
    uid = eng.add_request(np.array([1, 2, 3], np.int32), max_new_tokens=8)
    out = eng.run_to_completion()
    print("generated:", out[uid])


if __name__ == "__main__":
    main()
