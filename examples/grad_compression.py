"""FP8 gradient-compression demo on an 8-device (emulated) pod axis.

  PYTHONPATH=src python examples/grad_compression.py

Shows the beyond-paper distributed trick: cross-pod data-parallel gradient
all-reduce with the gradients quantized to e5m2 on the wire plus error
feedback — the paper's storage format turned into a wire format.

NOTE: must run as its own process (sets XLA device-count flags).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402
from jax.sharding import PartitionSpec as P                    # noqa: E402

from repro.distributed.grad_compress import compressed_psum_mean  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 4096)) * 0.01
    err = jnp.zeros_like(g)

    def step(g, e):
        def inner(gl, el):
            red, ne = compressed_psum_mean({"g": gl[0]}, {"g": el[0]},
                                           axis_name="pod")
            return red["g"][None], ne["g"][None]
        return jax.shard_map(inner, mesh=mesh,
                             in_specs=(P("pod", None), P("pod", None)),
                             out_specs=(P("pod", None), P("pod", None)),
                             check_vma=False)(g, e)

    true = np.asarray(g).mean(0)
    with jax.set_mesh(mesh):
        jstep = jax.jit(step)
        red, err_ = jstep(g, err)
        one_shot = np.linalg.norm(np.asarray(red)[0] - true) \
            / np.linalg.norm(true)
        acc_t = acc_c = 0.0
        e = err
        for _ in range(20):
            red, e = jstep(g, e)
            acc_t = acc_t + true
            acc_c = acc_c + np.asarray(red)[0]
        with_feedback = np.linalg.norm(acc_c - acc_t) / np.linalg.norm(acc_t)
    print(f"one-shot rel err (pure e5m2 wire): {one_shot:.4f}")
    print(f"20-step accumulated rel err (error feedback): "
          f"{with_feedback:.4f}")
    print(f"wire bytes per element: 1 (e5m2) vs 2 (bf16) vs 4 (f32)")
    assert with_feedback < one_shot
    print("OK: error feedback converges the compressed reduction")


if __name__ == "__main__":
    main()
