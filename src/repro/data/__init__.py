from repro.data.pipeline import (DataConfig, synthetic_image_batches,
                                 synthetic_lm_batches, synthetic_seq2seq_batches)

__all__ = ["DataConfig", "synthetic_lm_batches", "synthetic_image_batches",
           "synthetic_seq2seq_batches"]
