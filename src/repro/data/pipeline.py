"""Deterministic, shardable synthetic data pipeline.

Data carries learnable structure so training losses actually fall and the
paper's convergence comparisons (FP8 vs FP32, RNE vs SR) are meaningful:

 * LM batches: an affine-bigram language — next = (a * prev + b) mod V with
   temperature noise. A model must learn the bigram map; unigram entropy is
   ~log V, so loss decreasing well below log V proves learning.
 * Image batches: class-dependent frequency patterns + noise (convnets must
   learn spatial filters, reproducing the paper's ResNet ablations at small
   scale).
 * seq2seq batches: target = deterministic token-wise transform of source
   (the Transformer/GNMT analogue).

Determinism: every batch is a pure function of (seed, step) — restarts and
elastic re-shards replay identically; per-host sharding is a pure slice of
the global batch, so multi-host pipelines stay bit-identical to single-host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    batch_size: int = 32
    seed: int = 0
    # bigram params (derived from seed if None)
    temperature: float = 0.3


def _bigram_params(vocab: int, seed: int):
    rng = np.random.default_rng(seed + 1234)
    a = int(rng.integers(1, vocab - 1)) | 1    # odd => invertible mod 2^k-ish
    b = int(rng.integers(0, vocab))
    return a, b


def synthetic_lm_batches(cfg: DataConfig, *, start_step: int = 0
                         ) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {'tokens', 'labels', 'loss_mask'} — labels[t] = next token."""
    a, b = _bigram_params(cfg.vocab_size, cfg.seed)
    step = start_step
    while True:
        rng = np.random.default_rng((cfg.seed, step))
        toks = np.empty((cfg.batch_size, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, cfg.batch_size)
        noise = rng.random((cfg.batch_size, cfg.seq_len)) < cfg.temperature
        rand_next = rng.integers(0, cfg.vocab_size,
                                 (cfg.batch_size, cfg.seq_len))
        for t in range(cfg.seq_len):
            det = (a * toks[:, t] + b) % cfg.vocab_size
            toks[:, t + 1] = np.where(noise[:, t], rand_next[:, t], det)
        yield {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((cfg.batch_size, cfg.seq_len), np.float32),
        }
        step += 1


def synthetic_seq2seq_batches(cfg: DataConfig, *, d_model: int,
                              start_step: int = 0
                              ) -> Iterator[Dict[str, np.ndarray]]:
    """Enc-dec batches: enc_inputs are embedded source frames (the audio-stub
    pathway), decoder must predict tgt[t+1] = f(src[t+1]) given tgt[:t]."""
    a, b = _bigram_params(cfg.vocab_size, cfg.seed)
    emb_rng = np.random.default_rng(cfg.seed + 77)
    emb = emb_rng.standard_normal((cfg.vocab_size, d_model)).astype(
        np.float32) * 0.5
    step = start_step
    while True:
        rng = np.random.default_rng((cfg.seed, 10_000 + step))
        src = rng.integers(0, cfg.vocab_size,
                           (cfg.batch_size, cfg.seq_len)).astype(np.int32)
        tgt = (a * src + b) % cfg.vocab_size
        yield {
            "enc_inputs": emb[src],                      # (B, S, D)
            "tokens": tgt[:, :-1],
            "labels": tgt[:, 1:].astype(np.int32),
            "loss_mask": np.ones((cfg.batch_size, cfg.seq_len - 1),
                                 np.float32),
        }
        step += 1


def synthetic_image_batches(*, batch_size: int = 64, image_size: int = 32,
                            n_classes: int = 10, seed: int = 0,
                            task_seed: int = 0, start_step: int = 0,
                            noise: float = 0.3
                            ) -> Iterator[Dict[str, np.ndarray]]:
    """Class-dependent 2-D sinusoid patterns + noise (CIFAR-scale stand-in).

    task_seed fixes the class prototypes independently of the sampling
    stream `seed`, so train/val streams draw from the SAME task."""
    proto_rng = np.random.default_rng(task_seed + 55)
    freqs = proto_rng.uniform(1.0, 4.0, (n_classes, 2))
    phases = proto_rng.uniform(0, 2 * np.pi, (n_classes, 3))
    xx, yy = np.meshgrid(np.linspace(0, 2 * np.pi, image_size),
                         np.linspace(0, 2 * np.pi, image_size))
    step = start_step
    while True:
        rng = np.random.default_rng((seed, 20_000 + step))
        labels = rng.integers(0, n_classes, batch_size).astype(np.int32)
        f = freqs[labels]
        p = phases[labels]
        base = np.stack([
            np.sin(f[:, 0, None, None] * xx[None] + p[:, c, None, None])
            * np.cos(f[:, 1, None, None] * yy[None])
            for c in range(3)], axis=-1).astype(np.float32)
        eps = rng.standard_normal(base.shape).astype(np.float32) * noise
        yield {"image": base + eps, "label": labels}
        step += 1


def host_shard(batch: Dict[str, np.ndarray], host_id: int,
               n_hosts: int) -> Dict[str, np.ndarray]:
    """Pure slice of the global batch for this host (deterministic)."""
    def slc(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: slc(v) for k, v in batch.items()}
