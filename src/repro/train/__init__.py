from repro.train.step import (make_optimizer_for, make_serve_decode,
                              make_serve_prefill, make_train_step)

__all__ = ["make_train_step", "make_serve_prefill", "make_serve_decode",
           "make_optimizer_for"]
