"""Fault-tolerant training loop.

Production behaviors for the 1000-node regime, exercised at CPU scale:
 * checkpoint/restart — periodic async checkpoints (atomic commit), restore
   on start from the newest committed step; a killed-and-relaunched run
   resumes bit-identically (the data pipeline is a pure function of step).
 * preemption handling — SIGTERM/SIGINT installs a "stop after this step"
   flag; the loop checkpoints and exits cleanly (the standard TPU-preemption
   contract).
 * straggler mitigation — per-step wall-time EMA; steps slower than
   `straggler_factor` x EMA are counted and surfaced through metrics and the
   `on_straggler` hook (at fleet scale the hook triggers host replacement /
   data re-sharding; here it logs and optionally checkpoints so the restart
   lands on a healthy machine).
 * overflow telemetry — the paper's dynamic loss scaling makes overflow a
   *normal* event; counts stream into the metrics log (jsonl) for the
   Fig. 2b-style scale-schedule plots.
"""
from __future__ import annotations

import dataclasses
import json
import signal
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.master_weights import MixedPrecisionOptimizer
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm
from repro.scaling.state import DelayedScaling
from repro.train.step import make_train_step

Array = jax.Array


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_last_k: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None
    straggler_factor: float = 3.0
    straggler_ema: float = 0.95
    n_microbatches: int = 1


class TrainLoop:
    def __init__(self, cfg: ModelConfig, optimizer: MixedPrecisionOptimizer,
                 data: Iterator[Dict[str, np.ndarray]],
                 loop: LoopConfig, *, seed: int = 0,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 scaling: Optional[DelayedScaling] = None,
                 amax_sync=None):
        """scaling: optional DelayedScaling bundle (delayed per-tensor FP8
        scaling). Its ScaleState rides through the jitted step and is
        checkpointed/restored next to the optimizer state."""
        self.cfg = cfg
        self.optimizer = optimizer
        self.data = data
        self.loop = loop
        self.seed = seed
        self.on_straggler = on_straggler
        self.scaling = scaling
        self.ckpt = Checkpointer(loop.checkpoint_dir,
                                 keep_last_k=loop.keep_last_k)
        self._stop = False
        self._step_fn = jax.jit(make_train_step(
            cfg, optimizer, n_microbatches=loop.n_microbatches,
            scaling=scaling, amax_sync=amax_sync))
        self._metrics_f = None
        if loop.metrics_path:
            Path(loop.metrics_path).parent.mkdir(parents=True, exist_ok=True)
            self._metrics_f = open(loop.metrics_path, "a")

    # -- preemption ----------------------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):  # noqa: ARG001
            print(f"[train] signal {signum}: will checkpoint and stop "
                  f"after the current step")
            self._stop = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # -- main -----------------------------------------------------------------
    def _pack(self, state, scale_state):
        if self.scaling is None:
            return state
        return {"train": state, "amax_scales": scale_state}

    def _unpack(self, tree):
        if self.scaling is None:
            return tree, None
        return tree["train"], tree["amax_scales"]

    def run(self) -> Dict[str, Any]:
        params = init_lm(jax.random.PRNGKey(self.seed), self.cfg)
        state = self.optimizer.init(params)
        scale_state = self.scaling.init() if self.scaling else None
        del params
        start_step = 0
        if self.ckpt.latest_step() is not None:
            proto = jax.eval_shape(lambda s: s,
                                   self._pack(state, scale_state))
            tree, start_step = self.ckpt.restore(proto)
            state, scale_state = self._unpack(tree)
            print(f"[train] restored checkpoint at step {start_step}")
            # Fast-forward the data stream so a resumed run consumes exactly
            # the batches an uninterrupted run would have (bit-identical
            # restart). Callable data sources seek directly.
            if callable(self.data):
                self.data = self.data(start_step)
            else:
                for _ in range(start_step):
                    next(self.data)
        elif callable(self.data):
            self.data = self.data(0)

        ema = None
        stragglers = 0
        last_metrics: Dict[str, Any] = {}
        step = start_step
        for step in range(start_step, self.loop.total_steps):
            batch = next(self.data)
            t0 = time.time()
            step_key = jax.random.fold_in(
                jax.random.PRNGKey(self.seed + 17), step)
            if self.scaling is None:
                state, metrics = self._step_fn(state, batch, step_key)
            else:
                (state, scale_state), metrics = self._step_fn(
                    state, scale_state, batch, step_key)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            dt = time.time() - t0
            # straggler detection (skip the compile step)
            if step > start_step:
                if ema is not None and dt > self.loop.straggler_factor * ema:
                    stragglers += 1
                    print(f"[train] straggler step {step}: {dt:.3f}s vs "
                          f"EMA {ema:.3f}s")
                    if self.on_straggler:
                        self.on_straggler(step, dt)
                ema = dt if ema is None else \
                    self.loop.straggler_ema * ema \
                    + (1 - self.loop.straggler_ema) * dt
            metrics.update(step=step, step_time_s=round(dt, 4),
                           stragglers=stragglers)
            last_metrics = metrics
            if self._metrics_f:
                self._metrics_f.write(json.dumps(metrics) + "\n")
                self._metrics_f.flush()
            if step % self.loop.log_every == 0:
                print(f"[train] step {step} loss={metrics.get('loss', 0):.4f} "
                      f"scale={metrics.get('loss_scale', 0):.0f} "
                      f"t={dt:.3f}s")
            done = step + 1 >= self.loop.total_steps
            if self._stop or done or \
                    (step + 1) % self.loop.checkpoint_every == 0:
                self.ckpt.save(step + 1, self._pack(state, scale_state))
                if self._stop:
                    print(f"[train] preempted: checkpointed at {step + 1}")
                    break
        self.ckpt.wait()
        return {"state": state, "scale_state": scale_state,
                "last_step": step + 1,
                "metrics": last_metrics, "stragglers": stragglers}
