"""Fault-tolerant training loop.

Production behaviors for the 1000-node regime, exercised at CPU scale:
 * checkpoint/restart — periodic async checkpoints (atomic commit), restore
   on start from the newest committed step; a killed-and-relaunched run
   resumes bit-identically (the data pipeline is a pure function of step).
 * preemption handling — SIGTERM/SIGINT installs a "stop after this step"
   flag; the loop checkpoints and exits cleanly (the standard TPU-preemption
   contract).
 * straggler mitigation — per-step wall-time EMA; steps slower than
   `straggler_factor` x EMA are counted and surfaced through metrics and the
   `on_straggler` hook (at fleet scale the hook triggers host replacement /
   data re-sharding; here it logs and optionally checkpoints so the restart
   lands on a healthy machine). EMA and straggler count ride the checkpoint
   manifest, so a resumed run keeps its timing baseline instead of
   re-learning it (and mis-flagging the first post-restore steps).
 * observability — each step's phases run inside `obs.trace.Tracer` spans
   (data_wait / step_dispatch / device_sync / checkpoint), metrics stream
   through `obs.metrics.MetricsLogger` (versioned-schema jsonl; vector
   metrics such as per-layer amax trajectories serialize as lists), and
   `obs.health.HealthMonitor` attaches structured `health_events` (overflow,
   loss-scale flapping, per-site FP8 saturation/underflow, stuck amax,
   straggler streaks) to the record that triggered them. The `on_metrics`
   hook sees every serialized record.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.master_weights import MixedPrecisionOptimizer
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm
from repro.obs.health import HealthConfig, HealthMonitor
from repro.obs.metrics import MetricsLogger, jsonable
from repro.obs.trace import Tracer
from repro.scaling.state import DelayedScaling
from repro.train.step import make_train_step

Array = jax.Array


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_last_k: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None
    trace_path: Optional[str] = None
    metrics_window: int = 64
    straggler_factor: float = 3.0
    straggler_ema: float = 0.95
    n_microbatches: int = 1


class TrainLoop:
    def __init__(self, cfg: ModelConfig, optimizer: MixedPrecisionOptimizer,
                 data: Iterator[Dict[str, np.ndarray]],
                 loop: LoopConfig, *, seed: int = 0,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 on_metrics: Optional[
                     Callable[[int, Dict[str, Any]], None]] = None,
                 health: Optional[HealthConfig] = None,
                 scaling: Optional[DelayedScaling] = None,
                 amax_sync=None, plan=None):
        """scaling: optional DelayedScaling bundle (delayed per-tensor FP8
        scaling). Its ScaleState rides through the jitted step and is
        checkpointed/restored next to the optimizer state.

        plan: optional distributed.strategy.ParallelPlan. Supplies gradient
        shardings to the step; when plan.compresses (policy.dist.wire ==
        "fp8_ef") the DP reduction runs over the fp8 error-feedback
        collective — the residual pytree then rides the step like
        ScaleState does (checkpointed under "wire_error", restored on
        resume) and the loop emits comm/* metrics plus a sampled
        span/allreduce_s timing probe.

        on_metrics(step, record): called with every serialized metrics
        record (the exact dict written to the jsonl sink, health_events
        included) — the seam for external sinks (wandb, fleet telemetry)."""
        self.cfg = cfg
        self.optimizer = optimizer
        self.data = data
        self.loop = loop
        self.seed = seed
        self.on_straggler = on_straggler
        self.on_metrics = on_metrics
        self.scaling = scaling
        self.plan = plan
        self.wire = plan is not None and plan.compresses
        self.ckpt = Checkpointer(loop.checkpoint_dir,
                                 keep_last_k=loop.keep_last_k)
        self._stop = False
        self._step_fn = jax.jit(make_train_step(
            cfg, optimizer, n_microbatches=loop.n_microbatches,
            scaling=scaling, amax_sync=amax_sync, plan=plan))
        # Timing probe for the wire collective: the step is ONE jitted
        # program, so the reduction cannot be timed from the host inside
        # it — instead a standalone jit of the same collective runs on the
        # (grad-shaped) residual pytree every log_every steps, under
        # span/allreduce_s.
        self._wire_probe = jax.jit(plan.dp_allreduce()) if self.wire else None
        self._comm: Dict[str, float] = {}
        self.tracer = Tracer(loop.trace_path)
        self.monitor = HealthMonitor(
            health,
            site_names=list(scaling.registry.keys) if scaling else None,
            scaler=optimizer.scaler)

    def _logger_meta(self) -> Dict[str, Any]:
        meta: Dict[str, Any] = {
            "arch": self.cfg.arch,
            "n_microbatches": self.loop.n_microbatches,
            "total_steps": self.loop.total_steps,
        }
        pol = getattr(self.cfg, "policy", None)
        if pol is not None and getattr(pol, "quant", None) is not None:
            meta["recipe"] = pol.quant.recipe
            meta["track_health"] = bool(pol.quant.track_health)
        if self.scaling is not None:
            # Row order of the dense health/amax_sites vector.
            meta["sites"] = list(self.scaling.registry.keys)
        if self.plan is not None:
            meta["dist"] = self.plan.describe()
        return meta

    # -- preemption ----------------------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):  # noqa: ARG001
            print(f"[train] signal {signum}: will checkpoint and stop "
                  f"after the current step")
            self._stop = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # -- main -----------------------------------------------------------------
    def _pack(self, state, scale_state, err=None):
        if self.scaling is None and not self.wire:
            return state
        tree = {"train": state}
        if self.scaling is not None:
            tree["amax_scales"] = scale_state
        if self.wire:
            tree["wire_error"] = err
        return tree

    def _unpack(self, tree):
        if self.scaling is None and not self.wire:
            return tree, None, None
        return (tree["train"], tree.get("amax_scales"),
                tree.get("wire_error"))

    def run(self) -> Dict[str, Any]:
        with MetricsLogger(self.loop.metrics_path, meta=self._logger_meta(),
                           window=self.loop.metrics_window) as logger:
            try:
                return self._run(logger)
            finally:
                self.tracer.export()

    def _run(self, logger: MetricsLogger) -> Dict[str, Any]:
        params = init_lm(jax.random.PRNGKey(self.seed), self.cfg)
        state = self.optimizer.init(params)
        scale_state = self.scaling.init() if self.scaling else None
        err = self.plan.init_wire_state(state.master) if self.wire else None
        if self.wire:
            self._comm = {f"comm/{k}": v for k, v in
                          self.plan.wire_bytes(state.master).items()
                          if isinstance(v, (int, float))}
        del params
        start_step = 0
        ema = None
        stragglers = 0
        if self.ckpt.latest_step() is not None:
            proto = jax.eval_shape(lambda s: s,
                                   self._pack(state, scale_state, err))
            tree, start_step = self.ckpt.restore(proto)
            state, scale_state, err = self._unpack(tree)
            # Straggler baseline survives restarts: a resumed run otherwise
            # re-learns the EMA from scratch and both forgets its count and
            # risks flagging warm steps against a cold baseline.
            extra = self.ckpt.manifest(start_step).get("extra", {}) or {}
            ema = extra.get("straggler_ema")
            stragglers = int(extra.get("stragglers", 0))
            print(f"[train] restored checkpoint at step {start_step}")
            # Fast-forward the data stream so a resumed run consumes exactly
            # the batches an uninterrupted run would have (bit-identical
            # restart). Callable data sources seek directly.
            if callable(self.data):
                self.data = self.data(start_step)
            else:
                for _ in range(start_step):
                    next(self.data)
        elif callable(self.data):
            self.data = self.data(0)

        last_metrics: Dict[str, Any] = {}
        step = start_step
        for step in range(start_step, self.loop.total_steps):
            t0 = time.time()
            with self.tracer.span("data_wait", step=step):
                batch = next(self.data)
            step_key = jax.random.fold_in(
                jax.random.PRNGKey(self.seed + 17), step)
            with self.tracer.span("step_dispatch", step=step):
                if self.wire and self.scaling is None:
                    (state, err), metrics = self._step_fn(
                        state, err, batch, step_key)
                elif self.wire:
                    (state, scale_state, err), metrics = self._step_fn(
                        state, scale_state, err, batch, step_key)
                elif self.scaling is None:
                    state, metrics = self._step_fn(state, batch, step_key)
                else:
                    (state, scale_state), metrics = self._step_fn(
                        state, scale_state, batch, step_key)
            with self.tracer.span("device_sync", step=step):
                metrics = jax.block_until_ready(metrics)
            if self.wire and step % self.loop.log_every == 0:
                # Sampled wire-collective timing: the residual pytree is
                # exactly grad-shaped, so reducing it exercises the real
                # program (result discarded; error buffers untouched).
                with self.tracer.span("allreduce", step=step):
                    jax.block_until_ready(self._wire_probe(err, err))
            dt = time.time() - t0
            # straggler detection (skip the compile step)
            if step > start_step:
                if ema is not None and dt > self.loop.straggler_factor * ema:
                    stragglers += 1
                    print(f"[train] straggler step {step}: {dt:.3f}s vs "
                          f"EMA {ema:.3f}s")
                    if self.on_straggler:
                        self.on_straggler(step, dt)
                ema = dt if ema is None else \
                    self.loop.straggler_ema * ema \
                    + (1 - self.loop.straggler_ema) * dt

            done = step + 1 >= self.loop.total_steps
            save = self._stop or done or \
                (step + 1) % self.loop.checkpoint_every == 0
            if save:
                with self.tracer.span("checkpoint", step=step):
                    self.ckpt.save(
                        step + 1, self._pack(state, scale_state, err),
                        extra={"straggler_ema": ema,
                               "stragglers": stragglers})

            # Serialize first (scalar/vector-aware), then let the health
            # detectors see the exact record, so events land ON the record
            # whose metrics triggered them.
            record = {k: jsonable(v) for k, v in metrics.items()}
            record.update(step=step, step_time_s=round(dt, 4),
                          stragglers=stragglers, **self._comm,
                          **self.tracer.durations())
            events = self.monitor.observe(step, record)
            if events:
                record["health_events"] = events
            record = logger.log(record)
            if self.on_metrics:
                self.on_metrics(step, record)
            last_metrics = record
            if step % self.loop.log_every == 0:
                # non-finite metrics serialize as strings ("inf"/"nan")
                loss = record.get("loss", 0)
                scale = record.get("loss_scale", 0)
                loss = f"{loss:.4f}" if isinstance(loss, float) else loss
                scale = f"{scale:.0f}" if isinstance(scale, float) else scale
                print(f"[train] step {step} loss={loss} scale={scale} "
                      f"t={dt:.3f}s")
            if self._stop and save:
                print(f"[train] preempted: checkpointed at {step + 1}")
                break
        self.ckpt.wait()
        return {"state": state, "scale_state": scale_state,
                "wire_error": err, "last_step": step + 1,
                "metrics": last_metrics, "stragglers": stragglers}
