"""Jittable step functions: the units the dry-run lowers and the train loop
runs.

train_step implements the paper's full Fig. 1b pipeline per step:
  compute params (fp16 master -> bf16) -> FP8 forward/backward (loss scaled)
  -> overflow probe -> unscale in f32 -> optimizer update in f32 -> fp16
  master store -> loss-scale update.

Optional gradient accumulation (n_microbatches) runs the loss/grad pass in a
scan with f32 accumulators — the standard large-batch memory lever.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.loss_scale import LossScaler
from repro.core.master_weights import MixedPrecisionOptimizer, MixedPrecisionState
from repro.models.config import ModelConfig
from repro.models.transformer import encode, forward, lm_loss
from repro.optim import make_optimizer
from repro.scaling import context as scale_ctx
from repro.scaling.context import AMAX_PREFIX, HEALTH_PREFIX
from repro.scaling.state import DelayedScaling, ScaleState, split_observations

Array = jax.Array


def make_optimizer_for(cfg: ModelConfig, *, name: str = "adam",
                       scaler: Optional[LossScaler] = None,
                       learning_rate: float = 1e-4) -> MixedPrecisionOptimizer:
    from repro.optim.optimizers import make_leafwise
    init, update = make_optimizer(name, learning_rate=learning_rate)
    names, leaf = make_leafwise(name, learning_rate=learning_rate)
    return MixedPrecisionOptimizer(
        inner_init=init, inner_update=update,
        scaler=scaler or LossScaler(mode="enhanced"),
        master_dtype=cfg.policy.master_weight_dtype,
        update_dtype=cfg.policy.update_dtype,
        compute_dtype=cfg.policy.activation_dtype,
        accum_names=names, leaf_update=leaf)


def make_train_step(cfg: ModelConfig, optimizer: MixedPrecisionOptimizer, *,
                    n_microbatches: int = 1,
                    scaling: Optional[DelayedScaling] = None,
                    amax_sync=None, plan=None):
    """Returns train_step(state, batch, step_key) -> (state, metrics).

    plan: optional distributed.strategy.ParallelPlan. Supplies the gradient
    shardings (grads / the f32 accumulator constrained to the ZeRO-1 master
    layout instead of ballooning to a model-sharded-only copy) and, when
    `plan.compresses` (policy.dist.wire == "fp8_ef" on a >1-device wire
    axis), reroutes the DP gradient reduction through the e5m2-compressed
    error-feedback all-reduce: the loss/grad pass then runs inside an
    explicit shard_map over the dp axes and the step signature grows the
    residual pytree,

        train_step(state, [scale_state,] err, batch, step_key)
            -> ((state, [scale_state,] err), metrics)

    with `err` created by plan.init_wire_state(state.master) and
    checkpointed next to ScaleState by the train loop.

    scaling: optional DelayedScaling bundle. When given, the returned step is
        train_step(state, scale_state, batch, step_key)
            -> ((state, scale_state), metrics)
    — the ScaleState pytree rides through the jitted step next to
    LossScaleState: per-site scales feed the quantize sites via the scaling
    context, forward amax observations come back through the loss aux,
    error/grad observations through the cotangents of per-site tokens, and
    the history is updated post-step (optionally cross-replica-synced via
    `amax_sync`, e.g. distributed.amax_sync.make_amax_sync('data')). In
    wire-compressed mode amax_sync is ignored: observations are already
    cross-device-combined (pmax) inside the shard_map body.
    """
    wire = plan is not None and plan.compresses

    def constrain_grads(g):
        if plan is None:
            return g
        from jax.sharding import NamedSharding
        specs = plan.grad_specs(g)
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(plan.mesh, s)),
            g, specs)

    def loss_fn(params, tokens, batch, step_key, scale, scale_state):
        if scaling is None:
            return lm_loss(params, batch, cfg=cfg, qkey=step_key,
                           loss_scale=scale)
        with scaling.collect(scale_state, tokens):
            return lm_loss(params, batch, cfg=cfg, qkey=step_key,
                           loss_scale=scale)

    def _grads_and_metrics(params, batch, step_key, scale, scale_state,
                           constrain=None):
        constrain = constrain_grads if constrain is None else constrain
        tokens = scaling.zero_tokens() if scaling is not None else {}

        if n_microbatches <= 1:
            (loss, metrics), (grads, tok_grads) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(
                    params, tokens, batch, step_key, scale, scale_state)
            return loss, metrics, constrain(grads), tok_grads

        def reshape_mb(x):
            return x.reshape((n_microbatches,
                              x.shape[0] // n_microbatches) + x.shape[1:])
        mb_batch = jax.tree_util.tree_map(reshape_mb, batch)

        def mb_body(carry, mb):
            acc, tacc, i = carry
            mkey = jax.random.fold_in(step_key, i)
            (l, m), (g, tg) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(
                    params, tokens, mb, mkey, scale, scale_state)
            acc = jax.tree_util.tree_map(
                lambda a, gg: a + gg.astype(jnp.float32) / n_microbatches,
                acc, g)
            tacc = jax.tree_util.tree_map(lambda a, gg: jnp.maximum(a, gg),
                                          tacc, tg)
            return (constrain(acc), tacc, i + 1), (l, m)

        zero = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        tzero = jax.tree_util.tree_map(jnp.zeros_like, tokens)
        (grads, tok_grads, _), (losses, metricses) = jax.lax.scan(
            mb_body, (zero, tzero, 0), mb_batch)
        loss = losses.mean()
        # Microbatch reduction: amax observations by max, losses by mean —
        # over the MICROBATCH axis only (axis 0): per-layer scanned-stack
        # observations are (n_groups,) vectors whose layer axis must
        # survive the reduction.
        metrics = {k: (v.max(axis=0)
                       if k.startswith((AMAX_PREFIX, HEALTH_PREFIX))
                       else v.mean())
                   for k, v in metricses.items()}
        return loss, metrics, grads, tok_grads

    def _combine_tokens(tok, axes):
        """Cross-device combine of token cotangents: amax channels by pmax
        (matching amax_sync semantics), the optional (sat, flush) health
        tail by pmean (they are per-batch fractions)."""
        c = scale_ctx.TOKEN_CHANNELS
        if tok.ndim and tok.shape[-1] > c:
            return jnp.concatenate(
                [jax.lax.pmax(tok[..., :c], axes),
                 jax.lax.pmean(tok[..., c:], axes)], axis=-1)
        return jax.lax.pmax(tok, axes)

    def _wire_grads_and_metrics(params, batch, step_key, scale, scale_state):
        """The fp8-on-the-wire gradient pass: loss/grads computed locally
        inside an explicit shard_map over the dp axes (so the cross-device
        reduction is OURS, not an XLA-inserted all-reduce), full-precision
        pmean over the fast intra-pod axes, then the e5m2 error-feedback
        collective over the wire axis. Returns stacked per-wire-device f32
        grads (leading axis = wire device) ready for plan.dp_allreduce."""
        from jax.sharding import PartitionSpec as P

        from repro.distributed import sharding as shmod

        dp = plan.dp_axes
        inner = plan.inner_dp_axes

        def local_body(*args):
            if scaling is None:
                params_, batch_, key_, scale_ = args
                sstate_ = None
            else:
                params_, batch_, key_, scale_, sstate_ = args
            # Logical activation constraints naming the manually-mapped dp
            # axes are meaningless inside the body — drop them.
            with shmod.manual_axes(dp):
                loss, metrics, grads, tok_grads = _grads_and_metrics(
                    params_, batch_, key_, scale_, sstate_,
                    constrain=lambda g: g)
            if inner:
                grads = jax.tree_util.tree_map(
                    lambda g: jax.lax.pmean(g, inner), grads)
            loss = jax.lax.pmean(loss, dp)
            metrics = {k: (jax.lax.pmax(v, dp)
                           if k.startswith((AMAX_PREFIX, HEALTH_PREFIX))
                           else jax.lax.pmean(v, dp))
                       for k, v in metrics.items()}
            tok_grads = {k: _combine_tokens(v, dp)
                         for k, v in tok_grads.items()}
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32)[None], grads)
            return loss, metrics, grads, tok_grads

        bspecs = plan.batch_specs(batch)
        operands = (params, batch, step_key, scale)
        in_specs = (P(), bspecs, P(), P())
        if scaling is not None:
            operands += (scale_state,)
            in_specs += (P(),)
        return plan.shard_map(
            local_body, in_specs,
            (P(), P(), P(plan.wire_axis), P()))(*operands)

    def _finish(state, grads, loss, metrics, scale):
        new_state, opt_metrics = optimizer.apply_gradients(state, grads)
        inv = 1.0 / jnp.maximum(scale, 1e-9)
        out = {"loss": loss.astype(jnp.float32) * inv,
               "grad_norm": optax_safe_norm(grads) * inv,
               **{k: v for k, v in metrics.items()}, **opt_metrics}
        return new_state, out

    def train_step(state: MixedPrecisionState, batch: Dict[str, Array],
                   step_key: Array) -> Tuple[MixedPrecisionState, Dict]:
        params = optimizer.compute_params(state)
        scale = state.loss_scale.scale
        loss, metrics, grads, _ = _grads_and_metrics(
            params, batch, step_key, scale, None)
        return _finish(state, grads, loss, metrics, scale)

    def train_step_scaled(state: MixedPrecisionState, scale_state: ScaleState,
                          batch: Dict[str, Array], step_key: Array):
        params = optimizer.compute_params(state)
        scale = state.loss_scale.scale
        loss, metrics, grads, tok_grads = _grads_and_metrics(
            params, batch, step_key, scale, scale_state)
        observed = split_observations(metrics, tok_grads, scaling.registry)
        new_scale_state = scaling.update(scale_state, observed,
                                         sync=amax_sync)
        new_state, out = _finish(state, grads, loss, metrics, scale)
        if scaling.qcfg.track_health:
            # Scale-churn rate: fraction of registry rows whose derived
            # scale moved this step; plus the dense freshest-amax vector
            # (registry row order — the logger meta carries the matching
            # site list) for the stuck/NaN-amax detectors.
            out["health/scale_churn"] = jnp.mean(
                (scale_state.scale != new_scale_state.scale)
                .astype(jnp.float32))
            out["health/amax_sites"] = new_scale_state.amax_history[:, 0]
        return (new_state, new_scale_state), out

    def train_step_wire(state: MixedPrecisionState, err,
                        batch: Dict[str, Array], step_key: Array):
        params = optimizer.compute_params(state)
        params = plan.gather_params(params)
        scale = state.loss_scale.scale
        loss, metrics, stacked, _ = _wire_grads_and_metrics(
            params, batch, step_key, scale, None)
        reduced, new_err = plan.dp_allreduce()(stacked, err)
        new_state, out = _finish(state, constrain_grads(reduced),
                                 loss, metrics, scale)
        return (new_state, new_err), out

    def train_step_wire_scaled(state: MixedPrecisionState,
                               scale_state: ScaleState, err,
                               batch: Dict[str, Array], step_key: Array):
        params = optimizer.compute_params(state)
        params = plan.gather_params(params)
        scale = state.loss_scale.scale
        loss, metrics, stacked, tok_grads = _wire_grads_and_metrics(
            params, batch, step_key, scale, scale_state)
        reduced, new_err = plan.dp_allreduce()(stacked, err)
        observed = split_observations(metrics, tok_grads, scaling.registry)
        # No amax_sync here: observations were pmax-combined across devices
        # inside the shard_map body already.
        new_scale_state = scaling.update(scale_state, observed, sync=None)
        new_state, out = _finish(state, constrain_grads(reduced),
                                 loss, metrics, scale)
        if scaling.qcfg.track_health:
            out["health/scale_churn"] = jnp.mean(
                (scale_state.scale != new_scale_state.scale)
                .astype(jnp.float32))
            out["health/amax_sites"] = new_scale_state.amax_history[:, 0]
        return (new_state, new_scale_state, new_err), out

    if wire:
        return train_step_wire if scaling is None else train_step_wire_scaled
    return train_step if scaling is None else train_step_scaled


def optax_safe_norm(tree) -> Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


# ---------------------------------------------------------------------------
# serving steps (deterministic eval: RNE, saturating)
# ---------------------------------------------------------------------------

def _eval_cfg(cfg: ModelConfig, frozen_scales=None) -> ModelConfig:
    quant = cfg.policy.quant.eval_mode()
    if frozen_scales is not None:
        # Calibrated serving: per-site scales come from the frozen dict
        # (python floats burned into the jitted program as constants).
        quant = dataclasses.replace(quant, scaling="delayed")
    pol = dataclasses.replace(cfg.policy, quant=quant)
    return cfg.replace(policy=pol)


def _maybe_frozen(frozen_scales):
    if frozen_scales is None:
        import contextlib
        return contextlib.nullcontext()
    return scale_ctx.activate(scale_ctx.frozen_context(frozen_scales))


def make_serve_prefill(cfg: ModelConfig, frozen_scales=None):
    """frozen_scales: optional {site_key: scale} dict from
    scaling.calibrate.freeze — enables deterministic calibrated FP8
    inference (including FP8 KV-cache scales)."""
    ecfg = _eval_cfg(cfg, frozen_scales)

    def prefill(params, batch, states):
        with _maybe_frozen(frozen_scales):
            enc_out = None
            if ecfg.is_encoder_decoder:
                enc_out = encode(params, batch["enc_inputs"], cfg=ecfg)
            logits, new_states, _ = forward(
                params, batch["tokens"], cfg=ecfg, mode="prefill",
                states=states, extra_embeds=batch.get("extra_embeds"),
                enc_out=enc_out, last_only=True)
        return logits, new_states

    return prefill


def make_serve_decode(cfg: ModelConfig, frozen_scales=None):
    ecfg = _eval_cfg(cfg, frozen_scales)

    def decode(params, batch, states):
        with _maybe_frozen(frozen_scales):
            enc_out = batch.get("enc_out")
            logits, new_states, _ = forward(
                params, batch["tokens"], cfg=ecfg, mode="decode",
                states=states, positions=batch["positions"], enc_out=enc_out)
        return logits[:, -1:], new_states

    return decode


def make_serve_chunk(cfg: ModelConfig, frozen_scales=None):
    """Paged chunked serving step over a block-table KV pool: each batch
    row carries either a prompt chunk or a single decode token through ONE
    fixed-shape program (mode='chunk' attention with a gather plan and
    per-row [start, n_valid] ragged bounds). `serve.engine.PagedServeEngine`
    builds its jitted step on the same forward call plus on-device
    sampling; this plain-logits variant is what the launch grid dry-runs.

    batch keys: tokens/positions/write_slots (B, T) int32,
    read_slots/slot_pos (B, C) int32, chunk_pos (B, 2) int32,
    last_row (B,) int32. Returns (logits (B, 1, V), new_states)."""
    ecfg = _eval_cfg(cfg, frozen_scales)

    def chunk_step(params, batch, states):
        with _maybe_frozen(frozen_scales):
            page = {k: batch[k] for k in
                    ("write_slots", "read_slots", "slot_pos", "chunk_pos")}
            logits, new_states, _ = forward(
                params, batch["tokens"], cfg=ecfg, mode="chunk",
                states=states, positions=batch["positions"], page=page,
                gather_rows=batch["last_row"])
        return logits, new_states

    return chunk_step
