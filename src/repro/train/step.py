"""Jittable step functions: the units the dry-run lowers and the train loop
runs.

train_step implements the paper's full Fig. 1b pipeline per step:
  compute params (fp16 master -> bf16) -> FP8 forward/backward (loss scaled)
  -> overflow probe -> unscale in f32 -> optimizer update in f32 -> fp16
  master store -> loss-scale update.

Optional gradient accumulation (n_microbatches) runs the loss/grad pass in a
scan with f32 accumulators — the standard large-batch memory lever.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.loss_scale import LossScaler
from repro.core.master_weights import MixedPrecisionOptimizer, MixedPrecisionState
from repro.models.config import ModelConfig
from repro.models.transformer import encode, forward, lm_loss
from repro.optim import make_optimizer

Array = jax.Array


def make_optimizer_for(cfg: ModelConfig, *, name: str = "adam",
                       scaler: Optional[LossScaler] = None,
                       learning_rate: float = 1e-4) -> MixedPrecisionOptimizer:
    from repro.optim.optimizers import make_leafwise
    init, update = make_optimizer(name, learning_rate=learning_rate)
    names, leaf = make_leafwise(name, learning_rate=learning_rate)
    return MixedPrecisionOptimizer(
        inner_init=init, inner_update=update,
        scaler=scaler or LossScaler(mode="enhanced"),
        master_dtype=cfg.policy.master_weight_dtype,
        update_dtype=cfg.policy.update_dtype,
        compute_dtype=cfg.policy.activation_dtype,
        accum_names=names, leaf_update=leaf)


def make_train_step(cfg: ModelConfig, optimizer: MixedPrecisionOptimizer, *,
                    n_microbatches: int = 1, grad_shardings=None):
    """Returns train_step(state, batch, step_key) -> (state, metrics).

    grad_shardings: optional PartitionSpec pytree (params-shaped). Applied to
    the gradients / accumulator so the f32 grad buffer is ZeRO-sharded like
    the master weights instead of ballooning to a model-sharded-only copy.
    """

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            g, grad_shardings)

    def loss_fn(params, batch, step_key, scale):
        return lm_loss(params, batch, cfg=cfg, qkey=step_key,
                       loss_scale=scale)

    def train_step(state: MixedPrecisionState, batch: Dict[str, Array],
                   step_key: Array) -> Tuple[MixedPrecisionState, Dict]:
        params = optimizer.compute_params(state)
        scale = state.loss_scale.scale

        if n_microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, step_key, scale)
            grads = constrain_grads(grads)
        else:
            def reshape_mb(x):
                return x.reshape((n_microbatches,
                                  x.shape[0] // n_microbatches) + x.shape[1:])
            mb_batch = jax.tree_util.tree_map(reshape_mb, batch)

            def mb_body(carry, mb):
                acc, i = carry
                mkey = jax.random.fold_in(step_key, i)
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, mkey, scale)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32) / n_microbatches,
                    acc, g)
                return (constrain_grads(acc), i + 1), (l, m)

            zero = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, _), (losses, metricses) = jax.lax.scan(
                mb_body, (zero, 0), mb_batch)
            loss = losses.mean()
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), metricses)

        new_state, opt_metrics = optimizer.apply_gradients(state, grads)
        inv = 1.0 / jnp.maximum(scale, 1e-9)
        out = {"loss": loss.astype(jnp.float32) * inv,
               "grad_norm": optax_safe_norm(grads) * inv,
               **{k: v for k, v in metrics.items()}, **opt_metrics}
        return new_state, out

    return train_step


def optax_safe_norm(tree) -> Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


# ---------------------------------------------------------------------------
# serving steps (deterministic eval: RNE, saturating)
# ---------------------------------------------------------------------------

def _eval_cfg(cfg: ModelConfig) -> ModelConfig:
    pol = dataclasses.replace(cfg.policy, quant=cfg.policy.quant.eval_mode())
    return cfg.replace(policy=pol)


def make_serve_prefill(cfg: ModelConfig):
    ecfg = _eval_cfg(cfg)

    def prefill(params, batch, states):
        enc_out = None
        if ecfg.is_encoder_decoder:
            enc_out = encode(params, batch["enc_inputs"], cfg=ecfg)
        logits, new_states, _ = forward(
            params, batch["tokens"], cfg=ecfg, mode="prefill", states=states,
            extra_embeds=batch.get("extra_embeds"), enc_out=enc_out,
            last_only=True)
        return logits, new_states

    return prefill


def make_serve_decode(cfg: ModelConfig):
    ecfg = _eval_cfg(cfg)

    def decode(params, batch, states):
        enc_out = batch.get("enc_out")
        logits, new_states, _ = forward(
            params, batch["tokens"], cfg=ecfg, mode="decode", states=states,
            positions=batch["positions"], enc_out=enc_out)
        return logits[:, -1:], new_states

    return decode
