"""Batched serving engine: continuous batching over a fixed slot pool.

Inference runs the deterministic FP8 path (RNE, saturating — no stochastic
rounding at eval, per the paper's training/inference split) with an
optionally FP8-quantized KV cache (beyond-paper: decode is KV-bandwidth
bound; e5m2 KV halves the dominant roofline term).

Slot model: `max_batch` concurrent sequences. add_request() fills a free
slot (prefilling its cache region); step() decodes one token for every
active slot; finished sequences (EOS or max_len) free their slot. The jitted
decode step is shape-stable — request churn never recompiles.

Observability: prefill and decode run inside `obs.trace.Tracer` spans
(perfetto-exportable via `engine.tracer`), per-request prefill/decode
latencies and KV-slot occupancy accumulate into rolling windows, and
`stats()` snapshots the serving counters (latency percentiles, decode
tokens/s, occupancy) in the same jsonable shape the metrics pipeline and
`repro.tools.healthdash` consume.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_stack_state
from repro.obs.trace import Tracer
from repro.train.step import make_serve_decode, make_serve_prefill

Array = jax.Array


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    eos_id: int = -1          # -1 => never stops early
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # -- per-request telemetry (wall-clock, host side) -----------------------
    t_added: float = 0.0      # time.perf_counter() at add_request entry
    prefill_s: float = 0.0    # prefill latency (includes slot merge + sample)
    decode_s: float = 0.0     # summed decode-step share while active
    t_finished: float = 0.0   # perf_counter when the slot freed


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig,
                 frozen_scales: Optional[Dict[str, float]] = None,
                 frozen_formats: Optional[Dict[str, str]] = None):
        """frozen_scales: calibrated per-site scales (scaling.calibrate
        freeze/load_frozen) — enables deterministic calibrated FP8 inference;
        the FP8 KV cache consumes its per-layer scales from the same dict.

        frozen_formats: per-site storage formats the scales were calibrated
        under (scaling.calibrate freeze_with_formats / load_frozen_formats).
        When given, serving refuses to start if this engine's QuantConfig /
        KV-cache policy would quantize a site in a DIFFERENT format than it
        was calibrated for — a scale targeting the e4m3 grid is 128x off on
        the e5m2 grid, a silent-accuracy bug otherwise."""
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.frozen_scales = frozen_scales
        self.frozen_formats = frozen_formats
        if frozen_formats:
            self._check_formats(frozen_formats)
        self._prefill = jax.jit(make_serve_prefill(cfg, frozen_scales))
        self._decode = jax.jit(make_serve_decode(cfg, frozen_scales))
        b, ml = serve.max_batch, serve.max_len
        self.states = init_stack_state(cfg, b, max_len=ml,
                                       n_layers=cfg.n_layers)
        self.slots: List[Optional[Request]] = [None] * b
        self.positions = np.zeros((b,), np.int64)
        self.last_token = np.zeros((b,), np.int32)
        self._uid = 0
        # -- serving counters (host wall-clock; window bounds memory) --------
        self.tracer = Tracer()
        win = 512
        self._prefill_lat = collections.deque(maxlen=win)
        self._decode_lat = collections.deque(maxlen=win)
        self._req_lat = collections.deque(maxlen=win)
        self._occupancy = collections.deque(maxlen=win)
        self._n_requests = 0
        self._n_finished = 0
        self._prefill_tokens = 0
        self._decode_tokens = 0
        self._decode_time_s = 0.0

    def _check_formats(self, frozen_formats: Dict[str, str]):
        from repro.scaling.state import format_for_site
        quant = self.cfg.policy.quant
        kv_fmt = self.cfg.policy.kv_cache_format
        for key, calibrated in frozen_formats.items():
            # the same site->format rule the freeze side used to record
            serving = format_for_site(key, quant, kv_fmt)
            if serving != calibrated:
                raise ValueError(
                    f"frozen scale for site {key!r} was calibrated under "
                    f"format {calibrated!r} but this engine would quantize "
                    f"it as {serving!r} (recipe={quant.recipe!r}, "
                    f"kv_cache_format={kv_fmt!r}); recalibrate or fix the "
                    "serving config")

    # -- slot management ------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def add_request(self, prompt: np.ndarray,
                    max_new_tokens: int = 32) -> int:
        """Prefill `prompt` into a free slot; returns the request uid."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slots; call step() until one frees")
        slot = free[0]
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens, t_added=time.perf_counter())
        self.slots[slot] = req
        # Prefill this slot: run a batch-1-style prefill into the slot's
        # cache rows (the whole batch is passed; only this slot's rows are
        # consumed by construction of the cache update).
        s = req.prompt.shape[0]
        tokens = np.zeros((len(self.slots), s), np.int32)
        tokens[slot] = req.prompt
        with self.tracer.span("prefill", uid=req.uid, tokens=s):
            logits, new_states = self._prefill(
                self.params, {"tokens": jnp.asarray(tokens)},
                self.states)
            # Merge: take the new cache rows for this slot only.
            self.states = _merge_slot(self.states, new_states, slot)
            self.positions[slot] = s
            nxt = self._sample(np.asarray(logits)[slot, -1])
        req.prefill_s = time.perf_counter() - req.t_added
        self._prefill_lat.append(req.prefill_s)
        self._n_requests += 1
        self._prefill_tokens += s
        self.last_token[slot] = nxt
        req.generated.append(int(nxt))
        return req.uid

    # -- decode ---------------------------------------------------------------
    def step(self) -> Dict[int, List[int]]:
        """One decode step for all active slots. Returns finished requests."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return {}
        t0 = time.perf_counter()
        self._occupancy.append(len(active) / len(self.slots))
        tokens = jnp.asarray(self.last_token[:, None])
        positions = jnp.asarray(self.positions[:, None].astype(np.int32))
        with self.tracer.span("decode", active=len(active)):
            logits, self.states = self._decode(
                self.params, {"tokens": tokens, "positions": positions},
                self.states)
            logits = np.asarray(logits)[:, 0]
        dt = time.perf_counter() - t0
        self._decode_lat.append(dt)
        self._decode_time_s += dt
        self._decode_tokens += len(active)
        finished: Dict[int, List[int]] = {}
        for i in active:
            req = self.slots[i]
            req.decode_s += dt
            nxt = self._sample(logits[i])
            req.generated.append(int(nxt))
            self.positions[i] += 1
            self.last_token[i] = nxt
            hit_eos = (self.serve.eos_id >= 0 and nxt == self.serve.eos_id)
            if hit_eos or len(req.generated) >= req.max_new_tokens \
                    or self.positions[i] >= self.serve.max_len - 1:
                req.t_finished = time.perf_counter()
                req.done = True
                self._n_finished += 1
                self._req_lat.append(req.t_finished - req.t_added)
                finished[req.uid] = req.generated
                self.slots[i] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            out.update(self.step())
            if not any(self.slots):
                break
        return out

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Snapshot of the serving counters (jsonable; shape documented in
        docs/metrics_schema.md, rendered by repro.tools.healthdash)."""
        def pct(win, q):
            return float(np.percentile(np.asarray(win), q)) if win else None
        return {
            "requests": self._n_requests,
            "finished": self._n_finished,
            "active": sum(s is not None for s in self.slots),
            "max_batch": len(self.slots),
            "kv_slot_occupancy": (float(np.mean(self._occupancy))
                                  if self._occupancy else 0.0),
            "prefill_tokens": self._prefill_tokens,
            "decode_tokens": self._decode_tokens,
            "decode_tokens_per_s": (self._decode_tokens / self._decode_time_s
                                    if self._decode_time_s > 0 else 0.0),
            "prefill_latency_s": {"p50": pct(self._prefill_lat, 50),
                                  "p99": pct(self._prefill_lat, 99)},
            "decode_step_s": {"p50": pct(self._decode_lat, 50),
                              "p99": pct(self._decode_lat, 99)},
            "request_latency_s": {"p50": pct(self._req_lat, 50),
                                  "p99": pct(self._req_lat, 99)},
        }

    def _sample(self, logits: np.ndarray) -> int:
        logits = logits[:self.cfg.vocab_size]
        if self.serve.temperature <= 0:
            return int(logits.argmax())
        p = np.exp((logits - logits.max()) / self.serve.temperature)
        p /= p.sum()
        rng = np.random.default_rng(self.serve.seed + self._uid)
        return int(rng.choice(len(p), p=p))


def _merge_slot(old_states, new_states, slot: int):
    """Take slot `slot`'s rows from new_states, keep others from old."""
    def merge(o, n):
        if o.ndim >= 2 and o.shape == n.shape:
            # batch dim is 1 for stacked leaves (G, B, ...) else 0
            bdim = 1 if o.ndim >= 2 else 0
            idx = [slice(None)] * o.ndim
            idx[bdim] = slice(slot, slot + 1)
            return o.at[tuple(idx)].set(n[tuple(idx)])
        return n
    return jax.tree_util.tree_map(merge, old_states, new_states)
