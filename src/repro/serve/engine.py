"""Batched serving engine: continuous batching over a fixed slot pool.

Inference runs the deterministic FP8 path (RNE, saturating — no stochastic
rounding at eval, per the paper's training/inference split) with an
optionally FP8-quantized KV cache (beyond-paper: decode is KV-bandwidth
bound; e5m2 KV halves the dominant roofline term).

Slot model: `max_batch` concurrent sequences. add_request() fills a free
slot (prefilling its cache region); step() decodes one token for every
active slot; finished sequences (EOS or max_len) free their slot. The jitted
decode step is shape-stable — request churn never recompiles.

Observability: prefill and decode run inside `obs.trace.Tracer` spans
(perfetto-exportable via `engine.tracer`), per-request prefill/decode
latencies and KV-slot occupancy accumulate into rolling windows, and
`stats()` snapshots the serving counters (latency percentiles, decode
tokens/s, occupancy) in the same jsonable shape the metrics pipeline and
`repro.tools.healthdash` consume.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_stack_state
from repro.obs.trace import Tracer
from repro.train.step import make_serve_decode, make_serve_prefill

Array = jax.Array


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    eos_id: int = -1          # -1 => never stops early
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # -- per-request telemetry (wall-clock, host side) -----------------------
    t_added: float = 0.0      # time.perf_counter() at add_request entry
    prefill_s: float = 0.0    # prefill latency (includes slot merge + sample)
    decode_s: float = 0.0     # summed decode-step share while active
    t_finished: float = 0.0   # perf_counter when the slot freed


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig,
                 frozen_scales: Optional[Dict[str, float]] = None,
                 frozen_formats: Optional[Dict[str, str]] = None):
        """frozen_scales: calibrated per-site scales (scaling.calibrate
        freeze/load_frozen) — enables deterministic calibrated FP8 inference;
        the FP8 KV cache consumes its per-layer scales from the same dict.

        frozen_formats: per-site storage formats the scales were calibrated
        under (scaling.calibrate freeze_with_formats / load_frozen_formats).
        When given, serving refuses to start if this engine's QuantConfig /
        KV-cache policy would quantize a site in a DIFFERENT format than it
        was calibrated for — a scale targeting the e4m3 grid is 128x off on
        the e5m2 grid, a silent-accuracy bug otherwise."""
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.frozen_scales = frozen_scales
        self.frozen_formats = frozen_formats
        if frozen_formats:
            self._check_formats(frozen_formats)
        self._prefill = jax.jit(make_serve_prefill(cfg, frozen_scales))
        self._decode = jax.jit(make_serve_decode(cfg, frozen_scales))
        b, ml = serve.max_batch, serve.max_len
        self.states = init_stack_state(cfg, b, max_len=ml,
                                       n_layers=cfg.n_layers)
        self.slots: List[Optional[Request]] = [None] * b
        self.positions = np.zeros((b,), np.int64)
        self.last_token = np.zeros((b,), np.int32)
        self._uid = 0
        # -- serving counters (host wall-clock; window bounds memory) --------
        self.tracer = Tracer()
        win = 512
        self._prefill_lat = collections.deque(maxlen=win)
        self._decode_lat = collections.deque(maxlen=win)
        self._req_lat = collections.deque(maxlen=win)
        self._occupancy = collections.deque(maxlen=win)
        self._n_requests = 0
        self._n_finished = 0
        self._prefill_tokens = 0
        self._decode_tokens = 0
        self._decode_time_s = 0.0

    def _check_formats(self, frozen_formats: Dict[str, str]):
        from repro.scaling.state import format_for_site
        quant = self.cfg.policy.quant
        kv_fmt = self.cfg.policy.kv_cache_format
        for key, calibrated in frozen_formats.items():
            # the same site->format rule the freeze side used to record
            serving = format_for_site(key, quant, kv_fmt)
            if serving != calibrated:
                raise ValueError(
                    f"frozen scale for site {key!r} was calibrated under "
                    f"format {calibrated!r} but this engine would quantize "
                    f"it as {serving!r} (recipe={quant.recipe!r}, "
                    f"kv_cache_format={kv_fmt!r}); recalibrate or fix the "
                    "serving config")

    # -- slot management ------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def add_request(self, prompt: np.ndarray,
                    max_new_tokens: int = 32) -> int:
        """Prefill `prompt` into a free slot; returns the request uid."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slots; call step() until one frees")
        slot = free[0]
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens, t_added=time.perf_counter())
        self.slots[slot] = req
        # Prefill this slot: run a batch-1-style prefill into the slot's
        # cache rows (the whole batch is passed; only this slot's rows are
        # consumed by construction of the cache update).
        s = req.prompt.shape[0]
        tokens = np.zeros((len(self.slots), s), np.int32)
        tokens[slot] = req.prompt
        with self.tracer.span("prefill", uid=req.uid, tokens=s):
            logits, new_states = self._prefill(
                self.params, {"tokens": jnp.asarray(tokens)},
                self.states)
            # Merge: take the new cache rows for this slot only.
            self.states = _merge_slot(self.states, new_states, slot)
            self.positions[slot] = s
            nxt = self._sample(np.asarray(logits)[slot, -1])
        req.prefill_s = time.perf_counter() - req.t_added
        self._prefill_lat.append(req.prefill_s)
        self._n_requests += 1
        self._prefill_tokens += s
        self.last_token[slot] = nxt
        req.generated.append(int(nxt))
        return req.uid

    # -- decode ---------------------------------------------------------------
    def step(self) -> Dict[int, List[int]]:
        """One decode step for all active slots. Returns finished requests."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return {}
        t0 = time.perf_counter()
        self._occupancy.append(len(active) / len(self.slots))
        tokens = jnp.asarray(self.last_token[:, None])
        positions = jnp.asarray(self.positions[:, None].astype(np.int32))
        with self.tracer.span("decode", active=len(active)):
            logits, self.states = self._decode(
                self.params, {"tokens": tokens, "positions": positions},
                self.states)
            logits = np.asarray(logits)[:, 0]
        dt = time.perf_counter() - t0
        self._decode_lat.append(dt)
        self._decode_time_s += dt
        self._decode_tokens += len(active)
        finished: Dict[int, List[int]] = {}
        for i in active:
            req = self.slots[i]
            req.decode_s += dt
            nxt = self._sample(logits[i])
            req.generated.append(int(nxt))
            self.positions[i] += 1
            self.last_token[i] = nxt
            hit_eos = (self.serve.eos_id >= 0 and nxt == self.serve.eos_id)
            if hit_eos or len(req.generated) >= req.max_new_tokens \
                    or self.positions[i] >= self.serve.max_len - 1:
                req.t_finished = time.perf_counter()
                req.done = True
                self._n_finished += 1
                self._req_lat.append(req.t_finished - req.t_added)
                finished[req.uid] = req.generated
                self.slots[i] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            out.update(self.step())
            if not any(self.slots):
                break
        return out

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Snapshot of the serving counters (jsonable; shape documented in
        docs/metrics_schema.md, rendered by repro.tools.healthdash)."""
        def pct(win, q):
            return float(np.percentile(np.asarray(win), q)) if win else None
        return {
            "requests": self._n_requests,
            "finished": self._n_finished,
            "active": sum(s is not None for s in self.slots),
            "max_batch": len(self.slots),
            "kv_slot_occupancy": (float(np.mean(self._occupancy))
                                  if self._occupancy else 0.0),
            "prefill_tokens": self._prefill_tokens,
            "decode_tokens": self._decode_tokens,
            "decode_tokens_per_s": (self._decode_tokens / self._decode_time_s
                                    if self._decode_time_s > 0 else 0.0),
            "prefill_latency_s": {"p50": pct(self._prefill_lat, 50),
                                  "p99": pct(self._prefill_lat, 99)},
            "decode_step_s": {"p50": pct(self._decode_lat, 50),
                              "p99": pct(self._decode_lat, 99)},
            "request_latency_s": {"p50": pct(self._req_lat, 50),
                                  "p99": pct(self._req_lat, 99)},
        }

    def _sample(self, logits: np.ndarray) -> int:
        logits = logits[:self.cfg.vocab_size]
        if self.serve.temperature <= 0:
            return int(logits.argmax())
        p = np.exp((logits - logits.max()) / self.serve.temperature)
        p /= p.sum()
        rng = np.random.default_rng(self.serve.seed + self._uid)
        return int(rng.choice(len(p), p=p))


def _merge_slot(old_states, new_states, slot: int):
    """Take slot `slot`'s rows from new_states, keep others from old.

    The batch dim depends on the stack layout, so it is resolved from the
    state-dict KEY, not the leaf rank: scanned groups ("stack_*") stack a
    leading group dim => batch at dim 1; unscanned ("layer_*"/"rem_*")
    leaves put batch at dim 0. (Guessing from rank alone merged unscanned
    KV caches along their LENGTH axis — every slot kept only its first
    cached token and decode walked off garbage.)"""
    def merge_with(bdim):
        def merge(o, n):
            if o.ndim > bdim and o.shape == n.shape:
                idx = [slice(None)] * o.ndim
                idx[bdim] = slice(slot, slot + 1)
                return o.at[tuple(idx)].set(n[tuple(idx)])
            return n
        return merge
    out = {}
    for key in old_states:
        bdim = 1 if key.startswith("stack_") else 0
        out[key] = jax.tree_util.tree_map(merge_with(bdim), old_states[key],
                                          new_states[key])
    return out


# ===========================================================================
# Paged engine: block-table KV, chunked prefill, on-device sampling
# ===========================================================================

@dataclasses.dataclass
class PagedServeConfig:
    """Knobs for `PagedServeEngine`.

    max_batch:   concurrent request rows per step (static shape).
    max_len:     max logical sequence length per request.
    n_pages:     KV pool pages per layer (page 0 is the reserved trash
                 page, so `(n_pages - 1) * page_size` tokens are
                 allocatable). KV memory scales with THIS, not with
                 max_batch * max_len.
    page_size:   tokens per page.
    chunk_size:  prompt tokens prefillable per request per step; decode is
                 the 1-token special case of the same jitted step.
    temperature / top_k / top_p: sampling controls (temperature<=0 =>
                 greedy argmax). seed: base of the per-request PRNG
                 streams (seed + uid, folded with the per-request token
                 index — batch-layout invariant).
    prefix_cache: exact full-page prompt-prefix reuse (bitwise-safe only
                 because frozen-scale serving is deterministic).
    """
    max_batch: int = 8
    max_len: int = 512
    n_pages: int = 64
    page_size: int = 16
    chunk_size: int = 32
    eos_id: int = -1
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    prefix_cache: bool = True
    max_cache_entries: int = 128


@dataclasses.dataclass
class _PagedRequest:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    table: list                 # block table: page ids, position-major
    prefill_pos: int = 0        # next prompt position to prefill
    pos: int = 0                # tokens materialized in KV so far
    generated: list = dataclasses.field(default_factory=list)
    cached_tokens: int = 0      # prompt tokens satisfied by the prefix cache
    t_added: float = 0.0
    prefill_s: float = 0.0
    t_finished: float = 0.0


class PagedServeEngine:
    """Production serving loop over a paged KV pool.

    One jitted fixed-shape `step()` serves every phase: each request row
    carries either a prompt chunk (up to `chunk_size` tokens) or a decode
    step (1 token) through the SAME compiled program — `mode='chunk'`
    attention with a block-table gather, per-row `[start, n_valid]` ragged
    bounds, and on-device sampling. The step's outputs are the updated KV
    pools and one sampled token id per row: logits never leave the device
    (no per-token host sync; the host reads only the (B,) token vector it
    needs for EOS/scheduling).

    Under frozen scales the token streams are bit-identical to the legacy
    fixed-slot `ServeEngine` (locked by tests/test_paging.py): with a bf16
    KV cache the FULL stream matches for any chunk size; with an FP8 KV
    cache the decode phase matches given the same cache payloads, while
    chunked prefill reads earlier chunks' FP8 payloads (the cache IS the
    attention input — legacy prefill attends raw bf16 K/V, a documented
    semantic difference of chunked prefill, not a bug).
    """

    def __init__(self, cfg: ModelConfig, params, serve: PagedServeConfig,
                 frozen_scales: Optional[Dict[str, float]] = None,
                 frozen_formats: Optional[Dict[str, str]] = None):
        from repro.models.transformer import init_paged_stack_state
        from repro.serve.paging import PageAllocator
        from repro.serve.prefix_cache import PrefixCache, scale_fingerprint
        from repro.serve import sampling as _sampling
        from repro.train.step import _eval_cfg, _maybe_frozen
        from repro.models.transformer import forward

        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.frozen_scales = frozen_scales
        self.frozen_formats = frozen_formats
        if frozen_formats:
            ServeEngine._check_formats(self, frozen_formats)

        self.pager = PageAllocator(serve.n_pages, serve.page_size)
        psize = serve.page_size
        # Static gather width: every position a request can ever hold.
        self.capacity = -(-serve.max_len // psize) * psize
        self.states = init_paged_stack_state(cfg, self.pager.n_slots,
                                             n_layers=cfg.n_layers)
        self.prefix_cache = None
        if serve.prefix_cache:
            fp = scale_fingerprint(
                frozen_scales, frozen_formats,
                recipe=cfg.policy.quant.recipe,
                kv_format=cfg.policy.kv_cache_format)
            self.prefix_cache = PrefixCache(
                self.pager, fp, max_entries=serve.max_cache_entries)

        ecfg = _eval_cfg(cfg, frozen_scales)
        temperature, top_k, top_p = (serve.temperature, serve.top_k,
                                     serve.top_p)
        vocab = cfg.vocab_size

        def step_fn(params, states, batch):
            """The whole serving step: chunk attention + head + sampling.
            Returns (sampled (B,) int32, new_states) — NO vocab-dim output,
            which the jaxpr test asserts."""
            with _maybe_frozen(frozen_scales):
                page = {"write_slots": batch["write_slots"],
                        "read_slots": batch["read_slots"],
                        "slot_pos": batch["slot_pos"],
                        "chunk_pos": batch["chunk_pos"]}
                logits, new_states, _ = forward(
                    params, batch["tokens"], cfg=ecfg, mode="chunk",
                    states=states, positions=batch["positions"], page=page,
                    gather_rows=batch["last_row"])
            lg = logits[:, 0].astype(jnp.float32)
            # Padded-vocab columns are masked BEFORE argmax/sampling — the
            # on-device greedy then bit-matches the legacy host-side
            # `logits[:vocab].argmax()`.
            col = jnp.arange(lg.shape[-1])
            lg = jnp.where(col[None, :] < vocab, lg, jnp.float32(-1e30))
            keys = _sampling.row_keys(batch["seeds"], batch["steps"])
            tok = _sampling.sample(lg, keys, temperature=temperature,
                                   top_k=top_k, top_p=top_p)
            return tok, new_states

        self._step = jax.jit(step_fn)

        b = serve.max_batch
        self.slots: List[Optional[_PagedRequest]] = [None] * b
        self._uid = 0
        self.tracer = Tracer()
        win = 512
        self._prefill_lat = collections.deque(maxlen=win)
        self._step_lat = collections.deque(maxlen=win)
        self._req_lat = collections.deque(maxlen=win)
        self._occupancy = collections.deque(maxlen=win)
        self._n_requests = 0
        self._n_finished = 0
        self._prefill_tokens = 0
        self._decode_tokens = 0
        self._decode_time_s = 0.0

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def add_request(self, prompt: np.ndarray,
                    max_new_tokens: int = 32) -> int:
        """Admit a request (prefill happens inside subsequent step()s).
        Raises `PagesExhausted` when the prompt needs more KV pages than
        the pool can allocate (after shedding LRU prefix-cache entries) —
        a structured refusal, never a silent truncation."""
        from repro.serve.paging import PagesExhausted
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slots; call step() until one frees")
        prompt = np.asarray(prompt, np.int32)
        n = int(prompt.shape[0])
        if n < 1 or n >= self.serve.max_len:
            raise ValueError(
                f"prompt length {n} out of range [1, {self.serve.max_len})")
        slot = free[0]
        self._uid += 1
        req = _PagedRequest(self._uid, prompt, max_new_tokens, table=[],
                            t_added=time.perf_counter())
        # Exact prefix reuse: splice cached full pages, prefill the rest.
        if self.prefix_cache is not None:
            pages, n_cached = self.prefix_cache.lookup(prompt)
            req.table = pages
            req.prefill_pos = req.pos = n_cached
            req.cached_tokens = n_cached
        need = self.pager.pages_for(n) - len(req.table)
        try:
            if need > self.pager.n_free and self.prefix_cache is not None:
                self.prefix_cache.evict_for(need)
            req.table += self.pager.alloc(max(need, 0),
                                          what=f"prompt of {n} tokens")
        except PagesExhausted:
            if req.cached_tokens:
                self.pager.release(req.table)   # undo the lookup retain
            raise
        self.slots[slot] = req
        self._n_requests += 1
        return req.uid

    # -- the unified step ---------------------------------------------------

    def _grow(self, req: _PagedRequest, pos: int):
        """Ensure `pos` is backed by a page (decode growth)."""
        from repro.serve.paging import PagesExhausted
        pageno = pos // self.serve.page_size
        if pageno < len(req.table):
            return
        try:
            req.table += self.pager.alloc(1, what=f"decode of req {req.uid}")
        except PagesExhausted:
            if self.prefix_cache is None or \
                    not self.prefix_cache.evict_for(1):
                raise
            req.table += self.pager.alloc(
                1, what=f"decode of req {req.uid}")

    def step(self) -> Dict[int, List[int]]:
        """One fixed-shape step: a prompt chunk OR one decode token per
        active row, interleaved freely. Returns finished requests."""
        from repro.serve import paging as _paging
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return {}
        t0 = time.perf_counter()
        self._occupancy.append(len(active) / len(self.slots))
        b, tchunk, cap = (self.serve.max_batch, self.serve.chunk_size,
                          self.capacity)
        psize = self.serve.page_size
        tokens = np.zeros((b, tchunk), np.int32)
        positions = np.zeros((b, tchunk), np.int32)
        write_slots = np.zeros((b, tchunk), np.int32)
        chunk_pos = np.zeros((b, 2), np.int32)
        last_row = np.zeros((b,), np.int32)
        seeds = np.zeros((b,), np.int32)
        steps = np.zeros((b,), np.int32)
        tables, lengths = [], []
        plan = {}   # row -> ("prefill", t_eff) | ("decode",)
        n_prefill_rows = n_decode_rows = 0
        for i in range(b):
            req = self.slots[i]
            if req is None:
                tables.append([])
                lengths.append(0)
                continue
            seeds[i] = self.serve.seed + req.uid
            steps[i] = len(req.generated)
            if req.prefill_pos < len(req.prompt):
                pp = req.prefill_pos
                t_eff = min(tchunk, len(req.prompt) - pp)
                tokens[i, :t_eff] = req.prompt[pp:pp + t_eff]
                positions[i] = pp + np.arange(tchunk)
                write_slots[i, :t_eff] = _paging.flat_slots(
                    req.table, psize, pp, t_eff)
                chunk_pos[i] = (pp, t_eff)
                last_row[i] = t_eff - 1
                lengths.append(pp + t_eff)
                plan[i] = ("prefill", t_eff)
                n_prefill_rows += 1
            else:
                pos = req.pos
                self._grow(req, pos)
                tokens[i, 0] = (req.generated[-1] if req.generated
                                else req.prompt[-1])
                positions[i] = pos + np.arange(tchunk)
                write_slots[i, 0] = _paging.flat_slots(
                    req.table, psize, pos, 1)[0]
                chunk_pos[i] = (pos, 1)
                last_row[i] = 0
                lengths.append(pos + 1)
                plan[i] = ("decode",)
                n_decode_rows += 1
            tables.append(req.table)
        read_slots, slot_pos = _paging.gather_plan(tables, lengths, psize,
                                                   cap)
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "write_slots": jnp.asarray(write_slots),
                 "read_slots": jnp.asarray(read_slots),
                 "slot_pos": jnp.asarray(slot_pos),
                 "chunk_pos": jnp.asarray(chunk_pos),
                 "last_row": jnp.asarray(last_row),
                 "seeds": jnp.asarray(seeds),
                 "steps": jnp.asarray(steps)}
        with self.tracer.span("step", prefill_rows=n_prefill_rows,
                              decode_rows=n_decode_rows):
            tok, self.states = self._step(self.params, self.states, batch)
            tok = np.asarray(tok)          # (B,) int32 — the ONLY sync
        dt = time.perf_counter() - t0
        self._step_lat.append(dt)
        finished: Dict[int, List[int]] = {}
        for i, what in plan.items():
            req = self.slots[i]
            if what[0] == "prefill":
                t_eff = what[1]
                req.prefill_pos += t_eff
                req.pos = req.prefill_pos
                self._prefill_tokens += t_eff
                if req.prefill_pos < len(req.prompt):
                    continue            # prompt not done; sample discarded
                req.prefill_s = time.perf_counter() - req.t_added
                self._prefill_lat.append(req.prefill_s)
                if self.prefix_cache is not None:
                    self.prefix_cache.insert(req.prompt, req.table)
            else:
                req.pos += 1
                self._decode_tokens += 1
                self._decode_time_s += dt / max(len(plan), 1)
            nxt = int(tok[i])
            req.generated.append(nxt)
            hit_eos = (self.serve.eos_id >= 0 and nxt == self.serve.eos_id)
            if hit_eos or len(req.generated) >= req.max_new_tokens \
                    or req.pos >= self.serve.max_len - 1:
                req.t_finished = time.perf_counter()
                self._n_finished += 1
                self._req_lat.append(req.t_finished - req.t_added)
                finished[req.uid] = req.generated
                self.pager.release(req.table)
                self.slots[i] = None
        return finished

    def run_to_completion(self,
                          max_steps: int = 10_000) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            out.update(self.step())
            if not any(s is not None for s in self.slots):
                break
        return out

    # -- telemetry ----------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Serving counters + page-pool occupancy + prefix-cache hit rate
        (jsonable, same shape family as the legacy engine's stats())."""
        def pct(win, q):
            return float(np.percentile(np.asarray(win), q)) if win else None
        out = {
            "requests": self._n_requests,
            "finished": self._n_finished,
            "active": sum(s is not None for s in self.slots),
            "max_batch": len(self.slots),
            "slot_occupancy": (float(np.mean(self._occupancy))
                               if self._occupancy else 0.0),
            "prefill_tokens": self._prefill_tokens,
            "decode_tokens": self._decode_tokens,
            "decode_tokens_per_s": (self._decode_tokens / self._decode_time_s
                                    if self._decode_time_s > 0 else 0.0),
            "prefill_latency_s": {"p50": pct(self._prefill_lat, 50),
                                  "p99": pct(self._prefill_lat, 99)},
            "step_s": {"p50": pct(self._step_lat, 50),
                       "p99": pct(self._step_lat, 99)},
            "request_latency_s": {"p50": pct(self._req_lat, 50),
                                  "p99": pct(self._req_lat, 99)},
        }
        out.update(self.pager.stats())
        if self.prefix_cache is not None:
            out.update(self.prefix_cache.stats())
        return out
