from repro.serve.engine import (PagedServeConfig, PagedServeEngine,
                                ServeConfig, ServeEngine)
from repro.serve.paging import PageAllocator, PagesExhausted

__all__ = ["ServeEngine", "ServeConfig", "PagedServeEngine",
           "PagedServeConfig", "PageAllocator", "PagesExhausted"]
