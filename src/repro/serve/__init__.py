from repro.serve.engine import ServeConfig, ServeEngine

__all__ = ["ServeEngine", "ServeConfig"]
