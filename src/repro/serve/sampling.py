"""On-device batched sampling for the serving engine.

Everything here runs inside the jitted serve step — no host logits
round-trip. Reproducibility contract: each request's sample stream is a
pure function of (request seed, n_generated), NOT of its batch row or of
which other requests share the step — `row_keys` folds the per-request
seed and per-request step count into an independent PRNG key per row, so
the same request produces the same tokens whatever batch layout the
scheduler packed it into (locked by the sampling tests).

Greedy (temperature <= 0) is the argmax special case and bit-matches the
legacy host-side `np.argmax` on the same logits row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = jnp.float32(-1e30)


def row_keys(seeds: Array, steps: Array) -> Array:
    """Per-row PRNG keys from per-request (seed, n_generated) — batch-layout
    invariant. seeds/steps: (B,) int32/uint32. Returns (B, 2) uint32."""
    def one(seed, step):
        return jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.vmap(one)(seeds.astype(jnp.uint32), steps.astype(jnp.uint32))


def top_k_mask(logits: Array, k: int) -> Array:
    """Keep the k highest logits per row (ties at the threshold all kept),
    mask the rest to -inf. k <= 0 disables."""
    if k <= 0:
        return logits
    k = min(k, logits.shape[-1])
    thresh = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits >= thresh, logits, NEG_INF)


def top_p_mask(logits: Array, p: float) -> Array:
    """Nucleus mask: keep the smallest set of tokens whose probability
    mass reaches `p` (descending-probability order; the token that crosses
    the boundary is kept). p >= 1 disables."""
    if p >= 1.0:
        return logits
    sort_idx = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, sort_idx, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # Token i survives iff the mass BEFORE it is < p (so the crossing
    # token is included and the top-1 token always survives).
    keep_sorted = (cum - probs) < p
    keep = jnp.zeros_like(keep_sorted).at[
        jnp.arange(logits.shape[0])[:, None], sort_idx].set(keep_sorted)
    return jnp.where(keep, logits, NEG_INF)


def sample(logits: Array, keys: Array, *, temperature: float,
           top_k: int = 0, top_p: float = 1.0) -> Array:
    """Sample one token id per row. logits (B, V); keys (B, 2) uint32 from
    `row_keys`. temperature <= 0 -> greedy argmax (keys unused)."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.float32(temperature)
    scaled = top_k_mask(scaled, top_k)
    scaled = top_p_mask(scaled, top_p)
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
