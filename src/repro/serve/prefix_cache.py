"""Exact prefix/prompt cache over paged KV (host-side bookkeeping).

Bitwise-deterministic serving (frozen per-site scales, RNE eval
quantization) means two requests with the same prompt prefix produce the
SAME KV payload bytes — so prefix reuse is exact, not approximate: a hit
splices the cached pages into the new request's block table and the decode
stream is bit-identical to a cold prefill (locked by the parity suite).

Safety rules that keep exactness without copy-on-write:
  - Only FULL pages are shared, and only pages covering at most
    `prompt_len - 1` tokens: the engine always recomputes at least the
    final prompt token (its logits seed generation), and every write a
    request ever makes lands strictly past its shared prefix, on pages it
    owns alone.
  - Entries are keyed on (scale fingerprint, exact token prefix). The
    fingerprint hashes the frozen scales, per-site formats, recipe and KV
    format — any recalibration or recipe change invalidates the cache by
    construction, because identical tokens would no longer reproduce
    identical payload bytes.
  - Pages are refcounted through the `PageAllocator`; LRU eviction
    releases the cache's hold, and the memory returns to the free list
    once the last in-flight request using those pages finishes.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.paging import PageAllocator


def scale_fingerprint(frozen_scales=None, frozen_formats=None,
                      recipe: str = "", kv_format=None) -> str:
    """Stable hash of everything that determines KV payload bytes for a
    given token prefix (beyond the weights, which are fixed per engine)."""
    h = hashlib.sha256()
    h.update(f"recipe={recipe};kv={kv_format}".encode())
    for key in sorted(frozen_scales or {}):
        h.update(f";{key}={float(frozen_scales[key]):.17g}".encode())
    for key in sorted(frozen_formats or {}):
        h.update(f";fmt:{key}={frozen_formats[key]}".encode())
    return h.hexdigest()


class PrefixCache:
    """LRU map: (fingerprint, token-prefix) -> list of full KV pages."""

    def __init__(self, allocator: PageAllocator, fingerprint: str,
                 max_entries: int = 128):
        self.alloc = allocator
        self.fingerprint = fingerprint
        self.max_entries = max_entries
        self._entries: "OrderedDict[Tuple, List[int]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def _key(self, tokens: Sequence[int]) -> Tuple:
        return (self.fingerprint, tuple(int(t) for t in tokens))

    def shareable_pages(self, prompt_len: int) -> int:
        """Longest cacheable prefix of a prompt, in full pages, leaving at
        least the final token to recompute."""
        if prompt_len <= 1:
            return 0
        return (prompt_len - 1) // self.alloc.page_size

    def lookup(self, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached full-page prefix of `prompt`. Returns
        (pages, n_tokens) with the pages RETAINED for the caller (the new
        request now co-owns them); ([], 0) on miss."""
        for m in range(self.shareable_pages(len(prompt)), 0, -1):
            n_tok = m * self.alloc.page_size
            key = self._key(prompt[:n_tok])
            pages = self._entries.get(key)
            if pages is not None:
                self._entries.move_to_end(key)
                self.alloc.retain(pages)
                self.hits += 1
                return list(pages), n_tok
        self.misses += 1
        return [], 0

    def insert(self, prompt: Sequence[int], table: Sequence[int]):
        """Offer a freshly prefilled request's full prompt pages. The cache
        retains its own reference on the shared prefix; no-ops when the
        prefix is already cached or too short for a full page."""
        m = self.shareable_pages(len(prompt))
        if m == 0:
            return
        n_tok = m * self.alloc.page_size
        key = self._key(prompt[:n_tok])
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        pages = [int(p) for p in table[:m]]
        self.alloc.retain(pages)
        self._entries[key] = pages
        while len(self._entries) > self.max_entries:
            self._evict_one()

    def _evict_one(self) -> bool:
        if not self._entries:
            return False
        _, pages = self._entries.popitem(last=False)   # LRU
        self.alloc.release(pages)
        return True

    def evict_for(self, n_pages: int) -> bool:
        """Shed LRU entries until the allocator has `n_pages` free (or the
        cache is empty). Returns True if the target was reached. Note a
        released page only becomes free once no in-flight request holds
        it, so eviction is best-effort under sharing."""
        while self.alloc.n_free < n_pages:
            if not self._evict_one():
                return self.alloc.n_free >= n_pages
        return True

    def clear(self):
        while self._evict_one():
            pass

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "prefix_cache_entries": len(self._entries),
            "prefix_cache_hits": self.hits,
            "prefix_cache_misses": self.misses,
            "prefix_cache_hit_rate": self.hits / total if total else 0.0,
        }
