"""Paged KV-cache bookkeeping for the serving engine (host side).

The device holds one flat slot pool per layer (`models.attention.
init_paged_pool`): `n_pages * page_size` token slots of KV, with NO
per-request layout baked in. This module owns the indirection that maps a
request's logical token positions onto pool slots:

  - `PageAllocator` — a free list + refcounts over pages. Page 0 is the
    reserved TRASH page: chunk rows past a request's `n_valid` scatter
    value-0 writes to slot 0, so it is pinned forever and never handed out.
    Refcounts (not ownership) because the prefix cache shares full prompt
    pages between requests — a page returns to the free list only when its
    last holder releases it.
  - Block tables — per-request page lists, position `p` of a request lives
    at flat slot `table[p // page_size] * page_size + p % page_size`.
  - `gather_plan` — the dense (B, C) `read_slots`/`slot_pos` arrays the
    chunk attention step consumes, built so that gathered column `i` holds
    logical position `i` (the contiguous-cache layout, which is what makes
    paged decode bit-identical to the legacy fixed-slot engine).

Everything here is numpy/python — shapes handed to the jitted step are
padded to static maxima by the engine, so the allocator itself never
triggers a recompile.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

TRASH_PAGE = 0


class PagesExhausted(RuntimeError):
    """Structured refusal: a request needs more KV pages than the pool can
    allocate right now. Carries the accounting so callers can shed load /
    retry instead of parsing a message (mirrors the `_kv_scales` strictness
    rule: never silently truncate a prompt)."""

    def __init__(self, *, needed: int, free: int, n_pages: int,
                 page_size: int, what: str = "request"):
        self.needed = needed
        self.free = free
        self.n_pages = n_pages
        self.page_size = page_size
        super().__init__(
            f"{what} needs {needed} KV page(s) of {page_size} tokens but "
            f"only {free} of {n_pages - 1} allocatable pages are free "
            f"(page {TRASH_PAGE} is the reserved trash page)")


class PageAllocator:
    """Free list + refcounts over `n_pages` pages of `page_size` KV slots.

    Deterministic: pages are handed out in ascending order (a sorted free
    heap), so identical request interleavings produce identical block
    tables — which the differential parity suite relies on to compare
    engines slot-for-slot.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the trash "
                             f"page), got n_pages={n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = self.n_pages * self.page_size
        # Ascending hand-out order: keep the free list sorted descending
        # and pop from the tail.
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))
        self._ref = np.zeros(self.n_pages, np.int32)
        self._ref[TRASH_PAGE] = 1       # pinned forever

    # -- allocation -------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Pages currently held by at least one owner (excl. trash)."""
        return int(np.count_nonzero(self._ref[1:] > 0))

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size) if n_tokens > 0 else 0

    def alloc(self, n: int, *, what: str = "request") -> List[int]:
        """Allocate `n` pages (refcount 1 each) or raise PagesExhausted —
        all-or-nothing, never a partial grant."""
        if n > len(self._free):
            raise PagesExhausted(needed=n, free=len(self._free),
                                 n_pages=self.n_pages,
                                 page_size=self.page_size, what=what)
        pages = [self._free.pop() for _ in range(n)]
        self._ref[pages] = 1
        return pages

    def retain(self, pages: Sequence[int]):
        for p in pages:
            if not self._ref[p] > 0:
                raise AssertionError(f"retain of dead page {p}")
            self._ref[p] += 1

    def release(self, pages: Sequence[int]):
        for p in pages:
            if p == TRASH_PAGE:
                raise AssertionError("release of the trash page")
            if not self._ref[p] > 0:
                raise AssertionError(f"double release of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                # Keep the free list sorted (descending) so hand-out order
                # stays ascending and deterministic.
                self._free.append(p)
                self._free.sort(reverse=True)

    # -- invariants (property tests) --------------------------------------

    def check(self):
        """Free-list / refcount accounting invariants; raises on violation."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate pages in free list")
        if TRASH_PAGE in free:
            raise AssertionError("trash page on the free list")
        live = {int(p) for p in np.nonzero(self._ref[1:] > 0)[0] + 1}
        if free & live:
            raise AssertionError(f"pages both free and live: {free & live}")
        if len(free) + len(live) != self.n_pages - 1:
            raise AssertionError(
                f"page accounting leak: {len(free)} free + {len(live)} "
                f"live != {self.n_pages - 1} allocatable")

    def stats(self) -> Dict[str, float]:
        allocatable = self.n_pages - 1
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_free": self.n_free,
            "pages_live": self.n_live,
            "page_occupancy": self.n_live / max(allocatable, 1),
        }


# ---------------------------------------------------------------------------
# block-table -> dense gather plans
# ---------------------------------------------------------------------------

def flat_slots(table: Sequence[int], page_size: int, start: int,
               count: int) -> np.ndarray:
    """Flat pool slots of logical positions [start, start+count)."""
    pos = np.arange(start, start + count)
    table = np.asarray(table, np.int32)
    return (table[pos // page_size] * page_size
            + pos % page_size).astype(np.int32)


def gather_plan(tables: Sequence[Sequence[int]], lengths: Sequence[int],
                page_size: int, capacity: int):
    """(read_slots, slot_pos): (B, C) int32 gather plan for a batch.

    Gathered column `i` of request `b` holds its logical position `i`
    (`slot_pos[b, i] = i`) for i < lengths[b]; holes point at the trash
    page with slot_pos = -1, which the position mask excludes exactly.
    `capacity` is the static column count (>= max length this step).
    """
    b = len(tables)
    read = np.zeros((b, capacity), np.int32)
    spos = np.full((b, capacity), -1, np.int32)
    for i, (table, n) in enumerate(zip(tables, lengths)):
        n = min(int(n), capacity)
        if n > 0:
            read[i, :n] = flat_slots(table, page_size, 0, n)
            spos[i, :n] = np.arange(n, dtype=np.int32)
    return read, spos
