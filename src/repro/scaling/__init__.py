"""Delayed per-tensor scaling subsystem.

Scales for FP8 quantization derived from a *history* of amax observations
(per site = layer x tensor-class W/A/E/G) instead of the current tensor:
no full-tensor amax reduction in the quantize hot path, cross-replica
synchronization via a single fused pmax, and a calibrate->freeze path for
deterministic quantized serving. See scaling.state and scaling.context.
"""
from repro.scaling.calibrate import (calibrate, discover_lm_sites,
                                     discover_sites, freeze,
                                     freeze_with_formats, load_frozen,
                                     load_frozen_formats, save_frozen)
from repro.scaling.context import (activate, collect_context,
                                   discover_context, frozen_context,
                                   layer_view, scope)
from repro.scaling.state import (DelayedScaling, ScaleState, ScalingConfig,
                                 SiteRegistry, amax_from_history,
                                 split_observations)

__all__ = [
    "DelayedScaling", "ScaleState", "ScalingConfig", "SiteRegistry",
    "amax_from_history", "split_observations",
    "calibrate", "discover_sites", "discover_lm_sites", "freeze",
    "freeze_with_formats", "save_frozen", "load_frozen",
    "load_frozen_formats",
    "activate", "collect_context", "discover_context", "frozen_context",
    "layer_view", "scope",
]
