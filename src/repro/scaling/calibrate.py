"""Calibration + freeze: from amax history to deterministic FP8 serving.

The serving path must be deterministic across batches — a just-in-time amax
scale changes with every batch's content, so two identical requests batched
with different neighbors would decode differently. The calibration flow
removes that data dependence:

 1. `discover_sites` abstractly traces the model once and registers every
    quantization site (including the FP8 KV cache sites).
 2. `calibrate` runs N forward batches under a calibration context: scales
    start at 1.0 and converge as the amax history fills (exactly the
    training-side delayed-scaling loop, forward-only, RNE/deterministic).
 3. `freeze` emits {site_key: float scale} — plain python floats that
    serve/engine.py burns into the jitted prefill/decode as constants.

Frozen scales round-trip through checkpoint/ (`save_frozen`/`load_frozen`
write a json sidecar; ScaleState itself is a pytree and checkpoints through
the ordinary Checkpointer).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.scaling import context as scale_ctx
from repro.scaling.state import (DelayedScaling, ScaleState, ScalingConfig,
                                 SiteRegistry)

FROZEN_SCALES_FILE = "frozen_scales.json"


def _delayed_eval_cfg(cfg: ModelConfig) -> ModelConfig:
    """Deterministic (RNE, saturating) config with delayed scaling on."""
    quant = cfg.policy.quant.eval_mode()
    quant = dataclasses.replace(quant, scaling="delayed")
    pol = dataclasses.replace(cfg.policy, quant=quant)
    return cfg.replace(policy=pol)


def discover_sites(fn: Callable, *args) -> SiteRegistry:
    """Abstractly trace `fn(*args)` (jax.eval_shape — no FLOPs) with a
    discovery context; returns the registry of every site it quantizes.
    Sites inside scanned stacks carry their layer multiplicity, so the
    registry allocates one ScaleState row per layer (not per stack
    position)."""
    ctx = scale_ctx.discover_context()
    with scale_ctx.activate(ctx):
        jax.eval_shape(fn, *args)
    return SiteRegistry(ctx.discovered, ctx.discovered_token_sites,
                        site_layers=ctx.discovered_layers,
                        token_site_layers=ctx.discovered_token_layers)


def discover_lm_sites(cfg: ModelConfig, params, batch) -> SiteRegistry:
    """Site registry for an LM: traces the training loss (covers W/A/E/G
    sites) with the delayed config."""
    from repro.models.transformer import lm_loss
    dcfg = _delayed_quant_model(cfg)

    def fn(p, b):
        key = jax.random.PRNGKey(0)
        return lm_loss(p, b, cfg=dcfg, qkey=key)

    return discover_sites(fn, params, batch)


def _delayed_quant_model(cfg: ModelConfig) -> ModelConfig:
    quant = dataclasses.replace(cfg.policy.quant, scaling="delayed")
    pol = dataclasses.replace(cfg.policy, quant=quant)
    return cfg.replace(policy=pol)


def calibrate(params, cfg: ModelConfig, batches: Iterable, *,
              scaling_cfg: ScalingConfig = ScalingConfig(),
              registry: Optional[SiteRegistry] = None,
              sync: Optional[Callable] = None
              ) -> Tuple[DelayedScaling, ScaleState]:
    """Populate amax history from N forward batches (deterministic eval
    path). batches: iterable of {"tokens": (B, S) int32} dicts —
    encoder-decoder models additionally need "enc_inputs" (B, T, D) so the
    encoder and cross-attention sites are observed too. Returns the
    DelayedScaling bundle and the converged ScaleState."""
    from repro.models.transformer import encode, forward
    ecfg = _delayed_eval_cfg(cfg)
    batches = list(batches)
    if ecfg.is_encoder_decoder and "enc_inputs" not in batches[0]:
        raise ValueError(
            "encoder-decoder calibration needs 'enc_inputs' in each batch "
            "(otherwise the encoder/cross-attention sites stay uncalibrated "
            "and serve with unit scales)")

    def _fwd(p, b):
        enc_out, enc_aux = None, {}
        if ecfg.is_encoder_decoder:
            enc_out, enc_aux = encode(p, b["enc_inputs"], cfg=ecfg,
                                      with_aux=True)
        _, _, aux = forward(p, b["tokens"], cfg=ecfg, mode="train",
                            enc_out=enc_out)
        aux = dict(aux)
        aux.update(enc_aux)
        return aux

    if registry is None:
        registry = discover_sites(_fwd, params, batches[0])

    ds = DelayedScaling(registry, config=scaling_cfg, qcfg=ecfg.policy.quant)
    state = ds.init()

    def observe(p, b, scale_vec):
        scales = registry.unpack(scale_vec)
        with scale_ctx.activate(scale_ctx.calibrate_context(scales)):
            aux = _fwd(p, b)
            aux.update(scale_ctx.drain_aux())
        return {k[len(scale_ctx.AMAX_PREFIX):]: v for k, v in aux.items()
                if k.startswith(scale_ctx.AMAX_PREFIX)}

    observe_jit = jax.jit(observe)
    for b in batches:
        observed = observe_jit(params, b, state.scale)
        state = ds.update(state, observed, sync=sync)
    return ds, state


def freeze(ds: DelayedScaling, state: ScaleState, *,
           per_layer: bool = False) -> Dict[str, float]:
    """Frozen per-site scales for serving (forward classes only).
    per_layer=True keeps one scale per layer for scanned-stack sites
    (threaded through the serve-time scan xs) instead of the max
    envelope."""
    return ds.freeze(state, per_layer=per_layer)


def freeze_with_formats(ds: DelayedScaling, state: ScaleState,
                        cfg: Optional[ModelConfig] = None, *,
                        per_layer: bool = False
                        ) -> Tuple[Dict[str, float], Dict[str, str]]:
    """(frozen scales, per-site formats) — the formats record what each
    scale was calibrated under, so serving can refuse a recipe/format
    mismatch (see ServeEngine(frozen_formats=...)). per_layer as in
    freeze(); the format of a site is shared by all of its layer rows, so
    the formats dict is unaffected."""
    kv_format = cfg.policy.kv_cache_format if cfg is not None else None
    return (ds.freeze(state, per_layer=per_layer),
            ds.frozen_formats(kv_format=kv_format))


def save_frozen(directory, scales: Dict[str, float],
                formats: Optional[Dict[str, str]] = None):
    """Persist frozen scales (+ optionally the formats they were calibrated
    under). Without `formats` the legacy plain-scales layout is written."""
    p = Path(directory)
    p.mkdir(parents=True, exist_ok=True)
    doc = scales if formats is None else {"scales": scales,
                                          "formats": formats}
    (p / FROZEN_SCALES_FILE).write_text(json.dumps(doc, indent=1,
                                                   sort_keys=True))


def _load_doc(directory) -> dict:
    return json.loads((Path(directory) / FROZEN_SCALES_FILE).read_text())


def load_frozen(directory) -> Dict[str, float]:
    doc = _load_doc(directory)
    if isinstance(doc.get("scales"), dict):   # formats-annotated layout
        return doc["scales"]
    return doc


def load_frozen_formats(directory) -> Dict[str, str]:
    """Formats sidecar of a frozen-scales file ({} for legacy files that
    predate format recording)."""
    doc = _load_doc(directory)
    if isinstance(doc.get("scales"), dict):
        return doc.get("formats", {})
    return {}
