"""Delayed-scaling state: per-site amax ring buffers and derived scales.

The subsystem's core object is `ScaleState`, a registered-dataclass pytree
holding, for every registered tensor site (layer x tensor-class W/A/E/G, see
scaling.context for the key grammar):

    amax_history : (n_sites, history_len) f32 ring buffer of recent amax
                   observations (most-recent-first; rolled every update)
    scale        : (n_sites,) f32 derived dequantization scales
                   (x ~= fp8_data * scale; quantize divides by scale)
    step         : i32 update counter

Scales are derived from *history*, not the current tensor — the delayed-
scaling contract (cf. Transformer Engine; Noune et al. 2206.02915): the
quantize hot path never reduces over the full tensor, it just multiplies by
a precomputed 1/scale. Observation feeds back one step later.

Because observations are taken from the already-quantized FP8 payload
(bit-pattern max — see core.quantize.fp8_amax_bits), an observation can
never exceed scale * fmt_max. Range growth therefore needs an explicit
escape hatch: an observation at the representable ceiling (saturation) is
bumped by `growth` before entering history, probing the range upward the
same way dynamic loss scaling backs off downward. `margin` keeps steady-
state tensors strictly inside the ceiling so the probe only fires on real
range jumps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fp8_formats import get_format
from repro.core.precision_policy import QuantConfig
from repro.scaling import context as scale_ctx

Array = jax.Array

_SAT_TOL = 1.0 - 2.0 ** -8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScaleState:
    amax_history: Array   # (n_sites, history_len) f32, col 0 = most recent
    scale: Array          # (n_sites,) f32
    step: Array           # i32 scalar

    @classmethod
    def create(cls, n_sites: int, history_len: int) -> "ScaleState":
        return cls(
            amax_history=jnp.zeros((n_sites, history_len), jnp.float32),
            scale=jnp.ones((n_sites,), jnp.float32),
            step=jnp.asarray(0, jnp.int32))


@dataclasses.dataclass(frozen=True)
class ScalingConfig:
    """Static policy for deriving scales from amax history."""
    history_len: int = 16
    policy: str = "max"          # max | most_recent | ema
    margin: float = 2.0          # headroom factor; >1 keeps steady-state
    #                              tensors off the ceiling (stable feedback)
    growth: float = 2.0          # range probe on saturation / overflow
    ema_decay: float = 0.75      # for policy="ema"


def amax_from_history(history: Array, cfg: ScalingConfig) -> Array:
    """(S, H) history -> (S,) representative amax, per policy."""
    if cfg.policy == "max":
        return history.max(axis=1)
    if cfg.policy == "most_recent":
        return history[:, 0]
    if cfg.policy == "ema":
        h = history.shape[1]
        w = (1.0 - cfg.ema_decay) * cfg.ema_decay ** np.arange(h)
        w = jnp.asarray(w / w.sum(), jnp.float32)
        # Normalize over the populated prefix only: zero rows contribute 0.
        populated = (history > 0).astype(jnp.float32)
        denom = jnp.maximum((populated * w[None, :]).sum(axis=1), 1e-30)
        return (history * w[None, :]).sum(axis=1) / denom
    raise ValueError(f"unknown history policy {cfg.policy!r}")


class SiteRegistry:
    """Stable key -> row mapping for ScaleState vectors (static, not a pytree).

    Keys follow scaling.context's grammar. `token_sites` are the sites with a
    backward E/G observation channel.
    """

    def __init__(self, keys: Iterable[str], token_sites: Iterable[str] = ()):
        self.keys: Tuple[str, ...] = tuple(sorted(set(keys)))
        self.index: Dict[str, int] = {k: i for i, k in enumerate(self.keys)}
        self.token_sites: Tuple[str, ...] = tuple(sorted(set(token_sites)))
        # Filled in (python-side) during the training trace: how many times
        # each site's token is used, so summed E/G cotangents can be
        # normalized back to a mean (see context.token_uses).
        self.token_uses: Dict[str, int] = {}

    def __len__(self) -> int:
        return len(self.keys)

    def class_letter(self, key: str) -> str:
        return key.rsplit("#", 1)[1][-1]   # W | A | E | G

    def fmt_max_vector(self, qcfg: QuantConfig) -> np.ndarray:
        fwd = get_format(qcfg.fwd_format).max_normal
        bwd = get_format(qcfg.bwd_format).max_normal
        return np.asarray([fwd if self.class_letter(k) in ("W", "A") else bwd
                           for k in self.keys], np.float32)


@dataclasses.dataclass(frozen=True)
class DelayedScaling:
    """Bundles a SiteRegistry + policies into the subsystem's public API."""
    registry: SiteRegistry
    config: ScalingConfig = ScalingConfig()
    qcfg: QuantConfig = QuantConfig(scaling="delayed")

    # -- state ---------------------------------------------------------------
    def init(self) -> ScaleState:
        return ScaleState.create(len(self.registry), self.config.history_len)

    def zero_tokens(self) -> Dict[str, Array]:
        """Per-site E/G cotangent tokens; pass as a differentiated input of
        the loss, the token 'gradients' come back as observed bwd amaxes."""
        return {s: jnp.zeros((2,), jnp.float32)
                for s in self.registry.token_sites}

    def scales_dict(self, state: ScaleState) -> Dict[str, Array]:
        return {k: state.scale[i] for k, i in self.registry.index.items()}

    # -- contexts ------------------------------------------------------------
    def collect(self, state: ScaleState, tokens: Mapping[str, Array]):
        ctx = scale_ctx.collect_context(self.scales_dict(state), tokens)
        ctx.use_sink = self.registry.token_uses
        return scale_ctx.activate(ctx)

    def calibrate_ctx(self, state: ScaleState):
        return scale_ctx.activate(
            scale_ctx.calibrate_context(self.scales_dict(state)))

    # -- update --------------------------------------------------------------
    def update(self, state: ScaleState, observed: Mapping[str, Array], *,
               sync: Optional[Callable[[Array], Array]] = None) -> ScaleState:
        """Fold one step of observations into history and re-derive scales.

        observed: key -> f32 amax scalar (any subset of registry keys; sites
        not observed this step carry their most recent history value
        forward). sync: optional cross-replica reduction (e.g.
        distributed.amax_sync.make_amax_sync('data')) applied to the dense
        observation vector — a single fused pmax instead of one collective
        per site.
        """
        prev = state.amax_history[:, 0]
        rows = []
        seen = np.zeros((len(self.registry),), bool)
        for i, k in enumerate(self.registry.keys):
            v = observed.get(k)
            if v is None:
                rows.append(prev[i])
            else:
                seen[i] = True
                rows.append(jnp.asarray(v, jnp.float32).reshape(()))
        obs = jnp.stack(rows)
        if sync is not None:
            obs = sync(obs)
        fmax = jnp.asarray(self.registry.fmt_max_vector(self.qcfg))
        cap = state.scale * fmax
        # Overflow (inf/nan from non-saturating error tensors) and saturation
        # (observation pinned at the representable ceiling) both mean "the
        # range was too small": probe upward by `growth`.
        obs = jnp.where(jnp.isfinite(obs), obs, cap * self.config.growth)
        seen_mask = jnp.asarray(seen)
        # Pinned AT the ceiling => the true amax was clipped away: probe
        # upward. Strictly beyond it (a raw, unclipped observation — e.g. KV
        # calibration) is exact and enters history as-is.
        saturated = seen_mask & (obs >= cap * _SAT_TOL) \
            & (obs <= cap / _SAT_TOL)
        obs = jnp.where(saturated, obs * self.config.growth, obs)
        hist = jnp.concatenate([obs[:, None], state.amax_history[:, :-1]],
                               axis=1)
        amax = amax_from_history(hist, self.config)
        scale = jnp.where(amax > 0, amax * self.config.margin / fmax, 1.0)
        return ScaleState(amax_history=hist, scale=scale.astype(jnp.float32),
                          step=state.step + 1)

    # -- freeze (calibrated serving) -----------------------------------------
    def freeze(self, state: ScaleState) -> Dict[str, float]:
        """Emit frozen per-site scales for deterministic quantized serving.
        Only forward-path classes (W/A) matter at inference; E/G rows are
        excluded."""
        scales = np.asarray(state.scale)
        return {k: float(scales[i]) for k, i in self.registry.index.items()
                if self.registry.class_letter(k) in ("W", "A")}


def split_observations(metrics: Dict[str, Array],
                       token_grads: Mapping[str, Array],
                       registry: SiteRegistry) -> Dict[str, Array]:
    """Assemble the per-key observation dict for DelayedScaling.update from
    (a) forward amax aux entries riding in `metrics` (popped in place) and
    (b) the cotangents of the E/G tokens.

    Token cotangents SUM over every use of a shared site (scan iterations,
    attention/CE chunks); dividing by the trace-time use count recovers the
    mean per-use amax. A mean can understate a heterogeneous group's max,
    which the saturation-growth guard in DelayedScaling.update then probes
    back up — whereas an uncorrected sum would overstate scales with no
    mechanism pulling them back down.
    """
    observed: Dict[str, Array] = {}
    for k in [k for k in metrics if k.startswith(scale_ctx.AMAX_PREFIX)]:
        observed[k[len(scale_ctx.AMAX_PREFIX):]] = metrics.pop(k)
    for site, tok in token_grads.items():
        inv = 1.0 / max(1, registry.token_uses.get(site, 1))
        ek, gk = f"{site}#E", f"{site}#G"
        if ek in registry.index:
            observed[ek] = tok[0] * inv
        if gk in registry.index:
            observed[gk] = tok[1] * inv
    return observed
