"""Delayed-scaling state: per-site amax ring buffers and derived scales.

The subsystem's core object is `ScaleState`, a registered-dataclass pytree
holding, for every registered tensor site (layer x tensor-class W/A/E/G, see
scaling.context for the key grammar):

    amax_history : (n_sites, history_len) f32 ring buffer of recent amax
                   observations (most-recent-first; rolled every update)
    scale        : (n_sites,) f32 derived dequantization scales
                   (x ~= fp8_data * scale; quantize divides by scale)
    step         : i32 update counter

Scales are derived from *history*, not the current tensor — the delayed-
scaling contract (cf. Transformer Engine; Noune et al. 2206.02915): the
quantize hot path never reduces over the full tensor, it just multiplies by
a precomputed 1/scale. Observation feeds back one step later.

Because observations are taken from the already-quantized FP8 payload
(bit-pattern max — see core.quantize.fp8_amax_bits), an observation can
never exceed scale * fmt_max. Range growth therefore needs an explicit
escape hatch: an observation at the representable ceiling (saturation) is
bumped by `growth` before entering history, probing the range upward the
same way dynamic loss scaling backs off downward. `margin` keeps steady-
state tensors strictly inside the ceiling so the probe only fires on real
range jumps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fp8_formats import get_format
from repro.core.precision_policy import QuantConfig
from repro.scaling import context as scale_ctx

Array = jax.Array

_SAT_TOL = 1.0 - 2.0 ** -8

_CLASS_OF_LETTER = {"W": "weight", "A": "act", "E": "error", "G": "grad"}


def format_for_site(key: str, qcfg: QuantConfig,
                    kv_format: Optional[str] = None) -> Optional[str]:
    """Storage format a site key quantizes with — THE site->format rule,
    shared by the freeze side (DelayedScaling.frozen_formats) and the serve
    side (ServeEngine's format check) so the two can never drift apart.

    FP8 KV-cache sites ('.../kv/{k,v}#A') quantize with the policy's
    kv_cache_format (returned verbatim — None means no FP8 KV cache);
    everything else follows the recipe via its class letter."""
    base = key.split("#", 1)[0]
    if base.endswith(("kv/k", "kv/v")):
        return kv_format
    letter = key.rsplit("#", 1)[1][-1]
    cls = _CLASS_OF_LETTER.get(letter)
    if cls is None:
        raise ValueError(f"unrecognized tensor class {letter!r} in site "
                         f"key {key!r}")
    return qcfg.format_for(cls)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScaleState:
    amax_history: Array   # (n_sites, history_len) f32, col 0 = most recent
    scale: Array          # (n_sites,) f32
    step: Array           # i32 scalar

    @classmethod
    def create(cls, n_sites: int, history_len: int) -> "ScaleState":
        return cls(
            amax_history=jnp.zeros((n_sites, history_len), jnp.float32),
            scale=jnp.ones((n_sites,), jnp.float32),
            step=jnp.asarray(0, jnp.int32))


@dataclasses.dataclass(frozen=True)
class ScalingConfig:
    """Static policy for deriving scales from amax history."""
    history_len: int = 16
    policy: str = "max"          # max | most_recent | ema
    margin: float = 2.0          # headroom factor; >1 keeps steady-state
    #                              tensors off the ceiling (stable feedback)
    growth: float = 2.0          # range probe on saturation / overflow
    ema_decay: float = 0.75      # for policy="ema"


def amax_from_history(history: Array, cfg: ScalingConfig) -> Array:
    """(S, H) history -> (S,) representative amax, per policy."""
    if cfg.policy == "max":
        return history.max(axis=1)
    if cfg.policy == "most_recent":
        return history[:, 0]
    if cfg.policy == "ema":
        h = history.shape[1]
        w = (1.0 - cfg.ema_decay) * cfg.ema_decay ** np.arange(h)
        w = jnp.asarray(w / w.sum(), jnp.float32)
        # Normalize over the populated prefix only: zero rows contribute 0.
        populated = (history > 0).astype(jnp.float32)
        denom = jnp.maximum((populated * w[None, :]).sum(axis=1), 1e-30)
        return (history * w[None, :]).sum(axis=1) / denom
    raise ValueError(f"unknown history policy {cfg.policy!r}")


class SiteRegistry:
    """Stable key -> row-span mapping for ScaleState vectors (static, not a
    pytree).

    Keys follow scaling.context's grammar. `token_sites` are the sites with a
    backward E/G observation channel. `site_layers` / `token_site_layers`
    give per-layer multiplicities for sites inside scanned stacks (discovered
    via scope(..., layers=N)): such a key owns N consecutive ScaleState rows
    — a true per-layer site even though the scan body is traced once —
    and its scales/observations are (N,) vectors threaded through scan
    xs/ys. `index[key]` is the first row; `n_rows[key]` the span (1 for
    ordinary sites, so the single-row accesses of existing callers are
    unchanged).
    """

    def __init__(self, keys: Iterable[str], token_sites: Iterable[str] = (),
                 site_layers: Optional[Mapping[str, int]] = None,
                 token_site_layers: Optional[Mapping[str, int]] = None):
        self.keys: Tuple[str, ...] = tuple(sorted(set(keys)))
        site_layers = dict(site_layers or {})
        self.n_rows: Dict[str, int] = {k: max(1, int(site_layers.get(k, 1)))
                                       for k in self.keys}
        self.index: Dict[str, int] = {}
        row = 0
        for k in self.keys:
            self.index[k] = row
            row += self.n_rows[k]
        self.total_rows: int = row
        self.token_sites: Tuple[str, ...] = tuple(sorted(set(token_sites)))
        token_site_layers = dict(token_site_layers or {})
        self.token_site_layers: Dict[str, int] = {
            s: max(1, int(token_site_layers.get(s, 1)))
            for s in self.token_sites}
        # Filled in (python-side) during the training trace: how many times
        # each site's token is used, so summed E/G cotangents can be
        # normalized back to a mean (see context.token_uses).
        self.token_uses: Dict[str, int] = {}

    def __len__(self) -> int:
        return self.total_rows

    def class_letter(self, key: str) -> str:
        return key.rsplit("#", 1)[1][-1]   # W | A | E | G

    def format_for(self, key: str, qcfg: QuantConfig) -> str:
        """Storage format a site quantizes with under `qcfg` (per the recipe:
        W/A -> fwd_format, E/G -> bwd_format)."""
        return qcfg.fwd_format if self.class_letter(key) in ("W", "A") \
            else qcfg.bwd_format

    def fmt_max_vector(self, qcfg: QuantConfig) -> np.ndarray:
        """(total_rows,) per-row format ceiling — the format-aware scale
        target: each site's rows map amax onto ITS storage format's grid."""
        vals = [get_format(self.format_for(k, qcfg)).max_normal
                for k in self.keys]
        return np.repeat(np.asarray(vals, np.float32),
                         [self.n_rows[k] for k in self.keys])

    def unpack(self, vec) -> Dict[str, object]:
        """Split a (total_rows,) vector into per-key values: a scalar for
        single-row sites, the (n_rows,) slice for per-layer sites."""
        out: Dict[str, object] = {}
        for k in self.keys:
            i, n = self.index[k], self.n_rows[k]
            out[k] = vec[i] if n == 1 else vec[i:i + n]
        return out


@dataclasses.dataclass(frozen=True)
class DelayedScaling:
    """Bundles a SiteRegistry + policies into the subsystem's public API."""
    registry: SiteRegistry
    config: ScalingConfig = ScalingConfig()
    qcfg: QuantConfig = QuantConfig(scaling="delayed")

    # -- state ---------------------------------------------------------------
    def init(self) -> ScaleState:
        return ScaleState.create(len(self.registry), self.config.history_len)

    def zero_tokens(self) -> Dict[str, Array]:
        """Per-site backward-observation tokens (scale_ctx.TOKEN_CHANNELS
        channels: E / G / fused dgrad output); pass as a differentiated
        input of the loss, the token 'gradients' come back as observed bwd
        amaxes. Per-layer (scanned-stack) sites get a stacked
        (n_layers, TOKEN_CHANNELS) token whose rows are threaded through
        scan xs — their cotangents come back one row per layer.

        Under qcfg.track_health the tokens widen to carry a (sat, flush)
        health pair per amax channel (scale_ctx.token_width)."""
        c = scale_ctx.token_width(self.qcfg.track_health)
        return {s: jnp.zeros((n, c) if n > 1 else (c,), jnp.float32)
                for s, n in self.registry.token_site_layers.items()}

    def scales_dict(self, state: ScaleState) -> Dict[str, Array]:
        """key -> scale: scalar for ordinary sites, (n_layers,) vector for
        per-layer scanned-stack sites."""
        return self.registry.unpack(state.scale)

    # -- contexts ------------------------------------------------------------
    def collect(self, state: ScaleState, tokens: Mapping[str, Array]):
        ctx = scale_ctx.collect_context(
            self.scales_dict(state), tokens,
            token_channels=scale_ctx.token_width(self.qcfg.track_health))
        ctx.use_sink = self.registry.token_uses
        return scale_ctx.activate(ctx)

    def calibrate_ctx(self, state: ScaleState):
        return scale_ctx.activate(scale_ctx.calibrate_context(
            self.scales_dict(state),
            token_channels=scale_ctx.token_width(self.qcfg.track_health)))

    # -- update --------------------------------------------------------------
    def update(self, state: ScaleState, observed: Mapping[str, Array], *,
               sync: Optional[Callable[[Array], Array]] = None) -> ScaleState:
        """Fold one step of observations into history and re-derive scales.

        observed: key -> f32 amax scalar (any subset of registry keys; sites
        not observed this step carry their most recent history value
        forward). sync: optional cross-replica reduction (e.g.
        distributed.amax_sync.make_amax_sync('data')) applied to the dense
        observation vector — a single fused pmax instead of one collective
        per site.
        """
        prev = state.amax_history[:, 0]
        rows = []
        seen = np.zeros((len(self.registry),), bool)
        for k in self.registry.keys:
            i, n = self.registry.index[k], self.registry.n_rows[k]
            v = observed.get(k)
            if v is None:
                rows.append(prev[i:i + n])
            else:
                seen[i:i + n] = True
                vv = jnp.asarray(v, jnp.float32).reshape((-1,))
                # Scalar observations of per-layer sites (e.g. an envelope
                # from an external source) broadcast over the key's rows.
                rows.append(jnp.broadcast_to(vv, (n,)) if vv.shape[0] != n
                            else vv)
        obs = jnp.concatenate(rows) if rows \
            else jnp.zeros((0,), jnp.float32)
        if sync is not None:
            obs = sync(obs)
        fmax = jnp.asarray(self.registry.fmt_max_vector(self.qcfg))
        cap = state.scale * fmax
        # Overflow (inf/nan from non-saturating error tensors) and saturation
        # (observation pinned at the representable ceiling) both mean "the
        # range was too small": probe upward by `growth`.
        obs = jnp.where(jnp.isfinite(obs), obs, cap * self.config.growth)
        seen_mask = jnp.asarray(seen)
        # Pinned AT the ceiling => the true amax was clipped away: probe
        # upward. Strictly beyond it (a raw, unclipped observation — e.g. KV
        # calibration) is exact and enters history as-is.
        saturated = seen_mask & (obs >= cap * _SAT_TOL) \
            & (obs <= cap / _SAT_TOL)
        obs = jnp.where(saturated, obs * self.config.growth, obs)
        hist = jnp.concatenate([obs[:, None], state.amax_history[:, :-1]],
                               axis=1)
        amax = amax_from_history(hist, self.config)
        scale = jnp.where(amax > 0, amax * self.config.margin / fmax, 1.0)
        return ScaleState(amax_history=hist, scale=scale.astype(jnp.float32),
                          step=state.step + 1)

    # -- freeze (calibrated serving) -----------------------------------------
    def freeze(self, state: ScaleState, *,
               per_layer: bool = False) -> Dict[str, object]:
        """Emit frozen per-site scales for deterministic quantized serving.
        Only forward-path classes (W/A) matter at inference; E/G rows are
        excluded.

        per_layer=False (legacy): per-layer (scanned-stack) sites collapse
        to their MAX row — the amax envelope over the layers — so serving
        keeps python-float scales baked into the jitted program.

        per_layer=True: per-layer sites keep one scale per layer (a list of
        floats, json-serializable); the scan body reads its own layer's
        slice through the stacked xs apply_stack threads (full per-layer
        serving fidelity instead of the envelope)."""
        scales = np.asarray(state.scale)
        out: Dict[str, object] = {}
        for k in self.registry.keys:
            if self.registry.class_letter(k) not in ("W", "A"):
                continue
            i, n = self.registry.index[k], self.registry.n_rows[k]
            if per_layer and n > 1:
                out[k] = [float(x) for x in scales[i:i + n]]
            else:
                out[k] = float(scales[i:i + n].max())
        return out

    def frozen_formats(self, *,
                       kv_format: Optional[str] = None) -> Dict[str, str]:
        """Storage format each frozen (forward) site was calibrated under —
        shipped alongside the frozen scales so serving can refuse a format
        mismatch (a scale calibrated for the e4m3 grid is 128x off on e5m2).
        FP8 KV-cache sites ('.../kv/{k,v}#A') quantize with the policy's
        kv_cache_format, passed as `kv_format`."""
        out: Dict[str, str] = {}
        for k in self.registry.keys:
            if self.registry.class_letter(k) not in ("W", "A"):
                continue
            fmt = format_for_site(k, self.qcfg, kv_format)
            out[k] = fmt or self.registry.format_for(k, self.qcfg)
        return out


def split_observations(metrics: Dict[str, Array],
                       token_grads: Mapping[str, Array],
                       registry: SiteRegistry) -> Dict[str, Array]:
    """Assemble the per-key observation dict for DelayedScaling.update from
    (a) forward amax aux entries riding in `metrics` (popped in place) and
    (b) the cotangents of the E/G tokens.

    Token cotangents SUM over every use of a shared site (scan iterations,
    attention/CE chunks); dividing by the trace-time use count recovers the
    mean per-use amax. A mean can understate a heterogeneous group's max,
    which the saturation-growth guard in DelayedScaling.update then probes
    back up — whereas an uncorrected sum would overstate scales with no
    mechanism pulling them back down.

    Tokens wider than TOKEN_CHANNELS (QuantConfig.track_health) carry a
    (sat, flush) health pair per amax channel in their tail; the pairs are
    routed into `metrics` under scale_ctx.HEALTH_PREFIX (telemetry only —
    they never enter ScaleState), use-count-averaged like the amaxes.
    """
    observed: Dict[str, Array] = {}
    for k in [k for k in metrics if k.startswith(scale_ctx.AMAX_PREFIX)]:
        observed[k[len(scale_ctx.AMAX_PREFIX):]] = metrics.pop(k)

    def health(tok, site_key, channel, inv):
        if tok.shape[-1] <= scale_ctx.TOKEN_CHANNELS:
            return
        c0 = scale_ctx.TOKEN_CHANNELS + 2 * channel
        metrics[scale_ctx.HEALTH_PREFIX + site_key] = \
            tok[..., c0:c0 + 2] * inv

    for site, tok in token_grads.items():
        inv = 1.0 / max(1, registry.token_uses.get(site, 1))
        ek, gk = f"{site}#E", f"{site}#G"
        # tok is (TOKEN_CHANNELS,) for ordinary sites; (n_layers, C) for
        # per-layer scanned-stack sites (one cotangent row per scan
        # iteration) — [..., c] handles both, yielding a scalar or
        # (n_layers,) vector.
        if ek in registry.index:
            observed[ek] = tok[..., 0] * inv
            health(tok, ek, 0, inv)
        if gk in registry.index:
            observed[gk] = tok[..., 1] * inv
            health(tok, gk, 1, inv)
        if tok.shape[-1] > 2:
            # Fused-epilogue sites: channel 2 is the error-class dgrad
            # output observation ("#da.E" / "#db.E" by which operand the
            # error flows back to).
            for dk in (f"{site}#da.E", f"{site}#db.E"):
                if dk in registry.index:
                    observed[dk] = tok[..., 2] * inv
                    health(tok, dk, 2, inv)
        if tok.shape[-1] > 4:
            # Fused-attention sites: channels 3/4 carry the in-kernel dP/dS
            # intermediate observations.
            for c, dk in ((3, f"{site}#dp.E"), (4, f"{site}#ds.E")):
                if dk in registry.index:
                    observed[dk] = tok[..., c] * inv
                    health(tok, dk, c, inv)
    return observed
