"""Trace-time plumbing for delayed per-tensor scaling.

A `ScaleContext` carries per-site scales *into* the quantization call sites
(core.qlinear / core.qconv / models.attention) and collects observed amaxes
*out of* them, without changing every model-function signature. The context
is pure plumbing: every value that crosses a jit/scan boundary still flows
functionally (scales enter as traced function inputs; collected amaxes are
drained into the layer `aux` dict inside the scan body, and error/grad
amaxes ride the cotangent of per-site token inputs). The context object only
routes trace-time references — it holds no state across traces.

Site keys
---------
A qeinsum call at scoped site S with operand classes (Ca, Cb) produces
registry keys:

    "{S}#a.{W|A}"   — operand a (forward observation)
    "{S}#b.{W|A}"   — operand b (forward observation)
    "{S}#E"         — the error tensor dY quantized in backward
    "{S}#G"         — the FP8-stored weight gradient (if a weight operand)

Sites whose GEMMs route through the fused quantize-in-epilogue kernels
(core.qlinear with backend="pallas*" + delayed scaling) additionally
quantize their GEMM *outputs* in the epilogue and register:

    "{S}#y.A"       — the forward output Y = Q(A.W) (activation class)
    "{S}#da.E"      — the dgrad output dA = Q_E(dY.W^T) (error class;
                      "#db.E" when the weight is operand a instead)

Fused flash-attention sites (core.qattention with backend="pallas*" +
delayed scaling; one site replaces the unfused qk/pv qeinsum pair) register:

    "{S}#q.A" / "{S}#k.A" / "{S}#v.A"  — the three operands
    "{S}#qk.A"      — the quantized score matrix S = Q_A(QK^T)
    "{S}#p.A"       — the quantized softmax probs P
    "{S}#E"         — the incoming output error dO quantized in backward
    "{S}#dp.E"      — the backward intermediate dP = Q_E(dO.V^T)
    "{S}#ds.E"      — the backward intermediate dS (softmax VJP output)

The in-kernel attention observations (#qk.A, #p.A, #dp.E, #ds.E) are
scalars masked to the ATTENDED region — causal/window/kv-masked positions
never contribute. Under the streamed-KV kernel grid, fully-masked kv
stripes are skipped entirely, so observing masked positions would make the
observation depend on the stripe partition; masking keeps the amaxes
invariant to block sizes and the stripe count out of every observation
shape (they stay scalars — nothing here changes with context length).

Raw (non-qeinsum) sites — the FP8 KV cache — use "{S}#A".

Modes
-----
    discover  — abstract trace (jax.eval_shape) that registers site keys;
                scales read as 1.0, nothing is recorded.
    collect   — training: scales come from ScaleState, forward amaxes are
                recorded (from the already-materialized FP8 data — no extra
                pass over the high-precision tensor).
    calibrate — like collect, plus KV-cache range observation (an offline
                full-tensor reduce that is deliberately NOT done in the
                training hot path).
    frozen    — serving: scales are python floats (burned into the jitted
                program as constants); nothing is recorded.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Set

import jax.numpy as jnp
import numpy as np

_CLASS_LETTER = {"weight": "W", "act": "A", "error": "E", "grad": "G"}

AMAX_PREFIX = "amax/"
# Forward precision-health observations (repro.obs counters) ride the same
# aux channel as amaxes under their own prefix: values are (2,) f32
# [sat_frac, flush_frac] per site (or (n_layers, 2) for scanned stacks).
HEALTH_PREFIX = "health/"
# Internal marker inside ScaleContext.collected separating health entries
# from amax entries, so drain_raw()/re_record() round-trip both through
# remat/chunk boundaries unchanged. Site keys never contain '!'.
_HEALTH_MARK = "health!"

# Channels of a site's backward-observation token cotangent:
#   [amax_E (quantized dY / dO), amax_G (FP8-stored weight grad),
#    amax of the error-class fused dgrad output (0 unless the site's GEMMs
#    run through the fused quantize-in-epilogue path),
#    amax of the fused-attention dP intermediate ("#dp.E"),
#    amax of the fused-attention dS intermediate ("#ds.E")].
TOKEN_CHANNELS = 5
# With QuantConfig.track_health the token widens by a (sat_frac, flush_frac)
# pair per amax channel: channels 5+2c / 6+2c carry the health pair of amax
# channel c. The pairs are fractions, so the same sum-over-uses/divide-by-
# use-count reduction that recovers the mean amax recovers mean fractions.
HEALTH_TOKEN_CHANNELS = 2 * TOKEN_CHANNELS


def token_width(track_health: bool) -> int:
    return TOKEN_CHANNELS + (HEALTH_TOKEN_CHANNELS if track_health else 0)


def token_cotangent(e=0.0, g=0.0, err=0.0, dp=0.0, ds=0.0, health=None):
    """Assemble a backward-observation cotangent; qeinsum fills the first
    three channels, fused attention e/dp/ds. `health` (iff
    QuantConfig.track_health): (HEALTH_TOKEN_CHANNELS,) of [sat, flush]
    pairs, one per amax channel, appended as channels 5..14."""
    base = jnp.stack([jnp.asarray(v, jnp.float32)
                      for v in (e, g, err, dp, ds)])
    if health is None:
        return base
    return jnp.concatenate(
        [base, jnp.asarray(health, jnp.float32).reshape((-1,))])


def health_pairs(pairs) -> jnp.ndarray:
    """Pack per-channel [sat_frac, flush_frac] pairs (None => zeros) into
    the (HEALTH_TOKEN_CHANNELS,) tail of a token cotangent. `pairs` lists
    one entry per amax channel, in channel order."""
    out = []
    for p in pairs:
        out.append(jnp.zeros((2,), jnp.float32) if p is None
                   else jnp.asarray(p, jnp.float32))
    return jnp.concatenate(out)


@dataclasses.dataclass
class ScaleContext:
    mode: str                                   # discover|collect|calibrate|frozen
    scales: Mapping[str, Any]                   # key -> f32 scalar / float,
    #                                             or (n_layers,) vector for
    #                                             per-layer scanned-stack sites
    tokens: Mapping[str, Any]                   # site -> f32[2] (E/G channel),
    #                                             or f32[n_layers, 2] stacked
    discovered: Set[str] = dataclasses.field(default_factory=set)
    discovered_token_sites: Set[str] = dataclasses.field(default_factory=set)
    # Per-layer multiplicity of sites registered inside a layered scope
    # (scope(name, layers=N) — the scanned-stack body): key/site -> N. The
    # registry allocates that many ScaleState rows per key, giving true
    # per-layer sites even though the scan body is traced once.
    discovered_layers: Dict[str, int] = dataclasses.field(default_factory=dict)
    discovered_token_layers: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    collected: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # Trace-time count of token uses per site. A site token used N times
    # (chunked attention, chunked CE, scanned layer groups) accumulates the
    # SUM of N per-use amaxes in its cotangent; the consumer divides by this
    # count to recover the mean (see ScaleState docs — the saturation-growth
    # guard corrects any residual underestimate upward).
    token_uses: Dict[str, int] = dataclasses.field(default_factory=dict)
    use_sink: Optional[Dict[str, int]] = None
    _scope: List[str] = dataclasses.field(default_factory=list)
    _layers: List[int] = dataclasses.field(default_factory=list)
    # Innermost-first stack of per-layer slice views pushed by the scan body
    # (see layer_view): full-key -> this iteration's scalar scale / (2,)
    # token, sliced from the stacked xs the caller threads through lax.scan.
    _layer_scales: List[Mapping[str, Any]] = dataclasses.field(
        default_factory=list)
    _layer_tokens: List[Mapping[str, Any]] = dataclasses.field(
        default_factory=list)
    # Width of the backward-observation tokens this trace runs with
    # (TOKEN_CHANNELS, or +HEALTH_TOKEN_CHANNELS under track_health); the
    # token_for fallback must match the cotangent width the call sites emit.
    token_channels: int = TOKEN_CHANNELS

    # -- scoping -------------------------------------------------------------
    def site_key(self, site: str) -> str:
        return "/".join(self._scope + [site])

    def scope_prefix(self) -> str:
        """Current scope path with a trailing '/' (empty scope -> '')."""
        return "/".join(self._scope + [""])

    def _layer_count(self) -> int:
        n = 1
        for m in self._layers:
            n *= m
        return n

    # -- registry ------------------------------------------------------------
    def register(self, key: str):
        if self.mode == "discover":
            self.discovered.add(key)
            n = self._layer_count()
            if n > 1:
                self.discovered_layers[key] = n

    def register_token_site(self, site_key: str):
        if self.mode == "discover":
            self.discovered_token_sites.add(site_key)
            n = self._layer_count()
            if n > 1:
                self.discovered_token_layers[site_key] = n

    # -- scale lookup --------------------------------------------------------
    def scale_for(self, key: str, default: float = 1.0):
        for view in reversed(self._layer_scales):
            s = view.get(key)
            if s is not None:
                return jnp.asarray(s, jnp.float32)
        s = self.scales.get(key)
        if s is None:
            return jnp.asarray(default, jnp.float32)
        return jnp.asarray(s, jnp.float32)

    def frozen_scale(self, key: str, default: float = 1.0):
        """Frozen-serving scale lookup. Ordinary sites return a python float
        (burned into the jitted program as a constant). Per-layer
        scanned-stack sites resolve through the scan body's layer_view to
        THIS iteration's traced slice; a per-layer vector hit outside a
        layer view collapses to its max envelope."""
        if self.mode != "frozen":
            return default
        for view in reversed(self._layer_scales):
            s = view.get(key)
            if s is not None:
                return s
        s = self.scales.get(key, default)
        if getattr(s, "ndim", 0):
            return float(np.max(s))
        return float(s)

    def has_scale(self, key: str) -> bool:
        """Whether `key` resolves to a calibrated scale (layer views
        included) rather than falling back to the unit default."""
        return any(key in view for view in self._layer_scales) \
            or key in self.scales

    # -- tokens (backward E/G observation channel) ---------------------------
    def token_for(self, site_key: str):
        self.register_token_site(site_key)
        self.token_uses[site_key] = self.token_uses.get(site_key, 0) + 1
        for view in reversed(self._layer_tokens):
            t = view.get(site_key)
            if t is not None:
                return t
        t = self.tokens.get(site_key)
        if t is None:
            return jnp.zeros((self.token_channels,), jnp.float32)
        return t

    # -- forward observation -------------------------------------------------
    def record(self, key: str, amax):
        if key.startswith(_HEALTH_MARK):
            # re_record() replaying a drain_raw() dict: route health entries
            # back to their own channel (no registry side effects).
            self.record_health(key[len(_HEALTH_MARK):], amax)
            return
        self.register(key)
        if self.mode in ("collect", "calibrate"):
            prev = self.collected.get(key)
            self.collected[key] = amax if prev is None \
                else jnp.maximum(prev, amax)

    def record_health(self, key: str, frac2):
        """Record a (2,) [sat_frac, flush_frac] forward health observation
        for `key` (a site already registered by its amax record). Multiple
        uses max-combine — remat replay then cannot double-count, and a
        high fraction in ANY use is the signal of interest."""
        if self.mode in ("collect", "calibrate"):
            k = _HEALTH_MARK + key
            prev = self.collected.get(k)
            self.collected[k] = frac2 if prev is None \
                else jnp.maximum(prev, frac2)

    def drain_aux(self) -> Dict[str, Any]:
        """Pull collected amaxes (and health pairs) as aux entries. Must be
        called inside the same scan body that recorded them (apply_layer
        does this) so the traced values exit the scan functionally via the
        aux ys."""
        out = {}
        for k, v in self.collected.items():
            if k.startswith(_HEALTH_MARK):
                out[HEALTH_PREFIX + k[len(_HEALTH_MARK):]] = v
            else:
                out[AMAX_PREFIX + k] = v
        self.collected.clear()
        return out


_ACTIVE: Optional[ScaleContext] = None


def current() -> Optional[ScaleContext]:
    return _ACTIVE


@contextlib.contextmanager
def activate(ctx: ScaleContext):
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a ScaleContext is already active")
    _ACTIVE = ctx
    try:
        yield ctx
    finally:
        _ACTIVE = None
        if ctx.use_sink is not None:
            ctx.use_sink.clear()
            ctx.use_sink.update(ctx.token_uses)


@contextlib.contextmanager
def scope(name: str, *, layers: int = 1):
    """Push a site-scope segment (no-op when no context is active).

    layers > 1 marks a scanned-stack scope: the body is traced once but runs
    `layers` times, and every site registered inside gets that multiplicity
    in the registry — one ScaleState row per layer instead of one shared row
    per stack position.
    """
    ctx = _ACTIVE
    if ctx is None:
        yield
        return
    ctx._scope.append(name)
    if layers > 1:
        ctx._layers.append(layers)
    try:
        yield
    finally:
        ctx._scope.pop()
        if layers > 1:
            ctx._layers.pop()


@contextlib.contextmanager
def layer_view(scales: Mapping[str, Any], tokens: Mapping[str, Any]):
    """Override per-layer sites with this scan iteration's slices.

    The scanned stack threads stacked (n_layers,)-leading scale/token arrays
    through lax.scan xs; the body pushes the per-iteration slices here so
    scale_for/token_for resolve to the *current layer's* traced values while
    everything else still falls through to the shared context mappings.
    """
    ctx = _ACTIVE
    if ctx is None:
        yield
        return
    ctx._layer_scales.append(scales)
    ctx._layer_tokens.append(tokens)
    try:
        yield
    finally:
        ctx._layer_scales.pop()
        ctx._layer_tokens.pop()


def drain_aux() -> Dict[str, Any]:
    ctx = _ACTIVE
    return ctx.drain_aux() if ctx is not None else {}


def drain_raw() -> Dict[str, Any]:
    """Drain collected amaxes with raw (unprefixed) keys. Use inside a
    jax.checkpoint-wrapped function so the observations exit the remat trace
    through the function's outputs; pair with re_record() at the call site."""
    ctx = _ACTIVE
    if ctx is None:
        return {}
    out = dict(ctx.collected)
    ctx.collected.clear()
    return out


def re_record(obs: Dict[str, Any]):
    """Re-inject observations drained from an inner (remat/chunk) trace."""
    ctx = _ACTIVE
    if ctx is None:
        return
    for k, v in obs.items():
        ctx.record(k, v)


def token_use_snapshot() -> Optional[Set[str]]:
    """Sites with token uses recorded so far (None when no context)."""
    ctx = _ACTIVE
    return None if ctx is None else set(ctx.token_uses)


def amplify_token_uses(snapshot: Optional[Set[str]], factor: int,
                       exclude: Optional[Set[str]] = None):
    """Multiply the use count of sites first touched since `snapshot` by
    `factor`. Called by apply_stack after lax.scan: the scan body is traced
    once, but its token cotangents accumulate over all `factor` iterations
    at runtime. Sites in `exclude` (per-layer sites whose tokens were
    threaded through scan xs — their cotangents come back stacked, one row
    per iteration, not summed over the group) keep their per-iteration
    count."""
    ctx = _ACTIVE
    if ctx is None or snapshot is None or factor <= 1:
        return
    for k in ctx.token_uses:
        if k not in snapshot and not (exclude and k in exclude):
            ctx.token_uses[k] *= factor


# Convenience constructors ----------------------------------------------------

def discover_context() -> ScaleContext:
    return ScaleContext(mode="discover", scales={}, tokens={})


def collect_context(scales: Mapping[str, Any],
                    tokens: Mapping[str, Any], *,
                    token_channels: int = TOKEN_CHANNELS) -> ScaleContext:
    return ScaleContext(mode="collect", scales=scales, tokens=tokens,
                        token_channels=token_channels)


def calibrate_context(scales: Mapping[str, Any],
                      token_channels: int = TOKEN_CHANNELS) -> ScaleContext:
    return ScaleContext(mode="calibrate", scales=scales, tokens={},
                        token_channels=token_channels)


def frozen_context(scales: Mapping[str, Any]) -> ScaleContext:
    """Frozen-serving context. Values are python floats (ordinary sites) or
    per-layer vectors (lists / arrays emitted by freeze(per_layer=True) for
    scanned-stack sites; coerced to f32 arrays so apply_stack can thread
    them through the scan xs)."""
    out: Dict[str, Any] = {}
    for k, v in scales.items():
        if isinstance(v, (list, tuple, np.ndarray)):
            out[k] = np.asarray(v, np.float32)
        else:
            out[k] = v
    return ScaleContext(mode="frozen", scales=out, tokens={})


def operand_keys(site_key: str, classes) -> Dict[str, str]:
    """Registry keys for one qeinsum call site."""
    ca, cb = _CLASS_LETTER[classes[0]], _CLASS_LETTER[classes[1]]
    return {"a": f"{site_key}#a.{ca}", "b": f"{site_key}#b.{cb}",
            "E": f"{site_key}#E", "G": f"{site_key}#G"}


def attention_keys(site_key: str) -> Dict[str, str]:
    """Registry keys for one fused flash-attention call site: the three
    operands, the two in-kernel forward Q nodes (scores S, probs P — both
    activation class), and the three error-class backward tensors (incoming
    dO plus the in-kernel dP/dS intermediates). The letter grammar matches
    operand_keys, so freeze/serve format rules apply unchanged."""
    return {"q": f"{site_key}#q.A", "k": f"{site_key}#k.A",
            "v": f"{site_key}#v.A", "s": f"{site_key}#qk.A",
            "p": f"{site_key}#p.A", "do": f"{site_key}#E",
            "dp": f"{site_key}#dp.E", "ds": f"{site_key}#ds.E"}


def fused_output_keys(site_key: str, classes) -> Dict[str, str]:
    """Registry keys for the GEMM *outputs* a fused quantize-in-epilogue
    site additionally quantizes: the forward output Y (activation class)
    and — when one operand is an activation — the error-class dgrad output
    flowing back to it ("#da.E" / "#db.E" by operand position)."""
    out = {"y": f"{site_key}#y.A"}
    if classes[0] != "weight":
        out["err"] = f"{site_key}#da.E"
    elif classes[1] != "weight":
        out["err"] = f"{site_key}#db.E"
    return out
