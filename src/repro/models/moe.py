"""Mixture-of-Experts FFN with FP8 expert GEMMs (dbrx, moonshot archs).

Capacity-based top-k routing (GShard/Switch semantics) with *gather*
dispatch: instead of the one-hot (N, E, C) dispatch einsum — whose
materialization is O(N*E*C) and dwarfs memory at 1M tokens — we compute each
pair's position-in-expert by cumsum, scatter token ids into an (E, C) index
table, and gather. Expert GEMMs run through qeinsum with classes
(act, weight), so the paper's FP8 recipe covers expert weights exactly like
dense FFNs. The router stays in f32: top-k boundaries are
precision-critical, the same reasoning the paper uses to keep softmax/tanh
at higher precision.

Sharding: expert dim E maps to the 'model' mesh axis (expert parallelism);
the token gather/scatter across the data axis lowers to all-to-all-style
collectives under pjit.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision_policy import QuantConfig
from repro.core.qlinear import qeinsum
from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, subkey

Array = jax.Array


def init_moe(key, cfg: ModelConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)

    def expert_stack(k, d_in, d_out, scale=1.0):
        return jax.vmap(
            lambda kk: dense_init(kk, d_in, d_out, scale=scale)
        )(jax.random.split(k, e))

    return {
        "router": dense_init(ks[0], d, e).astype(jnp.float32),
        "w_gate": expert_stack(ks[1], d, f),
        "w_up": expert_stack(ks[2], d, f),
        "w_down": expert_stack(ks[3], f, d, scale=0.5),
    }


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(n_tokens * cfg.experts_per_token
                  * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling friendliness


def moe_ffn(params, x: Array, *, cfg: ModelConfig, qcfg: QuantConfig,
            qkey) -> Tuple[Array, dict]:
    """x: (B, S, D) -> (y, aux) with aux = {'lb_loss', 'router_z_loss'}."""
    if cfg.moe_per_sample_dispatch:
        return moe_ffn_per_sample(params, x, cfg=cfg, qcfg=qcfg, qkey=qkey)
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    c = capacity(n, cfg)
    xf = x.reshape(n, d)

    # ---- routing (f32) -----------------------------------------------------
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)           # (N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ---------------------------------------------------------
    me = probs.mean(axis=0)                               # (E,) mean prob
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (n * k))                                    # (E,) token fraction
    lb_loss = e * jnp.sum(me * ce) * cfg.router_aux_coef
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * 1e-3

    # ---- dispatch: position of each (token, slot) pair in its expert --------
    flat_e = expert_idx.reshape(-1)                       # (N*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (N*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot             # pairs before me
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < c
    token_of_pair = jnp.arange(n * k) // k
    dest = flat_e * c + pos_in_e                          # (N*k,) in [0, E*C)
    dest = jnp.where(keep, dest, e * c)                   # dropped -> overflow

    # (E*C + 1,) slot -> token+1 (0 = empty slot)
    slot_token = jnp.zeros((e * c + 1,), jnp.int32).at[dest].set(
        token_of_pair.astype(jnp.int32) + 1)[:e * c]
    slot_valid = slot_token > 0
    xe = xf[jnp.maximum(slot_token - 1, 0)].reshape(e, c, d)
    xe = jnp.where(slot_valid.reshape(e, c, 1), xe, 0).astype(jnp.bfloat16)
    # Expert-parallel: expert dim over 'model' (the token gather above is the
    # all-to-all boundary between data- and expert-parallel regions).
    xe = constrain(xe, "model", None, None)

    # ---- expert GEMMs (FP8, per the paper) ----------------------------------
    g = qeinsum("ecd,edf->ecf", xe, params["w_gate"],
                key=subkey(qkey, 50), cfg=qcfg, site="w_gate")
    u = qeinsum("ecd,edf->ecf", xe, params["w_up"],
                key=subkey(qkey, 51), cfg=qcfg, site="w_up")
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u)
    ye = qeinsum("ecf,efd->ecd", h, params["w_down"],
                 key=subkey(qkey, 52), cfg=qcfg, site="w_down")
    ye = constrain(ye, "model", None, None)

    # ---- combine: gather each pair's expert output, weight, segment-sum -----
    ye_flat = ye.reshape(e * c, d)
    pair_out = ye_flat[jnp.minimum(dest, e * c - 1)]      # (N*k, D)
    w = (gate.reshape(-1) * keep.astype(jnp.float32))[:, None]
    pair_out = pair_out.astype(jnp.float32) * w
    y = jax.ops.segment_sum(pair_out, token_of_pair, num_segments=n)
    return y.reshape(b, s, d).astype(x.dtype), {
        "lb_loss": lb_loss, "router_z_loss": z_loss,
        "dropped_frac": 1.0 - keep.mean(),
    }


def moe_ffn_per_sample(params, x: Array, *, cfg: ModelConfig,
                       qcfg: QuantConfig, qkey) -> Tuple[Array, dict]:
    """Per-sample dispatch: every gather/scatter indexes along the sequence
    dim of ONE batch element, so the batch dim stays data-sharded end to end
    and no cross-shard gather (= SPMD one-hot GEMM) is ever generated.
    Expert buffers are (E, B, C_s, D) with E on 'model', B on dp."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    c = capacity(s, cfg)                                   # per-sample slots

    # ---- routing (f32) -----------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)             # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ----------------------------------------------------------
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (b * s * k))
    lb_loss = e * jnp.sum(me * ce) * cfg.router_aux_coef
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * 1e-3

    # ---- per-sample positions ------------------------------------------------
    flat_e = expert_idx.reshape(b, s * k)                  # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_e = jnp.take_along_axis(
        pos, flat_e[..., None], axis=2)[..., 0]            # (B, S*k)
    keep = pos_in_e < c
    token_of_pair = jnp.broadcast_to(
        (jnp.arange(s * k) // k)[None], (b, s * k))
    dest = jnp.where(keep, flat_e * c + pos_in_e, e * c)   # (B, S*k)

    b_idx = jnp.arange(b)[:, None]
    slot_token = jnp.zeros((b, e * c + 1), jnp.int32).at[
        b_idx, dest].set(token_of_pair.astype(jnp.int32) + 1)[:, :e * c]
    slot_valid = slot_token > 0                            # (B, E*C)
    # per-sample gather along S (local to each dp shard)
    xe = jnp.take_along_axis(
        x, jnp.maximum(slot_token - 1, 0)[..., None],
        axis=1)                                            # (B, E*C, D)
    xe = jnp.where(slot_valid[..., None], xe, 0)
    xe = xe.reshape(b, e, c, d).transpose(1, 0, 2, 3)      # (E, B, C, D)
    xe = constrain(xe.astype(jnp.bfloat16), "model", "dp", None, None)

    # ---- expert GEMMs (FP8, per the paper) -----------------------------------
    g = qeinsum("ebcd,edf->ebcf", xe, params["w_gate"],
                key=subkey(qkey, 50), cfg=qcfg, site="w_gate")
    u = qeinsum("ebcd,edf->ebcf", xe, params["w_up"],
                key=subkey(qkey, 51), cfg=qcfg, site="w_up")
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u)
    ye = qeinsum("ebcf,efd->ebcd", h, params["w_down"],
                 key=subkey(qkey, 52), cfg=qcfg, site="w_down")
    ye = constrain(ye, "model", "dp", None, None)

    # ---- combine (per-sample gather + scatter-add) ----------------------------
    ye_flat = ye.transpose(1, 0, 2, 3).reshape(b, e * c, d)
    pair_out = jnp.take_along_axis(
        ye_flat, jnp.minimum(dest, e * c - 1)[..., None], axis=1)
    w = (gate.reshape(b, s * k) * keep.astype(jnp.float32))[..., None]
    pair_out = pair_out.astype(jnp.float32) * w            # (B, S*k, D)
    y = jnp.zeros((b, s, d), jnp.float32).at[
        b_idx, token_of_pair].add(pair_out)
    return y.astype(x.dtype), {
        "lb_loss": lb_loss, "router_z_loss": z_loss,
        "dropped_frac": 1.0 - keep.mean(),
    }
