"""Model assembly: decoder-only LMs, hybrid/recurrent stacks, enc-dec.

Layers are grouped by the repeating `block_pattern` and scanned with
jax.lax.scan (per-group stacked params => small HLO, fast SPMD compile, true
full-model memory analysis). Non-divisible remainder layers are applied
unrolled after the scan. Per-layer PRNG keys for stochastic rounding are
fold_in'd from a single step key, so the whole model is reproducible from
(params, batch, step_key).

The same forward supports:
  mode="train"    — causal LM (or enc-dec) with loss masks.
  mode="prefill"  — builds KV caches / recurrent states, returns them.
  mode="decode"   — single-token step against caches/states.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision_policy import QuantConfig
from repro.distributed.sharding import constrain
from repro.scaling import context as scale_ctx
from repro.scaling.context import AMAX_PREFIX, HEALTH_PREFIX
from repro.models.attention import attention, init_attention
from repro.models.config import ModelConfig
from repro.models.layers import (apply_norm, embed, init_embedding, init_mlp,
                                 logits_head, make_norm, mlp, subkey)
from repro.models.moe import init_moe, moe_ffn
from repro.models.rglru import init_rglru, init_rglru_state, rglru_block
from repro.models.xlstm import (init_mlstm, init_mlstm_state, init_slstm,
                                init_slstm_state, mlstm_block, slstm_block)

Array = jax.Array


# ---------------------------------------------------------------------------
# single-layer init / apply
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str, *, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": make_norm("rmsnorm", cfg.d_model)}
    if kind in ("attn", "local_attn", "enc_attn"):
        p["attn"] = init_attention(ks[0], cfg)
        if cross:
            p["cross_norm"] = make_norm("rmsnorm", cfg.d_model)
            p["cross_attn"] = init_attention(ks[1], cfg)
        if cfg.n_experts:
            p["norm2"] = make_norm("rmsnorm", cfg.d_model)
            p["moe"] = init_moe(ks[2], cfg)
        elif cfg.d_ff:
            p["norm2"] = make_norm("rmsnorm", cfg.d_model)
            p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    elif kind == "rglru":
        p["rglru"] = init_rglru(ks[0], cfg)
        if cfg.d_ff:
            p["norm2"] = make_norm("rmsnorm", cfg.d_model)
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    elif kind == "mlstm":
        p["mlstm"] = init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = init_slstm(ks[0], cfg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def init_layer_state(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    """Per-layer serving state (KV cache or recurrent state)."""
    from repro.models.attention import init_cache
    if kind in ("attn", "enc_attn"):
        c = init_cache(cfg, batch, max_len, n_layers=1)
        return {"kv": jax.tree_util.tree_map(lambda x: x[0], c)}
    if kind == "local_attn":
        c = init_cache(cfg, batch, max_len, n_layers=1, window=cfg.window)
        return {"kv": jax.tree_util.tree_map(lambda x: x[0], c)}
    if kind == "rglru":
        return {"rec": init_rglru_state(cfg, batch)}
    if kind == "mlstm":
        return {"rec": init_mlstm_state(cfg, batch)}
    if kind == "slstm":
        return {"rec": init_slstm_state(cfg, batch)}
    raise ValueError(kind)


def _merge_aux(dst: Dict[str, Array], src: Dict[str, Array]):
    """Accumulate aux entries: amax observations and health fractions
    combine by max (range/worst-case statistics — remat replay then cannot
    double-count), everything else (aux losses) by sum."""
    for k, v in src.items():
        if k in dst:
            dst[k] = jnp.maximum(dst[k], v) \
                if k.startswith((AMAX_PREFIX, HEALTH_PREFIX)) else dst[k] + v
        else:
            dst[k] = v
    return dst


def apply_layer(p, h: Array, *, kind: str, cfg: ModelConfig,
                qcfg: QuantConfig, qkey, positions: Array, mode: str,
                state=None, enc_out: Optional[Array] = None, page=None):
    """Returns (h, new_state, aux)."""
    aux = {}
    new_state = None
    if cfg.sequence_parallel and mode in ("train", "prefill"):
        # SP: residual stream sequence-sharded over 'model' between blocks;
        # attention/MLP re-gather internally (Megatron-SP dataflow). Also the
        # cure for full-sequence f32 GEMM-output transients at 32k prefill.
        h = constrain(h, "dp", "model", None)
    else:
        h = constrain(h, "dp", None, None)   # keep the residual batch-sharded
    if kind in ("attn", "local_attn", "enc_attn"):
        window = cfg.window if kind == "local_attn" else 0
        attn_mode = {"train": "train", "prefill": "prefill",
                     "decode": "decode", "chunk": "chunk"}[mode]
        if kind == "enc_attn":
            attn_mode = "encode"
        with scale_ctx.scope("attn"):
            a, new_cache = attention(
                p["attn"], apply_norm(p["norm1"], h, eps=cfg.norm_eps),
                cfg=cfg, qcfg=qcfg, qkey=subkey(qkey, 100),
                positions=positions, mode=attn_mode,
                cache_layer=None if state is None else state.get("kv"),
                window=window, page=page)
        h = h + a
        if "cross_attn" in p and enc_out is not None:
            with scale_ctx.scope("cross_attn"):
                ca, _ = attention(
                    p["cross_attn"], apply_norm(p["cross_norm"], h,
                                                eps=cfg.norm_eps),
                    cfg=cfg, qcfg=qcfg, qkey=subkey(qkey, 101),
                    positions=positions, mode="cross", kv_x=enc_out)
            h = h + ca
        if "moe" in p:
            with scale_ctx.scope("moe"):
                f, moe_aux = moe_ffn(
                    p["moe"], apply_norm(p["norm2"], h, eps=cfg.norm_eps),
                    cfg=cfg, qcfg=qcfg, qkey=subkey(qkey, 102))
            aux.update(moe_aux)
            h = h + f
        elif "mlp" in p:
            with scale_ctx.scope("mlp"):
                f = mlp(p["mlp"], apply_norm(p["norm2"], h, eps=cfg.norm_eps),
                        act=cfg.act, qcfg=qcfg, qkey=subkey(qkey, 102))
            h = h + f
        if new_cache is not None:
            new_state = {"kv": new_cache}
    elif kind == "rglru":
        r, rec = rglru_block(p["rglru"],
                             apply_norm(p["norm1"], h, eps=cfg.norm_eps),
                             cfg=cfg, qcfg=qcfg, qkey=subkey(qkey, 103),
                             mode=mode,
                             state=None if state is None else state.get("rec"))
        h = h + r
        if "mlp" in p:
            with scale_ctx.scope("mlp"):
                f = mlp(p["mlp"], apply_norm(p["norm2"], h, eps=cfg.norm_eps),
                        act=cfg.act, qcfg=qcfg, qkey=subkey(qkey, 104))
            h = h + f
        if rec is not None:
            new_state = {"rec": rec}
    elif kind == "mlstm":
        r, rec = mlstm_block(p["mlstm"],
                             apply_norm(p["norm1"], h, eps=cfg.norm_eps),
                             cfg=cfg, qcfg=qcfg, qkey=subkey(qkey, 105),
                             mode=mode,
                             state=None if state is None else state.get("rec"))
        h = h + r
        if rec is not None:
            new_state = {"rec": rec}
    elif kind == "slstm":
        r, rec = slstm_block(p["slstm"],
                             apply_norm(p["norm1"], h, eps=cfg.norm_eps),
                             cfg=cfg, qcfg=qcfg, qkey=subkey(qkey, 106),
                             mode=mode,
                             state=None if state is None else state.get("rec"))
        h = h + r
        if rec is not None:
            new_state = {"rec": rec}
    else:
        raise ValueError(kind)
    # Drain delayed-scaling amax observations INTO this layer's aux: when the
    # stack is scanned, this is the point where the traced observations exit
    # the scan body functionally (via the aux ys).
    aux = _merge_aux(aux, scale_ctx.drain_aux())
    return h, new_state, aux


# ---------------------------------------------------------------------------
# stacks (scan over pattern groups)
# ---------------------------------------------------------------------------

def _split_layers(cfg: ModelConfig, n_layers: int) -> Tuple[int, int]:
    pat = cfg.pattern()
    n_groups = n_layers // len(pat)
    rem = n_layers - n_groups * len(pat)
    return n_groups, rem


def init_stack(key, cfg: ModelConfig, *, n_layers: int, kinds=None,
               cross: bool = False):
    """Params for a stack of layers: scanned groups + unrolled remainder."""
    pat = tuple(kinds) if kinds else cfg.pattern()
    n_groups = n_layers // len(pat)
    rem = n_layers - n_groups * len(pat)
    params: Dict[str, Any] = {}
    if cfg.scan_layers and n_groups > 1:
        for pos, kind in enumerate(pat):
            gkeys = jax.random.split(jax.random.fold_in(key, pos), n_groups)
            params[f"stack_{pos}"] = jax.vmap(
                lambda k: init_layer(k, cfg, kind, cross=cross))(gkeys)
    else:
        for i in range(n_groups * len(pat)):
            kind = pat[i % len(pat)]
            params[f"layer_{i}"] = init_layer(
                jax.random.fold_in(key, 1000 + i), cfg, kind, cross=cross)
    for i in range(rem):
        kind = pat[i % len(pat)]
        params[f"rem_{i}"] = init_layer(
            jax.random.fold_in(key, 2000 + i), cfg, kind, cross=cross)
    return params


def init_stack_state(cfg: ModelConfig, batch: int, max_len: int, *,
                     n_layers: int, kinds=None):
    pat = tuple(kinds) if kinds else cfg.pattern()
    n_groups = n_layers // len(pat)
    rem = n_layers - n_groups * len(pat)
    state: Dict[str, Any] = {}

    def stacked(kind):
        proto = init_layer_state(cfg, kind, batch, max_len)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape).copy()
            if n_groups > 1 else x[None], proto)

    if cfg.scan_layers and n_groups > 1:
        for pos, kind in enumerate(pat):
            state[f"stack_{pos}"] = stacked(kind)
    else:
        for i in range(n_groups * len(pat)):
            state[f"layer_{i}"] = init_layer_state(
                cfg, pat[i % len(pat)], batch, max_len)
    for i in range(rem):
        state[f"rem_{i}"] = init_layer_state(cfg, pat[i % len(pat)],
                                             batch, max_len)
    return state


def init_paged_stack_state(cfg: ModelConfig, n_slots: int, *,
                           n_layers: int, kinds=None):
    """Per-layer paged KV pools for mode='chunk' serving (mirrors
    `init_stack_state`'s stack_/layer_/rem_ structure so the scan threading
    is identical). Paged serving is an attention-stack feature: recurrent
    kinds have no paged representation and are refused."""
    from repro.models.attention import init_paged_pool
    pat = tuple(kinds) if kinds else cfg.pattern()
    bad = [k for k in pat if k not in ("attn", "local_attn")]
    if bad:
        raise ValueError(f"paged serving supports attention stacks only, "
                         f"got layer kinds {bad}")
    n_groups = n_layers // len(pat)
    rem = n_layers - n_groups * len(pat)

    def proto():
        pool = init_paged_pool(cfg, n_slots, n_layers=1)
        return {"kv": jax.tree_util.tree_map(lambda x: x[0], pool)}

    def stacked():
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape).copy()
            if n_groups > 1 else x[None], proto())

    state: Dict[str, Any] = {}
    if cfg.scan_layers and n_groups > 1:
        for pos in range(len(pat)):
            state[f"stack_{pos}"] = stacked()
    else:
        for i in range(n_groups * len(pat)):
            state[f"layer_{i}"] = proto()
    for i in range(rem):
        state[f"rem_{i}"] = proto()
    return state


def apply_stack(params, h: Array, *, cfg: ModelConfig, qcfg: QuantConfig,
                qkey, positions, mode, states=None, enc_out=None,
                n_layers: int, kinds=None, key_base: int = 0, page=None):
    """Returns (h, new_states, aux_sums). `page` (paged serving, mode
    'chunk') is the per-step block-table indirection shared by every layer
    — captured by the scan body as a closure constant, never sliced."""
    pat = tuple(kinds) if kinds else cfg.pattern()
    n_groups = n_layers // len(pat)
    rem = n_layers - n_groups * len(pat)
    aux_total: Dict[str, Array] = {}

    def add_aux(aux):
        _merge_aux(aux_total, aux)

    new_states: Dict[str, Any] = {}
    scanned = cfg.scan_layers and n_groups > 1

    if scanned:
        stacked_params = tuple(params[f"stack_{p}"] for p in range(len(pat)))
        stacked_states = None
        if states is not None:
            stacked_states = tuple(states[f"stack_{p}"]
                                   for p in range(len(pat)))

        # Per-layer scale sites: sites inside the scan body are registered
        # with multiplicity n_groups (scope(..., layers=)), so the registry
        # holds one ScaleState row per LAYER, not per stack position. The
        # stacked (n_groups,) scale vectors and (n_groups, TOKEN_CHANNELS)
        # observation tokens (E/G/fused-dgrad channels) of
        # those sites are threaded through the scan as xs — each iteration
        # reads ITS layer's scale slice (layer_view), and each iteration's
        # observations exit per-layer through the aux ys / stacked token
        # cotangents instead of being max-collapsed over the group.
        # Frozen serving threads per-layer frozen vectors the same way
        # (freeze(per_layer=True)): each scan iteration serves with ITS
        # layer's calibrated constant instead of the max envelope.
        ctx = scale_ctx.current()
        thread_scales: Dict[str, Array] = {}
        thread_tokens: Dict[str, Array] = {}
        if ctx is not None and ctx.mode in ("collect", "calibrate",
                                            "frozen"):
            pfx = ctx.scope_prefix()
            for k, v in ctx.scales.items():
                if k.startswith(pfx) and k[len(pfx):].startswith("stack_") \
                        and getattr(v, "ndim", 0) == 1 \
                        and v.shape[0] == n_groups:
                    thread_scales[k] = jnp.asarray(v, jnp.float32)
            for s, t in ctx.tokens.items():
                if s.startswith(pfx) and s[len(pfx):].startswith("stack_") \
                        and getattr(t, "ndim", 0) == 2 \
                        and t.shape[0] == n_groups:
                    thread_tokens[s] = t

        def body(carry, xs):
            hh, gi = carry
            gp = xs["params"]
            gs = xs.get("states", (None,) * len(pat))
            outs = []
            all_aux = {}
            with scale_ctx.layer_view(xs["scales"], xs["tokens"]):
                for p, kind in enumerate(pat):
                    lkey = None if qkey is None else jax.random.fold_in(
                        qkey, key_base + gi * len(pat) + p)
                    with scale_ctx.scope(f"stack_{p}", layers=n_groups):
                        hh, ns, aux = apply_layer(
                            gp[p], hh, kind=kind, cfg=cfg, qcfg=qcfg,
                            qkey=lkey, positions=positions, mode=mode,
                            state=gs[p], enc_out=enc_out, page=page)
                    outs.append(ns)
                    _merge_aux(all_aux, aux)
            if cfg.sequence_parallel and mode in ("train", "prefill"):
                # Keep the scan carry (= the saved remat residual)
                # sequence-sharded; applied at body END so the stored value
                # is the sharded one.
                hh = constrain(hh, "dp", "model", None)
            ys = (tuple(outs) if states is not None else 0,
                  all_aux if all_aux else {"_": jnp.float32(0)})
            return (hh, gi + 1), ys

        body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") \
            else body
        xs = {"params": stacked_params,
              "scales": thread_scales, "tokens": thread_tokens}
        if states is not None:
            xs["states"] = stacked_states
        # Token-use accounting: the body is traced once but runs n_groups
        # times, so E/G token cotangents of sites inside it accumulate over
        # the whole group — record the multiplicity for normalization.
        # Threaded (per-layer) sites are excluded: their cotangents come
        # back stacked, one row per layer, never summed over the group.
        use_snap = scale_ctx.token_use_snapshot()
        (h, _), (out_states, aux_stack) = jax.lax.scan(body_fn, (h, 0), xs)
        scale_ctx.amplify_token_uses(use_snap, n_groups,
                                     exclude=set(thread_tokens))
        for k, v in aux_stack.items():
            if k == "_":
                continue
            if k.startswith(AMAX_PREFIX):
                # Per-layer threaded sites keep their (n_groups,) amax
                # trajectory; legacy shared sites reduce by max as before.
                red = v if k[len(AMAX_PREFIX):] in thread_scales else v.max()
            elif k.startswith(HEALTH_PREFIX):
                # Health (sat, flush) pairs: keep the per-layer trajectory
                # for threaded sites (n_groups, 2), worst-case max over the
                # group otherwise — always preserving the trailing pair dim.
                red = v if k[len(HEALTH_PREFIX):] in thread_scales \
                    else v.max(axis=0)
            else:
                red = v.sum()   # aux losses sum over the group
            add_aux({k: red})
        if states is not None:
            for p in range(len(pat)):
                new_states[f"stack_{p}"] = out_states[p]
    else:
        for i in range(n_groups * len(pat)):
            kind = pat[i % len(pat)]
            lkey = None if qkey is None else jax.random.fold_in(
                qkey, key_base + i)
            st = None if states is None else states[f"layer_{i}"]
            with scale_ctx.scope(f"layer_{i}"):
                h, ns, aux = apply_layer(params[f"layer_{i}"], h, kind=kind,
                                         cfg=cfg, qcfg=qcfg, qkey=lkey,
                                         positions=positions, mode=mode,
                                         state=st, enc_out=enc_out,
                                         page=page)
            add_aux(aux)
            if states is not None and ns is not None:
                new_states[f"layer_{i}"] = ns

    base = n_groups * len(pat)
    for i in range(rem):
        kind = pat[i % len(pat)]
        lkey = None if qkey is None else jax.random.fold_in(
            qkey, key_base + base + i)
        st = None if states is None else states[f"rem_{i}"]
        with scale_ctx.scope(f"rem_{i}"):
            h, ns, aux = apply_layer(params[f"rem_{i}"], h, kind=kind,
                                     cfg=cfg, qcfg=qcfg, qkey=lkey,
                                     positions=positions, mode=mode,
                                     state=st, enc_out=enc_out, page=page)
        add_aux(aux)
        if states is not None and ns is not None:
            new_states[f"rem_{i}"] = ns
    return h, (new_states if states is not None else None), aux_total


# ---------------------------------------------------------------------------
# full models
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = {
        "embed": init_embedding(ks[0], cfg.padded_vocab_size, cfg.d_model,
                                tie=cfg.tie_embeddings),
        "final_norm": make_norm("rmsnorm", cfg.d_model),
        "decoder": init_stack(ks[1], cfg, n_layers=cfg.n_layers,
                              cross=cfg.is_encoder_decoder),
    }
    if cfg.is_encoder_decoder:
        params["encoder"] = init_stack(ks[2], cfg,
                                       n_layers=cfg.n_encoder_layers,
                                       kinds=("enc_attn",))
        params["enc_norm"] = make_norm("rmsnorm", cfg.d_model)
    return params


def encode(params, enc_inputs: Array, *, cfg: ModelConfig, qkey=None,
           with_aux: bool = False):
    """Encoder forward (seamless): enc_inputs are precomputed frame
    embeddings (B, T, D) from the stub frontend. with_aux=True additionally
    returns the stack aux (amax observations for delayed scaling)."""
    qcfg = cfg.policy.quant
    b, t, _ = enc_inputs.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    h = enc_inputs.astype(jnp.bfloat16)
    with scale_ctx.scope("encoder"):
        h, _, aux = apply_stack(params["encoder"], h, cfg=cfg, qcfg=qcfg,
                                qkey=qkey, positions=positions, mode="train",
                                states=None, n_layers=cfg.n_encoder_layers,
                                kinds=("enc_attn",), key_base=500)
    out = apply_norm(params["enc_norm"], h, eps=cfg.norm_eps)
    return (out, aux) if with_aux else out


def forward(params, tokens: Array, *, cfg: ModelConfig, qkey=None,
            mode: str = "train", states=None, positions=None,
            extra_embeds: Optional[Array] = None,
            enc_out: Optional[Array] = None, last_only: bool = False,
            page=None, gather_rows: Optional[Array] = None):
    """Backbone forward. Returns (logits, new_states, aux).

    extra_embeds: (B, P, D) precomputed patch/frame embeddings prepended to
    the token embeddings (llava anyres stub). enc_out: encoder output for
    enc-dec cross-attention. last_only=True computes logits only for the
    final position (prefill: avoids a (B, S, V) materialization).
    page: block-table indirection for mode='chunk' (paged serving).
    gather_rows: (B,) per-request row index — computes logits only at that
    row of each sequence (the chunk step's last VALID token, which differs
    per request under ragged chunks; mutually exclusive with last_only).
    """
    qcfg = cfg.policy.quant
    head_cfg = cfg.policy.quant_for_layer(is_head=True)
    h = embed(params["embed"], tokens)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    with scale_ctx.scope("decoder"):
        h, new_states, aux = apply_stack(
            params["decoder"], h, cfg=cfg, qcfg=qcfg, qkey=qkey,
            positions=positions, mode=mode, states=states, enc_out=enc_out,
            n_layers=cfg.n_layers, page=page)
    if last_only:
        h = h[:, -1:]
    elif gather_rows is not None:
        h = h[jnp.arange(b), gather_rows.astype(jnp.int32)][:, None]
    h = apply_norm(params["final_norm"], h, eps=cfg.norm_eps)
    logits = logits_head(params["embed"], h, qcfg=head_cfg, qkey=qkey)
    return logits, new_states, aux


def _chunked_ce(params, h, labels, mask, *, cfg, head_cfg, qkey, chunk: int):
    """Sequence-chunked cross-entropy: materializes (B, chunk, V) logits per
    chunk instead of (B, S, V), rematerializing the head GEMM in backward —
    the standard memory lever for large-vocab LM heads."""
    def chunk_loss(hc, lc, mc):
        logits = logits_head(params["embed"], hc, qcfg=head_cfg, qkey=qkey)
        lf = logits.astype(jnp.float32)
        if cfg.padded_vocab_size != cfg.vocab_size:
            col = jnp.arange(lf.shape[-1])
            lf = jnp.where(col < cfg.vocab_size, lf, -1e30)
        logz = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, lc[..., None], axis=-1)[..., 0]
        # Drain inside the remat'd chunk so any head amax observations exit
        # the checkpoint trace functionally (re-recorded by the caller).
        return jnp.sum((logz - gold) * mc), scale_ctx.drain_raw()

    chunk_loss = jax.checkpoint(chunk_loss)
    s = h.shape[1]
    total = jnp.asarray(0.0, jnp.float32)
    for c0 in range(0, s, chunk):
        c1 = min(c0 + chunk, s)
        part, obs = chunk_loss(h[:, c0:c1], labels[:, c0:c1],
                               mask[:, c0:c1])
        scale_ctx.re_record(obs)
        total = total + part
    return total


def lm_loss(params, batch: Dict[str, Array], *, cfg: ModelConfig, qkey=None,
            loss_scale: Optional[Array] = None):
    """Causal-LM (or seq2seq) cross-entropy + MoE aux. Returns (loss, metrics).
    If loss_scale is given the returned loss is scaled (paper Fig. 1b: scale
    before backprop; unscale in the optimizer in f32)."""
    qcfg = cfg.policy.quant
    head_cfg = cfg.policy.quant_for_layer(is_head=True)
    enc_out = None
    enc_aux: Dict[str, Array] = {}
    if cfg.is_encoder_decoder:
        enc_out, enc_aux = encode(params, batch["enc_inputs"], cfg=cfg,
                                  qkey=qkey, with_aux=True)
    tokens = batch["tokens"]
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)

    # backbone (without head)
    h = embed(params["embed"], tokens)
    extra = batch.get("extra_embeds")
    if extra is not None:
        h = jnp.concatenate([extra.astype(h.dtype), h], axis=1)
        labels = jnp.pad(labels, ((0, 0), (extra.shape[1], 0)))
        mask = jnp.pad(mask, ((0, 0), (extra.shape[1], 0)))
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    with scale_ctx.scope("decoder"):
        h, _, aux = apply_stack(params["decoder"], h, cfg=cfg, qcfg=qcfg,
                                qkey=qkey, positions=positions, mode="train",
                                states=None, enc_out=enc_out,
                                n_layers=cfg.n_layers)
    h = apply_norm(params["final_norm"], h, eps=cfg.norm_eps)

    denom = jnp.maximum(mask.sum(), 1.0)
    nll_sum = _chunked_ce(params, h, labels, mask, cfg=cfg,
                          head_cfg=head_cfg, qkey=qkey,
                          chunk=min(s, cfg.attn_chunk_size))
    loss = nll_sum / denom
    aux = _merge_aux(aux, enc_aux)
    aux = _merge_aux(aux, scale_ctx.drain_aux())   # head + any stragglers
    for k, v in aux.items():
        if not k.startswith((AMAX_PREFIX, HEALTH_PREFIX)):
            loss = loss + v   # amax/health entries are observations,
    #                           not aux losses
    metrics = {"nll": nll_sum / denom, **aux}
    if loss_scale is not None:
        loss = loss * loss_scale.astype(loss.dtype)
    return loss, metrics
