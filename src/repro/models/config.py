"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.precision_policy import PAPER_POLICY, PrecisionPolicy


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str = "custom"
    family: str = "dense"   # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None
    qkv_bias: bool = False          # qwen2 keeps QKV bias
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"               # silu | gelu
    rope_theta: float = 10_000.0
    max_seq_len: int = 8192

    # MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # Per-sample dispatch keeps gather/scatter indices local to each batch
    # element (dp shard) — under SPMD a *global* token dispatch lowers to
    # one-hot GEMMs over the full token table (measured: ~300x the useful
    # expert FLOPs at 1M tokens). Global dispatch kept for ablation.
    moe_per_sample_dispatch: bool = True

    # hybrid / ssm -------------------------------------------------------
    # Repeating block pattern; () means all-attention. Entries:
    #  "attn" | "local_attn" | "rglru" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ()
    window: int = 0                  # local-attention window (recurrentgemma)
    lru_dim: int = 0                 # RG-LRU recurrent width (0 => d_model)
    ssm_proj_factor: float = 2.0     # xLSTM block up-projection factor

    # encoder-decoder (seamless) ------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # modality frontends (stubs per assignment) ---------------------------
    frontend: Optional[str] = None   # None | "patch_stub" | "audio_stub"
    n_frontend_tokens: int = 0       # patches / frames provided as embeddings

    # numerics / execution -------------------------------------------------
    policy: PrecisionPolicy = PAPER_POLICY
    remat: bool = True
    scan_layers: bool = True
    # Megatron-style sequence parallelism: shard the residual stream's
    # sequence dim over 'model' between blocks — the saved scan residuals
    # shrink by the TP degree (needed to fit 88-layer x 12k-wide models).
    sequence_parallel: bool = False
    # Attention memory strategy: sequences longer than this use chunked
    # (static-prefix) attention; <= uses a single dense attention. 2048 keeps
    # the per-chunk f32 score tile bounded even at train_4k.
    attn_chunk_threshold: int = 2048
    attn_chunk_size: int = 1024

    # ----------------------------------------------------------------------
    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 16 (Megatron-style) so the embedding
        table / logits head shard over a 16-way model axis; lm_loss masks the
        padded columns."""
        return -(-self.vocab_size // 16) * 16

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern if self.block_pattern else ("attn",)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for all n_layers, repeating the pattern."""
        pat = self.pattern()
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers + head)."""
        d, dh = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        for kind in self.layer_kinds():
            if kind in ("attn", "local_attn"):
                per_layer += d * (self.n_heads * dh + 2 * self.n_kv_heads * dh)
                per_layer += self.n_heads * dh * d
            elif kind == "rglru":
                w = self.lru_dim or self.d_model
                per_layer += 2 * d * w + 3 * w + w * d
            elif kind in ("mlstm", "slstm"):
                inner = int(d * self.ssm_proj_factor)
                per_layer += 2 * d * inner + 4 * inner * inner // 4 + inner * d
            if kind not in ("mlstm", "slstm"):
                if self.n_experts:
                    per_layer += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
                elif self.d_ff:
                    per_layer += 3 * d * self.d_ff
        enc = 0
        if self.is_encoder_decoder:
            enc = self.n_encoder_layers * (4 * d * d + 3 * d * self.d_ff
                                           + 2 * d * d)
        return emb + per_layer + enc
