"""Architecture registry: --arch <id> -> ModelConfig.

Configs live in repro.configs.<id> (dashes -> underscores), each exporting
full() and smoke(). full() is exercised only through the dry-run
(ShapeDtypeStruct, no allocation); smoke() instantiates on CPU in tests.
"""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

ARCHS = [
    "internlm2-20b",
    "mistral-large-123b",
    "qwen2-1.5b",
    "codeqwen1.5-7b",
    "dbrx-132b",
    "moonshot-v1-16b-a3b",
    "llava-next-34b",
    "xlstm-125m",
    "recurrentgemma-9b",
    "seamless-m4t-large-v2",
    # The paper's own workloads:
    "paper-transformer",
    "paper-resnet",
]


def _module(arch: str):
    name = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def list_archs() -> List[str]:
    return list(ARCHS)


def build_config(arch: str, *, smoke: bool = False, **overrides) -> ModelConfig:
    if arch not in ARCHS:
        raise ValueError(f"unknown arch {arch!r}; have {ARCHS}")
    mod = _module(arch)
    cfg = mod.smoke() if smoke else mod.full()
    return cfg.replace(**overrides) if overrides else cfg
