"""xLSTM blocks (sLSTM + mLSTM) with FP8 projections (xlstm-125m arch).

mLSTM (matrix memory, parallelizable): trained in the stabilized *parallel
form* (xLSTM paper §2, Eq. 21-27): per head,

  F_i = sum_{t<=i} log sigmoid(f_t),  D_ij = F_i - F_j + i_j   (j <= i)
  m_i = max_j D_ij,  W_ij = exp(D_ij - m_i) * (q_i . k_j / sqrt(d))
  h_i = (sum_j W_ij v_j) / max(|sum_j W_ij|, 1)

which is an attention-shaped computation -> the QK/PV GEMMs run through the
same FP8 qeinsum path as attention. Decode uses the recurrent form with
(C, n, m) state carried in f32 (exponential gating is range-critical — the
same "sensitive ops stay high precision" rule the paper applies to
tanh/sigmoid).

sLSTM (scalar memory, sequential by construction): lax.scan over time with
block-diagonal recurrent mixing over 4 heads; exponential gating with the
m-stabilizer.

Block layouts follow the xLSTM paper: mLSTM lives inside an up-projection
sandwich (pf=2) with a SiLU gate branch; sLSTM is followed by a gated FFN.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision_policy import QuantConfig
from repro.core.qlinear import qeinsum
from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, dense_init, init_rmsnorm, subkey

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    inner = int(d * cfg.ssm_proj_factor)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, inner),
        "w_gate": dense_init(ks[1], d, inner),
        "wq": dense_init(ks[2], inner, inner),
        "wk": dense_init(ks[3], inner, inner),
        "wv": dense_init(ks[4], inner, inner),
        "w_if": dense_init(ks[5], inner, 2 * cfg.n_heads, scale=0.5),
        "norm": init_rmsnorm(inner),
        "w_down": dense_init(ks[6], inner, d, scale=0.5),
    }


def _mlstm_chunk(q, k, v, i_gate, log_f, state):
    """One chunk of the chunkwise-parallel mLSTM.

    q,k,v: (B,H,c,dh) f32; i_gate/log_f: (B,H,c) f32;
    state: (C (B,H,dh,dh), n (B,H,dh), m (B,H)) carried across chunks.
    Returns (h (B,H,c,dh), new_state). All math f32 + m-stabilized.
    """
    dh = q.shape[-1]
    c = q.shape[2]
    cum_f = jnp.cumsum(log_f, axis=-1)                   # (B,H,c) F_i (local)
    # intra-chunk decay D_ij = F_i - F_j + i_j for j <= i
    d_mat = cum_f[..., :, None] - cum_f[..., None, :] + i_gate[..., None, :]
    causal = jnp.tril(jnp.ones((c, c), bool))
    d_mat = jnp.where(causal, d_mat, -jnp.inf)
    # inter-chunk contribution scale: b_i = F_i + m_prev
    c_prev, n_prev, m_prev = state
    b_vec = cum_f + m_prev[..., None]                    # (B,H,c)
    m_i = jnp.maximum(jnp.max(d_mat, axis=-1), b_vec)    # (B,H,c)
    m_i = jnp.maximum(m_i, 0.0)
    decay = jnp.exp(d_mat - m_i[..., None])              # (B,H,c,c)
    qs = q / (dh ** 0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qs, k) * decay
    inter_w = jnp.exp(b_vec - m_i)                       # (B,H,c)
    num = jnp.einsum("bhqk,bhkd->bhqd", scores, v) \
        + inter_w[..., None] * jnp.einsum("bhvk,bhqk->bhqv", c_prev, qs)
    den = scores.sum(-1) + inter_w * jnp.einsum("bhk,bhqk->bhq", n_prev, qs)
    # Stabilized normalizer (xLSTM Eq. 24): the exp(-m) floor makes h exactly
    # independent of the stabilizer m, so parallel and recurrent forms match.
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
    # end-of-chunk state
    f_tail = cum_f[..., -1:] - cum_f                     # sum_{t>j} log f
    m_new = jnp.maximum(cum_f[..., -1] + m_prev,
                        jnp.max(f_tail + i_gate, axis=-1))
    w_j = jnp.exp(f_tail + i_gate - m_new[..., None])    # (B,H,c)
    carry = jnp.exp(cum_f[..., -1] + m_prev - m_new)     # (B,H)
    c_new = carry[..., None, None] * c_prev \
        + jnp.einsum("bhs,bhsv,bhsk->bhvk", w_j, v, k)
    n_new = carry[..., None] * n_prev + jnp.einsum("bhs,bhsk->bhk", w_j, k)
    return h, (c_new, n_new, m_new)


def _mlstm_parallel(q, k, v, i_gate, f_gate, *, chunk: int = 1024,
                    state: Optional[dict] = None, remat: bool = True):
    """Chunkwise-parallel mLSTM: static python loop over chunks (all FLOPs
    visible to cost analysis; per-chunk transients only). Returns
    (h (B,H,S,dh) f32, final_state dict)."""
    b, h, s, dh = q.shape
    log_f = jax.nn.log_sigmoid(f_gate)
    if state is None:
        st = (jnp.zeros((b, h, dh, dh), jnp.float32),
              jnp.zeros((b, h, dh), jnp.float32),
              jnp.zeros((b, h), jnp.float32))
    else:
        st = (state["C"], state["n"], state["m"])
    step = jax.checkpoint(_mlstm_chunk) if remat else _mlstm_chunk
    outs = []
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    for c0 in range(0, s, chunk):
        c1 = min(c0 + chunk, s)
        hc, st = step(qf[:, :, c0:c1], kf[:, :, c0:c1], vf[:, :, c0:c1],
                      i_gate[..., c0:c1], log_f[..., c0:c1], st)
        outs.append(hc)
    hs = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    return hs, {"C": st[0], "n": st[1], "m": st[2]}


def _mlstm_step(q, k, v, i_raw, f_raw, state):
    """Single decode step. q,k,v: (B,H,dh); gates: (B,H). state: C,n,m."""
    c_prev, n_prev, m_prev = state["C"], state["n"], state["m"]
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m_prev, i_raw)
    i_p = jnp.exp(i_raw - m_new)[..., None]
    f_p = jnp.exp(log_f + m_prev - m_new)[..., None]
    n_new = f_p * n_prev + i_p * k
    c_new = f_p[..., None] * c_prev + i_p[..., None] * \
        (v[..., :, None] * k[..., None, :])             # (B,H,dh,dh)
    dh = q.shape[-1]
    qn = q / (dh ** 0.5)
    num = jnp.einsum("bhvk,bhk->bhv", c_new, qn)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qn)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return h, {"C": c_new, "n": n_new, "m": m_new}


def mlstm_block(params, x: Array, *, cfg: ModelConfig, qcfg: QuantConfig,
                qkey, mode: str = "train",
                state: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    b, s, d = x.shape
    h_heads = cfg.n_heads
    inner = int(d * cfg.ssm_proj_factor)
    dh = inner // h_heads

    up = qeinsum("bsd,di->bsi", x, params["w_up"], key=subkey(qkey, 70),
                 cfg=qcfg, site="w_up")
    gate = qeinsum("bsd,di->bsi", x, params["w_gate"], key=subkey(qkey, 71),
                   cfg=qcfg, site="w_gate")
    q = qeinsum("bsi,ij->bsj", up, params["wq"], key=subkey(qkey, 72),
                cfg=qcfg, site="wq") \
        .reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3)
    k = qeinsum("bsi,ij->bsj", up, params["wk"], key=subkey(qkey, 73),
                cfg=qcfg, site="wk") \
        .reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3)
    v = qeinsum("bsi,ij->bsj", up, params["wv"], key=subkey(qkey, 74),
                cfg=qcfg, site="wv") \
        .reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3)
    gates = qeinsum("bsi,ig->bsg", up, params["w_if"], key=subkey(qkey, 75),
                    cfg=qcfg, site="w_if").astype(jnp.float32)       # (B,S,2H)
    i_raw = gates[..., :h_heads].transpose(0, 2, 1)     # (B,H,S)
    f_raw = gates[..., h_heads:].transpose(0, 2, 1) + 1.0  # forget bias init

    new_state = None
    if mode == "decode":
        assert state is not None
        h, new_state = _mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                   i_raw[..., 0], f_raw[..., 0], state)
        h = h[:, :, None]                                # (B,H,1,dh)
    else:
        h, end_state = _mlstm_parallel(q, k, v, i_raw, f_raw,
                                       chunk=cfg.attn_chunk_size,
                                       remat=cfg.remat)
        if mode == "prefill":
            new_state = end_state

    h = h.transpose(0, 2, 1, 3).reshape(b, s, inner).astype(x.dtype)
    h = apply_norm(params["norm"], h, eps=cfg.norm_eps)
    h = h * jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype)
    return qeinsum("bsi,id->bsd", h, params["w_down"], key=subkey(qkey, 76),
                   cfg=qcfg, site="w_down"), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    inner = int(cfg.d_model * cfg.ssm_proj_factor)
    dh = inner // cfg.n_heads
    h = cfg.n_heads
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    ff = max(8, int(d * 4 / 3))
    return {
        "w_zifo": dense_init(ks[0], d, 4 * d),
        # Block-diagonal recurrent mixing: per-head (dh, 4*dh).
        "r_zifo": (jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
                   / (dh ** 0.5)),
        "norm": init_rmsnorm(d),
        "w_up": dense_init(ks[2], d, ff),
        "w_gate": dense_init(ks[3], d, ff),
        "w_down": dense_init(ks[4], ff, d, scale=0.5),
    }


def _slstm_scan(params, z_in: Array, h0, c0, n0, m0):
    """z_in: (B, S, 4D) pre-activations from the input projection."""
    b, s, d4 = z_in.shape
    d = d4 // 4
    h_heads = params["r_zifo"].shape[0]
    dh = d // h_heads

    def step(carry, zt):
        h_prev, c_prev, n_prev, m_prev = carry
        hh = h_prev.reshape(b, h_heads, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, params["r_zifo"]
                         ).reshape(b, 4 * d)
        zifo = zt.astype(jnp.float32) + rec
        z_r, i_r, f_r, o_r = jnp.split(zifo, 4, axis=-1)
        z = jnp.tanh(z_r)
        o = jax.nn.sigmoid(o_r)
        log_f = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(log_f + m_prev, i_r)
        i_p = jnp.exp(i_r - m_new)
        f_p = jnp.exp(log_f + m_prev - m_new)
        c_new = f_p * c_prev + i_p * z
        n_new = f_p * n_prev + i_p
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0),
                                    z_in.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), (h, c, n, m)


def slstm_block(params, x: Array, *, cfg: ModelConfig, qcfg: QuantConfig,
                qkey, mode: str = "train",
                state: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    b, s, d = x.shape
    z_in = qeinsum("bsd,dz->bsz", x, params["w_zifo"], key=subkey(qkey, 80),
                   cfg=qcfg, site="w_zifo")
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        carry0 = (zeros, zeros, zeros, zeros)
    else:
        carry0 = (state["h"], state["c"], state["n"], state["m"])
    hs, (h, c, n, m) = _slstm_scan(params, z_in, *carry0)
    new_state = {"h": h, "c": c, "n": n, "m": m} \
        if mode in ("prefill", "decode") else None

    y = apply_norm(params["norm"], hs.astype(x.dtype), eps=cfg.norm_eps)
    up = qeinsum("bsd,df->bsf", y, params["w_up"], key=subkey(qkey, 81),
                 cfg=qcfg, site="ff_up")
    gate = qeinsum("bsd,df->bsf", y, params["w_gate"], key=subkey(qkey, 82),
                   cfg=qcfg, site="ff_gate")
    hff = jax.nn.gelu(gate.astype(jnp.float32)).astype(up.dtype) * up
    return qeinsum("bsf,fd->bsd", hff, params["w_down"], key=subkey(qkey, 83),
                   cfg=qcfg, site="ff_down"), new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}
