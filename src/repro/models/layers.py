"""Shared NN layers, FP8-aware. Plain functional style: init_* returns a
param dict, the apply function takes (params, x, ...).

GEMM-bearing layers route through core.qlinear.qeinsum so the paper's W/A/E/G
quantization applies uniformly; norms, softmax and embedding lookups run in
f32/bf16 (the paper keeps non-GEMM ops at >= 16-bit).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision_policy import QuantConfig, dtype_of
from repro.core.qlinear import qeinsum

Array = jax.Array


def subkey(key: Optional[Array], op_id: int) -> Optional[Array]:
    """Deterministic per-op PRNG key (None passes through for RNE configs)."""
    if key is None:
        return None
    return jax.random.fold_in(key, op_id)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float = 1.0,
               dtype=jnp.float32) -> Array:
    std = scale / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out),
                                        jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, *, dtype=jnp.float32) -> Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d),
                                        jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms (f32 math regardless of input dtype)
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x: Array, *, eps: float = 1e-5) -> Array:
    # Statistics in f32 (a per-row scalar), elementwise application in x's
    # dtype — avoids materializing full-sequence f32 copies (and their f32
    # cotangents) of the residual stream.
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x: Array, *, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return ((x - mu.astype(x.dtype)) * inv * params["scale"].astype(x.dtype)
            + params["bias"].astype(x.dtype))


def make_norm(norm_type: str, d: int):
    if norm_type == "rmsnorm":
        return init_rmsnorm(d)
    return init_layernorm(d)


def apply_norm(params, x: Array, *, eps: float = 1e-5) -> Array:
    if "bias" in params:
        return layernorm(params, x, eps=eps)
    return rmsnorm(params, x, eps=eps)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)          # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP / GLU (FP8 GEMMs)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, *, glu: bool = True):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, d_ff),
         "down": dense_init(ks[1], d_ff, d, scale=0.5)}
    if glu:
        p["gate"] = dense_init(ks[2], d, d_ff)
    return p


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def mlp(params, x: Array, *, act: str, qcfg: QuantConfig,
        qkey: Optional[Array]) -> Array:
    """(Gated) MLP with all three GEMMs in FP8."""
    a = activation(act)
    up = qeinsum("bsd,df->bsf", x, params["up"], key=subkey(qkey, 1),
                 cfg=qcfg, site="up")
    if "gate" in params:
        gate = qeinsum("bsd,df->bsf", x, params["gate"],
                       key=subkey(qkey, 2), cfg=qcfg, site="gate")
        h = a(gate.astype(jnp.float32)).astype(up.dtype) * up
    else:
        h = a(up.astype(jnp.float32)).astype(up.dtype)
    return qeinsum("bsf,fd->bsd", h, params["down"],
                   key=subkey(qkey, 3), cfg=qcfg, site="down")


# ---------------------------------------------------------------------------
# embedding + logits head (16-bit per the paper's first/last-layer rule)
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, *, tie: bool):
    ks = jax.random.split(key, 2)
    p = {"table": embed_init(ks[0], vocab, d)}
    if not tie:
        p["head"] = dense_init(ks[1], d, vocab, scale=0.5)
    return p


def embed(params, tokens: Array, *, dtype=jnp.bfloat16) -> Array:
    return params["table"].astype(dtype)[tokens]


def logits_head(params, x: Array, *, qcfg: QuantConfig,
                qkey: Optional[Array]) -> Array:
    """Final projection. qcfg here is usually the *baseline* (16-bit) config
    via PrecisionPolicy.quant_for_layer(is_head=True)."""
    if "head" in params:
        w = params["head"]
    else:
        w = params["table"].T  # tied embeddings
    return qeinsum("bsd,dv->bsv", x, w, key=subkey(qkey, 4), cfg=qcfg,
                   site="head")
