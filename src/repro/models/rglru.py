"""RG-LRU recurrent block (Griffin / RecurrentGemma) with FP8 projections.

Block layout (Griffin Fig. 2): two branches from the input —
  left:  W_x -> causal depthwise conv (width 4) -> RG-LRU
  right: W_g -> GeLU
merged by elementwise product, then W_o back to d_model.

RG-LRU recurrence (f32; the a_t^(c*sigma) powers underflow in fp8, so state
math stays full precision — same principle as the paper keeping tanh/sigmoid
at >= 16-bit):

  r_t = sigmoid(W_a xi_t);  i_t = sigmoid(W_i xi_t)
  a_t = exp(-c * softplus(Lambda) * r_t),   c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

Training/prefill uses jax.lax.associative_scan — the TPU-native O(log S)
evaluation that also keeps every FLOP visible to the roofline cost analysis
(a sequential lax.scan body would be counted once by XLA's cost model).
Decode is the single-step recurrence with carried (h, conv window) state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision_policy import QuantConfig
from repro.core.qlinear import qeinsum
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, subkey

Array = jax.Array

_C = 8.0
_CONV_W = 4


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_dim or d
    ks = jax.random.split(key, 6)
    # Lambda init so a in [0.9, 0.999] at r=1 (Griffin appendix).
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "wx": dense_init(ks[0], d, w),
        "wg": dense_init(ks[1], d, w),
        "wa": dense_init(ks[2], w, w, scale=0.5),
        "wi": dense_init(ks[3], w, w, scale=0.5),
        "lam": lam,
        "conv": (jax.random.normal(ks[5], (_CONV_W, w), jnp.float32)
                 * (1.0 / _CONV_W)),
        "wo": dense_init(jax.random.fold_in(key, 9), w, d, scale=0.5),
    }


def _causal_conv(x: Array, kernel: Array,
                 state: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv width 4. x: (B,S,W); state: (B,3,W) history."""
    b, s, w = x.shape
    hist = jnp.zeros((b, _CONV_W - 1, w), x.dtype) if state is None \
        else state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)          # (B, S+3, W)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(_CONV_W):
        out = out + xp[:, i:i + s].astype(jnp.float32) * kernel[i]
    new_state = xp[:, -( _CONV_W - 1):]
    return out.astype(x.dtype), new_state


def _rglru_scan(xi: Array, a: Array) -> Array:
    """Parallel evaluation of h_t = a_t h_{t-1} + b_t via associative scan.
    xi: (B,S,W) the gated input sqrt(1-a^2)*i*x; a: (B,S,W) decay."""
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2
    _, h = jax.lax.associative_scan(combine, (a, xi), axis=1)
    return h


def rglru_block(params, x: Array, *, cfg: ModelConfig, qcfg: QuantConfig,
                qkey, mode: str = "train",
                state: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    """x: (B,S,D) -> (y, new_state). state = {'h': (B,W), 'conv': (B,3,W)}."""
    xi = qeinsum("bsd,dw->bsw", x, params["wx"], key=subkey(qkey, 60),
                 cfg=qcfg, site="wx")
    gate = qeinsum("bsd,dw->bsw", x, params["wg"], key=subkey(qkey, 61),
                   cfg=qcfg, site="wg")
    conv_state = None if state is None else state.get("conv")
    xi, new_conv = _causal_conv(xi, params["conv"], conv_state)

    r = jax.nn.sigmoid(qeinsum("bsw,wv->bsv", xi, params["wa"],
                               key=subkey(qkey, 62), cfg=qcfg, site="wa")
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(qeinsum("bsw,wv->bsv", xi, params["wi"],
                               key=subkey(qkey, 63), cfg=qcfg, site="wi")
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r    # (B,S,W) f32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i \
        * xi.astype(jnp.float32)

    new_state = None
    if mode == "decode":
        assert state is not None
        h_prev = state["h"]                             # (B, W) f32
        h = a[:, 0] * h_prev + gated[:, 0]
        hs = h[:, None]
        new_state = {"h": h, "conv": new_conv}
    else:
        hs = _rglru_scan(gated, a)                      # (B,S,W)
        if mode == "prefill":
            new_state = {"h": hs[:, -1], "conv": new_conv}

    merged = hs.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)
                                              ).astype(x.dtype)
    y = qeinsum("bsw,wd->bsd", merged, params["wo"], key=subkey(qkey, 64),
                cfg=qcfg, site="wo")
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int):
    w = cfg.lru_dim or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_W - 1, w), jnp.bfloat16)}
