"""Reduced ResNet (the paper's convnet workload family) with FP8 convs.

Used by the paper-reproduction benchmarks: Fig. 2a (loss-scale sweep),
Fig. 3/4 (RNE vs stochastic rounding generalization), Table 2 (FP8 vs FP32
accuracy). CIFAR-scale so it trains on CPU in minutes; the mechanisms the
paper ablates (gradient underflow, rounding-induced L2 growth) reproduce at
this scale.

Per paper §4: the first conv and the final FC stay at 16-bit precision; all
other convs/GEMMs run the FP8 recipe. BatchNorm is replaced by GroupNorm-
style per-channel scale+shift computed in f32 (batch statistics in f32 — the
paper keeps non-GEMM ops at high precision; GN avoids cross-device batch
stats in data-parallel training).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision_policy import (BASELINE, PAPER_FP8, PrecisionPolicy,
                                         QuantConfig)
from repro.core.qconv import conv_init, qconv2d
from repro.models.layers import dense_init, subkey

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    depth_per_stage: Tuple[int, ...] = (2, 2, 2)
    widths: Tuple[int, ...] = (32, 64, 128)
    n_classes: int = 10
    quant: QuantConfig = PAPER_FP8
    weight_decay: float = 5e-4


def _groupnorm(params, x, *, groups: int = 8, eps: float = 1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = xf.var(axis=(1, 2, 4), keepdims=True)
    xn = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(b, h, w, c)
    return (xn * params["scale"] + params["bias"]).astype(x.dtype)


def _init_gn(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def init_resnet(key, cfg: ResNetConfig):
    ks = iter(jax.random.split(key, 256))
    params = {"stem": conv_init(next(ks), 3, 3, 3, cfg.widths[0]),
              "stem_gn": _init_gn(cfg.widths[0])}
    c_prev = cfg.widths[0]
    for s, (depth, c) in enumerate(zip(cfg.depth_per_stage, cfg.widths)):
        for i in range(depth):
            blk = {
                "conv1": conv_init(next(ks), 3, 3, c_prev if i == 0 else c, c),
                "gn1": _init_gn(c),
                "conv2": conv_init(next(ks), 3, 3, c, c),
                "gn2": _init_gn(c),
            }
            if i == 0 and c_prev != c:
                blk["proj"] = conv_init(next(ks), 1, 1, c_prev, c)
            params[f"s{s}_b{i}"] = blk
        c_prev = c
    params["head"] = dense_init(next(ks), c_prev, cfg.n_classes)
    return params


def resnet_forward(params, x: Array, *, cfg: ResNetConfig,
                   qkey: Optional[Array] = None) -> Array:
    """x: (B, H, W, 3) -> logits (B, n_classes)."""
    q = cfg.quant
    if qkey is None and q.needs_key:
        q = q.eval_mode()   # deterministic eval: RNE, saturating
    # First conv at 16-bit (paper §4).
    h = qconv2d(x.astype(jnp.bfloat16), params["stem"], cfg=BASELINE)
    h = jax.nn.relu(_groupnorm(params["stem_gn"], h))
    op = 0
    for s, (depth, c) in enumerate(zip(cfg.depth_per_stage, cfg.widths)):
        for i in range(depth):
            blk = params[f"s{s}_b{i}"]
            stride = (2, 2) if (i == 0 and s > 0) else (1, 1)
            r = qconv2d(h, blk["conv1"], stride=stride,
                        key=subkey(qkey, op), cfg=q)
            op += 1
            r = jax.nn.relu(_groupnorm(blk["gn1"], r))
            r = qconv2d(r, blk["conv2"], key=subkey(qkey, op), cfg=q)
            op += 1
            r = _groupnorm(blk["gn2"], r)
            sc = h
            if "proj" in blk:
                sc = qconv2d(h, blk["proj"], stride=stride,
                             key=subkey(qkey, op), cfg=q)
                op += 1
            elif stride != (1, 1):
                sc = h[:, ::2, ::2]
            h = jax.nn.relu(sc.astype(jnp.float32)
                            + r.astype(jnp.float32)).astype(jnp.bfloat16)
    pooled = h.astype(jnp.float32).mean(axis=(1, 2))
    # Last FC at 16-bit (paper §4).
    logits = pooled.astype(jnp.bfloat16) @ params["head"].astype(jnp.bfloat16)
    return logits.astype(jnp.float32)


def resnet_loss(params, batch, *, cfg: ResNetConfig, qkey=None,
                loss_scale: Optional[Array] = None,
                include_l2: bool = True):
    """Cross-entropy + paper Eq. (1) L2 loss. Returns (loss, metrics)."""
    logits = resnet_forward(params, batch["image"], cfg=cfg, qkey=qkey)
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    l2 = jnp.asarray(0.0, jnp.float32)
    if include_l2:
        from repro.optim import l2_regularization_loss
        l2 = l2_regularization_loss(params, cfg.weight_decay)
    loss = nll + l2
    acc = (logits.argmax(-1) == labels).mean()
    if loss_scale is not None:
        loss = loss * loss_scale.astype(loss.dtype)
    return loss, {"nll": nll, "l2_loss": l2, "accuracy": acc}
