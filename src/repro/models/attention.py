"""GQA attention with FP8 GEMMs, long-context chunking, and KV caches.

Memory strategy for long sequences (prefill_32k and train_4k cells): queries
are processed in chunks of `cfg.attn_chunk_size`; each q-chunk attends to its
*static* causal prefix (a python-level slice, so shapes stay static and the
compiled FLOPs are the true triangular count, not the masked-full-matrix
2x overcount). The per-chunk score tile (cq x prefix) is the only transient.

Local (sliding-window) attention slices the static band instead of the full
prefix. Decode uses a ring-buffer cache of `window` slots for local layers —
softmax is permutation-invariant over KV slots, so ring order is fine as long
as RoPE is applied before caching; slot validity is tracked by absolute
position, and entries always live at slot `pos % capacity` (prefill included)
so appends evict exactly the oldest position. The permutation-invariance
claim holds through the fused `fp8_sdpa_decode` kernel too — out-of-order
(wrapped) slots are handled by the validity mask, for FP8 and bf16 caches
alike (locked by TestRingDecode in tests/test_fp8_attention.py).

KV caches can be stored in FP8 e5m2 (beyond-paper; halves the decode
bandwidth, which the roofline shows is the decode bottleneck).

Under a Pallas backend with delayed scaling (and the
`QuantConfig.fuse_attention` knob on), the attention inner products route
through the fused FP8 flash kernel (core.qattention / kernels.fp8_attention)
instead of the `_sdpa` composition below: the score matrix and softmax probs
are quantized inside the kernel with fused amax observation and never
materialized in HBM, GQA grouping happens in the kernel's block index maps
(no `_repeat_kv` copies), and the decode path consumes FP8 KV-cache payloads
directly with their frozen scales.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision_policy import QuantConfig
from repro.core.qattention import (fp8_sdpa, fp8_sdpa_chunk, fp8_sdpa_decode,
                                   fuse_attention)
from repro.core.qlinear import qeinsum
from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, subkey
from repro.scaling import context as scale_ctx

Array = jax.Array


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh),
        "wk": dense_init(ks[1], d, hkv * dh),
        "wv": dense_init(ks[2], d, hkv * dh),
        "wo": dense_init(ks[3], h * dh, d, scale=0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# KV cache (optionally FP8)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               n_layers: Optional[int] = None, window: int = 0):
    """Stacked-over-layers cache pytree. window>0 => ring buffer of that size."""
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    length = min(window, max_len) if window else max_len
    l = cfg.n_layers if n_layers is None else n_layers
    fmt = cfg.policy.kv_cache_format
    dtype = {"e5m2": jnp.float8_e5m2, "e4m3": jnp.float8_e4m3fn,
             None: jnp.bfloat16}[fmt]
    return {
        "k": jnp.zeros((l, batch, length, hkv, dh), dtype),
        "v": jnp.zeros((l, batch, length, hkv, dh), dtype),
        # Absolute position stored in each slot; -1 = empty.
        "slot_pos": jnp.full((l, batch, length), -1, jnp.int32),
        "length": jnp.zeros((l, batch), jnp.int32),
    }


def init_paged_pool(cfg: ModelConfig, n_slots: int, *,
                    n_layers: Optional[int] = None):
    """Flat paged KV pool: `n_slots` token slots per layer, carved into
    pages by the serving-side allocator (serve/paging.py). Slot 0 lives on
    the reserved trash page — chunk rows past `n_valid` write value 0
    there, never to a live page."""
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    l = cfg.n_layers if n_layers is None else n_layers
    fmt = cfg.policy.kv_cache_format
    dtype = {"e5m2": jnp.float8_e5m2, "e4m3": jnp.float8_e4m3fn,
             None: jnp.bfloat16}[fmt]
    return {
        "k": jnp.zeros((l, n_slots, hkv, dh), dtype),
        "v": jnp.zeros((l, n_slots, hkv, dh), dtype),
    }


def _store_dtype(cache_layer):
    return cache_layer["k"].dtype


def _to_cache_dtype(x: Array, dtype, scale: float = 1.0) -> Array:
    if dtype in (jnp.float8_e5m2, jnp.float8_e4m3fn):
        # RNE, saturating — inference-side quantization (no SR at eval).
        # `scale` is a calibrated frozen per-site scale (python float, burned
        # in as a constant) mapping the KV range onto the FP8 grid.
        lim = 57344.0 if dtype == jnp.float8_e5m2 else 448.0
        xs = x.astype(jnp.float32) * (1.0 / scale)
        return jnp.clip(xs, -lim, lim).astype(dtype)
    return x.astype(dtype)


def _from_cache_dtype(x: Array, dtype=jnp.bfloat16, scale=1.0) -> Array:
    # `scale` may be a traced per-layer slice (frozen per-layer serving of a
    # scanned stack), so only the static-unit case short-circuits.
    if isinstance(scale, (int, float)) and scale == 1.0:
        return x.astype(dtype)
    return (x.astype(jnp.float32) * scale).astype(dtype)


def _kv_scales(cfg: ModelConfig) -> Tuple[float, float]:
    """Frozen per-site KV-cache scales from the active scaling context
    (1.0 outside frozen serving).

    Frozen serving with an FP8 KV cache REFUSES to fall back to unit scales
    when the cache sites were never calibrated: a silently wrong constant
    would mis-scale every cached key/value (the scale is burned into the
    jitted program), which surfaces only as degraded generations."""
    ctx = scale_ctx.current()
    if ctx is None or cfg.policy.kv_cache_format is None:
        return 1.0, 1.0
    kk = ctx.site_key("kv/k") + "#A"
    vk = ctx.site_key("kv/v") + "#A"
    if ctx.mode == "frozen":
        missing = [key for key in (kk, vk) if not ctx.has_scale(key)]
        if missing:
            raise ValueError(
                f"frozen serving with kv_cache_format="
                f"{cfg.policy.kv_cache_format!r} but the KV-cache site(s) "
                f"{missing} have no calibrated scale — the cache would be "
                "quantized with a silent unit scale; calibrate with the FP8 "
                "KV cache enabled (the kv/* sites are observed during "
                "calibration) or serve without frozen scales")
    return (ctx.frozen_scale(kk), ctx.frozen_scale(vk))


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _qk_scores(q: Array, k: Array, qcfg: QuantConfig, qkey, op: int) -> Array:
    """q: (B,H,Q,dh) x k: (B,H,K,dh) -> (B,H,Q,K) f32."""
    if qcfg.enabled and qcfg.quantize_attention:
        s = qeinsum("bhqd,bhkd->bhqk", q, k, key=subkey(qkey, op), cfg=qcfg,
                    classes=("act", "act"), site="qk")
    else:
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.bfloat16),
                       k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    return s.astype(jnp.float32)


def _pv(probs: Array, v: Array, qcfg: QuantConfig, qkey, op: int) -> Array:
    if qcfg.enabled and qcfg.quantize_attention:
        return qeinsum("bhqk,bhkd->bhqd", probs.astype(jnp.bfloat16), v,
                       key=subkey(qkey, op), cfg=qcfg, classes=("act", "act"),
                       site="pv")
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(jnp.bfloat16),
                      v.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32).astype(jnp.bfloat16)


def _repeat_kv(k: Array, groups: int) -> Array:
    """(B,Hkv,S,dh) -> (B,Hkv*groups,S,dh) for GQA."""
    if groups == 1:
        return k
    b, hkv, s, dh = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, hkv, groups, s, dh)
                            ).reshape(b, hkv * groups, s, dh)


def _sdpa(q, k, v, mask, scale, qcfg, qkey, op_base) -> Array:
    """Dense scaled-dot-product attention on (B,H,S,dh) tensors; f32 softmax."""
    s = _qk_scores(q, k, qcfg, qkey, op_base) * scale
    if mask is not None:
        s = jnp.where(mask, s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    return _pv(p, v, qcfg, qkey, op_base + 1)


def chunked_causal_attention(q, k, v, *, chunk: int, scale: float,
                             qcfg: QuantConfig, qkey, window: int = 0,
                             remat: bool = True) -> Array:
    """Causal attention over (B,H,S,dh) with static-prefix chunking.

    Python loop over q chunks; chunk i attends k/v[: (i+1)*chunk] (or the
    static window band). Shapes are static per chunk; compiled FLOPs equal the
    true triangular cost. The per-chunk compute is rematerialized in backward.
    """
    b, h, s, dh = q.shape
    n_chunks = max(1, (s + chunk - 1) // chunk)

    def one_chunk(qc, kc, vc, mask):
        o = _sdpa(qc, kc, vc, mask, scale, qcfg, qkey, 10)
        # Drain amax observations inside the remat trace; re-recorded below.
        return o, scale_ctx.drain_raw()

    if remat:
        one_chunk = jax.checkpoint(one_chunk)

    outs = []
    for i in range(n_chunks):
        q0, q1 = i * chunk, min((i + 1) * chunk, s)
        k0 = 0 if not window else max(0, q0 - window + 1)
        k1 = q1
        qc = q[:, :, q0:q1]
        kc, vc = k[:, :, k0:k1], v[:, :, k0:k1]
        qpos = jnp.arange(q0, q1)[:, None]
        kpos = jnp.arange(k0, k1)[None, :]
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        o, obs = one_chunk(qc, kc, vc, mask[None, None])
        scale_ctx.re_record(obs)
        outs.append(o)
    return jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]


def full_bidirectional_attention(q, k, v, *, scale, qcfg, qkey,
                                 kv_mask=None) -> Array:
    mask = None if kv_mask is None else kv_mask[:, None, None, :]
    return _sdpa(q, k, v, mask, scale, qcfg, qkey, 20)


# ---------------------------------------------------------------------------
# attention block (projections + modes)
# ---------------------------------------------------------------------------

def attention(params, x: Array, *, cfg: ModelConfig, qcfg: QuantConfig,
              qkey, positions: Array, mode: str = "train",
              cache_layer=None, kv_x: Optional[Array] = None,
              window: int = 0,
              page: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    """Full attention block.

    modes:
      train   — causal self-attention, no cache.
      encode  — bidirectional self-attention (encoder), no cache.
      cross   — queries from x, keys/values from kv_x (no cache, train) .
      prefill — causal; writes the cache and returns it.
      decode  — single-token step against cache_layer.
      chunk   — T consecutive tokens per request against a PAGED cache
                (cache_layer = flat slot pool from `init_paged_pool`);
                `page` carries the per-step block-table indirection
                (`write_slots`/`read_slots`/`slot_pos`/`chunk_pos`, shared
                by every layer). One chunk step subsumes chunked prefill
                AND decode (T=1): K/V are scattered to their pool slots
                first, then the gathered cache — in-chunk tokens included
                — is attended under the position mask, so in-chunk
                causality emerges from `slot_pos <= qpos` with no separate
                causal mask.
    Returns (y, new_cache_layer) (new cache is None unless
    prefill/decode/chunk).
    """
    b, sq, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / (dh ** 0.5)

    q = qeinsum("bsd,dn->bsn", x, params["wq"], key=subkey(qkey, 0), cfg=qcfg,
                site="wq")
    src = kv_x if kv_x is not None else x
    k = qeinsum("bsd,dn->bsn", src, params["wk"], key=subkey(qkey, 1),
                cfg=qcfg, site="wk")
    v = qeinsum("bsd,dn->bsn", src, params["wv"], key=subkey(qkey, 2),
                cfg=qcfg, site="wv")
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)

    q = q.reshape(b, sq, h, dh)
    k = k.reshape(b, -1, hkv, dh)
    v = v.reshape(b, -1, hkv, dh)

    if kv_x is None and mode != "cross":
        q = apply_rope(q, positions, cfg.rope_theta)
        if mode != "decode":
            k = apply_rope(k, positions, cfg.rope_theta)
        else:
            k = apply_rope(k, positions, cfg.rope_theta)  # single position

    # KV-cache range observation (calibration only — this full-tensor reduce
    # is deliberately kept out of the training hot path) and frozen-scale
    # lookup for calibrated FP8 KV serving.
    ctx = scale_ctx.current()
    if ctx is not None and cfg.policy.kv_cache_format is not None:
        kk, vk = ctx.site_key("kv/k") + "#A", ctx.site_key("kv/v") + "#A"
        ctx.register(kk)
        ctx.register(vk)
        if ctx.mode == "calibrate":
            ctx.record(kk, jnp.max(jnp.abs(k.astype(jnp.float32))))
            ctx.record(vk, jnp.max(jnp.abs(v.astype(jnp.float32))))
    k_scale, v_scale = _kv_scales(cfg)

    # (B, S, H, dh) -> (B, H, S, dh); shard heads over 'model' (falls back to
    # replication when H does not divide the axis, e.g. qwen2's 12 heads).
    qt = constrain(q.transpose(0, 2, 1, 3), "dp", "model", None, None)
    new_cache = None

    fused = fuse_attention(qcfg)
    if mode in ("train", "encode", "cross", "prefill"):
        if fused:
            # Fused FP8 flash path: K/V stay UNREPEATED (B, Hkv, S, dh) —
            # GQA grouping happens in the kernel's block index maps — and
            # the kernel chunks queries internally (no python q-chunk loop,
            # no remat: backward recomputes from the FP8 residuals). With
            # the streamed-KV grid this IS the long-sequence path: VMEM
            # holds one (attn_block_q, attn_block_kv) working set whatever
            # the context length, and sliding-window layers skip their
            # fully-masked kv stripes — the python chunked loop below only
            # serves the unfused fallback.
            kt = constrain(k.transpose(0, 2, 1, 3), "dp", "model", None,
                           None)
            vt = constrain(v.transpose(0, 2, 1, 3), "dp", "model", None,
                           None)
            mm = "full" if mode in ("encode", "cross") else "causal"
            o = fp8_sdpa(qt, kt, vt, key=subkey(qkey, 10), cfg=qcfg,
                         sm_scale=scale, mask_mode=mm, window=window,
                         site="sdpa")
        else:
            kt = _repeat_kv(k.transpose(0, 2, 1, 3), h // hkv)
            vt = _repeat_kv(v.transpose(0, 2, 1, 3), h // hkv)
            kt = constrain(kt, "dp", "model", None, None)
            vt = constrain(vt, "dp", "model", None, None)
            if mode in ("encode", "cross"):
                o = full_bidirectional_attention(qt, kt, vt, scale=scale,
                                                 qcfg=qcfg, qkey=qkey)
            else:
                use_chunks = sq > cfg.attn_chunk_threshold or window
                if use_chunks:
                    o = chunked_causal_attention(
                        qt, kt, vt, chunk=min(cfg.attn_chunk_size, sq),
                        scale=scale, qcfg=qcfg, qkey=qkey, window=window,
                        remat=cfg.remat)
                else:
                    qpos = jnp.arange(sq)
                    mask = (qpos[None, :, None]
                            >= qpos[None, None, :])[:, None]
                    o = _sdpa(qt, kt, vt, mask, scale, qcfg, qkey, 30)
        if mode == "prefill" and cache_layer is not None:
            new_cache = _prefill_cache(cache_layer, k, v, positions,
                                       k_scale=k_scale, v_scale=v_scale)
    elif mode == "decode":
        assert cache_layer is not None
        new_cache = _append_cache(cache_layer, k, v, positions,
                                  k_scale=k_scale, v_scale=v_scale)
        # Validity: slot filled and within window (if any).
        slot_pos = new_cache["slot_pos"]            # (B, C)
        cur = positions[:, -1:]                     # (B, 1)
        valid = (slot_pos >= 0) & (slot_pos <= cur)
        if window:
            valid &= slot_pos > cur - window
        if fused:
            # Fused decode: FP8 cache payloads feed the kernel directly
            # with their frozen scales (no dequantize -> requantize round
            # trip); bf16 caches are quantized inside fp8_sdpa_decode.
            kt = constrain(new_cache["k"].transpose(0, 2, 1, 3),
                           "dp", "model", None, None)
            vt = constrain(new_cache["v"].transpose(0, 2, 1, 3),
                           "dp", "model", None, None)
            o = fp8_sdpa_decode(qt, kt, vt, valid, cfg=qcfg,
                                sm_scale=scale, key=subkey(qkey, 40),
                                k_cache_scale=k_scale,
                                v_cache_scale=v_scale, site="sdpa")
        else:
            dt = jnp.bfloat16
            kt = _from_cache_dtype(new_cache["k"], dt,
                                   k_scale).transpose(0, 2, 1, 3)
            vt = _from_cache_dtype(new_cache["v"], dt,
                                   v_scale).transpose(0, 2, 1, 3)
            kt = constrain(_repeat_kv(kt, h // hkv), "dp", "model", None,
                           None)
            vt = constrain(_repeat_kv(vt, h // hkv), "dp", "model", None,
                           None)
            o = _sdpa(qt, kt, vt, valid[:, None, None, :], scale, qcfg,
                      qkey, 40)
    elif mode == "chunk":
        assert cache_layer is not None and page is not None
        dtype = _store_dtype(cache_layer)
        rows = jnp.arange(sq)[None, :]               # (1, T)
        row_ok = rows < page["chunk_pos"][:, 1:2]    # (B, T)
        # Scatter the chunk's K/V into their pool slots. Rows past n_valid
        # all target slot 0 (the reserved trash page) with value 0, so the
        # duplicate scatter writes agree and write order is irrelevant.
        kq = _to_cache_dtype(jnp.where(row_ok[..., None, None], k, 0),
                             dtype, k_scale)
        vq = _to_cache_dtype(jnp.where(row_ok[..., None, None], v, 0),
                             dtype, v_scale)
        wslots = jnp.where(row_ok, page["write_slots"], 0).reshape(-1)
        new_k = cache_layer["k"].at[wslots].set(kq.reshape(b * sq, hkv, dh))
        new_v = cache_layer["v"].at[wslots].set(vq.reshape(b * sq, hkv, dh))
        new_cache = {"k": new_k, "v": new_v}
        # Gather the block-table-ordered view: gathered column i holds
        # logical position i (read_slots is built that way), so the
        # position mask reproduces the contiguous-cache layout exactly.
        kt = new_k[page["read_slots"]]               # (B, C, Hkv, dh)
        vt = new_v[page["read_slots"]]
        slot_pos = page["slot_pos"]                  # (B, C), -1 = hole
        if fused:
            kt = constrain(kt.transpose(0, 2, 1, 3), "dp", "model", None,
                           None)
            vt = constrain(vt.transpose(0, 2, 1, 3), "dp", "model", None,
                           None)
            o = fp8_sdpa_chunk(qt, kt, vt, slot_pos, page["chunk_pos"],
                               cfg=qcfg, sm_scale=scale, window=window,
                               key=subkey(qkey, 40), k_cache_scale=k_scale,
                               v_cache_scale=v_scale, site="sdpa")
        else:
            dt = jnp.bfloat16
            kt = _from_cache_dtype(kt, dt, k_scale).transpose(0, 2, 1, 3)
            vt = _from_cache_dtype(vt, dt, v_scale).transpose(0, 2, 1, 3)
            kt = constrain(_repeat_kv(kt, h // hkv), "dp", "model", None,
                           None)
            vt = constrain(_repeat_kv(vt, h // hkv), "dp", "model", None,
                           None)
            qpos = jnp.where(row_ok, page["chunk_pos"][:, 0:1] + rows, -1)
            mask = ((slot_pos[:, None, :] >= 0)
                    & (slot_pos[:, None, :] <= qpos[:, :, None]))
            if window:
                mask &= slot_pos[:, None, :] > qpos[:, :, None] - window
            o = _sdpa(qt, kt, vt, mask[:, None], scale, qcfg, qkey, 40)
    else:
        raise ValueError(f"unknown attention mode {mode!r}")

    o = o.transpose(0, 2, 1, 3).reshape(b, sq, h * dh)
    y = qeinsum("bsn,nd->bsd", o, params["wo"], key=subkey(qkey, 3), cfg=qcfg,
                site="wo")
    return y, new_cache


# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------

def _prefill_cache(cache_layer, k, v, positions, *, k_scale: float = 1.0,
                   v_scale: float = 1.0):
    """Write the first S entries (or last `window` for ring caches)."""
    dtype = _store_dtype(cache_layer)
    cap = cache_layer["k"].shape[1]
    s = k.shape[1]
    if s <= cap:
        kq = _to_cache_dtype(k, dtype, k_scale)
        vq = _to_cache_dtype(v, dtype, v_scale)
        new_k = jax.lax.dynamic_update_slice(
            cache_layer["k"], kq, (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache_layer["v"], vq, (0, 0, 0, 0))
        slot = jnp.full(cache_layer["slot_pos"].shape, -1, jnp.int32)
        slot = jax.lax.dynamic_update_slice(slot, positions.astype(jnp.int32),
                                            (0, 0))
    else:
        # Ring cache smaller than the prompt: keep the last `cap` tokens AT
        # THEIR RING SLOTS (pos % cap) — the invariant `_append_cache`
        # relies on. Writing them sequentially to slots 0..cap-1 instead
        # (the pre-fix behavior) desynchronizes the ring whenever
        # s % cap != 0: the next append overwrites a slot that still holds
        # an in-window position while older out-of-window entries survive,
        # silently dropping valid keys from local attention. Slot order is
        # irrelevant to correctness (softmax is permutation-invariant over
        # KV slots; validity tracks absolute positions).
        kq = _to_cache_dtype(k[:, -cap:], dtype, k_scale)
        vq = _to_cache_dtype(v[:, -cap:], dtype, v_scale)
        keep_pos = positions[:, -cap:].astype(jnp.int32)      # (B, cap)
        ring = keep_pos % cap
        b_idx = jnp.arange(k.shape[0])[:, None]
        new_k = jnp.zeros_like(cache_layer["k"]).at[b_idx, ring].set(kq)
        new_v = jnp.zeros_like(cache_layer["v"]).at[b_idx, ring].set(vq)
        slot = jnp.full(cache_layer["slot_pos"].shape, -1,
                        jnp.int32).at[b_idx, ring].set(keep_pos)
    length = jnp.minimum(
        jnp.full(cache_layer["length"].shape, s, jnp.int32), cap)
    return {"k": new_k, "v": new_v, "slot_pos": slot, "length": length}


def _append_cache(cache_layer, k, v, positions, *, k_scale: float = 1.0,
                  v_scale: float = 1.0):
    """Insert one token at position pos (ring index pos % capacity)."""
    dtype = _store_dtype(cache_layer)
    cap = cache_layer["k"].shape[1]
    pos = positions[:, -1]                      # (B,)
    idx = pos % cap                             # ring slot per batch element
    kq = _to_cache_dtype(k, dtype, k_scale)     # (B, 1, Hkv, dh)
    vq = _to_cache_dtype(v, dtype, v_scale)
    b_idx = jnp.arange(k.shape[0])
    new_k = cache_layer["k"].at[b_idx, idx].set(kq[:, 0])
    new_v = cache_layer["v"].at[b_idx, idx].set(vq[:, 0])
    slot = cache_layer["slot_pos"].at[b_idx, idx].set(pos.astype(jnp.int32))
    length = jnp.minimum(cache_layer["length"] + 1, cap)
    return {"k": new_k, "v": new_v, "slot_pos": slot, "length": length}
