from repro.models.config import ModelConfig
from repro.models.registry import build_config, list_archs

__all__ = ["ModelConfig", "build_config", "list_archs"]
