"""Precision-flow lint over the jitted train/serve step jaxprs of a cell.

The paper's recipe only works if *every* W/A/E/G tensor actually flows
through the FP8 quantize/scale machinery — a single silent XLA-dot
fallback or unregistered scale site degrades to bf16 training without
any test failing (PR 3 found exactly this: both projection adjoints fell
back silently).  These passes turn the invariants the test suite proves
on toy steps into repo-wide checked laws over every config-zoo cell:

  fused_coverage   no `dot_general` outside `pallas_call` when the fused
                   predicates hold; remaining outside-dots are classified
                   (logits head / MoE experts / recurrent blocks /
                   unfused-by-config) and anything unexplained is an
                   ERROR.
  f8_payload       every pallas_call touches a real f8 dtype (uint8
                   bit-carriers don't count); the recipe's formats
                   actually appear (hybrid => e4m3fn AND e5m2; paper =>
                   e5m2 only); fp8-wire cells carry f8 payloads on their
                   collectives.
  site_bijection   quantize-site <-> SiteRegistry bijection: every
                   observation in the collect-mode aux maps to a
                   registered site and every registered site is
                   observed (no unregistered or dead sites).
  token_width      backward-observation tokens carry exactly
                   `scale_ctx.token_width(track_health)` channels.
  double_rounding  no f32 -> bf16/f16 -> fp8 convert chains (two
                   rounding steps where the quantizer contract is one).
  vmem_fit         the cell's resolved attention/GEMM block configs fit
                   the analytic VMEM model (`analysis.vmem`).

Severities: `error` gates CI; `warning` marks known, ROADMAP-tracked
fallbacks; `info` is context.  A suppression file
(`lint_suppressions.json`, overridable via the CLI) downgrades findings
by (pass, cell-glob, message-substring) — every suppression carries a
reason and shows up in the report, so nothing is silently waived.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_walk as jw
from repro.analysis import vmem as vm

SEVERITY_RANK = {"error": 0, "warning": 1, "info": 2}
DEFAULT_SUPPRESSIONS = Path(__file__).with_name("lint_suppressions.json")


@dataclasses.dataclass
class Finding:
    pass_name: str
    severity: str
    cell: str
    message: str
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)
    suppressed: bool = False
    suppressed_by: Optional[str] = None

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        return {k: v for k, v in out.items() if v not in (None, {})}


# ------------------------------------------------------------ suppressions
def load_suppressions(path=None) -> List[dict]:
    """Suppression rules: [{"pass": name-or-*, "cell": glob, "match":
    message-substring, "max_severity": downgrade-to, "reason": why}]."""
    p = Path(path) if path is not None else DEFAULT_SUPPRESSIONS
    if not p.exists():
        return []
    data = json.loads(p.read_text())
    rules = data.get("rules", []) if isinstance(data, dict) else data
    for r in rules:
        if "reason" not in r:
            raise ValueError(f"suppression rule without a reason: {r}")
    return rules


def apply_suppressions(findings: Sequence[Finding],
                       rules: Sequence[dict]) -> List[Finding]:
    """Downgrade matching findings to the rule's max_severity (default
    info) and mark them suppressed; never upgrades."""
    for f in findings:
        for r in rules:
            if r.get("pass", "*") not in ("*", f.pass_name):
                continue
            if not fnmatch.fnmatch(f.cell, r.get("cell", "*")):
                continue
            if r.get("match") and r["match"] not in f.message:
                continue
            cap = r.get("max_severity", "info")
            if SEVERITY_RANK[cap] > SEVERITY_RANK[f.severity]:
                f.severity = cap
                f.suppressed = True
                f.suppressed_by = r["reason"]
            break
    return list(findings)


# ------------------------------------------------------------------ passes
def _fused_gemm_on(q) -> bool:
    return bool(q.enabled and q.scaling == "delayed" and q.fuse_epilogue
                and q.backend.startswith("pallas"))


def _fused_attn_on(q) -> bool:
    from repro.core.qattention import fuse_attention
    return fuse_attention(q)


def _classify_outside_dot(eqn, cfg, q):
    """(kind, severity, why) for one dot_general outside any pallas
    kernel.  Known, policy- or ROADMAP-explained fallbacks classify as
    info/warning; anything unexplained is an error."""
    shapes = [tuple(v.aval.shape) for v in eqn.invars
              if hasattr(getattr(v, "aval", None), "shape")]
    dims_all = {d for s in shapes for d in s}
    if cfg.padded_vocab_size in dims_all:
        return ("logits_head", "info",
                "unquantized embedding/logits head "
                "(policy.quantize_logits_head=False — the paper keeps "
                "first/last layers at 16-bit)")
    if cfg.n_experts > 1 and cfg.n_experts in dims_all:
        return ("moe_expert_gemm", "warning",
                "MoE router/expert GEMM not yet on the fused FP8 path "
                "(ROADMAP: grouped/ragged FP8 expert GEMM)")
    if cfg.family in ("ssm", "hybrid"):
        return ("recurrent_inner_product", "warning",
                "recurrent-block inner product still unfused "
                "(ROADMAP: route rglru/mlstm through the fused kernels)")
    if not _fused_attn_on(q):
        return ("unfused_attention", "warning",
                "attention GEMM outside pallas (fuse_attention disabled "
                "or predicates unmet for this cell)")
    return ("unfused_gemm", "error",
            "dot_general outside pallas_call with the fused epilogue "
            "path enabled — a silent XLA fallback")


def fused_coverage_pass(jaxpr, cfg, meta, cell: str) -> List[Finding]:
    q = cfg.policy.quant
    findings: List[Finding] = []
    counts = jw.count_prims(jaxpr)
    if not _fused_gemm_on(q):
        if q.enabled and q.scaling == "delayed" and not q.fuse_epilogue:
            findings.append(Finding(
                "fused_coverage", "warning", cell,
                "fuse_epilogue=False: projection GEMMs and both adjoints "
                "run the unfused quantize->XLA-dot fallback "
                f"({counts['outside_dot']} dots outside pallas)",
                {"counts": counts}))
        return findings
    by_kind: Dict[str, Dict[str, Any]] = {}
    for eqn, inside in jw.iter_eqns(jaxpr):
        if inside or eqn.primitive.name != "dot_general":
            continue
        kind, sev, why = _classify_outside_dot(eqn, cfg, q)
        slot = by_kind.setdefault(kind, {"severity": sev, "why": why,
                                         "count": 0, "shapes": []})
        slot["count"] += 1
        if len(slot["shapes"]) < 4:
            slot["shapes"].append(
                [list(v.aval.shape) for v in eqn.invars
                 if hasattr(getattr(v, "aval", None), "shape")])
    for kind, slot in sorted(by_kind.items()):
        findings.append(Finding(
            "fused_coverage", slot["severity"], cell,
            f"{slot['count']} dot_general(s) outside pallas_call "
            f"[{kind}]: {slot['why']}",
            {"kind": kind, "count": slot["count"],
             "example_shapes": slot["shapes"]}))
    if counts["pallas"] == 0:
        findings.append(Finding(
            "fused_coverage", "error", cell,
            "fused predicates hold but the step contains no pallas_call "
            "at all — the entire cell fell back to XLA",
            {"counts": counts}))
    return findings


def f8_payload_pass(jaxpr, cfg, meta, cell: str) -> List[Finding]:
    q = cfg.policy.quant
    findings: List[Finding] = []
    for eqn, _ in jw.iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        if not jw.touches_f8(eqn):
            findings.append(Finding(
                "f8_payload", "error", cell,
                "pallas_call with no real f8-dtype operand or output — "
                "an FP8 kernel whose payloads are not actually FP8",
                {"out_dtypes": [str(v.aval.dtype) for v in eqn.outvars
                                if hasattr(getattr(v, "aval", None),
                                           "dtype")]}))
    if meta.get("mode") == "train" and q.enabled \
            and q.scaling == "delayed":
        census = jw.dtype_census(jaxpr)
        e4 = census.get("float8_e4m3fn", 0)
        e5 = census.get("float8_e5m2", 0)
        if q.recipe == "hybrid":
            if not e4:
                findings.append(Finding(
                    "f8_payload", "error", cell,
                    "hybrid recipe but no e4m3fn (W/A) payloads appear "
                    "in the train step", {"census_e4m3fn": e4}))
            if not e5:
                findings.append(Finding(
                    "f8_payload", "error", cell,
                    "hybrid recipe but no e5m2 (E/G) payloads appear "
                    "in the train step", {"census_e5m2": e5}))
        elif q.recipe == "paper_e5m2":
            if not e5:
                findings.append(Finding(
                    "f8_payload", "error", cell,
                    "paper_e5m2 recipe but no e5m2 payloads appear in "
                    "the train step", {"census_e5m2": e5}))
            if e4:
                findings.append(Finding(
                    "f8_payload", "error", cell,
                    "paper_e5m2 recipe lowered e4m3fn payloads — the "
                    "recipe label and the executed formats disagree",
                    {"census_e4m3fn": e4}))
    if meta.get("wire_bytes"):
        wire_prims = ("psum", "ppermute", "all_gather", "all_to_all",
                      "psum_scatter", "reduce_scatter")
        n_f8 = sum(1 for eqn, _ in jw.iter_eqns(jaxpr)
                   if eqn.primitive.name in wire_prims
                   and jw.touches_f8(eqn))
        if n_f8 == 0:
            findings.append(Finding(
                "f8_payload", "error", cell,
                "fp8-wire cell (dist.wire=fp8_ef) but no collective "
                "carries a real f8 payload", {"wire_prims": wire_prims}))
        else:
            findings.append(Finding(
                "f8_payload", "info", cell,
                f"{n_f8} collective(s) carry real f8 wire payloads",
                {"count": n_f8}))
    return findings


def double_rounding_pass(jaxpr, cell: str) -> List[Finding]:
    """Flag convert chains f32/f64 -> bf16/f16 -> fp8: the intermediate
    16-bit rounding loses mantissa bits before the fp8 rounding, so the
    result can differ from the single-rounding quantizer contract
    (core/quantize grids wide inputs in f32 precisely to avoid this)."""
    findings: List[Finding] = []
    wide = {"float32", "float64"}
    mid = {"bfloat16", "float16"}
    for jx, _ in jw.iter_jaxprs(jaxpr):
        producers = {}
        for eqn in jx.eqns:
            for ov in eqn.outvars:
                producers[ov] = eqn
        for eqn in jx.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            out_dt = eqn.outvars[0].aval.dtype
            if not jw.is_f8(out_dt):
                continue
            prod = producers.get(eqn.invars[0])
            if prod is None \
                    or prod.primitive.name != "convert_element_type":
                continue
            src_aval = getattr(prod.invars[0], "aval", None)
            if src_aval is None:
                continue
            src_dt, mid_dt = str(src_aval.dtype), str(
                prod.outvars[0].aval.dtype)
            if src_dt in wide and mid_dt in mid:
                findings.append(Finding(
                    "double_rounding", "error", cell,
                    f"double-rounding chain {src_dt} -> {mid_dt} -> "
                    f"{out_dt}: the 16-bit intermediate rounds before "
                    f"the fp8 rounding",
                    {"chain": [src_dt, mid_dt, str(out_dt)]}))
    return findings


def vmem_fit_pass(cfg, meta, cell: str) -> List[Finding]:
    """The cell's resolved kernel block configs must fit the analytic
    VMEM model — the same model the autotuner prunes candidates with and
    `launch/specs.py` rejects explicit knobs with."""
    q = cfg.policy.quant
    findings: List[Finding] = []
    if meta.get("fuse_attention") and "attn_block_q" in meta:
        bq, bkv, d = (meta["attn_block_q"], meta["attn_block_kv"],
                      meta["head_dim"])
        for kind in ("fwd", "bwd") if meta.get("mode") == "train" \
                else ("fwd",):
            est = vm.attn_vmem(kind, bq, bkv, d)
            if not est.fits:
                findings.append(Finding(
                    "vmem_fit", "error", cell,
                    f"resolved attention blocks do not fit: "
                    f"{est.describe()}", est.to_dict()))
    if meta.get("mode") == "train" and _fused_gemm_on(q):
        from repro.kernels import autotune as at
        from repro.kernels.fused_quant_matmul import kernel as _fk
        defaults = (_fk.DEFAULT_BM, _fk.DEFAULT_BK, _fk.DEFAULT_BN)
        tokens = meta["seq"] * meta["batch"] \
            // max(1, meta.get("n_microbatches", 1))
        for (m, k, n), dims in (((tokens, meta["d_model"], meta["d_ff"]),
                                 "nn"),
                                ((tokens, meta["d_ff"], meta["d_model"]),
                                 "nt"),
                                ((meta["d_model"], tokens, meta["d_ff"]),
                                 "tn")):
            bm, bk, bn = at.resolve_gemm_blocks(
                dims, m, k, n, autotune=q.autotune, defaults=defaults)
            est = vm.gemm_vmem(min(bm, max(8, m)), min(bk, max(128, k)),
                               min(bn, max(128, n)), dims=dims)
            if not est.fits:
                findings.append(Finding(
                    "vmem_fit", "error", cell,
                    f"resolved GEMM blocks for the {dims} projection "
                    f"shape ({m}, {k}, {n}) do not fit: "
                    f"{est.describe()}", est.to_dict()))
    return findings


def site_passes(cfg, params_s, batch_s, cell: str, *,
                registry=None) -> List[Finding]:
    """site_bijection + token_width over a delayed-scaling train cell.

    `registry` defaults to a fresh discovery trace (what build_cell
    runs with); tests inject a tampered registry to prove the pass
    fails on unregistered / dead sites."""
    from repro.models.transformer import lm_loss
    from repro.scaling import context as sc
    from repro.scaling.calibrate import discover_lm_sites
    from repro.scaling.state import DelayedScaling

    findings: List[Finding] = []
    fresh = discover_lm_sites(cfg, params_s, batch_s)
    reg = fresh if registry is None else registry
    for k in sorted(set(fresh.keys) - set(reg.keys)):
        findings.append(Finding(
            "site_bijection", "error", cell,
            f"quantize site observed in the step but absent from the "
            f"SiteRegistry (unregistered site): {k}", {"site": k}))
    for k in sorted(set(reg.keys) - set(fresh.keys)):
        findings.append(Finding(
            "site_bijection", "error", cell,
            f"registered site never observed by the step (dead site): "
            f"{k}", {"site": k}))

    ds = DelayedScaling(reg, qcfg=cfg.policy.quant)
    state = ds.init()
    tokens = ds.zero_tokens()

    def probe(p, t, b):
        with ds.collect(state, t):
            _, metrics = lm_loss(p, b, cfg=cfg, qkey=jax.random.PRNGKey(0))
        return metrics

    try:
        metrics_s = jax.eval_shape(probe, params_s, tokens, batch_s)
    except Exception as e:  # noqa: BLE001 — a failed collect trace IS a finding
        findings.append(Finding(
            "site_bijection", "error", cell,
            f"collect-mode trace failed: {type(e).__name__}: {e}"))
        return findings

    amax_keys = {k[len(sc.AMAX_PREFIX):] for k in metrics_s
                 if k.startswith(sc.AMAX_PREFIX)}
    fwd_reg = {k for k in reg.keys if reg.class_letter(k) in ("W", "A")}
    for k in sorted(amax_keys - fwd_reg):
        findings.append(Finding(
            "site_bijection", "error", cell,
            f"forward amax observation for a site the registry does not "
            f"carry (unregistered site): {k}", {"site": k}))
    for k in sorted(fwd_reg - amax_keys):
        findings.append(Finding(
            "site_bijection", "error", cell,
            f"registered forward site produced no amax observation "
            f"(dead site): {k}", {"site": k}))
    for s in sorted(reg.token_sites):
        if reg.token_uses.get(s, 0) <= 0:
            findings.append(Finding(
                "site_bijection", "error", cell,
                f"backward-observation token never used by the trace "
                f"(dead token site): {s}", {"site": s}))

    want = sc.token_width(cfg.policy.quant.track_health)
    for s, tok in sorted(tokens.items()):
        if tok.shape[-1] != want:
            findings.append(Finding(
                "token_width", "error", cell,
                f"token for site {s} carries {tok.shape[-1]} channels, "
                f"expected {want} "
                f"(track_health={cfg.policy.quant.track_health})",
                {"site": s, "width": int(tok.shape[-1]),
                 "expected": int(want)}))
    return findings


# ------------------------------------------------------------- cell driver
def lint_cell(arch: str, shape: str, mesh, *,
              overrides: Optional[Dict[str, Any]] = None,
              cell_id: Optional[str] = None) -> List[Finding]:
    """Build one (arch, shape) cell, trace its step jaxpr, and run every
    applicable pass.  A build or trace failure is itself an error
    finding — the lint never crashes the sweep."""
    from repro.launch import specs as S
    from repro.launch.mesh import enter_mesh
    from repro.models.transformer import init_lm

    cell = cell_id or f"{arch}/{shape}"
    findings: List[Finding] = []
    with enter_mesh(mesh):
        try:
            built = S.build_cell(arch, shape, mesh, overrides=overrides)
        except Exception as e:  # noqa: BLE001
            return [Finding("build", "error", cell,
                            f"cell failed to build: "
                            f"{type(e).__name__}: {e}")]
        cfg = S.cell_config(arch, shape, overrides=overrides)
        meta = built["meta"]
        try:
            jaxpr = jax.make_jaxpr(built["fn"])(*built["args"])
        except Exception as e:  # noqa: BLE001
            return [Finding("trace", "error", cell,
                            f"step trace failed: "
                            f"{type(e).__name__}: {e}")]
        findings += fused_coverage_pass(jaxpr, cfg, meta, cell)
        findings += f8_payload_pass(jaxpr, cfg, meta, cell)
        findings += double_rounding_pass(jaxpr, cell)
        findings += vmem_fit_pass(cfg, meta, cell)
        if meta.get("mode") == "train" \
                and cfg.policy.quant.scaling == "delayed":
            info = S.SHAPES[shape]
            params_s = jax.eval_shape(
                lambda: init_lm(jax.random.PRNGKey(0), cfg))
            batch_s = S._token_batch(cfg, info["batch"], info["seq"],
                                     labels=True)
            findings += site_passes(cfg, params_s, batch_s, cell)
    return findings


def summarize(findings: Sequence[Finding]) -> Dict[str, int]:
    out = {"error": 0, "warning": 0, "info": 0, "suppressed": 0}
    for f in findings:
        out[f.severity] += 1
        out["suppressed"] += int(f.suppressed)
    return out


def to_markdown(findings: Sequence[Finding],
                summary: Optional[dict] = None) -> str:
    """Human-readable report next to the JSON artifact."""
    lines = ["# Precision lint report", ""]
    s = summary or summarize(findings)
    lines.append(f"**{s['error']} error(s), {s['warning']} warning(s), "
                 f"{s['info']} info, {s['suppressed']} suppressed.**")
    lines.append("")
    by_cell: Dict[str, List[Finding]] = {}
    for f in findings:
        by_cell.setdefault(f.cell, []).append(f)
    for cell in sorted(by_cell):
        lines.append(f"## {cell}")
        lines.append("")
        lines.append("| severity | pass | finding |")
        lines.append("|---|---|---|")
        for f in sorted(by_cell[cell],
                        key=lambda x: SEVERITY_RANK[x.severity]):
            msg = f.message.replace("|", "\\|")
            if f.suppressed:
                msg += f" _(suppressed: {f.suppressed_by})_"
            lines.append(f"| {f.severity} | {f.pass_name} | {msg} |")
        lines.append("")
    if not by_cell:
        lines.append("No findings.")
    return "\n".join(lines) + "\n"
