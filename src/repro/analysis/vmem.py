"""Analytic per-kernel VMEM footprint model for the fused Pallas kernels.

Byte accounting per grid step, straight from the kernels' BlockSpecs and
scratch_shapes (`kernels/fused_quant_matmul/kernel.py`,
`kernels/fp8_attention/kernel.py`):

 * every grid-blocked input/output block is counted TWICE — Mosaic's grid
   pipeline revolves two buffers per blocked ref so the next grid step's
   DMA overlaps compute;
 * scratch (`pltpu.VMEM`) refs are single-buffered (persistent across the
   innermost grid dim);
 * the attention kernels materialize per-(q-tile, kv-stripe) score/P
   tiles in vector registers / VMEM; the model charges one f32 + one fp8
   (bq, bkv) tile forward and two of each backward (dP and dS chains);
 * SMEM operands (scales, seeds) and (1, 1) amax tiles are charged at
   their true byte size (negligible but honest);
 * head_dim is padded to LANE (128) exactly as the ops-layer padding
   contract does before the kernel sees it.

The budget defaults to a full 16 MiB/core of TPU VMEM.  The model is
deliberately a lower bound on what Mosaic will actually allocate (it
ignores compiler spills and semaphore overhead), so a config the model
rejects can NEVER fit — safe for pruning autotune candidates and
refusing explicit knobs — while a config it accepts may still be tight.

Consumers: `kernels/autotune.py` (prune can't-fit sweep candidates before
timing them), `launch/specs.py` (reject oversized explicit
attn_block_q/attn_block_kv at spec-build time), and
`analysis/precision_lint.py` (the vmem_fit pass over built cells).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.kernels.autotune import LANE, TQ

VMEM_BYTES = 16 * 1024 * 1024   # per-core VMEM budget the model fits into
DMA_BUF = 2                     # grid-pipeline double buffering factor


def _budget(budget: Optional[int]) -> int:
    return VMEM_BYTES if budget is None else int(budget)


def _pad_lane(d: int) -> int:
    return -(-max(int(d), 1) // LANE) * LANE


@dataclasses.dataclass(frozen=True)
class VmemEstimate:
    """Modeled per-grid-step VMEM footprint of one kernel launch."""
    kernel: str
    blocks: Dict[str, int]
    parts: Dict[str, int]
    budget_bytes: int = VMEM_BYTES

    @property
    def total_bytes(self) -> int:
        return int(sum(self.parts.values()))

    @property
    def fits(self) -> bool:
        return self.total_bytes <= self.budget_bytes

    def describe(self) -> str:
        blocks = ", ".join(f"{k}={v}" for k, v in self.blocks.items())
        return (f"{self.kernel}[{blocks}]: modeled VMEM "
                f"{self.total_bytes} bytes "
                f"({self.total_bytes / 2**20:.2f} MiB) vs budget "
                f"{self.budget_bytes} bytes "
                f"({self.budget_bytes / 2**20:.2f} MiB)")

    def to_dict(self) -> dict:
        return {"kernel": self.kernel, "blocks": dict(self.blocks),
                "vmem_bytes": self.total_bytes,
                "budget_bytes": self.budget_bytes, "fits": self.fits}


# -------------------------------------------------------------- fused GEMM
def gemm_vmem(bm: int, bk: int, bn: int, *, dims: str = "nn",
              with_amax: bool = True, with_counts: bool = False,
              budget: Optional[int] = None) -> VmemEstimate:
    """Fused quantize-epilogue GEMM (and the plain fp8_matmul, whose
    working set is a strict subset): fp8 a/b blocks + u8 SR-bits block in,
    fp8 out block + scalar amax/health tiles out, one (bm, bn) f32
    accumulator scratch.  Layout transposes (nn/nt/tn) permute block
    dims, not bytes."""
    a_blk = bm * bk                       # fp8, 1 byte
    b_blk = bk * bn
    rand_blk = bm * bn                    # uint8 SR bits
    out_blk = bm * bn                     # fp8 payload
    tiles = (4 if with_amax else 0) + (2 * 4 if with_counts else 0)
    parts = {
        "in_blocks_x2": DMA_BUF * (a_blk + b_blk + rand_blk),
        "out_blocks_x2": DMA_BUF * (out_blk + tiles),
        "acc_scratch_f32": bm * bn * 4,
    }
    return VmemEstimate("fused_gemm", {"bm": bm, "bk": bk, "bn": bn},
                        parts, _budget(budget))


# --------------------------------------------------------------- attention
def attn_fwd_vmem(block_q: int, block_kv: int, head_dim: int, *,
                  mask_mode: str = "causal", with_counts: bool = False,
                  budget: Optional[int] = None) -> VmemEstimate:
    """One-pass fwd kernel, grid (B, H, nq, nk): fp8 q/k/v blocks in
    (+ per-stripe mask block for kv/chunk modes), bf16 o block + scalar
    amax tiles out, (bq, 1) m/l + (bq, dp) f32 accumulator scratch, and
    the transient (bq, bkv) score (f32) + P (fp8) tiles."""
    bq, bkv, dp = int(block_q), int(block_kv), _pad_lane(head_dim)
    mask_blk = 0
    if mask_mode == "kv":
        mask_blk = bkv                     # bool/int8 kv-mask stripe
    elif mask_mode == "chunk":
        mask_blk = bkv * 4                 # int32 slot-position stripe
    out_tiles = 2 * 4 + (2 * 3 * 4 if with_counts else 0)
    parts = {
        "in_blocks_x2": DMA_BUF * (bq * dp + 2 * bkv * dp + mask_blk),
        "out_blocks_x2": DMA_BUF * (bq * dp * 2 + out_tiles),
        "scratch_f32": (2 * bq + bq * dp) * 4,
        "score_tiles": bq * bkv * (4 + 1),
    }
    return VmemEstimate(
        "fp8_attention_fwd",
        {"block_q": bq, "block_kv": bkv, "head_dim_padded": dp},
        parts, _budget(budget))


def attn_bwd_dq_vmem(block_q: int, block_kv: int, head_dim: int, *,
                     with_counts: bool = False,
                     budget: Optional[int] = None) -> VmemEstimate:
    """dQ kernel, grid (B, H, nq, 4*nk): fp8 q/k/v/do blocks in, f32 dq
    block + (bq, 1) m/l/rd statistics + amax tiles out, 3x (bq, 1) +
    (bq, dp) f32 scratch, transient score/P and dP/dS tiles."""
    bq, bkv, dp = int(block_q), int(block_kv), _pad_lane(head_dim)
    out_tiles = 2 * 4 + (2 * 3 * 4 if with_counts else 0)
    parts = {
        "in_blocks_x2": DMA_BUF * (2 * bq * dp + 2 * bkv * dp),
        "out_blocks_x2": DMA_BUF * (bq * dp * 4 + 3 * bq * 4 + out_tiles),
        "scratch_f32": (3 * bq + bq * dp) * 4,
        "score_tiles": bq * bkv * (2 * 4 + 2 * 1),
    }
    return VmemEstimate(
        "fp8_attention_bwd_dq",
        {"block_q": bq, "block_kv": bkv, "head_dim_padded": dp},
        parts, _budget(budget))


def attn_bwd_dkv_vmem(block_q: int, block_kv: int, head_dim: int, *,
                      budget: Optional[int] = None) -> VmemEstimate:
    """dK/dV kernel, grid (B, Hkv, nk, group*nq): fp8 q/do blocks +
    (bq, 1) m/l/rd statistics + fp8 k/v blocks in, two f32 (bkv, dp)
    accumulating out blocks, transient score/dS tiles."""
    bq, bkv, dp = int(block_q), int(block_kv), _pad_lane(head_dim)
    parts = {
        "in_blocks_x2": DMA_BUF * (2 * bq * dp + 2 * bkv * dp
                                   + 3 * bq * 4),
        "out_blocks_x2": DMA_BUF * (2 * bkv * dp * 4),
        "score_tiles": bq * bkv * (2 * 4 + 2 * 1),
    }
    return VmemEstimate(
        "fp8_attention_bwd_dkv",
        {"block_q": bq, "block_kv": bkv, "head_dim_padded": dp},
        parts, _budget(budget))


def attn_vmem(kind: str, block_q: int, block_kv: int, head_dim: int, *,
              mask_mode: str = "causal", with_counts: bool = False,
              budget: Optional[int] = None) -> VmemEstimate:
    """Worst-case estimate for an attention pass: the fwd kernel, or the
    larger of the two backward kernels (bwd block_q below TQ is lifted to
    TQ exactly as the ops layer does)."""
    if kind == "fwd":
        return attn_fwd_vmem(block_q, block_kv, head_dim,
                             mask_mode=mask_mode, with_counts=with_counts,
                             budget=budget)
    bq = max(int(block_q), TQ)
    ests = (attn_bwd_dq_vmem(bq, block_kv, head_dim,
                             with_counts=with_counts, budget=budget),
            attn_bwd_dkv_vmem(bq, block_kv, head_dim, budget=budget))
    return max(ests, key=lambda e: e.total_bytes)


# ------------------------------------------------------------------ checks
def check_attn_blocks(block_q: int, block_kv: int, head_dim: int, *,
                      kinds: Sequence[str] = ("fwd", "bwd"),
                      mask_mode: str = "causal",
                      budget: Optional[int] = None,
                      label: str = "attention blocks") -> List[VmemEstimate]:
    """Raise ValueError (with the modeled footprint) when the blocks
    exceed the VMEM budget for any requested kernel kind.  Returns the
    per-kind estimates when everything fits."""
    ests = []
    for kind in kinds:
        est = attn_vmem(kind, block_q, block_kv, head_dim,
                        mask_mode=mask_mode, budget=budget)
        if not est.fits:
            raise ValueError(
                f"{label} exceed the analytic VMEM model: "
                f"{est.describe()}. Shrink attn_block_kv/attn_block_q "
                f"(or leave them unset to resolve through the autotuner "
                f"winners table).")
        ests.append(est)
    return ests


def check_gemm_blocks(bm: int, bk: int, bn: int, *, dims: str = "nn",
                      budget: Optional[int] = None,
                      label: str = "GEMM blocks") -> VmemEstimate:
    """Raise ValueError (with the modeled footprint) when a GEMM block
    config exceeds the VMEM budget."""
    est = gemm_vmem(bm, bk, bn, dims=dims, budget=budget)
    if not est.fits:
        raise ValueError(
            f"{label} exceed the analytic VMEM model: {est.describe()}.")
    return est


# ----------------------------------------------------------------- pruning
def prune_gemm_candidates(cands: Sequence[Tuple[int, int, int]], *,
                          dims: str = "nn", budget: Optional[int] = None
                          ) -> Tuple[list, List[dict]]:
    """Split GEMM sweep candidates into (kept, pruned).  `pruned` entries
    carry the modeled footprint so the sweep can record WHAT it skipped
    and WHY (no silent caps)."""
    kept, pruned = [], []
    for c in cands:
        est = gemm_vmem(*c, dims=dims, budget=budget)
        if est.fits:
            kept.append(c)
        else:
            pruned.append({"blocks": list(c),
                           "vmem_bytes": est.total_bytes,
                           "budget_bytes": est.budget_bytes,
                           "reason": "modeled VMEM exceeds budget"})
    return kept, pruned


def prune_attn_candidates(kind: str, cands: Sequence[Tuple[int, int]],
                          head_dim: int, *, mask_mode: str = "causal",
                          budget: Optional[int] = None
                          ) -> Tuple[list, List[dict]]:
    """Split attention sweep candidates into (kept, pruned) — same
    contract as `prune_gemm_candidates`."""
    kept, pruned = [], []
    for bq, bkv in cands:
        est = attn_vmem(kind, bq, bkv, head_dim, mask_mode=mask_mode,
                        budget=budget)
        if est.fits:
            kept.append((bq, bkv))
        else:
            pruned.append({"blocks": [bq, bkv],
                           "vmem_bytes": est.total_bytes,
                           "budget_bytes": est.budget_bytes,
                           "reason": "modeled VMEM exceeds budget"})
    return kept, pruned
