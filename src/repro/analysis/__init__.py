"""Static analysis for the FP8 training/serving stack.

Three tools, one import surface:

 * `jaxpr_walk`     — the canonical nested-jaxpr traversal every jaxpr
   assertion in the repo goes through (tests included): pallas_call /
   scan / custom_vjp / shard_map aware, primitive counting, dtype
   census.
 * `vmem`           — analytic per-kernel VMEM/grid footprint model for
   the fused GEMM and attention kernels, consulted by the autotuner
   (prune can't-fit candidates before timing) and by `launch/specs.py`
   (reject oversized explicit block knobs at spec-build time).
 * `precision_lint` — lint passes over the jitted train/serve step
   jaxprs of a built cell: fused-path coverage, real-f8 payload checks,
   quantize-site <-> SiteRegistry bijection, token-channel width, and
   double-rounding chains.  CLI: `python -m repro.tools.lint`.
"""
