"""One canonical nested-jaxpr traversal.

Every jaxpr assertion in the repo (tests and lint passes alike) walks
nested jaxprs the same way: descend into every jaxpr found in an eqn's
params — scan/while/cond bodies, custom_vjp/custom_jvp branches,
shard_map bodies, and pallas_call kernel jaxprs — tracking whether the
current eqn sits inside a Pallas kernel body (dots inside a kernel are
the kernel's own MXU tiles, not XLA fallbacks).

The traversal is duck-typed (`hasattr(x, "eqns") / hasattr(x, "jaxpr")`)
rather than isinstance-based so it survives the jax.core ->
jax.extend.core move (JAX 0.4.x straddles both).
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Iterator, List, Tuple

import jax

try:
    from jax.extend import core as _jcore
except ImportError:   # pragma: no cover — older JAX
    from jax import core as _jcore

JAXPR_TYPES = (_jcore.Jaxpr, _jcore.ClosedJaxpr)

# Real 8-bit float dtypes (never uint8 stand-ins) — the payload dtypes the
# f8-payload lint pass accepts as proof a tensor is actually FP8.
F8_DTYPE_NAMES = frozenset((
    "float8_e5m2", "float8_e4m3fn", "float8_e4m3", "float8_e4m3b11_fnuz",
    "float8_e5m2fnuz", "float8_e4m3fnuz",
))


def as_jaxpr(jaxpr):
    """Accept a Jaxpr, a ClosedJaxpr, or the object `jax.make_jaxpr`
    returns; hand back the underlying Jaxpr."""
    return getattr(jaxpr, "jaxpr", jaxpr)


def subjaxprs(eqn) -> Iterator:
    """Every jaxpr nested in `eqn.params` (ClosedJaxprs unwrapped)."""
    for v in eqn.params.values():
        for sub in jax.tree_util.tree_leaves(
                v, is_leaf=lambda x: hasattr(x, "eqns")
                or hasattr(x, "jaxpr")):
            if hasattr(sub, "jaxpr"):
                yield sub.jaxpr
            elif hasattr(sub, "eqns"):
                yield sub


def iter_jaxprs(jaxpr, *, inside_pallas: bool = False) -> Iterator:
    """Yield (jaxpr, inside_pallas) for `jaxpr` and every nested jaxpr,
    outer first.  `inside_pallas` is True for jaxprs that are (or sit
    inside) a pallas_call kernel body."""
    jaxpr = as_jaxpr(jaxpr)
    yield jaxpr, inside_pallas
    for eqn in jaxpr.eqns:
        inner = inside_pallas or eqn.primitive.name == "pallas_call"
        for sub in subjaxprs(eqn):
            yield from iter_jaxprs(sub, inside_pallas=inner)


def iter_eqns(jaxpr, *, inside_pallas: bool = False) -> Iterator[Tuple]:
    """Yield (eqn, inside_pallas) over `jaxpr` and every nested jaxpr."""
    for jx, inside in iter_jaxprs(jaxpr, inside_pallas=inside_pallas):
        for eqn in jx.eqns:
            yield eqn, inside


def walk_eqns(jaxpr) -> Iterator:
    """Flat eqn generator over `jaxpr` and every nested jaxpr."""
    for eqn, _ in iter_eqns(jaxpr):
        yield eqn


def all_eqns(jaxpr) -> List:
    """Flat eqn list over `jaxpr` and every nested jaxpr."""
    return [eqn for eqn, _ in iter_eqns(jaxpr)]


def count_prims(jaxpr, inside_pallas: bool = False,
                counts: Dict[str, int] = None) -> Dict[str, int]:
    """Count pallas_call eqns and dot_generals OUTSIDE pallas kernel
    bodies: {"pallas": n, "outside_dot": n}.  The fused-lowering law
    (`pallas == expected`, `outside_dot == 0`) is asserted through this
    single function by tests and the precision lint alike."""
    if counts is None:
        counts = {"pallas": 0, "outside_dot": 0}
    for eqn, inside in iter_eqns(jaxpr, inside_pallas=inside_pallas):
        name = eqn.primitive.name
        if name == "pallas_call":
            counts["pallas"] += 1
        elif name == "dot_general" and not inside:
            counts["outside_dot"] += 1
    return counts


# ------------------------------------------------------------------ dtypes
def is_f8(dtype) -> bool:
    """True for a REAL 8-bit float dtype (uint8 bit-carriers don't count).
    Accepts dtype instances and scalar types alike."""
    try:
        import numpy as np
        return str(np.dtype(dtype)) in F8_DTYPE_NAMES
    except TypeError:
        return str(dtype) in F8_DTYPE_NAMES


def eqn_avals(eqn) -> Iterator:
    """Shaped avals of an eqn's invars + outvars (Literals included)."""
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            yield aval


def touches_f8(eqn) -> bool:
    """True when any operand or output of `eqn` is a real f8 dtype."""
    return any(is_f8(a.dtype) for a in eqn_avals(eqn))


def dtype_census(jaxpr) -> Counter:
    """Counter of outvar dtype names over every eqn, nested included —
    the recipe checks read fp8-format presence/absence off this."""
    census: Counter = Counter()
    for eqn in walk_eqns(jaxpr):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                census[str(aval.dtype)] += 1
    return census
