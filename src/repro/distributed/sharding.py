"""Sharding rules: parameter/optimizer/batch PartitionSpecs for any mesh.

Megatron-style tensor parallelism over the 'model' axis:
  * column-parallel: qkv / up / gate projections — shard the output dim.
  * row-parallel: out / down projections — shard the input dim.
  * vocab-parallel embedding (+ head).
  * expert-parallel MoE: expert dim over 'model'.
Data parallelism over ('pod', 'data') on the batch dim; ZeRO-1 shards the
master weights + optimizer state over 'data' on the largest free dim.

Every rule checks divisibility against the actual mesh axis sizes and falls
back to replication when a dim does not divide — small models (xlstm-125m)
thus degrade gracefully instead of failing to lower.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# (regex on the param path, candidate dims for the 'model' axis counted from
# the *end* of the shape — first divisible candidate wins; none => replicate).
_RULES = [
    (r"embed/table", (-2, -1)),    # (vocab, d): vocab-parallel, else d
    (r"embed/head", (-1, -2)),     # (d, vocab)
    (r"moe/router", None),         # replicated (f32, precision-critical)
    (r"moe/w_(gate|up|down)", (-3,)),  # (E, d, f): expert-parallel
    (r"(wq|wk|wv|up|gate|w_up|w_gate|wx|wg|wa|wi|w_zifo|w_if)$", (-1,)),
    (r"(wo|down|w_down)$", (-2,)),
    (r"(bq|bk|bv)$", (-1,)),       # column-parallel bias
    (r"(scale|bias|lam|conv|r_zifo|norm)", None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path: str, shape: Tuple[int, ...], *, model_size: int,
              model_axis: str = "model") -> P:
    ndim = len(shape)
    for pat, dims in _RULES:
        if re.search(pat, path):
            if dims is None or ndim == 0 or model_size <= 1:
                return P()
            for dim in dims:
                if -dim > ndim:
                    continue
                if shape[dim] % model_size == 0 and shape[dim] >= model_size:
                    spec = [None] * ndim
                    spec[ndim + dim] = model_axis
                    return P(*spec)
            return P()              # graceful fallback: replicate
    return P()


def param_specs(params: Any, mesh) -> Any:
    """PartitionSpec pytree matching `params` (arrays or ShapeDtypeStructs)."""
    msize = dict(mesh.shape).get("model", 1)
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _spec_for(_path_str(path), np.shape(x),
                                  model_size=msize), params)


def zero1_specs(params: Any, pspecs: Any, mesh) -> Any:
    """ZeRO-1: additionally shard the largest unsharded dim over 'data'."""
    dsize = dict(mesh.shape).get("data", 1)
    if dsize <= 1:
        return pspecs

    def shard_one(x, spec: P):
        shape = np.shape(x)
        if not shape:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        # Largest dim that is unsharded and divides the data axis.
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if entries[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
                entries[i] = "data"
                return P(*entries)
        return spec

    return jax.tree_util.tree_map(shard_one, params, pspecs)


def state_specs(state_proto: Any, mesh, *, batch_axes=("pod", "data")) -> Any:
    """Serving-state (KV cache / recurrent state) specs: shard the batch dim
    (dim 1 for stacked (L, B, ...) leaves, dim 0 for per-layer (B, ...))."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    sizes = dict(mesh.shape)
    total = int(np.prod([sizes[a] for a in axes])) if axes else 1

    msize = sizes.get("model", 1)

    def spec_one(path, x):
        shape = np.shape(x)
        # stacked leaves: (groups, B, ...); per-layer leaves: (B, ...)
        pstr = _path_str(path)
        bdim = 1 if ("stack" in pstr and len(shape) >= 2) else 0
        spec = [None] * len(shape)
        ok = False
        if len(shape) > bdim and total > 1 and shape[bdim] % total == 0:
            spec[bdim] = axes if len(axes) > 1 else axes[0]
            ok = True
        # KV caches: additionally shard the cache-length dim over 'model'
        # (decode is KV-bandwidth bound; XLA handles the softmax reduction
        # over the sharded dim with an all-reduce — flash-decoding style).
        cdim = bdim + 1
        if (pstr.endswith("kv/k") or pstr.endswith("kv/v")
                or pstr.endswith("kv/slot_pos")) and len(shape) > cdim \
                and msize > 1 and shape[cdim] % msize == 0 \
                and shape[cdim] >= msize:
            spec[cdim] = "model"
            ok = True
        return P(*spec) if ok else P()

    return jax.tree_util.tree_map_with_path(spec_one, state_proto)


def batch_specs(batch: Any, mesh, *, batch_axes=("pod", "data")) -> Any:
    """Input batch: shard dim 0 over the data-parallel axes (if divisible)."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    sizes = dict(mesh.shape)
    total = int(np.prod([sizes[a] for a in axes])) if axes else 1

    def spec_one(x):
        shape = np.shape(x)
        if shape and total > 1 and shape[0] % total == 0:
            return P(axes if len(axes) > 1 else axes[0],
                     *([None] * (len(shape) - 1)))
        return P()

    return jax.tree_util.tree_map(spec_one, batch)


def replicated(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda _: P(), tree)


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False,
                     auto: frozenset = frozenset()):
    """shard_map across JAX versions (jax.shard_map + check_vma in newer
    releases, jax.experimental.shard_map + check_rep in older ones).

    auto: mesh axes left to the XLA partitioner instead of manually mapped
    (tensor-parallel axes under an explicitly data-parallel collective).
    NOTE: JAX 0.4.37 accepts the parameter but raises NotImplementedError at
    trace time for nonempty sets — callers gate on it (ParallelPlan refuses
    fp8 wire formats on meshes with a model axis > 1)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
        try:
            return sm(f, auto=auto, **kw) if auto else sm(f, **kw)
        except TypeError:   # newest JAX dropped `auto` (axis types instead)
            return sm(f, **kw)
    from jax.experimental.shard_map import shard_map as sm_old
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check)
    return sm_old(f, auto=auto, **kw) if auto else sm_old(f, **kw)


# ---------------------------------------------------------------------------
# activation sharding constraints (logical-axis style, divisibility-checked)
# ---------------------------------------------------------------------------

# Axes currently manually mapped by an enclosing shard_map body. Inside such
# a body the axes are *gone* from the positional sharding world —
# with_sharding_constraint naming them is meaningless (and rejected), so
# `constrain` drops those entries. Installed by `manual_axes(...)`, which the
# train step wraps around the model call in wire-compressed mode.
_MANUAL_AXES: frozenset = frozenset()


class manual_axes:
    """Context manager: declare mesh axes as manually mapped (shard_map) so
    logical activation constraints over them become no-ops in this scope."""

    def __init__(self, names):
        self.names = frozenset(names)

    def __enter__(self):
        global _MANUAL_AXES
        self._saved = _MANUAL_AXES
        _MANUAL_AXES = _MANUAL_AXES | self.names
        return self

    def __exit__(self, *exc):
        global _MANUAL_AXES
        _MANUAL_AXES = self._saved
        return False


def _drop_manual(entry):
    if entry is None or not _MANUAL_AXES:
        return entry
    if isinstance(entry, tuple):
        kept = tuple(a for a in entry if a not in _MANUAL_AXES)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return None if entry in _MANUAL_AXES else entry


def constrain(x, *logical_spec):
    """with_sharding_constraint with logical axes and graceful fallback.

    logical entries: "dp" -> the ('pod','data') axes present in the current
    mesh; "model" -> the model axis; None -> unsharded. Any entry whose mesh
    axes do not divide the corresponding dim degrades to None. No-op outside
    a mesh context — models stay runnable on a single CPU device.
    """
    # jax.sharding.get_abstract_mesh only exists in newer JAX; older versions
    # install the ambient mesh via `with mesh:` and expose it through the
    # thread-resources env. Fall back to "no mesh" (constraints become a
    # no-op and the model stays runnable on a single device).
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is not None:
        mesh = get_mesh()
    else:
        try:
            from jax._src.mesh import thread_resources
            pm = thread_resources.env.physical_mesh
            mesh = None if pm.empty else pm
        except Exception:
            mesh = None
    if mesh is None or not mesh.axis_names:
        return x
    if not isinstance(x, jax.core.Tracer):
        return x   # eager (smoke-test) execution: constraints are jit-only
    sizes = dict(mesh.shape)
    entries = []
    for dim, name in zip(x.shape, logical_spec):
        if name is None:
            entries.append(None)
        elif name == "dp":
            axes = tuple(a for a in ("pod", "data") if a in sizes)
            total = 1
            for a in axes:
                total *= sizes[a]
            if axes and dim % total == 0 and dim >= total:
                entries.append(axes if len(axes) > 1 else axes[0])
            else:
                entries.append(None)
        else:
            if name in sizes and dim % sizes[name] == 0 and dim >= sizes[name]:
                entries.append(name)
            else:
                entries.append(None)
    entries = [_drop_manual(e) for e in entries]
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))
