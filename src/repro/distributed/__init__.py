from repro.distributed.amax_sync import (all_reduce_amax, host_amax_sync,
                                         make_amax_sync)
from repro.distributed.sharding import (batch_specs, param_specs,
                                        state_specs, zero1_specs)

__all__ = ["batch_specs", "param_specs", "state_specs", "zero1_specs",
           "all_reduce_amax", "host_amax_sync", "make_amax_sync"]
