from repro.distributed.amax_sync import (all_reduce_amax, host_amax_sync,
                                         make_amax_sync)
from repro.distributed.sharding import (batch_specs, param_specs,
                                        shard_map_compat, state_specs,
                                        zero1_specs)
from repro.distributed.strategy import (DataParallel, ParallelPlan,
                                        TensorParallel, ZeRO1Sharded)

__all__ = ["batch_specs", "param_specs", "state_specs", "zero1_specs",
           "shard_map_compat",
           "all_reduce_amax", "host_amax_sync", "make_amax_sync",
           "DataParallel", "ZeRO1Sharded", "TensorParallel", "ParallelPlan"]
