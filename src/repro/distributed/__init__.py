from repro.distributed.sharding import (batch_specs, param_specs,
                                        state_specs, zero1_specs)

__all__ = ["batch_specs", "param_specs", "state_specs", "zero1_specs"]
