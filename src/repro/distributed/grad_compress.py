"""FP8-compressed cross-pod gradient all-reduce with error feedback.

Beyond-paper distributed optimization: the paper makes FP8 a *storage*
format for W/A/E/G; here it also becomes the *wire* format for the
data-parallel gradient reduction across the pod boundary — the slowest link
in a multi-pod mesh (DCN / inter-pod ICI), and the collective the roofline
shows dominating multi-pod training steps.

Algorithm (per gradient leaf, executed under shard_map over the wire axis):

  1. e      <- error-feedback buffer (f32, same shape as grad)
  2. y      =  g + e
  3. scale  =  pmax(amax(|y|)) / fmt.max_normal  (shared scale: decode-correct)
  4. q      =  RNE_fp8(y / scale)                (1 byte/element on the wire)
  5. reduce-scatter in FP8: all_to_all the fp8 shards (1B/elt), upcast to
     f32 locally, sum — single-hop summation, so precision loss is one
     quantization, not log(N) re-quantizations.
  6. q2     =  RNE_fp8(partial_sum / scale2)     ; all_gather q2 (1B/elt)
  7. out    =  dequant                           ; e' = y - dequant(q)

The payloads really are 8-bit dtypes (f8e5m2 / f8e4m3fn), so the collective
bytes in the lowered HLO are the wire bytes — `launch.dryrun.parse_collectives`
counts them at 1 byte/element.

Wire bytes: 2 x (N-1)/N x |g| x 1 byte — half of a bf16 ring all-reduce,
quarter of f32. Error feedback makes the compression unbiased over time
(residuals re-enter the next step), the standard convergence fix for lossy
gradient compression.

`make_compressed_dp_allreduce` is the shard_map-wrapped entry point used by
`train/step.py` when `policy.dist.wire == "fp8_ef"`; it operates on the
STACKED layout (leaves carry a leading per-wire-device axis holding each
device's local contribution and its error-feedback residual).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fp8_formats import E5M2, FloatFormat
from repro.core.quantize import quantize_rne

Array = jax.Array


def _amax(x: Array) -> Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def _to_wire(q: Array, fmt: FloatFormat) -> Array:
    """Values already on the fmt grid -> the real 8-bit dtype (exact cast),
    so the collective moves 1 byte/element for real. Formats wider than 8
    bits (ablations) ship in their own dtype."""
    return q.astype(fmt.dtype)


def fp8_allreduce_mean(y: Array, *, axis_name: str,
                       fmt: FloatFormat = E5M2) -> Tuple[Array, Array]:
    """Compressed all-reduce-mean of y over `axis_name` (inside shard_map).

    Returns (mean, dequantized_local_contribution) — the caller computes the
    error-feedback residual as y - dequantized_local_contribution.
    """
    # jax.lax.axis_size is newer-JAX; psum of a python 1 is the classic
    # spelling and constant-folds to a static int under shard_map/pmap.
    n = jax.lax.axis_size(axis_name) \
        if hasattr(jax.lax, "axis_size") else jax.lax.psum(1, axis_name)
    scale = jax.lax.pmax(_amax(y), axis_name) / fmt.max_normal
    scale = jnp.maximum(scale, 1e-30)
    q = quantize_rne(y / scale, fmt, saturate=True)          # local fp8 grid

    flat = _to_wire(q, fmt).reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    # reduce-scatter leg: all_to_all moves fp8 (1B/elt on the wire)
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    partial = recv.astype(jnp.float32).sum(axis=0) * scale   # (chunk,) f32
    # all-gather leg: re-quantize the reduced shard, 1B/elt again
    scale2 = jnp.maximum(jax.lax.pmax(_amax(partial), axis_name)
                         / fmt.max_normal, 1e-30)
    q2 = quantize_rne(partial / scale2, fmt, saturate=True)
    gathered = jax.lax.all_gather(_to_wire(q2, fmt), axis_name)  # (n, chunk)
    total = gathered.astype(jnp.float32).reshape(-1) * scale2
    if pad:
        total = total[:-pad]
    mean = (total / n).reshape(y.shape)
    local_contrib = (q.astype(jnp.float32) * scale).reshape(y.shape)
    return mean, local_contrib


def compressed_psum_mean(grads: Any, error: Optional[Any], *,
                         axis_name: str,
                         fmt: FloatFormat = E5M2) -> Tuple[Any, Any]:
    """Tree-wise compressed mean-reduce with error feedback.

    grads: pytree of per-device gradient shards (inside shard_map over
    `axis_name`). error: matching residual pytree (or None on step 0).
    Returns (reduced_grads, new_error).
    """
    if error is None:
        error = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        y = g.astype(jnp.float32) + e
        mean, local = fp8_allreduce_mean(y, axis_name=axis_name, fmt=fmt)
        return mean.astype(g.dtype), y - local

    pairs = jax.tree_util.tree_map(one, grads, error)
    reduced = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_err


def make_compressed_dp_allreduce(mesh, *, axis_name: str = "pod",
                                 fmt: FloatFormat = E5M2,
                                 auto: frozenset = frozenset()):
    """shard_map-wrapped compressed all-reduce over one mesh axis.

    Stacked contract (how the train step hands per-device values across a
    shard_map boundary): every leaf of `grads` and `error` carries a leading
    axis of size mesh.shape[axis_name], sharded PartitionSpec(axis_name) —
    slot i is device i's local contribution / residual. Returns

        (reduced, new_error)

    with `reduced` the replicated compressed mean (leading axis dropped) and
    `new_error` the updated residuals, stacked like the input. Mesh axes not
    named stay untouched: the inputs must be replicated over them (true after
    the caller's full-precision intra-pod pre-reduction).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    def allreduce(grads, error):
        def inner(g, e):
            g0 = jax.tree_util.tree_map(lambda x: jnp.squeeze(x, 0), g)
            e0 = jax.tree_util.tree_map(lambda x: jnp.squeeze(x, 0), e)
            red, new_err = compressed_psum_mean(g0, e0, axis_name=axis_name,
                                                fmt=fmt)
            return red, jax.tree_util.tree_map(lambda x: x[None], new_err)

        stacked = jax.tree_util.tree_map(lambda _: P(axis_name), grads)
        rep = jax.tree_util.tree_map(lambda _: P(), grads)
        return shard_map_compat(inner, mesh,
                                in_specs=(stacked, stacked),
                                out_specs=(rep, stacked),
                                auto=auto)(grads, error)

    return allreduce


def make_full_dp_allreduce(mesh, *, axis_name: str = "pod",
                           auto: frozenset = frozenset()):
    """Uncompressed twin of `make_compressed_dp_allreduce` — same stacked
    contract, full-precision pmean on the wire, error returned unchanged.
    The A/B baseline for benchmarks/comm_bench.py."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    def allreduce(grads, error):
        def inner(g, e):
            red = jax.tree_util.tree_map(
                lambda x: jax.lax.pmean(jnp.squeeze(x, 0), axis_name), g)
            return red, e

        stacked = jax.tree_util.tree_map(lambda _: P(axis_name), grads)
        rep = jax.tree_util.tree_map(lambda _: P(), grads)
        return shard_map_compat(inner, mesh,
                                in_specs=(stacked, stacked),
                                out_specs=(rep, stacked),
                                auto=auto)(grads, error)

    return allreduce


def wire_bytes_model(tree: Any, n: int) -> dict:
    """Cost model for the DP gradient reduction of one step, ring-style:
    2 x (N-1)/N x numel payload bytes per device. The fp8_ef path moves
    1 byte/element on both legs (all_to_all + all_gather); the uncompressed
    baseline moves bf16 (2 bytes/element)."""
    numel = int(sum(np.prod(np.shape(x), dtype=np.int64)
                    for x in jax.tree_util.tree_leaves(tree)))
    hops = 2.0 * (n - 1) / n if n > 1 else 0.0
    full = hops * numel * 2.0        # bf16 wire
    fp8 = hops * numel * 1.0         # e5m2 payloads, both legs
    return {"numel": numel, "dp_size": int(n),
            "bytes_full_bf16": full, "bytes_fp8_ef": fp8,
            "ratio_fp8_vs_bf16": (fp8 / full) if full else 0.0}
