"""FP8-compressed cross-pod gradient all-reduce with error feedback.

Beyond-paper distributed optimization: the paper makes FP8 a *storage*
format for W/A/E/G; here it also becomes the *wire* format for the
data-parallel gradient reduction across the pod boundary — the slowest link
in a multi-pod mesh (DCN / inter-pod ICI), and the collective the roofline
shows dominating multi-pod training steps.

Algorithm (per gradient leaf, executed under shard_map over the pod axis):

  1. e      <- error-feedback buffer (f32, same shape as grad)
  2. y      =  g + e
  3. scale  =  pmax(amax(|y|)) / E5M2_max      (shared scale: decode-correct)
  4. q      =  RNE_e5m2(y / scale)             (1 byte/element on the wire)
  5. reduce-scatter in FP8: all_to_all the fp8 shards (1B/elt), upcast to
     f32 locally, sum — single-hop summation, so precision loss is one
     quantization, not log(N) re-quantizations.
  6. q2     =  RNE_e5m2(partial_sum / (scale * n))   ; all_gather q2 (1B/elt)
  7. out    =  dequant                                ; e' = y - dequant(q)

Wire bytes: 2 x (N-1)/N x |g| x 1 byte — half of a bf16 ring all-reduce,
quarter of f32. Error feedback makes the compression unbiased over time
(residuals re-enter the next step), the standard convergence fix for lossy
gradient compression.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fp8_formats import E5M2
from repro.core.quantize import quantize_rne

Array = jax.Array


def _amax(x: Array) -> Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def fp8_allreduce_mean(y: Array, *, axis_name: str) -> Tuple[Array, Array]:
    """Compressed all-reduce-mean of y over `axis_name` (inside shard_map).

    Returns (mean, dequantized_local_contribution) — the caller computes the
    error-feedback residual as y - dequantized_local_contribution.
    """
    # jax.lax.axis_size is newer-JAX; psum of a python 1 is the classic
    # spelling and constant-folds to a static int under shard_map/pmap.
    n = jax.lax.axis_size(axis_name) \
        if hasattr(jax.lax, "axis_size") else jax.lax.psum(1, axis_name)
    scale = jax.lax.pmax(_amax(y), axis_name) / E5M2.max_normal
    scale = jnp.maximum(scale, 1e-30)
    q = quantize_rne(y / scale, E5M2, saturate=True)        # local fp8

    flat = q.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    # reduce-scatter leg: all_to_all moves fp8 (1B/elt on the wire)
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    partial = recv.astype(jnp.float32).sum(axis=0) * scale   # (chunk,) f32
    # all-gather leg: re-quantize the reduced shard, 1B/elt again
    scale2 = jnp.maximum(jax.lax.pmax(_amax(partial), axis_name)
                         / E5M2.max_normal, 1e-30)
    q2 = quantize_rne(partial / scale2, E5M2, saturate=True)
    gathered = jax.lax.all_gather(q2, axis_name)             # (n, chunk) fp8
    total = gathered.astype(jnp.float32).reshape(-1) * scale2
    if pad:
        total = total[:-pad]
    mean = (total / n).reshape(y.shape)
    local_contrib = (q.astype(jnp.float32) * scale).reshape(y.shape)
    return mean, local_contrib


def compressed_psum_mean(grads: Any, error: Optional[Any], *,
                         axis_name: str) -> Tuple[Any, Any]:
    """Tree-wise compressed mean-reduce with error feedback.

    grads: pytree of per-device gradient shards (inside shard_map over
    `axis_name`). error: matching residual pytree (or None on step 0).
    Returns (reduced_grads, new_error).
    """
    if error is None:
        error = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        y = g.astype(jnp.float32) + e
        mean, local = fp8_allreduce_mean(y, axis_name=axis_name)
        return mean.astype(g.dtype), y - local

    pairs = jax.tree_util.tree_map(one, grads, error)
    reduced = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_err


def make_compressed_dp_allreduce(mesh, *, axis_name: str = "pod"):
    """shard_map-wrapped compressed all-reduce over one mesh axis; other axes
    pass through. Usable as a drop-in on a gradient pytree whose leaves are
    replicated over `axis_name` — e.g. after per-pod reduction, before the
    optimizer."""
    from jax.sharding import PartitionSpec as P

    def allreduce(grads, error):
        def inner(g, e):
            return compressed_psum_mean(g, e, axis_name=axis_name)
        specs = jax.tree_util.tree_map(lambda _: P(), grads)
        return jax.shard_map(inner, mesh=mesh,
                             in_specs=(specs, specs),
                             out_specs=(specs, specs),
                             check_vma=False)(grads, error)

    return allreduce
