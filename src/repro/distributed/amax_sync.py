"""Cross-replica amax synchronization for delayed scaling.

Under data parallelism every replica observes amaxes from its own shard of
the batch; scales must stay identical across replicas or the quantized
networks diverge (and checkpointed ScaleStates become replica-dependent).
The sync is ONE fused element-wise pmax over the dense (n_sites,)
observation vector per step — not one collective per site — inserted by
DelayedScaling.update(..., sync=make_amax_sync(axis)).

Two flavors:
 * make_amax_sync(axis_name)  — inside pmap/shard_map: lax.pmax over the
   named axis (compiles to a single small all-reduce).
 * host_amax_sync             — outside any mapped axis (jit-of-sharded or
   multi-controller): element-wise max across processes via
   multihost_utils.process_allgather; degrades to identity on one process.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

Array = jax.Array


def all_reduce_amax(obs: Array,
                    axis_name: Union[str, Sequence[str]]) -> Array:
    """Element-wise max of the observation vector over mapped axes."""
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    for name in names:
        obs = jax.lax.pmax(obs, name)
    return obs


def make_amax_sync(axis_name: Optional[Union[str, Sequence[str]]]
                   ) -> Optional[Callable[[Array], Array]]:
    """Sync hook for DelayedScaling.update. None axis -> no sync (single
    replica / scales already consistent by construction)."""
    if axis_name is None:
        return None
    return functools.partial(all_reduce_amax, axis_name=axis_name)


def host_amax_sync(obs: Array) -> Array:
    """Process-level max for multi-controller runs (no mapped axis needed).
    Identity on a single process."""
    if jax.process_count() <= 1:
        return obs
    from jax.experimental import multihost_utils
    gathered = multihost_utils.process_allgather(obs)
    return jnp.max(gathered, axis=0)
