"""Composable parallelism strategies -> one ParallelPlan.

The launch/train layers used to hand-roll their sharding decisions
(`zero1_specs` calls, inline `sizes.get("pod") * sizes.get("data")`
arithmetic) at every call site. This module turns that mesh code into
config: three small strategy objects —

  * DataParallel   — batch over ('pod', 'data'), gradient mean-reduction
  * ZeRO1Sharded   — master weights + optimizer moments over 'data'
  * TensorParallel — Megatron-style param sharding over 'model'

— compose into a `ParallelPlan` built from (mesh, policy.dist). The plan
owns every PartitionSpec the launch specs and the train step need, plus the
collective implementations, including the wire-format knob:

  policy.dist.wire = "full" | "fp8_ef"
      "fp8_ef" routes the DP gradient reduction through the e5m2-compressed
      error-feedback all-reduce (grad_compress) over the *slowest* dp link
      (the 'pod' axis when present); the remaining dp axes pre-reduce in
      full precision (fast intra-pod ICI).
  policy.dist.wire_zero_gather = "full" | "fp8"
      "fp8" moves the ZeRO-1 weight all-gather leg as e4m3 payloads with a
      shared per-leaf scale (1 byte/element for the frozen-format shards).

Environment constraint: JAX 0.4.37's shard_map cannot leave axes to the
auto partitioner (`auto=` raises NotImplementedError), so the fp8 wire
formats — which need an explicit shard_map over the dp axes — are refused
on meshes with a model axis > 1. `ParallelPlan.build` raises a clear error
rather than failing to lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.fp8_formats import E4M3, E5M2
from repro.core.precision_policy import DistConfig
from repro.core.quantize import quantize_rne
from repro.distributed import sharding
from repro.distributed.grad_compress import (make_compressed_dp_allreduce,
                                             make_full_dp_allreduce,
                                             wire_bytes_model)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataParallel:
    """Batch-dim parallelism over the given mesh axes (outermost first)."""
    axes: Tuple[str, ...] = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class ZeRO1Sharded:
    """ZeRO stage 1: master weights + optimizer moments sharded over one
    data-parallel axis (largest divisible dim per leaf)."""
    axis: str = "data"


@dataclasses.dataclass(frozen=True)
class TensorParallel:
    """Megatron tensor parallelism (column/row/vocab/expert rules from
    sharding._RULES) over one mesh axis."""
    axis: str = "model"


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """The composed plan for one mesh: which strategies are active, every
    PartitionSpec they imply, and the wire-format collectives."""
    mesh: Any
    dist: DistConfig
    dp: Optional[DataParallel]
    zero1: Optional[ZeRO1Sharded]
    tp: Optional[TensorParallel]

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, mesh, dist: DistConfig = DistConfig()) -> "ParallelPlan":
        names = set(mesh.axis_names)
        sizes = dict(mesh.shape)
        dp = DataParallel(tuple(a for a in DataParallel.axes
                                if a in names)) if dist.dp else None
        if dp is not None and not dp.axes:
            dp = None
        zero1 = ZeRO1Sharded() if (dist.zero1 and sizes.get("data", 1) > 1) \
            else None
        tp = TensorParallel() if (dist.tp and sizes.get("model", 1) > 1) \
            else None
        plan = cls(mesh=mesh, dist=dist, dp=dp, zero1=zero1, tp=tp)
        if (dist.wire == "fp8_ef" or dist.wire_zero_gather == "fp8") \
                and plan.tp_size > 1:
            raise NotImplementedError(
                "fp8 wire formats need an explicit shard_map over the dp "
                "axes, and JAX < 0.5 cannot combine that with an "
                "auto-partitioned model axis (shard_map auto= is "
                "NotImplemented on 0.4.37). Use a pure data-parallel mesh "
                "or policy.dist.wire='full'.")
        if dist.wire_axis is not None and dist.wire_axis not in names:
            raise ValueError(f"wire_axis {dist.wire_axis!r} not in mesh "
                             f"axes {sorted(names)}")
        return plan

    # -- axis bookkeeping ----------------------------------------------------
    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.dp.axes if self.dp is not None else ()

    @property
    def dp_size(self) -> int:
        sizes = dict(self.mesh.shape)
        n = 1
        for a in self.dp_axes:
            n *= sizes[a]
        return n

    @property
    def model_size(self) -> int:
        return dict(self.mesh.shape).get("model", 1)

    @property
    def tp_size(self) -> int:
        """Model-axis size when TensorParallel is active, else 1 (a mesh
        may carry a model axis the plan deliberately leaves replicated)."""
        return self.model_size if self.tp is not None else 1

    @property
    def wire_axis(self) -> Optional[str]:
        """The dp axis the (possibly compressed) reduction runs over — the
        slowest link: 'pod' when present, else 'data'. None when there is
        no data parallelism."""
        if not self.dp_axes:
            return None
        if self.dist.wire_axis is not None:
            return self.dist.wire_axis
        return self.dp_axes[0]

    @property
    def inner_dp_axes(self) -> Tuple[str, ...]:
        """dp axes pre-reduced in full precision before the wire hop."""
        return tuple(a for a in self.dp_axes if a != self.wire_axis)

    @property
    def n_wire(self) -> int:
        w = self.wire_axis
        return dict(self.mesh.shape)[w] if w is not None else 1

    @property
    def compresses(self) -> bool:
        """Whether the DP reduction actually goes through the fp8_ef path
        (needs the knob AND >1 device on the wire axis)."""
        return self.dist.wire == "fp8_ef" and self.n_wire > 1 \
            and self.dp is not None

    # -- specs ---------------------------------------------------------------
    def param_specs(self, params: Any) -> Any:
        if self.tp is None:
            return sharding.replicated(params)
        return sharding.param_specs(params, self.mesh)

    def master_specs(self, params: Any, pspecs: Any = None) -> Any:
        """TP specs + the ZeRO-1 'data' shard on the largest free dim."""
        if pspecs is None:
            pspecs = self.param_specs(params)
        if self.zero1 is None:
            return pspecs
        return sharding.zero1_specs(params, pspecs, self.mesh)

    # Gradients share the master layout: the f32 grad buffer is ZeRO-sharded
    # instead of ballooning to a model-sharded-only copy.
    grad_specs = master_specs

    def train_state_specs(self, state: Any) -> Any:
        """Spec tree for a MixedPrecisionState (master / opt moments get the
        zero1 layout, scalars replicate)."""
        from repro.core.loss_scale import LossScaleState
        from repro.core.master_weights import MixedPrecisionState
        mspecs = self.master_specs(state.master)
        opt_specs = {k: (mspecs if k in ("mu", "nu") else P())
                     for k in state.opt_state}
        return MixedPrecisionState(
            master=mspecs, opt_state=opt_specs,
            loss_scale=LossScaleState(P(), P(), P(), P()))

    def batch_specs(self, batch: Any) -> Any:
        if self.dp is None:
            return sharding.replicated(batch)
        return sharding.batch_specs(batch, self.mesh,
                                    batch_axes=self.dp_axes)

    def serve_state_specs(self, states: Any, *, paged: bool = False) -> Any:
        if paged:
            return self.paged_state_specs(states)
        return sharding.state_specs(states, self.mesh,
                                    batch_axes=self.dp_axes)

    def paged_state_specs(self, states: Any) -> Any:
        """Specs for the paged KV slot pool. Unlike fixed-slot caches there
        is no batch dim to shard — the pool is shared by every in-flight
        request and slots are gathered by index, so the slot dim stays
        replicated over the data axes; the kv-head dim shards over 'model'
        (matching attention TP) when divisible."""
        msize = self.tp_size

        def spec_one(x):
            shape = np.shape(x)
            hdim = len(shape) - 2   # (..., n_slots, n_kv_heads, head_dim)
            if msize > 1 and len(shape) >= 3 and shape[hdim] % msize == 0:
                spec = [None] * len(shape)
                spec[hdim] = "model"
                return P(*spec)
            return P()

        return jax.tree_util.tree_map(spec_one, states)

    def logits_spec(self, batch: int, vocab: int) -> P:
        vdim = "model" if (self.tp_size > 1
                           and vocab % self.tp_size == 0) else None
        dp = self.dp_axes
        bdim = None
        if dp and batch % self.dp_size == 0:
            bdim = dp if len(dp) > 1 else dp[0]
        return P(bdim, None, vdim)

    # -- collectives ---------------------------------------------------------
    def shard_map(self, f, in_specs, out_specs):
        """shard_map over the dp axes (manual); the model axis would be left
        to the auto partitioner — refused at build() on old JAX."""
        auto = frozenset({"model"}) if self.tp_size > 1 else frozenset()
        return sharding.shard_map_compat(f, self.mesh, in_specs, out_specs,
                                         auto=auto)

    def dp_allreduce(self, *, wire: Optional[str] = None):
        """The stacked-contract DP reduction over the wire axis:
        allreduce(grads, error) -> (reduced, new_error); leaves of grads /
        error carry a leading per-device axis sharded P(wire_axis)."""
        w = self.wire_axis
        if w is None:
            raise ValueError("no data-parallel axes: nothing to reduce")
        auto = frozenset({"model"}) if self.tp_size > 1 else frozenset()
        wire = self.dist.wire if wire is None else wire
        if wire == "fp8_ef":
            return make_compressed_dp_allreduce(self.mesh, axis_name=w,
                                                fmt=E5M2, auto=auto)
        return make_full_dp_allreduce(self.mesh, axis_name=w, auto=auto)

    def gather_params(self, params: Any) -> Array:
        """The ZeRO-1 weight all-gather leg. With wire_zero_gather='fp8'
        each 'data'-sharded leaf is re-gathered explicitly as e4m3 payloads
        (shared per-leaf scale, 1 byte/element on the wire); otherwise the
        params pass through and XLA's native bf16 gather applies."""
        if self.dist.wire_zero_gather != "fp8" or self.zero1 is None:
            return params
        mspecs = self.master_specs(params)
        zaxis = self.zero1.axis

        def manual_spec(x, spec):
            entries = list(spec) + [None] * (len(np.shape(x)) - len(spec))
            return P(*[e if e == zaxis else None for e in entries])

        in_specs = jax.tree_util.tree_map(manual_spec, params, mspecs)
        out_specs = sharding.replicated(params)

        def body(tree):
            def leaf(x, spec):
                entries = tuple(spec)
                if zaxis not in entries:
                    return x
                d = entries.index(zaxis)
                xf = x.astype(jnp.float32)
                amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), zaxis)
                scale = jnp.maximum(amax / E4M3.max_normal, 1e-30)
                q = quantize_rne(xf / scale, E4M3, saturate=True)
                g = jax.lax.all_gather(q.astype(E4M3.dtype), zaxis,
                                       axis=d, tiled=True)
                return (g.astype(jnp.float32) * scale).astype(x.dtype)

            return jax.tree_util.tree_map(leaf, tree, mspecs)

        return self.shard_map(body, (in_specs,), out_specs)(params)

    # -- error-feedback wire state -------------------------------------------
    def init_wire_state(self, params: Any) -> Any:
        """Error-feedback residual pytree: one f32 residual per wire device
        per master leaf, stacked on a leading axis sharded P(wire_axis).
        Lives next to ScaleState in the checkpoint."""
        n = self.n_wire

        def one(p):
            z = jnp.zeros((n,) + tuple(np.shape(p)), jnp.float32)
            return z

        err = jax.tree_util.tree_map(one, params)
        if jax.tree_util.tree_leaves(params) and isinstance(
                jax.tree_util.tree_leaves(params)[0], jax.Array):
            shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(self.mesh, s),
                self.wire_state_specs(err))
            err = jax.device_put(err, shardings)
        return err

    def wire_state_struct(self, params_struct: Any) -> Any:
        n = self.n_wire
        return jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct((n,) + tuple(np.shape(p)),
                                           jnp.float32), params_struct)

    def wire_state_specs(self, err: Any) -> Any:
        w = self.wire_axis
        return jax.tree_util.tree_map(lambda _: P(w), err)

    # -- accounting / description --------------------------------------------
    def wire_bytes(self, params: Any) -> dict:
        """Modeled per-step wire bytes of the DP gradient reduction over the
        wire axis (matches the 1-byte fp8 payload dtypes in the lowered
        HLO). Keys feed the comm/* metrics stream and BENCH_comm.json."""
        m = wire_bytes_model(params, self.n_wire)
        active = m["bytes_fp8_ef"] if self.compresses \
            else m["bytes_full_bf16"]
        m["wire"] = self.dist.wire if self.compresses else "full"
        m["bytes_per_step"] = active
        return m

    def describe(self) -> dict:
        """JSON-able summary for launch meta / logger sidecars / docs."""
        return {
            "dp_axes": list(self.dp_axes),
            "dp_size": self.dp_size,
            "zero1_axis": self.zero1.axis if self.zero1 else None,
            "tp_axis": self.tp.axis if self.tp else None,
            "tp_size": self.model_size if self.tp else 1,
            "wire": self.dist.wire,
            "wire_axis": self.wire_axis,
            "wire_zero_gather": self.dist.wire_zero_gather,
            "compresses": self.compresses,
        }
