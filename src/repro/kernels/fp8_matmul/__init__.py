from repro.kernels.fp8_matmul.ops import fp8_matmul
from repro.kernels.fp8_matmul.ref import fp8_matmul_ref

__all__ = ["fp8_matmul", "fp8_matmul_ref"]
