"""Pallas TPU kernel: FP8 x FP8 -> FP32-accumulated matmul (paper Fig. 1a).

TPU adaptation of the paper's FP8 GEMM primitive. The v5e MXU has no FP8
datapath, so FP8 here is a *memory* format (that is the paper's own stance:
FP32 accumulation, rounding in the epilogue, no exotic MAC hardware):

  HBM:  A (M,K) e5m2, B (K,N) e5m2      — half the bytes of bf16, quarter f32
  VMEM: tiles up-converted e5m2 -> bf16  — a VPU-register pass, no HBM traffic
  MXU:  bf16 x bf16 -> f32 accumulator scratch (paper: "32-bit accumulator")
  out:  f32 accumulator cast to out_dtype on the last K step

Blocking: (bm, bk) x (bk, bn) with K innermost ("arbitrary" semantics) so the
f32 accumulator tile lives in VMEM scratch across the K sweep. Default tiles
(256, 512, 256): A-tile 128 KiB + B-tile 128 KiB (fp8 bytes) + acc 256 KiB —
~0.5 MiB working set, leaving VMEM room for double buffering. All dims are
multiples of the 128-lane MXU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.kernels.compat import CompilerParams as _CompilerParams

DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256


def _mm_body(a_ref, b_ref, o_ref, acc_ref, *, out_dtype, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.bfloat16)   # e5m2 -> bf16 up-convert in VMEM
    b = b_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def fp8_matmul_kernel(a, b, *, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN,
                      out_dtype=jnp.float32, interpret: bool = False):
    """a: (M, K) fp8, b: (K, N) fp8 -> (M, N) out_dtype. Dims must divide."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_mm_body, out_dtype=out_dtype, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, b)
