"""Pure-jnp oracle for fp8_matmul: the exact MXU dataflow, untiled."""
from __future__ import annotations

import jax.numpy as jnp


def fp8_matmul_ref(a, b, *, out_dtype=jnp.float32):
    """bf16 multiplies, f32 accumulation — bit-matches the kernel because
    fp8->bf16 up-conversion is exact and tiled f32 accumulation of bf16
    products reassociates only across K blocks (tested at allclose 1e-6)."""
    return jnp.dot(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32).astype(out_dtype)
