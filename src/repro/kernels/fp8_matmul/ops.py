"""Jit'd public wrapper for fp8_matmul: padding to MXU-aligned tiles."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _at
from repro.kernels.fp8_matmul import kernel as _k


def _pad_to(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "autotune",
                                             "out_dtype", "interpret"))
def fp8_matmul(a, b, *, bm=None, bk=None, bn=None, autotune: str = "table",
               out_dtype=jnp.float32, interpret: bool = False):
    """a: (M, K) fp8, b: (K, N) fp8 -> (M, N). Pads to tile multiples
    (zero padding is exact for matmul) and slices the result back.
    Unset bm/bk/bn resolve through the autotuner winners table (see
    kernels.autotune; `autotune="off"` pins the built-in defaults);
    explicit ints always win."""
    m, n = a.shape[0], b.shape[1]
    # Shares the fused-GEMM (e5m2) table entries: the tile-dot dataflow is
    # identical and the quantize epilogue cost is block-independent.
    bm, bk, bn = _at.resolve_gemm_blocks(
        "nn", m, a.shape[1], n, out_format="e5m2", bm=bm, bk=bk, bn=bn,
        autotune=autotune,
        defaults=(_k.DEFAULT_BM, _k.DEFAULT_BK, _k.DEFAULT_BN))
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(128, n))
    bk_ = min(bk, max(128, a.shape[1]))
    ap = _pad_to(a, bm_, bk_)
    bp = _pad_to(b, bk_, bn_)
    out = _k.fp8_matmul_kernel(ap, bp, bm=bm_, bk=bk_, bn=bn_,
                               out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]
