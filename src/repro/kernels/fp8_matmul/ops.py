"""Jit'd public wrapper for fp8_matmul: padding to MXU-aligned tiles."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fp8_matmul import kernel as _k


def _pad_to(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "out_dtype",
                                             "interpret"))
def fp8_matmul(a, b, *, bm=_k.DEFAULT_BM, bk=_k.DEFAULT_BK, bn=_k.DEFAULT_BN,
               out_dtype=jnp.float32, interpret: bool = False):
    """a: (M, K) fp8, b: (K, N) fp8 -> (M, N). Pads to tile multiples
    (zero padding is exact for matmul) and slices the result back."""
    m, n = a.shape[0], b.shape[1]
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(128, n))
    bk_ = min(bk, max(128, a.shape[1]))
    ap = _pad_to(a, bm_, bk_)
    bp = _pad_to(b, bk_, bn_)
    out = _k.fp8_matmul_kernel(ap, bp, bm=bm_, bk=bk_, bn=bn_,
                               out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]
