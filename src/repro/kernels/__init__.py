"""Pallas TPU kernels for the FP8 training hot spots.

Three kernels, each with kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with padding/shape handling) and ref.py (pure-jnp
oracle used by tests):

 * stochastic_round   — the paper's Q node: f32/bf16 -> e5m2 with SR/RNE.
 * fp8_matmul         — FP8xFP8 -> FP32-accumulated matmul (paper Fig. 1a):
                        fp8 tiles live in HBM, are up-converted in VMEM, and
                        hit the MXU as bf16 with an f32 accumulator.
 * fused_quant_matmul — matmul with the quantize epilogue fused in VMEM: the
                        f32 accumulator tile is scaled + rounded to e5m2
                        before it ever leaves the chip (beyond-paper: the
                        paper materializes the f32 output then quantizes).
"""
