"""Jit'd public wrapper for the stochastic_round kernel (padding + reshaping)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.stochastic_round import kernel as _k


@functools.partial(jax.jit,
                   static_argnames=("fmt", "saturate", "interpret",
                                    "use_onchip_prng"))
def stochastic_round_fp8(x, key, scale=None, *, fmt: str = "e5m2",
                         saturate: bool = True, interpret: bool = False,
                         use_onchip_prng: bool = False):
    """Quantize x -> fp8 (`fmt` in {'e5m2','e4m3'}) with stochastic rounding
    via the Pallas kernel.

    Accepts any rank; internally flattens to 2D (TPU tiles are 2D). `key` is
    a JAX PRNG key (operand-randomness path) or an int32 seed scalar
    (on-chip-PRNG path).
    """
    if scale is None:
        scale = jnp.ones((1,), jnp.float32)
    scale = jnp.asarray(scale, jnp.float32).reshape((1,))
    orig_shape = x.shape
    n = orig_shape[-1] if x.ndim >= 1 else 1
    x2 = x.reshape((-1, n))
    if use_onchip_prng:
        seed = jnp.asarray(key, jnp.int32).reshape((1,))
        out = _k.sr_quantize_kernel_onchip(x2, seed, scale, fmt=fmt,
                                           saturate=saturate)
    else:
        rand8 = jax.random.bits(key, x2.shape, jnp.uint8)
        out = _k.sr_quantize_kernel(x2, rand8, scale, fmt=fmt,
                                    saturate=saturate, interpret=interpret)
    return out.reshape(orig_shape)


def stochastic_round_e5m2(x, key, scale=None, *, saturate: bool = True,
                          interpret: bool = False,
                          use_onchip_prng: bool = False):
    """Back-compat alias for the e5m2-hardwired name."""
    return stochastic_round_fp8(x, key, scale, fmt="e5m2", saturate=saturate,
                                interpret=interpret,
                                use_onchip_prng=use_onchip_prng)
