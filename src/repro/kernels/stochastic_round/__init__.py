from repro.kernels.stochastic_round.ops import (stochastic_round_e5m2,
                                                stochastic_round_fp8)
from repro.kernels.stochastic_round.ref import (stochastic_round_e5m2_ref,
                                                stochastic_round_fp8_ref)

__all__ = ["stochastic_round_fp8", "stochastic_round_fp8_ref",
           "stochastic_round_e5m2", "stochastic_round_e5m2_ref"]
