"""Pure-jnp oracle for the stochastic_round kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import sr_e5m2_from_bits


def stochastic_round_e5m2_ref(x, rand8, scale, *, saturate: bool = True):
    """Bit-exact reference: same math as the kernel, no tiling."""
    inv = (1.0 / scale.reshape(())).astype(jnp.float32)
    h = (x.astype(jnp.float32) * inv).astype(jnp.float16)
    bits = jax.lax.bitcast_convert_type(h, jnp.uint16)
    out_bits = sr_e5m2_from_bits(bits, rand8.astype(jnp.uint16),
                                 saturate=saturate)
    return jax.lax.bitcast_convert_type(out_bits, jnp.float16).astype(
        jnp.float8_e5m2)
