"""Pure-jnp oracle for the stochastic_round kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8_formats import get_format
from repro.core.quantize import sr_fp8_via_f16


def stochastic_round_fp8_ref(x, rand8, scale, *, fmt: str = "e5m2",
                             saturate: bool = True):
    """Bit-exact reference: same math as the kernel, no tiling."""
    inv = (1.0 / scale.reshape(())).astype(jnp.float32)
    y = x.astype(jnp.float32) * inv
    return sr_fp8_via_f16(y, rand8, get_format(fmt), saturate=saturate)


def stochastic_round_e5m2_ref(x, rand8, scale, *, saturate: bool = True):
    """Back-compat alias for the e5m2-hardwired name."""
    return stochastic_round_fp8_ref(x, rand8, scale, fmt="e5m2",
                                    saturate=saturate)
