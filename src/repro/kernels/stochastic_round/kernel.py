"""Pallas TPU kernel: stochastic rounding f32/bf16 -> fp8 (paper §3.2).

TPU adaptation of the paper's SR: the paper argues SR belongs in the
*epilogue*, not in the MAC path — on TPU that means a VPU pass over the
output tile while it is still in VMEM. The rounding itself is the exact
fp16 bit-twiddle (add uniform random bits below the kept mantissa, then
truncate; e4m3 goes through a power-of-two prescale first), shared
bit-for-bit with repro.core.quantize.sr_fp8_via_f16 — the kernel is
format-parameterized over float8_e5m2 and float8_e4m3fn.

Randomness: two sources, selected at trace time —
 * rand operand (uint8 tile streamed from HBM) — validated in interpret mode
   on CPU; costs 1 byte/element of extra HBM read.
 * on-chip PRNG (pltpu.prng_seed + prng_random_bits) — the production TPU
   path, zero extra HBM traffic. Not executable in CPU interpret mode (the
   interpreter stubs the PRNG), so it is exercised only when a real TPU is
   attached.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fp8_formats import get_format
from repro.core.quantize import sr_fp8_via_f16
from repro.kernels.compat import CompilerParams as _CompilerParams

# Block shape: 8x128 VPU lanes; 512x1024 f32 = 2 MiB in + 0.5 MiB out per
# block — comfortably inside a 16 MiB VMEM with double buffering.
DEFAULT_BLOCK = (512, 1024)


def _sr_body(x_ref, rand_ref, scale_ref, o_ref, *, fmt_name: str,
             saturate: bool):
    fmt = get_format(fmt_name)
    inv = 1.0 / scale_ref[0]
    y = x_ref[...].astype(jnp.float32) * inv
    o_ref[...] = sr_fp8_via_f16(y, rand_ref[...], fmt, saturate=saturate)


def _sr_body_onchip(seed_ref, x_ref, scale_ref, o_ref, *, fmt_name: str,
                    saturate: bool):
    fmt = get_format(fmt_name)
    # Per-block seed decorrelation: fold the grid position into the seed.
    i, j = pl.program_id(0), pl.program_id(1)
    pltpu.prng_seed(seed_ref[0] + i * pl.num_programs(1) + j)
    r = pltpu.prng_random_bits(x_ref.shape)
    r8 = (r & 0xFF).astype(jnp.uint16)
    inv = 1.0 / scale_ref[0]
    y = x_ref[...].astype(jnp.float32) * inv
    o_ref[...] = sr_fp8_via_f16(y, r8, fmt, saturate=saturate)


def sr_quantize_kernel(x, rand8, scale, *, block=DEFAULT_BLOCK,
                       fmt: str = "e5m2", saturate: bool = True,
                       interpret: bool = False):
    """x: (M, N) float; rand8: (M, N) uint8; scale: (1,) f32 -> (M, N) fp8."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_sr_body, fmt_name=fmt, saturate=saturate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), get_format(fmt).dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(x, rand8, scale)


def sr_quantize_kernel_onchip(x, seed, scale, *, block=DEFAULT_BLOCK,
                              fmt: str = "e5m2", saturate: bool = True):
    """Production TPU variant using the on-chip PRNG (no rand operand)."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_sr_body_onchip, fmt_name=fmt, saturate=saturate),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), get_format(fmt).dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(seed, x, scale)
