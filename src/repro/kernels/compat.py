"""JAX-version compat shared by the Pallas kernels."""
from jax.experimental.pallas import tpu as pltpu

# Renamed across JAX versions (TPUCompilerParams -> CompilerParams);
# accept both so the kernels run on either API generation.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
