"""Per-(shape, dtype, layout) block-size autotuner for the fused kernels.

The hand-picked block constants (fused GEMM DEFAULT_BM/BK/BN, attention
DEFAULT_BQ/DEFAULT_BKV) were tuned for large shapes and *lose* wall-clock
at small ones (BENCH_kernels.json: fused/unfused GEMM 0.88, attention 0.95
at s=256).  This module closes that gap:

 * it sweeps candidate block configs per (shape-bucket, layout, format)
   key and times each candidate on a *blocked XLA analogue* of the kernel
   schedule — the same dataflow the Pallas kernel executes (tile dots with
   f32 accumulation, quantize-in-epilogue, amax read once from the
   quantized tile).  The single-read amax is modelled as the kernel
   computes it: a 1-byte bit-pattern reduce (`fp8_amax_bits`) off the
   materialized quantized tile, never a float upcast-abs-max over the
   producer (XLA CPU would re-run the quantize inside the reduce loop and
   bill the kernel dataflow for work it never does);

 * every winner is gated on a bit-exact parity check of the REAL kernel
   (interpret mode) against the ref.py oracle before it is persisted —
   the autotuner can never record a config the kernel won't honor;

 * winners land in a JSON table consulted by the ops-layer entry points
   (`fused_quant_matmul`, `fp8_matmul`, `fp8_attention_fwd/bwd`) and by
   `launch/specs.py`.  Explicit knobs always win over the table; the table
   wins over the built-in defaults.  Correctness never depends on the
   table: results are bit-invariant to every valid block config (the
   streamed-invariance law), so a stale or foreign table can only change
   speed, never bits.

Table location: `src/repro/kernels/autotune_table.json` (shipped with the
repo), overridable via `$REPRO_AUTOTUNE_TABLE`.  The `autotune` knob on
the ops (and `QuantConfig.autotune`) is `"table"` (consult the default
table), `"off"` (built-in defaults only), or a path to an alternative
table.  Ops resolve at trace time, so an in-process table edit is picked
up on the next new-shape trace, not for already-traced shapes.

Shape keys bucket each dim to the next power of two so neighbouring sizes
share an entry:

    gemm.{nn|nt|tn}.{e5m2|e4m3}.m{M}_k{K}_n{N}
    attn.{fwd|bwd}.{mask_mode}.q{Q}_s{S}_d{D}

CLI:  python -m repro.kernels.autotune [--smoke] [--table PATH]
      (sweeps, prints a report, and writes winners to the table).
"""
from __future__ import annotations

import functools
import json
import os
import threading
from pathlib import Path

LANE = 128   # fp8 lane width shared by every kernel in this package
TQ = 128     # backward dK/dV contraction granularity (fp8_attention)

DEFAULT_TABLE = Path(__file__).with_name("autotune_table.json")
ENV_VAR = "REPRO_AUTOTUNE_TABLE"

_CACHE: dict = {}
_CACHE_LOCK = threading.Lock()


# --------------------------------------------------------------- table I/O
def table_path(autotune: str = "table"):
    """Map the `autotune` knob to a table path (None = don't consult)."""
    if autotune == "off":
        return None
    if autotune == "table":
        return Path(os.environ.get(ENV_VAR) or DEFAULT_TABLE)
    return Path(autotune)


def load_table(path) -> dict:
    """mtime-cached JSON load; a missing or malformed table reads empty
    (the table is advisory — it must never be able to break a run)."""
    if path is None:
        return {}
    path = Path(path)
    try:
        mtime = path.stat().st_mtime_ns
    except OSError:
        return {}
    with _CACHE_LOCK:
        hit = _CACHE.get(str(path))
        if hit is not None and hit[0] == mtime:
            return hit[1]
    try:
        table = json.loads(path.read_text())
    except (OSError, ValueError):
        return {}
    if not isinstance(table, dict):
        return {}
    with _CACHE_LOCK:
        _CACHE[str(path)] = (mtime, table)
    return table


def save_table(path, table: dict):
    """Atomic write (tmp + rename) + read-cache invalidation."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + f".tmp{os.getpid()}")
    tmp.write_text(json.dumps(table, indent=1, sort_keys=True) + "\n")
    tmp.rename(path)
    with _CACHE_LOCK:
        _CACHE.pop(str(path), None)


# ------------------------------------------------------------------- keys
def _bucket(n) -> int:
    b = 8
    while b < max(int(n), 1):
        b *= 2
    return b


def gemm_key(dims: str, m: int, k: int, n: int,
             out_format: str = "e5m2") -> str:
    return (f"gemm.{dims}.{out_format}."
            f"m{_bucket(m)}_k{_bucket(k)}_n{_bucket(n)}")


def attn_key(kind: str, mask_mode: str, q_len: int, s_len: int,
             d: int) -> str:
    return (f"attn.{kind}.{mask_mode}."
            f"q{_bucket(q_len)}_s{_bucket(s_len)}_d{_bucket(d)}")


# -------------------------------------------------------------- resolution
def _table_int(entry, key):
    v = entry.get(key) if isinstance(entry, dict) else None
    return int(v) if isinstance(v, int) and not isinstance(v, bool) \
        and v > 0 else None


def resolve_gemm_blocks(dims, m, k, n, *, out_format="e5m2",
                        bm=None, bk=None, bn=None, autotune="table",
                        defaults):
    """Effective (bm, bk, bn) for a GEMM call.  Per-knob precedence:
    explicit int > table entry > built-in default (`defaults` triple).
    Explicit knobs must be positive — no silent correction."""
    for name, v in (("bm", bm), ("bk", bk), ("bn", bn)):
        if v is not None and v <= 0:
            raise ValueError(f"explicit {name} must be positive, got {v}")
    entry = {}
    if autotune != "off" and (bm is None or bk is None or bn is None):
        entry = load_table(table_path(autotune)).get(
            gemm_key(dims, m, k, n, out_format), {})
    dbm, dbk, dbn = defaults
    bm = bm if bm is not None else (_table_int(entry, "bm") or dbm)
    bk = bk if bk is not None else (_table_int(entry, "bk") or dbk)
    bn = bn if bn is not None else (_table_int(entry, "bn") or dbn)
    return int(bm), int(bk), int(bn)


def _valid_block_q(kind, bq):
    if bq is None or bq <= 0:
        return False
    if kind == "bwd":
        return bq >= TQ and bq % TQ == 0
    return bq <= TQ or bq % TQ == 0


def resolve_attn_blocks(kind, mask_mode, q_len, s_len, d, *,
                        block_q=None, block_kv=None, autotune="table"):
    """Effective (block_q, block_kv) for an attention call; block_kv may
    resolve to None (downstream ref.resolve_block_kv applies the kernel
    default).  Explicit knobs the kernel cannot honor raise instead of
    being silently clamped: backward block_q is pinned to TQ multiples
    (dK/dV contraction granularity) and forward block_q above TQ must be
    a TQ multiple.  Table entries failing the same checks are ignored."""
    if block_q is not None and not _valid_block_q(kind, block_q):
        if kind == "bwd":
            raise ValueError(
                f"backward block_q must be a positive multiple of "
                f"TQ={TQ} (dK/dV contraction granularity), got {block_q}")
        raise ValueError(
            f"block_q must be positive and a multiple of {TQ} when "
            f"larger than {TQ}, got {block_q}")
    if block_kv is not None and (block_kv <= 0 or block_kv % LANE):
        raise ValueError(
            f"block_kv must be a positive multiple of {LANE}, "
            f"got {block_kv}")
    entry = {}
    if autotune != "off" and (block_q is None or block_kv is None):
        entry = load_table(table_path(autotune)).get(
            attn_key(kind, mask_mode, q_len, s_len, d), {})
    bq = block_q
    if bq is None:
        tv = _table_int(entry, "block_q")
        bq = tv if _valid_block_q(kind, tv) else TQ
    bkv = block_kv
    if bkv is None:
        tv = _table_int(entry, "block_kv")
        bkv = tv if tv is not None and tv % LANE == 0 else None
    return int(bq), bkv


# ------------------------------------------------- blocked timing analogues
# The sweep runs on whatever backend the process has (CI: CPU).  Pallas
# interpret-mode walls only measure the interpreter, so candidates are
# timed on blocked XLA programs with the kernel's dataflow instead: block
# shape genuinely moves the wall (loop trip counts, cache blocking,
# fusion extents) the same way it moves the kernel's schedule.

def _bench(fn, *args, iters=20, reps=5):
    """Best-of-`reps` mean wall of `iters` calls, in microseconds."""
    import time

    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def make_gemm_analogue(m, k, n, *, dims="nn", bm, bk, bn,
                       out_format="e5m2"):
    """Blocked analogue of the fused quantize-epilogue GEMM: (bm, bn)
    output tiles, bk-stepped f32 accumulation, SR quantize in the
    epilogue — all one program, so the f32 accumulator never round-trips
    HBM between the GEMM and the Q pass. The amax observation is a
    separate 1-byte bit-pattern reduce over the quantized payload,
    modelled IDENTICALLY to the unfused side's amax pass: in the kernel
    it's a grid-unit scalar accumulated from VMEM-resident bits (free),
    and folding it into this program instead would bill the fused
    dataflow for XLA CPU's in-program reduce codegen — work the kernel
    never does. Keeping the amax program symmetric on both sides leaves
    the measured difference to what the fused epilogue actually
    eliminates: the materialized f32 intermediate and the separate
    Q-pass dispatch."""
    import jax
    import jax.numpy as jnp

    from repro.core.fp8_formats import get_format
    from repro.core.quantize import fp8_amax_bits, sr_fp8_via_f16
    fmt = get_format(out_format)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)

    def tile_dot(a8, b8, i0, j0, k0):
        if dims == "nn":
            at, bt = a8[i0:i0 + bm, k0:k0 + bk], b8[k0:k0 + bk, j0:j0 + bn]
        elif dims == "nt":
            at, bt = a8[i0:i0 + bm, k0:k0 + bk], b8[j0:j0 + bn, k0:k0 + bk].T
        else:  # "tn"
            at, bt = a8[k0:k0 + bk, i0:i0 + bm].T, b8[k0:k0 + bk, j0:j0 + bn]
        return jax.lax.dot_general(
            at.astype(jnp.bfloat16), bt.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @jax.jit
    def dot_quant(a8, b8, rand8, scale):
        inv = 1.0 / scale
        rows = []
        for i0 in range(0, m, bm):
            cols = []
            for j0 in range(0, n, bn):
                # No zeros-init accumulator: the kernel's VMEM scratch is
                # written by the first k-step, and a materialized zeros +
                # add is an extra full-tile pass XLA CPU does not elide.
                parts = [tile_dot(a8, b8, i0, j0, k0)
                         for k0 in range(0, k, bk)]
                acc = functools.reduce(lambda x, y: x + y, parts)
                cols.append(sr_fp8_via_f16(
                    acc * inv, rand8[i0:i0 + bm, j0:j0 + bn], fmt))
            rows.append(cols[0] if len(cols) == 1
                        else jnp.concatenate(cols, axis=1))
        # Single-tile configs skip the concatenate: XLA materializes a
        # concat of one operand as a full copy.
        return rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=0)

    amax_bits = jax.jit(fp8_amax_bits)

    def f(a8, b8, rand8, scale):
        q = dot_quant(a8, b8, rand8, scale)
        return q, amax_bits(q)

    return f


def make_attn_analogue(s, d, *, bq, bkv, passes=1, fmt="e5m2"):
    """Blocked analogue of the causal fused-attention forward over
    (B, S, D) flattened heads. Each q-tile row of bq queries visits the
    kv-stripes the kernel's causal block maps visit — the strip
    [0, roundup(i0 + bq, bkv)), stripe-granular like the kernel, so
    coarser bkv honestly costs more over-diagonal work. passes=1 is the
    one-pass schedule: each score strip is computed once and consumed
    once. passes=2 is the retired two-pass schedule: an extra (m, l)
    score pass re-computes every strip first — the wall ratio of the two
    is the honest cost of that extra pass.

    Structure is a pipeline of small jitted programs per row (score dot
    + mask + S quantize | softmax + P quantize + PV), with tile offsets
    static so masks fold to constants and slicing happens in-jit — an
    eager slice or scalar on this host is a full dispatch (~100µs+) on
    its own. This mirrors the separately-jitted passes of the unfused
    side so per-element codegen is comparable and the measured
    difference is the dataflow: causal strip skipping, single-visit
    scores, and row-strip (never (S, S)) intermediates. One big jitted
    program would be unfaithful the other way — XLA CPU re-runs fused
    producers inside downstream float reduces, billing the kernel
    dataflow for work it never does. For the same reason amaxes are
    1-byte bit-pattern reduces off materialized inputs; the P amax uses
    the softmax identity max(e) = exp(rowmax(xx) - m) = 1 computed from
    the already-reduced m rather than a reduce over the in-jit e (which
    would re-run the exp chain inside the reduce loop).

    The per-row online (m, l, acc) rescale the real kernel carries
    across stripes is per-lane scalar work; the analogue folds it into
    one strip-level softmax per row, which preserves per-element visit
    counts and memory traffic — the quantities this cost model ranks
    block sizes by."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core.fp8_formats import get_format
    from repro.core.quantize import fp8_amax_bits, quantize_rne
    fmt_ = get_format(fmt)
    bq, bkv = min(bq, s), min(bkv, s)

    def _hi(i0):
        # Columns visited for the row at i0: stripe-granular roundup.
        return min(-(-(i0 + bq) // bkv) * bkv, s)

    def _mask(i0, hi):
        # Static offsets: the comparison folds to a constant mask.
        rows = i0 + jnp.arange(bq)[None, :, None]
        cols = jnp.arange(hi)[None, None, :]
        return cols <= rows

    @functools.partial(jax.jit, static_argnums=(0,))
    def score_row(i0, q8, k8):
        hi = _hi(i0)
        x = jax.lax.dot_general(
            q8[:, i0:i0 + bq].astype(jnp.bfloat16),
            k8[:, :hi].astype(jnp.bfloat16),
            (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        return quantize_rne(jnp.where(_mask(i0, hi), x, 0.0), fmt_)

    @functools.partial(jax.jit, static_argnums=(0,))
    def ml_row(i0, s8):
        # passes=2 first pass: (m, l) only, no PV work.
        xx = jnp.where(_mask(i0, _hi(i0)), s8.astype(jnp.float32), -1e30)
        m = jnp.max(xx, -1, keepdims=True)
        return m, jnp.sum(jnp.exp(xx - m), -1, keepdims=True)

    @functools.partial(jax.jit, static_argnums=(0,))
    def consume_row(i0, s8, v8):
        hi = _hi(i0)
        am_s = fp8_amax_bits(s8)
        xx = jnp.where(_mask(i0, hi), s8.astype(jnp.float32), -1e30)
        m = jnp.max(xx, -1, keepdims=True)
        e = jnp.exp(xx - m)      # masked: exp(-1e30 - m) flushes to 0
        p8 = quantize_rne(e, fmt_)
        am_p = fp8_amax_bits(quantize_rne(
            jnp.max(jnp.exp(jnp.max(xx, -1, keepdims=True) - m)), fmt_))
        l = jnp.sum(e, -1, keepdims=True)
        o = jax.lax.dot_general(
            p8.astype(jnp.bfloat16),
            v8[:, :hi].astype(jnp.bfloat16),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        return ((o / jnp.where(l > 0, l, 1.0)).astype(jnp.bfloat16),
                am_s, am_p)

    @jax.jit
    def epilogue(outs, am_s, am_p):
        return (jnp.concatenate(outs, axis=1),
                jnp.max(jnp.stack(am_s)), jnp.max(jnp.stack(am_p)))

    def f(q8, k8, v8):
        outs, am_s, am_p = [], [], []
        for i0 in range(0, s, bq):
            if passes == 2:
                r = ml_row(i0, score_row(i0, q8, k8))
                jax.tree_util.tree_map(
                    lambda x: x.block_until_ready(), r)
            o, a_s, a_p = consume_row(i0, score_row(i0, q8, k8), v8)
            outs.append(o)
            am_s.append(a_s)
            am_p.append(a_p)
        return epilogue(tuple(outs), tuple(am_s), tuple(am_p))

    return f


def make_attn_bwd_analogue(s, d, *, bq, bkv, fmt="e5m2"):
    """Jitted blocked analogue of the dQ backward schedule for one head:
    per (q-tile, stripe) recompute scores -> P, form dP = dO.V^T and
    dS = P*(dP - delta), quantize both (amax read once), accumulate
    dQ += dS.K — the per-stripe op mix of the real dq kernel body."""
    import jax
    import jax.numpy as jnp

    from repro.core.fp8_formats import get_format
    from repro.core.quantize import fp8_amax_bits, quantize_rne
    fmt_ = get_format(fmt)
    bq, bkv = min(bq, s), min(bkv, s)

    def f(q8, k8, v8, do):
        amax_dp = jnp.float32(0)
        amax_ds = jnp.float32(0)
        outs = []
        for i0 in range(0, s, bq):
            hi = i0 + bq
            dq = jnp.zeros((bq, d), jnp.float32)
            dot = jnp.zeros((bq, 1), jnp.float32)
            for j0 in range(0, hi, bkv):
                x = jax.lax.dot_general(
                    q8[i0:i0 + bq].astype(jnp.bfloat16),
                    k8[j0:j0 + bkv].astype(jnp.bfloat16),
                    (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                rows = i0 + jnp.arange(bq)[:, None]
                cols = j0 + jnp.arange(bkv)[None, :]
                valid = cols <= rows
                p = jnp.where(valid, jnp.exp(x - jnp.max(
                    x, -1, keepdims=True)), 0.0)
                dp = jax.lax.dot_general(
                    do[i0:i0 + bq].astype(jnp.bfloat16),
                    v8[j0:j0 + bkv].astype(jnp.bfloat16),
                    (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                dp8 = quantize_rne(dp, fmt_)
                dp8 = jax.lax.optimization_barrier(dp8)
                amax_dp = jnp.maximum(amax_dp, fp8_amax_bits(dp8))
                ds = p * (dp8.astype(jnp.float32) - dot)
                ds8 = quantize_rne(ds, fmt_)
                ds8 = jax.lax.optimization_barrier(ds8)
                amax_ds = jnp.maximum(amax_ds, fp8_amax_bits(ds8))
                dq = dq + jax.lax.dot_general(
                    ds8.astype(jnp.bfloat16),
                    k8[j0:j0 + bkv].astype(jnp.bfloat16),
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            outs.append(dq)
        return jnp.concatenate(outs, axis=0), amax_dp, amax_ds

    return jax.jit(f)


# ------------------------------------------------------------------ sweeps
def gemm_candidates(m, k, n, *, defaults, smoke=False):
    """Candidate (bm, bk, bn) triples for a shape: always includes the
    built-in default (so tuned-vs-default >= 1.0 by construction) and the
    whole-shape single block; deduped after the ops-layer clamps."""
    raw = [defaults, (m, k, n), (128, 128, 128)]
    if not smoke:
        raw += [(128, 256, 256), (256, 256, 256), (256, 512, 256),
                (512, 512, 512), (128, 512, 512)]
    out, seen = [], set()
    for bm, bk, bn in raw:
        c = (min(bm, max(8, m)), min(bk, max(128, k)),
             min(bn, max(128, n)))
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def attn_candidates(kind, q_len, s_len, *, smoke=False):
    """Candidate (block_q, block_kv) pairs — only configs the kernel
    honors (bwd block_q pinned to TQ multiples)."""
    bqs = (64, 128, 256) if kind == "fwd" else (128, 256)
    bkvs = (128, 256, 512)
    if smoke:
        bqs = (64, 128) if kind == "fwd" else (128,)
        bkvs = (128, 512)
    out, seen = [], set()
    for bq in bqs:
        for bkv in bkvs:
            c = (min(bq, max(1 if kind == "fwd" else TQ, q_len)),
                 min(bkv, -(-max(s_len, 1) // LANE) * LANE))
            if _valid_block_q(kind, c[0]) and c not in seen:
                seen.add(c)
                out.append(c)
    return out


def _gemm_parity(m, k, n, dims, out_format, bm, bk, bn):
    """Bit-check the real fused kernel (interpret) against its oracle at
    this block config; raises on any mismatch."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.fused_quant_matmul import (fused_quant_matmul,
                                                  fused_quant_matmul_ref)
    shapes = {"nn": ((m, k), (k, n)), "nt": ((m, k), (n, k)),
              "tn": ((k, m), (k, n))}[dims]
    a8 = (jax.random.normal(jax.random.PRNGKey(0), shapes[0])
          * 0.25).astype(jnp.float8_e5m2)
    b8 = (jax.random.normal(jax.random.PRNGKey(1), shapes[1])
          * 0.1).astype(jnp.float8_e5m2)
    key = jax.random.PRNGKey(2)
    scale = jnp.ones((1,), jnp.float32) * 2.0
    got, ga = fused_quant_matmul(a8, b8, key, scale, dims=dims, bm=bm,
                                 bk=bk, bn=bn, out_format=out_format,
                                 with_amax=True, amax_units="grid",
                                 interpret=True)
    rand8 = jax.random.bits(key, (m, n), jnp.uint8)
    ref, ra = fused_quant_matmul_ref(a8, b8, rand8, scale, dims=dims,
                                     out_format=out_format, with_amax=True)
    np.testing.assert_array_equal(np.asarray(got).view(np.uint8),
                                  np.asarray(ref).view(np.uint8))
    assert float(ga) == float(ra), (float(ga), float(ra))


def _attn_parity(s, d, kind, bq, bkv, fmt):
    """Bit-check the real attention kernel (interpret) against the ref
    oracle at this block config; raises on any mismatch."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.fp8_attention import (fp8_attention_bwd,
                                             fp8_attention_bwd_ref,
                                             fp8_attention_fwd,
                                             fp8_attention_fwd_ref)
    dt = jnp.float8_e4m3fn if fmt == "e4m3" else jnp.float8_e5m2
    q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i), (1, 2, s, d))
                   * 0.3).astype(dt) for i in range(3)]
    seed = jnp.uint32(7)
    kw = dict(mask_mode="causal", fmt_s=fmt, fmt_p=fmt, rounding_s="sr",
              rounding_p="sr")
    if kind == "fwd":
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                        block_q=bq, block_kv=bkv,
                                        interpret=True, **kw)
        ro, rs, rp, _, _ = fp8_attention_fwd_ref(q8, k8, v8, seed, scal,
                                                 block_kv=bkv, **kw)
        np.testing.assert_array_equal(
            np.asarray(o).view(np.uint16), np.asarray(ro).view(np.uint16))
        assert (float(a_s), float(a_p)) == (float(rs), float(rp))
    else:
        do8 = (jax.random.normal(jax.random.PRNGKey(4), (1, 2, s, d))
               * 0.2).astype(jnp.float8_e5m2)
        scal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                          0.05], jnp.float32)
        kw.update(fmt_e="e5m2", rounding_e="sr", saturate_e=False)
        outs = fp8_attention_bwd(q8, k8, v8, do8, seed, scal, block_q=bq,
                                 block_kv=bkv, interpret=True, **kw)
        refs = fp8_attention_bwd_ref(q8, k8, v8, do8, seed, scal, **kw)
        for a, r in zip(outs[:3], refs[:3]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(r))
        assert (float(outs[3]), float(outs[4])) \
            == (float(refs[3]), float(refs[4]))


def sweep_gemm(shapes=None, *, dims_list=("nn", "nt", "tn"),
               out_format="e5m2", smoke=False, parity=True, table=None,
               iters=20, reps=5, log=print):
    """Time every candidate per (shape, dims), gate the winner on kernel
    parity, and return (table_entries, report_rows)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_quant_matmul import kernel as _fk
    defaults = (_fk.DEFAULT_BM, _fk.DEFAULT_BK, _fk.DEFAULT_BN)
    if shapes is None:
        shapes = [(256, 256, 256)] if smoke \
            else [(256, 256, 256), (512, 512, 512), (1024, 1024, 1024)]
    table = {} if table is None else table
    report = []
    for m, k, n in shapes:
        a8 = (jax.random.normal(jax.random.PRNGKey(0), (m, k))
              * 0.25).astype(jnp.float8_e5m2)
        b8 = (jax.random.normal(jax.random.PRNGKey(1), (k, n))
              * 0.1).astype(jnp.float8_e5m2)
        rand8 = jax.random.bits(jax.random.PRNGKey(2), (m, n), jnp.uint8)
        scale = jnp.float32(2.0)
        for dims in dims_list:
            cands = gemm_candidates(m, k, n, defaults=defaults,
                                    smoke=smoke)
            # Analytic VMEM pre-filter: never time a candidate the model
            # proves can't fit.  The clamped default (cands[0]) is exempt
            # — it anchors tuned_vs_default — but gets a loud warning if
            # the model says it wouldn't fit either.
            from repro.analysis import vmem as _vm
            kept, pruned = _vm.prune_gemm_candidates(cands[1:], dims=dims)
            if not _vm.gemm_vmem(*cands[0], dims=dims).fits:
                log(f"[autotune] WARNING: default GEMM blocks "
                    f"{cands[0]} exceed the VMEM model for "
                    f"({m}, {k}, {n}) {dims}; timing it anyway as the "
                    f"baseline")
            for p in pruned:
                log(f"[autotune] prune {tuple(p['blocks'])} for "
                    f"({m}, {k}, {n}) {dims}: {p['reason']} "
                    f"({p['vmem_bytes']} > {p['budget_bytes']} bytes)")
            cands = [cands[0]] + kept
            walls = {}
            for bm, bk, bn in cands:
                fn = make_gemm_analogue(m, k, n, dims=dims, bm=bm, bk=bk,
                                        bn=bn, out_format=out_format)
                walls[(bm, bk, bn)] = _bench(fn, a8, b8, rand8, scale,
                                             iters=iters, reps=reps)
            default = cands[0]      # clamped built-in default, always first
            best = min(walls, key=walls.get)
            if parity:
                _gemm_parity(m, k, n, dims, out_format, *best)
            key = gemm_key(dims, m, k, n, out_format)
            table[key] = {
                "bm": best[0], "bk": best[1], "bn": best[2],
                "wall_us": round(walls[best], 2),
                "default_wall_us": round(walls[default], 2),
                "tuned_vs_default": round(walls[default] / walls[best], 4),
                "parity": "bitexact" if parity else "unchecked",
            }
            report.append({"key": key, "shape": [m, k, n], "dims": dims,
                           "candidates": {f"{c[0]}x{c[1]}x{c[2]}":
                                          round(w, 2)
                                          for c, w in walls.items()},
                           "pruned": pruned,
                           **table[key]})
            log(f"[autotune] {key}: tuned {best} "
                f"{walls[best]:.0f}us vs default {default} "
                f"{walls[default]:.0f}us "
                f"(x{walls[default] / walls[best]:.2f})")
    return table, report


def sweep_attention(shapes=None, *, kinds=("fwd", "bwd"),
                    mask_mode="causal", fmt="e5m2", smoke=False,
                    parity=True, table=None, iters=20, reps=5,
                    log=print):
    """Time every (block_q, block_kv) candidate per (s, d) and kind, gate
    winners on kernel parity, and return (table_entries, report_rows)."""
    import jax
    import jax.numpy as jnp
    if shapes is None:
        shapes = [(256, 64)] if smoke else [(256, 64), (512, 64),
                                            (1024, 128)]
    table = {} if table is None else table
    report = []
    for s, d in shapes:
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i), (s, d))
                       * 0.3).astype(jnp.float8_e5m2) for i in range(3)]
        do = (jax.random.normal(jax.random.PRNGKey(4), (s, d))
              * 0.2).astype(jnp.float8_e5m2)
        for kind in kinds:
            cands = attn_candidates(kind, s, s, smoke=smoke)
            # Analytic VMEM pre-filter (see sweep_gemm): can't-fit
            # candidates are logged + recorded, never timed.
            from repro.analysis import vmem as _vm
            kept, pruned = _vm.prune_attn_candidates(
                kind, cands, d, mask_mode=mask_mode)
            for p in pruned:
                log(f"[autotune] prune q{p['blocks'][0]}_kv"
                    f"{p['blocks'][1]} for ({s}, {d}) {kind}: "
                    f"{p['reason']} ({p['vmem_bytes']} > "
                    f"{p['budget_bytes']} bytes)")
            cands = kept
            walls = {}
            for bq, bkv in cands:
                if kind == "fwd":
                    fn = make_attn_analogue(s, d, bq=bq, bkv=bkv,
                                            passes=1, fmt=fmt)
                    walls[(bq, bkv)] = _bench(fn, q8[None], k8[None],
                                              v8[None], iters=iters,
                                              reps=reps)
                else:
                    fn = make_attn_bwd_analogue(s, d, bq=bq, bkv=bkv,
                                                fmt=fmt)
                    walls[(bq, bkv)] = _bench(fn, q8, k8, v8, do,
                                              iters=iters, reps=reps)
            from repro.kernels.fp8_attention import ref as _ar
            default = (min(TQ, s), _ar.resolve_block_kv(s, None))
            if not _vm.attn_vmem(kind, *default, d,
                                 mask_mode=mask_mode).fits:
                log(f"[autotune] WARNING: default attention blocks "
                    f"{default} exceed the VMEM model for ({s}, {d}) "
                    f"{kind}; timing them anyway as the baseline")
            if default not in walls:
                fn = (make_attn_analogue(s, d, bq=default[0],
                                         bkv=default[1], passes=1,
                                         fmt=fmt) if kind == "fwd" else
                      make_attn_bwd_analogue(s, d, bq=default[0],
                                             bkv=default[1], fmt=fmt))
                args_ = ((q8[None], k8[None], v8[None]) if kind == "fwd"
                         else (q8, k8, v8, do))
                walls[default] = _bench(fn, *args_, iters=iters, reps=reps)
            best = min(walls, key=walls.get)
            if parity:
                _attn_parity(s, d, kind, *best, fmt)
            key = attn_key(kind, mask_mode, s, s, d)
            table[key] = {
                "block_q": best[0], "block_kv": best[1],
                "wall_us": round(walls[best], 2),
                "default_wall_us": round(walls[default], 2),
                "tuned_vs_default": round(walls[default] / walls[best], 4),
                "parity": "bitexact" if parity else "unchecked",
            }
            report.append({"key": key, "shape": [s, d], "kind": kind,
                           "candidates": {f"q{c[0]}_kv{c[1]}": round(w, 2)
                                          for c, w in walls.items()},
                           "pruned": pruned,
                           **table[key]})
            log(f"[autotune] {key}: tuned {best} "
                f"{walls[best]:.0f}us vs default {default} "
                f"{walls[default]:.0f}us "
                f"(x{walls[default] / walls[best]:.2f})")
    return table, report


def run_sweep(*, smoke=False, table_file=None, parity=True, log=print):
    """Full sweep -> merge winners into the persisted table.  Returns the
    report rows (what kernel_bench records into BENCH_kernels.json)."""
    path = Path(table_file) if table_file is not None \
        else table_path("table")
    table = dict(load_table(path))
    _, rep_g = sweep_gemm(smoke=smoke, parity=parity, table=table,
                          log=log)
    _, rep_a = sweep_attention(smoke=smoke, parity=parity, table=table,
                               log=log)
    save_table(path, table)
    log(f"[autotune] wrote {len(table)} entries to {path}")
    return rep_g + rep_a


def main(argv=None):
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="small shapes / few candidates (CI nightly)")
    p.add_argument("--table", default=None,
                   help=f"winners table path (default: $"
                        f"{ENV_VAR} or {DEFAULT_TABLE})")
    p.add_argument("--no-parity", action="store_true",
                   help="skip the interpret-mode winner parity gate")
    args = p.parse_args(argv)
    run_sweep(smoke=args.smoke, table_file=args.table,
              parity=not args.no_parity)


if __name__ == "__main__":
    main()
