"""Pallas TPU kernel: FP8 matmul with the quantize epilogue FUSED in VMEM.

Beyond-paper optimization. The paper's dataflow materializes the FP32 GEMM
output to memory and then applies the Q node (down-convert + round) as a
separate op — on TPU that is an extra HBM round-trip of 4 bytes/element out +
4 in + 1 out. Fusing Q into the matmul epilogue means the f32 accumulator
tile is scaled and rounded to e5m2 *while still in VMEM*, writing only
1 byte/element to HBM: an 8x reduction in epilogue write traffic and the
elimination of the Q-node read pass entirely.

Rounding in the epilogue supports both RNE (deterministic) and SR, matching
the paper's Q-node semantics (sr via the exact fp16 bit-twiddle shared with
core.quantize). This is precisely the paper's architectural argument —
"rounding belongs in the epilogue, not the MAC" — taken one step further:
the epilogue never leaves the chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fp8_formats import get_format
from repro.core.quantize import sr_fp8_via_f16
from repro.kernels.compat import CompilerParams as _CompilerParams

DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256


def _quantize_tile(acc, rand8, inv_scale, *, fmt_name: str, rounding: str,
                   saturate: bool):
    fmt = get_format(fmt_name)
    y = acc * inv_scale
    if rounding == "rne":
        if saturate:
            y = jnp.clip(y, -fmt.max_normal, fmt.max_normal)
        return y.astype(fmt.dtype)
    return sr_fp8_via_f16(y, rand8, fmt, saturate=saturate)


def _body(a_ref, b_ref, rand_ref, scale_ref, o_ref, acc_ref, *,
          fmt_name: str, rounding: str, saturate: bool, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.bfloat16)
    b = b_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        inv = 1.0 / scale_ref[0]
        o_ref[...] = _quantize_tile(acc_ref[...], rand_ref[...], inv,
                                    fmt_name=fmt_name, rounding=rounding,
                                    saturate=saturate)


def _body_amax(a_ref, b_ref, rand_ref, scale_ref, o_ref, amax_ref, acc_ref, *,
               fmt_name: str, rounding: str, saturate: bool, n_k: int):
    """_body plus a per-tile amax epilogue output for delayed scaling: the
    observed amax of the quantized tile is computed from the f32 values
    while they are STILL IN VMEM — the observation costs no extra pass over
    HBM (the alternative, a separate amax op, re-reads the whole output)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.bfloat16)
    b = b_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        inv = 1.0 / scale_ref[0]
        q = _quantize_tile(acc_ref[...], rand_ref[...], inv,
                           fmt_name=fmt_name, rounding=rounding,
                           saturate=saturate)
        o_ref[...] = q
        # amax of the *quantized* values, de-scaled back to real units —
        # exactly what ScaleState history records.
        amax_ref[0, 0] = jnp.max(jnp.abs(q.astype(jnp.float32))) \
            * scale_ref[0]


def fused_quant_matmul_kernel(a, b, rand8, scale, *,
                              bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN,
                              out_format: str = "e5m2",
                              rounding: str = "sr", saturate: bool = True,
                              with_amax: bool = False,
                              interpret: bool = False):
    """a: (M,K) fp8, b: (K,N) fp8, rand8: (M,N) u8, scale: (1,) f32
    -> (M,N) fp8 output in `out_format` (value semantics: Q((a@b)/scale)).
    with_amax=True additionally returns a (grid_m, grid_n) f32 array of
    per-tile observed amaxes (reduce with jnp.max for the scalar)."""
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        pl.BlockSpec(memory_space=pltpu.SMEM),
    ]
    common = dict(
        grid=grid,
        in_specs=in_specs,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )
    out_dtype = get_format(out_format).dtype
    if not with_amax:
        return pl.pallas_call(
            functools.partial(_body, fmt_name=out_format, rounding=rounding,
                              saturate=saturate, n_k=grid[2]),
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            **common,
        )(a, b, rand8, scale)
    return pl.pallas_call(
        functools.partial(_body_amax, fmt_name=out_format, rounding=rounding,
                          saturate=saturate, n_k=grid[2]),
        out_specs=(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j, kk: (i, j))),
        out_shape=(jax.ShapeDtypeStruct((m, n), out_dtype),
                   jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.float32)),
        **common,
    )(a, b, rand8, scale)
