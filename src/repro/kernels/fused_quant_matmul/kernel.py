"""Pallas TPU kernel: FP8 matmul with the quantize epilogue FUSED in VMEM.

Beyond-paper optimization. The paper's dataflow materializes the FP32 GEMM
output to memory and then applies the Q node (down-convert + round) as a
separate op — on TPU that is an extra HBM round-trip of 4 bytes/element out +
4 in + 1 out. Fusing Q into the matmul epilogue means the f32 accumulator
tile is scaled and rounded to fp8 *while still in VMEM*, writing only
1 byte/element to HBM: an 8x reduction in epilogue write traffic and the
elimination of the Q-node read pass entirely.

Rounding in the epilogue supports both RNE (deterministic, the correctly-
rounded single-rounding path shared with core.quantize.quantize_rne) and SR
(the exact fp16 bit-twiddle shared with core.quantize), matching the paper's
Q-node semantics. This is precisely the paper's architectural argument —
"rounding belongs in the epilogue, not the MAC" — taken one step further:
the epilogue never leaves the chip.

Three contraction layouts cover the full training step (qeinsum fwd/bwd):

    dims="nn"   out = A    @ B     A:(M,K)  B:(K,N)   forward  Y = Q(A.W)
    dims="nt"   out = A    @ B^T   A:(M,C)  B:(N,C)   dgrad   dA = Q(dY.W^T)
    dims="tn"   out = A^T  @ B     A:(C,M)  B:(C,N)   wgrad   dW = Q(A^T.dY)

The transposed layouts index the k-sweep over the *contraction* axis of each
operand in HBM, so no materialized transpose (and no extra HBM pass) is ever
needed for the backward GEMMs.

The optional amax epilogue output is reported in *grid units* (the max |q|
of the quantized fp8 values, before de-scaling) and masked to the logical
(m, n) region, so zero-padded tiles can never leak into the delayed-scaling
observation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fp8_formats import get_format
from repro.core.quantize import quantize_rne, sr_fp8_via_f16
from repro.kernels.compat import CompilerParams as _CompilerParams

DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256

DIMS = ("nn", "nt", "tn")


def _quantize_tile(acc, rand8, inv_scale, *, fmt_name: str, rounding: str,
                   saturate: bool):
    fmt = get_format(fmt_name)
    y = acc * inv_scale
    if rounding == "rne":
        # The correctly-rounded f32 path (single rounding + explicit
        # overflow semantics) — the same function the unfused Q node uses,
        # so fused and unfused payloads are bit-identical by construction.
        return quantize_rne(y, fmt, saturate=saturate)
    return sr_fp8_via_f16(y, rand8, fmt, saturate=saturate)


def _tile_dot(a, b, dims: str):
    """f32-accumulated bf16 tile contraction for one k step of `dims`."""
    a = a.astype(jnp.bfloat16)
    b = b.astype(jnp.bfloat16)
    if dims == "nn":      # (bm, bk) x (bk, bn)
        contract = (((1,), (0,)), ((), ()))
    elif dims == "nt":    # (bm, bk) x (bn, bk)
        contract = (((1,), (1,)), ((), ()))
    else:                 # "tn": (bk, bm) x (bk, bn)
        contract = (((0,), (0,)), ((), ()))
    return jax.lax.dot_general(a, b, contract,
                               preferred_element_type=jnp.float32)


def _amax_mask(bm: int, bn: int, m: int, n: int):
    """Validity mask of the current (bm, bn) output tile against the logical
    (m, n) bounds — padded rows/cols are excluded from the amax epilogue so
    the observation is invariant to the (bm, bk, bn) tiling choice."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) \
        + pl.program_id(0) * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) \
        + pl.program_id(1) * bn
    return (rows < m) & (cols < n)


def _body(a_ref, b_ref, rand_ref, scale_ref, o_ref, acc_ref, *,
          dims: str, fmt_name: str, rounding: str, saturate: bool, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _tile_dot(a_ref[...], b_ref[...], dims)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        inv = 1.0 / scale_ref[0]
        o_ref[...] = _quantize_tile(acc_ref[...], rand_ref[...], inv,
                                    fmt_name=fmt_name, rounding=rounding,
                                    saturate=saturate)


def _body_amax(a_ref, b_ref, rand_ref, scale_ref, o_ref, amax_ref, acc_ref, *,
               dims: str, fmt_name: str, rounding: str, saturate: bool,
               n_k: int, m: int, n: int):
    """_body plus a per-tile amax epilogue output for delayed scaling: the
    observed amax of the quantized tile is computed from the fp8 values
    while they are STILL IN VMEM — the observation costs no extra pass over
    HBM (the alternative, a separate amax op, re-reads the whole output).
    The amax is in grid units (max |q| of the quantized values, no scale
    multiply) and is masked to the logical (m, n) region, exactly matching
    the bit-pattern reduction core.quantize.fp8_amax_bits performs on a
    materialized payload."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _tile_dot(a_ref[...], b_ref[...], dims)

    # Computed at body top level: jax 0.4.37's interpret mode does not
    # substitute program_id inside pl.when sub-jaxprs (value uses only;
    # conditions are fine) — the epilogue closes over the mask instead.
    bm, bn = acc_ref.shape
    mask = _amax_mask(bm, bn, m, n)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        inv = 1.0 / scale_ref[0]
        q = _quantize_tile(acc_ref[...], rand_ref[...], inv,
                           fmt_name=fmt_name, rounding=rounding,
                           saturate=saturate)
        o_ref[...] = q
        mag = jnp.where(mask, jnp.abs(q.astype(jnp.float32)), 0.0)
        amax_ref[0, 0] = jnp.max(mag)


def _body_amax_counts(a_ref, b_ref, rand_ref, scale_ref, o_ref, amax_ref,
                      sat_ref, flush_ref, acc_ref, *,
                      dims: str, fmt_name: str, rounding: str, saturate: bool,
                      n_k: int, m: int, n: int):
    """_body_amax plus per-tile precision-health counts (repro.obs): how many
    quantized values landed at/above the format ceiling (saturated — inf/nan
    from non-saturating error outputs included) and how many below min_normal
    (flushed: exact zeros + subnormals). Counted from the fp8 tile while it
    is STILL IN VMEM, in the same epilogue as the amax — the counters cost no
    extra pass over HBM — and masked to the logical (m, n) region like the
    amax. The quantize computation is untouched: counts on/off is
    bit-identical output (the repro.obs parity law)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _tile_dot(a_ref[...], b_ref[...], dims)

    bm, bn = acc_ref.shape
    mask = _amax_mask(bm, bn, m, n)
    fmt = get_format(fmt_name)
    hi = jnp.float32(fmt.max_normal)
    lo = jnp.float32(fmt.min_normal)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        inv = 1.0 / scale_ref[0]
        q = _quantize_tile(acc_ref[...], rand_ref[...], inv,
                           fmt_name=fmt_name, rounding=rounding,
                           saturate=saturate)
        o_ref[...] = q
        qf = q.astype(jnp.float32)
        mag = jnp.where(mask, jnp.abs(qf), 0.0)
        amax_ref[0, 0] = jnp.max(mag)
        sat = (jnp.abs(qf) >= hi) | ~jnp.isfinite(qf)
        flush = jnp.abs(qf) < lo
        sat_ref[0, 0] = jnp.sum(jnp.where(mask & sat, 1.0, 0.0))
        flush_ref[0, 0] = jnp.sum(jnp.where(mask & flush, 1.0, 0.0))


def _block_specs(dims: str, bm: int, bk: int, bn: int):
    if dims == "nn":
        return [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))]
    if dims == "nt":
        return [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk))]
    # "tn"
    return [pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))]


def gemm_shape(a_shape, b_shape, dims: str):
    """(M, N, C): logical output dims + contraction dim for a `dims` GEMM."""
    if dims == "nn":
        (m, c), (c2, n) = a_shape, b_shape
    elif dims == "nt":
        (m, c), (n, c2) = a_shape, b_shape
    elif dims == "tn":
        (c, m), (c2, n) = a_shape, b_shape
    else:
        raise ValueError(f"unknown dims {dims!r}; expected one of {DIMS}")
    assert c == c2, (a_shape, b_shape, dims)
    return m, n, c


def fused_quant_matmul_kernel(a, b, rand8, scale, *,
                              dims: str = "nn",
                              bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN,
                              out_format: str = "e5m2",
                              rounding: str = "sr", saturate: bool = True,
                              with_amax: bool = False,
                              with_counts: bool = False,
                              logical_mn=None,
                              interpret: bool = False):
    """fp8 GEMM (layout per `dims`, see module docstring) with the Q node in
    the epilogue: out = Q((a . b) / scale) -> (M, N) fp8 in `out_format`.
    rand8: (M, N) u8 SR bits, scale: (1,) f32.

    with_amax=True additionally returns a (grid_m, grid_n) f32 array of
    per-tile observed amaxes in grid units (reduce with jnp.max for the
    scalar; multiply by the dequantization scale for real units), masked to
    `logical_mn` (defaults to the padded (M, N)).

    with_counts=True (requires with_amax) further returns two (grid_m,
    grid_n) f32 arrays of per-tile saturated / flushed value counts
    (precision-health counters, see repro.obs.counters) — reduce with
    jnp.sum and divide by the logical element count for fractions."""
    m, n, k = gemm_shape(a.shape, b.shape, dims)
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    lm, ln = logical_mn if logical_mn is not None else (m, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    in_specs = _block_specs(dims, bm, bk, bn) + [
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        pl.BlockSpec(memory_space=pltpu.SMEM),
    ]
    common = dict(
        grid=grid,
        in_specs=in_specs,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )
    out_dtype = get_format(out_format).dtype
    if with_counts and not with_amax:
        raise ValueError("with_counts requires with_amax")
    if not with_amax:
        return pl.pallas_call(
            functools.partial(_body, dims=dims, fmt_name=out_format,
                              rounding=rounding, saturate=saturate,
                              n_k=grid[2]),
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            **common,
        )(a, b, rand8, scale)
    if not with_counts:
        return pl.pallas_call(
            functools.partial(_body_amax, dims=dims, fmt_name=out_format,
                              rounding=rounding, saturate=saturate,
                              n_k=grid[2], m=lm, n=ln),
            out_specs=(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
                       pl.BlockSpec((1, 1), lambda i, j, kk: (i, j))),
            out_shape=(jax.ShapeDtypeStruct((m, n), out_dtype),
                       jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.float32)),
            **common,
        )(a, b, rand8, scale)
    tile_f32 = jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.float32)
    return pl.pallas_call(
        functools.partial(_body_amax_counts, dims=dims, fmt_name=out_format,
                          rounding=rounding, saturate=saturate,
                          n_k=grid[2], m=lm, n=ln),
        out_specs=(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j, kk: (i, j))),
        out_shape=(jax.ShapeDtypeStruct((m, n), out_dtype),
                   tile_f32, tile_f32, tile_f32),
        **common,
    )(a, b, rand8, scale)
