"""Jit'd public wrapper for fused_quant_matmul."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _at
from repro.kernels.fused_quant_matmul import kernel as _k


def _pad_to(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("dims", "bm", "bk", "bn",
                                             "autotune",
                                             "out_format", "rounding",
                                             "saturate", "with_amax",
                                             "with_counts",
                                             "amax_units", "interpret"))
def fused_quant_matmul(a, b, key, scale=None, *,
                       dims: str = "nn",
                       bm=None, bk=None, bn=None,
                       autotune: str = "table",
                       out_format: str = "e5m2",
                       rounding: str = "sr", saturate: bool = True,
                       with_amax: bool = False,
                       with_counts: bool = False,
                       amax_units: str = "real",
                       interpret: bool = False):
    """Q((a . b) / scale) -> fp8 in `out_format` ('e5m2' | 'e4m3'), with the
    Q node fused into the epilogue. `dims` selects the contraction layout
    ('nn' A@B, 'nt' A@B^T, 'tn' A^T@B — see kernel module docstring); the
    transposed layouts serve the dgrad/wgrad GEMMs without materializing a
    transpose.

    with_amax=True returns (out, amax): the observed amax of the quantized
    output (delayed-scaling observation), computed in the epilogue while the
    tile is still in VMEM — no extra pass over HBM. amax_units='real'
    (default) de-scales the observation back to input units; 'grid' returns
    the raw max |q| over the fp8 grid, bit-identical to what the bit-pattern
    reduction core.quantize.fp8_amax_bits would report on the payload.

    SR random bits are drawn over the *logical* (m, n) output and zero-padded
    alongside the operands, and the amax epilogue masks the padded region, so
    results are invariant to the (bm, bk, bn) tiling choice.

    bm/bk/bn default to None: unset knobs resolve through the block-size
    autotuner winners table (`autotune`: "table" = the shipped /
    $REPRO_AUTOTUNE_TABLE table, "off" = built-in defaults, or a table
    path — see kernels.autotune) and fall back to the built-in defaults.
    Explicit ints always win. Resolution happens at trace time, per
    logical shape.

    with_counts=True (requires with_amax) returns (out, amax, health) where
    health is a (2,) f32 [saturated_fraction, flushed_fraction] of the
    logical output — the repro.obs precision-health counters, taken from the
    quantized tile in the same VMEM epilogue as the amax (no extra HBM
    pass). The quantize math is identical with counts on or off.
    """
    m, n, c = _k.gemm_shape(a.shape, b.shape, dims)
    bm, bk, bn = _at.resolve_gemm_blocks(
        dims, m, c, n, out_format=out_format, bm=bm, bk=bk, bn=bn,
        autotune=autotune,
        defaults=(_k.DEFAULT_BM, _k.DEFAULT_BK, _k.DEFAULT_BN))
    if scale is None:
        scale = jnp.ones((1,), jnp.float32)
    scale = jnp.asarray(scale, jnp.float32).reshape((1,))
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(128, n))
    bk_ = min(bk, max(128, c))
    if dims == "nn":
        ap, bp = _pad_to(a, bm_, bk_), _pad_to(b, bk_, bn_)
    elif dims == "nt":
        ap, bp = _pad_to(a, bm_, bk_), _pad_to(b, bn_, bk_)
    else:  # "tn"
        ap, bp = _pad_to(a, bk_, bm_), _pad_to(b, bk_, bn_)
    # Draw SR bits for the logical cells only; padded cells get zero bits
    # (their zero accumulator then stays exactly zero under SR truncation).
    rand8 = jax.random.bits(key, (m, n), jnp.uint8) if rounding == "sr" \
        else jnp.zeros((m, n), jnp.uint8)
    rand8 = _pad_to(rand8, bm_, bn_)
    out = _k.fused_quant_matmul_kernel(ap, bp, rand8, scale,
                                       dims=dims, bm=bm_, bk=bk_, bn=bn_,
                                       out_format=out_format,
                                       rounding=rounding, saturate=saturate,
                                       with_amax=with_amax,
                                       with_counts=with_counts,
                                       logical_mn=(m, n),
                                       interpret=interpret)
    if with_amax:
        health = None
        if with_counts:
            out, tile_amax, tile_sat, tile_flush = out
            health = jnp.stack([jnp.sum(tile_sat), jnp.sum(tile_flush)]) \
                / jnp.float32(m * n)
        else:
            out, tile_amax = out
        amax = jnp.max(tile_amax)
        if amax_units == "real":
            amax = amax * scale[0]
        elif amax_units != "grid":
            raise ValueError(f"unknown amax_units {amax_units!r}")
        if with_counts:
            return out[:m, :n], amax, health
        return out[:m, :n], amax
    return out[:m, :n]
