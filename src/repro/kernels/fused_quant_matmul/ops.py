"""Jit'd public wrapper for fused_quant_matmul."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_quant_matmul import kernel as _k


def _pad_to(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "out_format",
                                             "rounding", "saturate",
                                             "with_amax", "interpret"))
def fused_quant_matmul(a, b, key, scale=None, *,
                       bm=_k.DEFAULT_BM, bk=_k.DEFAULT_BK, bn=_k.DEFAULT_BN,
                       out_format: str = "e5m2",
                       rounding: str = "sr", saturate: bool = True,
                       with_amax: bool = False,
                       interpret: bool = False):
    """Q((a @ b) / scale) -> fp8 in `out_format` ('e5m2' | 'e4m3'), with the
    Q node fused into the epilogue.

    with_amax=True returns (out, amax): the observed amax of the quantized
    output (delayed-scaling observation), computed in the epilogue while the
    tile is still in VMEM — no extra pass over HBM."""
    m, n = a.shape[0], b.shape[1]
    if scale is None:
        scale = jnp.ones((1,), jnp.float32)
    scale = jnp.asarray(scale, jnp.float32).reshape((1,))
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(128, n))
    bk_ = min(bk, max(128, a.shape[1]))
    ap = _pad_to(a, bm_, bk_)
    bp = _pad_to(b, bk_, bn_)
    mp, np_ = ap.shape[0], bp.shape[1]
    rand8 = jax.random.bits(key, (mp, np_), jnp.uint8) if rounding == "sr" \
        else jnp.zeros((mp, np_), jnp.uint8)
    out = _k.fused_quant_matmul_kernel(ap, bp, rand8, scale,
                                       bm=bm_, bk=bk_, bn=bn_,
                                       out_format=out_format,
                                       rounding=rounding, saturate=saturate,
                                       with_amax=with_amax,
                                       interpret=interpret)
    if with_amax:
        out, tile_amax = out
        return out[:m, :n], jnp.max(tile_amax)
    return out[:m, :n]
