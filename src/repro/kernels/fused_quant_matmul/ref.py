"""Pure-jnp oracle for fused_quant_matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import sr_e5m2_from_bits


def fused_quant_matmul_ref(a, b, rand8, scale, *, rounding: str = "sr",
                           saturate: bool = True):
    acc = jnp.dot(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                  preferred_element_type=jnp.float32)
    y = acc * (1.0 / scale.reshape(()))
    if rounding == "rne":
        if saturate:
            y = jnp.clip(y, -57344.0, 57344.0)
        return y.astype(jnp.float8_e5m2)
    h = y.astype(jnp.float16)
    bits = jax.lax.bitcast_convert_type(h, jnp.uint16)
    out_bits = sr_e5m2_from_bits(bits, rand8.astype(jnp.uint16),
                                 saturate=saturate)
    return jax.lax.bitcast_convert_type(out_bits, jnp.float16).astype(
        jnp.float8_e5m2)
