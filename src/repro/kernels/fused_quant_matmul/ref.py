"""Pure-jnp oracle for fused_quant_matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8_formats import get_format
from repro.core.quantize import sr_fp8_via_f16


def fused_quant_matmul_ref(a, b, rand8, scale, *, out_format: str = "e5m2",
                           rounding: str = "sr", saturate: bool = True):
    fmt = get_format(out_format)
    acc = jnp.dot(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                  preferred_element_type=jnp.float32)
    y = acc * (1.0 / scale.reshape(()))
    if rounding == "rne":
        if saturate:
            y = jnp.clip(y, -fmt.max_normal, fmt.max_normal)
        return y.astype(fmt.dtype)
    return sr_fp8_via_f16(y, rand8, fmt, saturate=saturate)
