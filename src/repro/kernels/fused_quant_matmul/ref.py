"""Pure-jnp oracle for fused_quant_matmul: the UNFUSED quantize-after-matmul
composition (f32-accumulated bf16 GEMM, then a separate Q pass), against
which the fused kernel is locked bit-for-bit."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8_formats import get_format
from repro.core.quantize import quantize_rne, sr_fp8_via_f16


def _dot(a, b, dims: str):
    a = a.astype(jnp.bfloat16)
    b = b.astype(jnp.bfloat16)
    if dims == "nn":
        contract = (((1,), (0,)), ((), ()))
    elif dims == "nt":
        contract = (((1,), (1,)), ((), ()))
    elif dims == "tn":
        contract = (((0,), (0,)), ((), ()))
    else:
        raise ValueError(f"unknown dims {dims!r}")
    return jax.lax.dot_general(a, b, contract,
                               preferred_element_type=jnp.float32)


def fused_quant_matmul_ref(a, b, rand8, scale, *, dims: str = "nn",
                           out_format: str = "e5m2",
                           rounding: str = "sr", saturate: bool = True,
                           with_amax: bool = False):
    fmt = get_format(out_format)
    acc = _dot(a, b, dims)
    y = acc * (1.0 / scale.reshape(()))
    if rounding == "rne":
        q = quantize_rne(y, fmt, saturate=saturate)
    else:
        q = sr_fp8_via_f16(y, rand8, fmt, saturate=saturate)
    if with_amax:
        # Grid-units amax of the quantized payload (see ops.fused_quant_matmul
        # amax_units='grid').
        return q, jnp.max(jnp.abs(q.astype(jnp.float32)))
    return q
