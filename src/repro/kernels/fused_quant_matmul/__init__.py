from repro.kernels.fused_quant_matmul.ops import fused_quant_matmul
from repro.kernels.fused_quant_matmul.ref import fused_quant_matmul_ref

__all__ = ["fused_quant_matmul", "fused_quant_matmul_ref"]
