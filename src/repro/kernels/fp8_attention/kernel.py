"""Pallas TPU kernels: fused FP8 flash-attention with quantize-in-epilogue
S/P and delayed-scaling amax observation, zero S/P in HBM.

The unfused composition (models.attention._sdpa under FP8) round-trips the
(Q, S)-shaped score and prob matrices through HBM at full precision: QK^T
write + softmax read/write + Q-node read/write + PV read — O(Q*S) bytes of
traffic that dominates the training-step bandwidth at long context. These
kernels keep the whole S -> softmax -> P pipeline in VMEM: per query block
the score tile is computed, quantized to FP8 (the paper's Q_A node), fed
through a chunk-sequential softmax, re-quantized as FP8 probs and
immediately contracted with V — only the (Q, D) output and two scalar amax
observations per site ever leave the chip. The backward kernel recomputes
S8/P8 from the FP8 residuals (flash-attention style; the counter-based SR
hash in ref.py makes the recomputation bit-exact) and quantizes the dP/dS
intermediates to the error format so every backward GEMM is fp8 x fp8.

All tile math lives in ref.py (`fwd_q_tile` / `bwd_q_tile`) and is shared
verbatim with the unfused reference drivers, so kernel and oracle are
bit-identical in interpret mode by construction. GQA is resolved in the
block-index maps (kv head = q head // group) — the repeated K/V copies the
unfused path materializes via `_repeat_kv` never exist here.

Forward grid: (B, H, Q/block_q); K/V stream in as whole (padded) rows per
(batch, kv-head). Backward grid: (B, H) with a fixed internal 128-row query
tiling — dK/dV output blocks are revisited by the `group` consecutive query
heads of a kv head and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fp8_formats import get_format
from repro.kernels.compat import CompilerParams as _CompilerParams
from repro.kernels.fp8_attention import ref as _r

DEFAULT_BQ = 128
TQ = 128           # fixed backward query-tile height (not a knob: backward
#                    results are tiling-invariant by construction)


def _fwd_body(q_ref, k_ref, v_ref, msk_ref, scal_ref, seed_ref,
              o_ref, as_ref, ap_ref, *, n_heads: int, group: int, bq: int,
              mask_mode: str, window: int, q_len: int, s_len: int,
              fmt_s: str, fmt_p: str, rounding_s: str, rounding_p: str,
              saturate_s: bool, saturate_p: bool):
    b, h, iq = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    kvmask = None if msk_ref is None else msk_ref[...]
    o, amax_s, amax_p, _, _ = _r.fwd_q_tile(
        q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], kvmask,
        seed=seed_ref[0], bh=b * n_heads + h, row0=iq * bq,
        scal=(scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3]),
        mask_mode=mask_mode, window=window, q_len=q_len, s_len=s_len,
        fmt_s=fmt_s, fmt_p=fmt_p, rounding_s=rounding_s,
        rounding_p=rounding_p, saturate_s=saturate_s, saturate_p=saturate_p)
    o_ref[0, 0] = o
    as_ref[0, 0, 0] = amax_s
    ap_ref[0, 0, 0] = amax_p


def fp8_attention_fwd_kernel(q8, k8, v8, kv_mask, seed, scal, *,
                             block_q: int = DEFAULT_BQ,
                             mask_mode: str = "causal", window: int = 0,
                             q_len: int, s_len: int,
                             fmt_s: str, fmt_p: str,
                             rounding_s: str, rounding_p: str,
                             saturate_s: bool, saturate_p: bool,
                             interpret: bool = False):
    """q8 (B,H,Qp,Dp), k8/v8 (B,Hkv,Sp,Dp) fp8 payloads (pre-padded: Qp a
    block_q multiple, Sp/Dp LANE multiples); kv_mask None or (B,Sp) int8;
    seed (1,) u32; scal (4,) f32 [f_s, s_s, f_p, f_o].

    Returns (o (B,H,Qp,Dp) bf16, amax_s (B,H,nq) f32, amax_p (B,H,nq) f32)
    with amaxes in grid units, masked to the logical (q_len, s_len) region.
    """
    b_, h_, qp, dp = q8.shape
    hkv, sp = k8.shape[1], k8.shape[2]
    group = h_ // hkv
    bq = min(block_q, qp)
    grid = (b_, h_, qp // bq)

    def kv_index(b, h, i):
        return (b, h // group, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, bq, dp), lambda b, h, i: (b, h, i, 0)),
        pl.BlockSpec((1, 1, sp, dp), kv_index),
        pl.BlockSpec((1, 1, sp, dp), kv_index),
    ]
    args = [q8, k8, v8]
    if mask_mode == "kv":
        in_specs.append(pl.BlockSpec((1, sp), lambda b, h, i: (b, 0)))
        args.append(kv_mask)
        body = _fwd_body
    else:
        body = functools.partial(_masked_none_fwd, _fwd_body)
    in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM),
                 pl.BlockSpec(memory_space=pltpu.SMEM)]
    args += [scal, seed]
    return pl.pallas_call(
        functools.partial(body, n_heads=h_, group=group, bq=bq,
                          mask_mode=mask_mode, window=window,
                          q_len=q_len, s_len=s_len, fmt_s=fmt_s, fmt_p=fmt_p,
                          rounding_s=rounding_s, rounding_p=rounding_p,
                          saturate_s=saturate_s, saturate_p=saturate_p),
        grid=grid,
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, 1, bq, dp), lambda b, h, i: (b, h, i, 0)),
                   pl.BlockSpec((1, 1, 1), lambda b, h, i: (b, h, i)),
                   pl.BlockSpec((1, 1, 1), lambda b, h, i: (b, h, i))),
        out_shape=(jax.ShapeDtypeStruct((b_, h_, qp, dp), jnp.bfloat16),
                   jax.ShapeDtypeStruct((b_, h_, grid[2]), jnp.float32),
                   jax.ShapeDtypeStruct((b_, h_, grid[2]), jnp.float32)),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
    )(*args)


def _masked_none_fwd(body, q_ref, k_ref, v_ref, scal_ref, seed_ref,
                     o_ref, as_ref, ap_ref, **kw):
    """Adapter for mask-free modes: re-inserts msk_ref=None."""
    body(q_ref, k_ref, v_ref, None, scal_ref, seed_ref,
         o_ref, as_ref, ap_ref, **kw)


def _bwd_body(q_ref, k_ref, v_ref, do_ref, scal_ref, seed_ref,
              dq_ref, dk_ref, dv_ref, adp_ref, ads_ref, *,
              n_heads: int, group: int, mask_mode: str, window: int,
              q_len: int, s_len: int, fmt_s: str, fmt_p: str, fmt_e: str,
              rounding_s: str, rounding_p: str, rounding_e: str,
              saturate_s: bool, saturate_p: bool, saturate_e: bool):
    b, h = pl.program_id(0), pl.program_id(1)

    # dK/dV blocks are shared by the `group` query heads of one kv head;
    # the grid visits those heads consecutively, so zero on the first.
    @pl.when(h % group == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    q8, k8, v8, do8 = q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0]
    amax_dp = jnp.float32(0.0)
    amax_ds = jnp.float32(0.0)
    nt = q8.shape[0] // TQ
    for t in range(nt):
        sl = slice(t * TQ, (t + 1) * TQ)
        dq_t, dk_parts, dv_parts, a_dp, a_ds, _, _ = _r.bwd_q_tile(
            q8[sl], k8, v8, do8[sl], None,
            seed=seed_ref[0], bh=b * n_heads + h, row0=t * TQ,
            scal=tuple(scal_ref[i] for i in range(10)),
            mask_mode=mask_mode, window=window, q_len=q_len, s_len=s_len,
            fmt_s=fmt_s, fmt_p=fmt_p, fmt_e=fmt_e,
            rounding_s=rounding_s, rounding_p=rounding_p,
            rounding_e=rounding_e, saturate_s=saturate_s,
            saturate_p=saturate_p, saturate_e=saturate_e)
        dq_ref[0, 0, sl, :] = dq_t
        for j, (pk, pv_) in enumerate(zip(dk_parts, dv_parts)):
            js = slice(j * _r.LANE, (j + 1) * _r.LANE)
            dk_ref[0, 0, js, :] += pk
            dv_ref[0, 0, js, :] += pv_
        amax_dp = jnp.maximum(amax_dp, a_dp)
        amax_ds = jnp.maximum(amax_ds, a_ds)
    adp_ref[0, 0] = amax_dp
    ads_ref[0, 0] = amax_ds

    # dK/dV accumulate in raw grid units; the scale is applied exactly once
    # when the last query head of the kv-head group has contributed (see
    # ref.bwd_q_tile on why scale-per-part would FMA-fuse).
    @pl.when(h % group == group - 1)
    def _scale():
        dk_ref[...] = dk_ref[...] * scal_ref[8]
        dv_ref[...] = dv_ref[...] * scal_ref[9]


def fp8_attention_bwd_kernel(q8, k8, v8, do8, seed, scal, *,
                             mask_mode: str = "causal", window: int = 0,
                             q_len: int, s_len: int,
                             fmt_s: str, fmt_p: str, fmt_e: str,
                             rounding_s: str, rounding_p: str,
                             rounding_e: str,
                             saturate_s: bool, saturate_p: bool,
                             saturate_e: bool,
                             interpret: bool = False):
    """Backward of the fused attention (training masks only: causal/full).
    Inputs pre-padded (Qp a TQ multiple, Sp/Dp LANE multiples); scal (10,)
    f32 (see ref.bwd_q_tile). Returns (dq (B,H,Qp,Dp) f32,
    dk/dv (B,Hkv,Sp,Dp) f32, amax_dp (B,H) f32, amax_ds (B,H) f32) with
    amaxes in grid units."""
    b_, h_, qp, dp = q8.shape
    hkv, sp = k8.shape[1], k8.shape[2]
    group = h_ // hkv
    grid = (b_, h_)

    def kv_index(b, h):
        return (b, h // group, 0, 0)

    return pl.pallas_call(
        functools.partial(_bwd_body, n_heads=h_, group=group,
                          mask_mode=mask_mode, window=window,
                          q_len=q_len, s_len=s_len,
                          fmt_s=fmt_s, fmt_p=fmt_p, fmt_e=fmt_e,
                          rounding_s=rounding_s, rounding_p=rounding_p,
                          rounding_e=rounding_e, saturate_s=saturate_s,
                          saturate_p=saturate_p, saturate_e=saturate_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qp, dp), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, sp, dp), kv_index),
            pl.BlockSpec((1, 1, sp, dp), kv_index),
            pl.BlockSpec((1, 1, qp, dp), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, qp, dp), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, sp, dp), kv_index),
            pl.BlockSpec((1, 1, sp, dp), kv_index),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b_, h_, qp, dp), jnp.float32),
            jax.ShapeDtypeStruct((b_, hkv, sp, dp), jnp.float32),
            jax.ShapeDtypeStruct((b_, hkv, sp, dp), jnp.float32),
            jax.ShapeDtypeStruct((b_, h_), jnp.float32),
            jax.ShapeDtypeStruct((b_, h_), jnp.float32),
        ),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(q8, k8, v8, do8, scal, seed)
