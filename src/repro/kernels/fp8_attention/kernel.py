"""Pallas TPU kernels: streamed-KV fused FP8 flash attention with
quantize-in-epilogue S/P, delayed-scaling amax observation, and zero S/P in
HBM at ANY context length.

The PR-4 kernel held one (batch, kv-head)'s entire K/V row set in VMEM —
fine to ~8k fp8 context, hopeless at 32k. These kernels stream K/V through a
kv-stripe grid dimension instead, so the VMEM footprint is
O(block_kv * head_dim) per grid step regardless of the sequence length:

  forward grid   (B, H, Q/block_q, S/block_kv)
      ONE grid step per kv stripe: the online-softmax recurrence
      (ref.fwd_stripe_online) rescales the (l, PV accumulator) carries by
      exp(m_old - m_new) per LANE block, so each K/V stripe is DMA'd and
      read exactly once — the PR-5 kernel visited every stripe three times
      (m -> l -> PV phases), re-computing the quantized score tiles each
      visit. The carries live in VMEM scratch across stripes; the LANE-
      block chain is independent of the stripe cut, so outputs are
      invariant to block_kv. With every (k, v) block visited once, Mosaic's
      grid pipeline double-buffers the NEXT stripe's K/V DMA against the
      current stripe's compute (the revisiting phase structure used to
      defeat that overlap for 2 of every 3 visits).

  backward grid  (B, H, Q/block_q, 4 * S/block_kv)     [stats + dQ]
      Phases m -> l -> rd (the softmax-VJP row reduction, with the dP amax)
      -> dQ (with the dS amax). The tiny per-row (m, l, rd) statistics are
      written to HBM (the flash-attention LSE/delta pattern) for:

  backward grid  (B, Hkv, S/block_kv, group * Q/block_q)  [dK/dV]
      One dK/dV stripe block stays resident while every (GQA group member,
      query tile) contribution is accumulated into it in RAW grid units —
      contraction pinned to TQ=128 query rows so results are invariant to
      block_q — and the f_dk/f_dv scale is applied exactly once at the last
      visit (see ref.bwd_stripe_dkv on why scale-per-part would FMA-fuse).

Stripe skipping: causal and sliding-window modes visit only the
`ref.kv_stripe_span` / `ref.q_tile_span` stripe range per query tile — the
block index maps clamp skipped iterations onto an already-resident block (no
DMA) and `pl.when` predicates skip their compute entirely. A window=1k,
S=32k layer therefore touches ~1/32 of the stripes. Skipping is exact:
fully-masked stripes contribute exact zeros everywhere, and the amax
observations are masked to the attended region (ref.py module docstring).

All tile math lives in ref.py (the `*_stripe_*` pass functions) and is
shared verbatim with the unfused reference drivers, so kernel and oracle are
bit-identical in interpret mode by construction. GQA is resolved in the
block-index maps (kv head = q head // group) — the repeated K/V copies the
unfused path materializes via `_repeat_kv` never exist here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams
from repro.kernels.fp8_attention import ref as _r

DEFAULT_BQ = 128
DEFAULT_BKV = _r.DEFAULT_BKV   # kv-stripe rows resident in VMEM per step
TQ = _r.TQ        # fixed dK/dV contraction granularity in query rows (not a
#                   knob: backward results are tiling-invariant by
#                   construction)


def _span(iq, bq, bkv, nk, mask_mode, window):
    """Traced kv-stripe span for the q tile at grid index iq (same formula
    the reference drivers use — ref.kv_stripe_span)."""
    return _r.kv_stripe_span(iq * bq, bq, block_kv=bkv, n_kv=nk,
                             mask_mode=mask_mode, window=window,
                             _max=jnp.maximum, _min=jnp.minimum)


def _qspan(j, bq, bkv, nq, mask_mode, window):
    return _r.q_tile_span(j, block_q=bq, block_kv=bkv, n_q=nq,
                          mask_mode=mask_mode, window=window,
                          _max=jnp.maximum, _min=jnp.minimum)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_body(q_ref, k_ref, v_ref, msk_ref, scal_ref, seed_ref,
              o_ref, as_ref, ap_ref, m_scr, l_scr, acc_scr, *,
              n_heads: int, bq: int, bkv: int, nk: int,
              mask_mode: str, window: int, q_len: int, s_len: int,
              fmt_s: str, fmt_p: str, rounding_s: str, rounding_p: str,
              saturate_s: bool, saturate_p: bool,
              hs_ref=None, hp_ref=None, chunk_ref=None):
    # hs_ref/hp_ref: optional (1, 1, 1, 3) per-q-tile S/P precision-health
    # count outputs ([saturated, flushed, observed] — repro.obs), bound via
    # the _fwd_body_counts adapter. Observation-only: the stripe carries
    # and every quantize are untouched, so counts on/off is bit-identical.
    # chunk_ref ('chunk' mode): (B, 2) int32 SMEM [start, n_valid] rows —
    # per-batch chunk coordinates, bound via the _fwd_body_chunk adapter.
    b, h, iq, j = (pl.program_id(0), pl.program_id(1), pl.program_id(2),
                   pl.program_id(3))
    jmin, jmax = _span(iq, bq, bkv, nk, mask_mode, window)
    active = (j >= jmin) & (j <= jmax)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        as_ref[...] = jnp.zeros_like(as_ref)
        ap_ref[...] = jnp.zeros_like(ap_ref)
        if hs_ref is not None:
            hs_ref[...] = jnp.zeros_like(hs_ref)
            hp_ref[...] = jnp.zeros_like(hp_ref)

    kvmask = None if msk_ref is None else msk_ref[...]
    kw = dict(seed=seed_ref[0], bh=b * n_heads + h, row0=iq * bq,
              col0=j * bkv, scal2=(scal_ref[0], scal_ref[1]),
              mask_mode=mask_mode, window=window, q_len=q_len, s_len=s_len,
              fmt_s=fmt_s, rounding_s=rounding_s, saturate_s=saturate_s,
              f_p=scal_ref[2], fmt_p=fmt_p, rounding_p=rounding_p,
              saturate_p=saturate_p)
    if chunk_ref is not None:
        kw["chunk"] = (chunk_ref[b, 0], chunk_ref[b, 1])

    @pl.when(active)
    def _stripe():
        if hs_ref is None:
            m, l, acc, amax_s, amax_p, _, _ = _r.fwd_stripe_online(
                q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], kvmask,
                m_scr[...], l_scr[...], acc_scr[...],
                as_ref[0, 0, 0], ap_ref[0, 0, 0], **kw)
        else:
            m, l, acc, amax_s, amax_p, _, _, hs, hp = _r.fwd_stripe_online(
                q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], kvmask,
                m_scr[...], l_scr[...], acc_scr[...],
                as_ref[0, 0, 0], ap_ref[0, 0, 0],
                health_s=hs_ref[0, 0, 0], health_p=hp_ref[0, 0, 0], **kw)
            hs_ref[0, 0, 0] = hs
            hp_ref[0, 0, 0] = hp
        m_scr[...] = m
        l_scr[...] = l
        acc_scr[...] = acc
        as_ref[0, 0, 0] = amax_s
        ap_ref[0, 0, 0] = amax_p

    @pl.when(j == nk - 1)
    def _write():
        l = l_scr[...]
        d_safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] * scal_ref[3] / d_safe
                       ).astype(jnp.bfloat16)


def fp8_attention_fwd_kernel(q8, k8, v8, kv_mask, seed, scal, *,
                             chunk_pos=None,
                             block_q: int = DEFAULT_BQ,
                             block_kv: int = 0,
                             mask_mode: str = "causal", window: int = 0,
                             q_len: int, s_len: int,
                             fmt_s: str, fmt_p: str,
                             rounding_s: str, rounding_p: str,
                             saturate_s: bool, saturate_p: bool,
                             with_counts: bool = False,
                             interpret: bool = False):
    """q8 (B,H,Qp,Dp), k8/v8 (B,Hkv,Sp,Dp) fp8 payloads (pre-padded: Qp a
    block_q multiple, Sp a block_kv multiple, Dp a LANE multiple); kv_mask
    None or (B,Sp) int8 — (B,Sp) int32 slot positions for mask_mode='chunk',
    padded with -1, with chunk_pos (B,2) int32 [start, n_valid] per batch;
    seed (1,) u32; scal (4,) f32 [f_s, s_s, f_p, f_o].

    Returns (o (B,H,Qp,Dp) bf16, amax_s (B,H,nq) f32, amax_p (B,H,nq) f32)
    with amaxes in grid units, masked to the attended region.

    with_counts=True (training masks only) additionally returns hs, hp:
    (B, H, nq, 3) f32 per-q-tile [saturated, flushed, observed] counts of
    the in-kernel quantized S / P tiles — the repro.obs precision-health
    counters, accumulated next to the amaxes while the tiles are still in
    VMEM (S/P never reach HBM, so this is the ONLY place they can be
    counted). The stripe math is untouched: counts on/off is bit-identical.
    """
    b_, h_, qp, dp = q8.shape
    hkv, sp = k8.shape[1], k8.shape[2]
    group = h_ // hkv
    bq = min(block_q, qp)
    bkv = sp if not block_kv else min(block_kv, sp)
    nk = sp // bkv
    nq = qp // bq
    grid = (b_, h_, nq, nk)

    def kv_index(b, h, iq, u):
        jmin, jmax = _span(iq, bq, bkv, nk, mask_mode, window)
        return (b, h // group, jnp.clip(u, jmin, jmax), 0)

    in_specs = [
        pl.BlockSpec((1, 1, bq, dp), lambda b, h, iq, u: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, bkv, dp), kv_index),
        pl.BlockSpec((1, 1, bkv, dp), kv_index),
    ]
    args = [q8, k8, v8]
    if mask_mode in ("kv", "chunk"):
        if with_counts:
            raise ValueError("with_counts supports the training masks "
                             f"(causal/full), not {mask_mode!r}")
        in_specs.append(pl.BlockSpec((1, bkv),
                                     lambda b, h, iq, u: (b, u)))
        args.append(kv_mask)
        body = _fwd_body
        if mask_mode == "chunk":
            # Per-batch chunk coordinates ride whole in SMEM (scalars,
            # dynamically indexed by the batch program id).
            in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
            args.append(chunk_pos)
            body = _fwd_body_chunk
    elif with_counts:
        body = _fwd_body_counts
    else:
        body = functools.partial(_masked_none_fwd, _fwd_body)
    in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM),
                 pl.BlockSpec(memory_space=pltpu.SMEM)]
    args += [scal, seed]
    out_specs = (pl.BlockSpec((1, 1, bq, dp),
                              lambda b, h, iq, u: (b, h, iq, 0)),
                 pl.BlockSpec((1, 1, 1), lambda b, h, iq, u: (b, h, iq)),
                 pl.BlockSpec((1, 1, 1), lambda b, h, iq, u: (b, h, iq)))
    out_shape = (jax.ShapeDtypeStruct((b_, h_, qp, dp), jnp.bfloat16),
                 jax.ShapeDtypeStruct((b_, h_, nq), jnp.float32),
                 jax.ShapeDtypeStruct((b_, h_, nq), jnp.float32))
    if with_counts:
        out_specs += (pl.BlockSpec((1, 1, 1, 3),
                                   lambda b, h, iq, u: (b, h, iq, 0)),
                      pl.BlockSpec((1, 1, 1, 3),
                                   lambda b, h, iq, u: (b, h, iq, 0)))
        out_shape += (jax.ShapeDtypeStruct((b_, h_, nq, 3), jnp.float32),
                      jax.ShapeDtypeStruct((b_, h_, nq, 3), jnp.float32))
    return pl.pallas_call(
        functools.partial(body, n_heads=h_, bq=bq, bkv=bkv, nk=nk,
                          mask_mode=mask_mode, window=window,
                          q_len=q_len, s_len=s_len, fmt_s=fmt_s, fmt_p=fmt_p,
                          rounding_s=rounding_s, rounding_p=rounding_p,
                          saturate_s=saturate_s, saturate_p=saturate_p),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dp), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(*args)


def _masked_none_fwd(body, q_ref, k_ref, v_ref, scal_ref, seed_ref,
                     o_ref, as_ref, ap_ref, m_scr, l_scr, acc_scr, **kw):
    """Adapter for mask-free modes: re-inserts msk_ref=None."""
    body(q_ref, k_ref, v_ref, None, scal_ref, seed_ref,
         o_ref, as_ref, ap_ref, m_scr, l_scr, acc_scr, **kw)


def _fwd_body_chunk(q_ref, k_ref, v_ref, msk_ref, chunk_ref, scal_ref,
                    seed_ref, o_ref, as_ref, ap_ref, m_scr, l_scr, acc_scr,
                    **kw):
    """Adapter for 'chunk' mode: rebinds the positional (B, 2) SMEM chunk
    coordinates (after the slot-position mask in pallas_call order) as the
    chunk_ref keyword."""
    _fwd_body(q_ref, k_ref, v_ref, msk_ref, scal_ref, seed_ref,
              o_ref, as_ref, ap_ref, m_scr, l_scr, acc_scr,
              chunk_ref=chunk_ref, **kw)


def _fwd_body_counts(q_ref, k_ref, v_ref, scal_ref, seed_ref,
                     o_ref, as_ref, ap_ref, hs_ref, hp_ref,
                     m_scr, l_scr, acc_scr, **kw):
    """Mask-free forward body with the S/P health-count outputs bound
    (training masks only — the counts path is never used for serving's
    'kv' mode)."""
    _fwd_body(q_ref, k_ref, v_ref, None, scal_ref, seed_ref,
              o_ref, as_ref, ap_ref, m_scr, l_scr, acc_scr,
              hs_ref=hs_ref, hp_ref=hp_ref, **kw)


# ---------------------------------------------------------------------------
# backward kernel 1: softmax statistics + dQ  (grid streams kv stripes)
# ---------------------------------------------------------------------------

def _bwd_dq_body(q_ref, k_ref, v_ref, do_ref, scal_ref, seed_ref,
                 dq_ref, m_ref, l_ref, rd_ref, adp_ref, ads_ref,
                 m_scr, l_scr, rd_scr, dq_scr, *,
                 n_heads: int, bq: int, bkv: int, nk: int,
                 mask_mode: str, window: int, q_len: int, s_len: int,
                 fmt_s: str, fmt_p: str, fmt_e: str,
                 rounding_s: str, rounding_p: str, rounding_e: str,
                 saturate_s: bool, saturate_p: bool, saturate_e: bool,
                 hdp_ref=None, hds_ref=None):
    b, h, iq, u = (pl.program_id(0), pl.program_id(1), pl.program_id(2),
                   pl.program_id(3))
    j, phase = u % nk, u // nk
    jmin, jmax = _span(iq, bq, bkv, nk, mask_mode, window)
    active = (j >= jmin) & (j <= jmax)

    # amax outputs are PER (b, h, iq) — like the forward kernel — so the
    # parallel iq dimension carries no cross-iteration state (ops.py
    # reduces with an exact jnp.max); accumulating a shared (b, h) block
    # across iq would race if Mosaic partitioned the parallel dim.
    @pl.when(u == 0)
    def _init_row():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        rd_scr[...] = jnp.zeros_like(rd_scr)
        dq_scr[...] = jnp.zeros_like(dq_scr)
        adp_ref[...] = jnp.zeros_like(adp_ref)
        ads_ref[...] = jnp.zeros_like(ads_ref)
        if hdp_ref is not None:
            hdp_ref[...] = jnp.zeros_like(hdp_ref)
            hds_ref[...] = jnp.zeros_like(hds_ref)

    kw = dict(seed=seed_ref[0], bh=b * n_heads + h, row0=iq * bq,
              col0=j * bkv, scal2=(scal_ref[0], scal_ref[1]),
              mask_mode=mask_mode, window=window, q_len=q_len, s_len=s_len,
              fmt_s=fmt_s, rounding_s=rounding_s, saturate_s=saturate_s)
    bkw = dict(f_p=scal_ref[2], s_p=scal_ref[3], f_dp=scal_ref[4],
               s_dp=scal_ref[5], fmt_p=fmt_p, fmt_e=fmt_e,
               rounding_p=rounding_p, rounding_e=rounding_e,
               saturate_p=saturate_p, saturate_e=saturate_e)

    @pl.when(active & (phase == 0))
    def _pass_m():
        m, _, _ = _r.fwd_stripe_m(q_ref[0, 0], k_ref[0, 0], None,
                                  m_scr[...], jnp.float32(0.0), **kw)
        m_scr[...] = m

    @pl.when(active & (phase == 1))
    def _pass_l():
        l_scr[...] = _r.fwd_stripe_l(q_ref[0, 0], k_ref[0, 0], None,
                                     m_scr[...], l_scr[...], **kw)

    @pl.when(active & (phase == 2))
    def _pass_rd():
        l = l_scr[...]
        d_safe = jnp.where(l > 0, l, 1.0)
        if hdp_ref is not None:
            rd, amax_dp, _, hdp = _r.bwd_stripe_rd(
                q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0], None,
                m_scr[...], d_safe, rd_scr[...], adp_ref[0, 0, 0],
                health=hdp_ref[0, 0, 0], **kw, **bkw)
            hdp_ref[0, 0, 0] = hdp
        else:
            rd, amax_dp, _ = _r.bwd_stripe_rd(
                q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0], None,
                m_scr[...], d_safe, rd_scr[...], adp_ref[0, 0, 0],
                **kw, **bkw)
        rd_scr[...] = rd
        adp_ref[0, 0, 0] = amax_dp

    @pl.when(active & (phase == 3))
    def _pass_dq():
        l = l_scr[...]
        d_safe = jnp.where(l > 0, l, 1.0)
        if hds_ref is not None:
            dq_acc, amax_ds, _, hds = _r.bwd_stripe_dq(
                q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0], None,
                m_scr[...], d_safe, rd_scr[...], dq_scr[...],
                ads_ref[0, 0, 0], f_ds=scal_ref[6],
                health=hds_ref[0, 0, 0], **kw, **bkw)
            hds_ref[0, 0, 0] = hds
        else:
            dq_acc, amax_ds, _ = _r.bwd_stripe_dq(
                q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], do_ref[0, 0], None,
                m_scr[...], d_safe, rd_scr[...], dq_scr[...],
                ads_ref[0, 0, 0], f_ds=scal_ref[6], **kw, **bkw)
        dq_scr[...] = dq_acc
        ads_ref[0, 0, 0] = amax_ds

    @pl.when(u == 4 * nk - 1)
    def _write():
        dq_ref[0, 0] = dq_scr[...] * scal_ref[7]
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]
        rd_ref[0, 0] = rd_scr[...]


def _bwd_dq_body_counts(q_ref, k_ref, v_ref, do_ref, scal_ref, seed_ref,
                        dq_ref, m_ref, l_ref, rd_ref, adp_ref, ads_ref,
                        hdp_ref, hds_ref,
                        m_scr, l_scr, rd_scr, dq_scr, **kw):
    """Positional-ref adapter: the dP/dS health count outputs land after the
    amax outputs in pallas_call order; rebind them as keywords. Only the dQ
    kernel counts dP/dS — the dK/dV kernel replays the same quantized tiles
    and would double-count."""
    _bwd_dq_body(q_ref, k_ref, v_ref, do_ref, scal_ref, seed_ref,
                 dq_ref, m_ref, l_ref, rd_ref, adp_ref, ads_ref,
                 m_scr, l_scr, rd_scr, dq_scr,
                 hdp_ref=hdp_ref, hds_ref=hds_ref, **kw)


# ---------------------------------------------------------------------------
# backward kernel 2: dK/dV stripes  (grid streams GQA-group query tiles)
# ---------------------------------------------------------------------------

def _bwd_dkv_body(q_ref, do_ref, k_ref, v_ref, m_ref, l_ref, rd_ref,
                  scal_ref, seed_ref, dk_ref, dv_ref, *,
                  n_heads: int, group: int, bq: int, bkv: int,
                  nq: int, nk: int, mask_mode: str, window: int,
                  q_len: int, s_len: int,
                  fmt_s: str, fmt_p: str, fmt_e: str,
                  rounding_s: str, rounding_p: str, rounding_e: str,
                  saturate_s: bool, saturate_p: bool, saturate_e: bool):
    b, hkv, j, t = (pl.program_id(0), pl.program_id(1), pl.program_id(2),
                    pl.program_id(3))
    iq = t % nq
    h = hkv * group + t // nq
    jmin, jmax = _span(iq, bq, bkv, nk, mask_mode, window)
    active = (j >= jmin) & (j <= jmax)

    @pl.when(t == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    @pl.when(active)
    def _accumulate():
        bkw = dict(f_p=scal_ref[2], s_p=scal_ref[3], f_dp=scal_ref[4],
                   s_dp=scal_ref[5], fmt_p=fmt_p, fmt_e=fmt_e,
                   rounding_p=rounding_p, rounding_e=rounding_e,
                   saturate_p=saturate_p, saturate_e=saturate_e)

        # TQ sub-tiles via fori_loop (one traced body however large
        # block_q is — a python loop would inline bq/TQ copies of the
        # stripe math and blow up compile time at long context). The loop
        # is sequential, so the per-slice add order over (head, TQ tile)
        # is exactly the oracle's flat chain.
        def t2_body(t2, carry):
            r0 = t2 * TQ
            kw = dict(seed=seed_ref[0], bh=b * n_heads + h,
                      row0=iq * bq + r0, col0=j * bkv,
                      scal2=(scal_ref[0], scal_ref[1]),
                      mask_mode=mask_mode, window=window,
                      q_len=q_len, s_len=s_len, fmt_s=fmt_s,
                      rounding_s=rounding_s, saturate_s=saturate_s)
            l = l_ref[0, 0, pl.dslice(r0, TQ)]
            d_safe = jnp.where(l > 0, l, 1.0)
            dk_parts, dv_parts = _r.bwd_stripe_dkv(
                q_ref[0, 0, pl.dslice(r0, TQ)], k_ref[0, 0], v_ref[0, 0],
                do_ref[0, 0, pl.dslice(r0, TQ)], None,
                m_ref[0, 0, pl.dslice(r0, TQ)], d_safe,
                rd_ref[0, 0, pl.dslice(r0, TQ)], f_ds=scal_ref[6],
                **kw, **bkw)
            # RAW grid-unit accumulation; the scale is applied exactly
            # once below (see ref.bwd_stripe_dkv on the FMA hazard).
            for jj, (pk, pv_) in enumerate(zip(dk_parts, dv_parts)):
                js = slice(jj * _r.LANE, (jj + 1) * _r.LANE)
                dk_ref[0, 0, js, :] += pk
                dv_ref[0, 0, js, :] += pv_
            return carry

        jax.lax.fori_loop(0, max(1, bq // TQ), t2_body, 0)

    @pl.when(t == group * nq - 1)
    def _scale():
        dk_ref[...] = dk_ref[...] * scal_ref[8]
        dv_ref[...] = dv_ref[...] * scal_ref[9]


def fp8_attention_bwd_kernel(q8, k8, v8, do8, seed, scal, *,
                             block_q: int = DEFAULT_BQ,
                             block_kv: int = 0,
                             mask_mode: str = "causal", window: int = 0,
                             q_len: int, s_len: int,
                             fmt_s: str, fmt_p: str, fmt_e: str,
                             rounding_s: str, rounding_p: str,
                             rounding_e: str,
                             saturate_s: bool, saturate_p: bool,
                             saturate_e: bool,
                             with_counts: bool = False,
                             interpret: bool = False):
    """Backward of the fused attention (training masks only: causal/full).
    Inputs pre-padded (Qp a block_q multiple — block_q a TQ multiple when
    larger, Sp a block_kv multiple, Dp a LANE multiple); scal (10,) f32
    (see ref.bwd_q_tile). Runs the two streamed kernels (stats+dQ, then
    dK/dV) with the per-row (m, l, rd) statistics round-tripped through HBM
    in exact f32. Returns (dq (B,H,Qp,Dp) f32, dk/dv (B,Hkv,Sp,Dp) f32,
    amax_dp (B,H,nq) f32, amax_ds (B,H,nq) f32) with amaxes in grid units
    per query block (reduce with an exact max).

    with_counts=True additionally returns hdp, hds: (B, H, nq, 3) f32
    per-q-tile [saturated, flushed, observed] counts of the in-kernel
    quantized dP / dS tiles, accumulated in the dQ kernel's epilogue (the
    dK/dV kernel re-quantizes the same tiles and is deliberately excluded
    so nothing is counted twice). Stripe math is unchanged: counts on/off
    is bit-identical."""
    b_, h_, qp, dp = q8.shape
    hkv, sp = k8.shape[1], k8.shape[2]
    group = h_ // hkv
    bq = min(block_q, qp)
    if bq > TQ and bq % TQ:
        raise ValueError(f"backward block_q must be a multiple of {TQ}")
    bkv = sp if not block_kv else min(block_kv, sp)
    nk = sp // bkv
    nq = qp // bq
    fmt_kw = dict(mask_mode=mask_mode, window=window, q_len=q_len,
                  s_len=s_len, fmt_s=fmt_s, fmt_p=fmt_p, fmt_e=fmt_e,
                  rounding_s=rounding_s, rounding_p=rounding_p,
                  rounding_e=rounding_e, saturate_s=saturate_s,
                  saturate_p=saturate_p, saturate_e=saturate_e)

    def kv_index(b, h, iq, u):
        jmin, jmax = _span(iq, bq, bkv, nk, mask_mode, window)
        return (b, h // group, jnp.clip(u % nk, jmin, jmax), 0)

    dq_out_specs = (
        pl.BlockSpec((1, 1, bq, dp), lambda b, h, iq, u: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, bq, 1), lambda b, h, iq, u: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, bq, 1), lambda b, h, iq, u: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, bq, 1), lambda b, h, iq, u: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, 1), lambda b, h, iq, u: (b, h, iq)),
        pl.BlockSpec((1, 1, 1), lambda b, h, iq, u: (b, h, iq)),
    )
    dq_out_shape = (
        jax.ShapeDtypeStruct((b_, h_, qp, dp), jnp.float32),
        jax.ShapeDtypeStruct((b_, h_, qp, 1), jnp.float32),
        jax.ShapeDtypeStruct((b_, h_, qp, 1), jnp.float32),
        jax.ShapeDtypeStruct((b_, h_, qp, 1), jnp.float32),
        jax.ShapeDtypeStruct((b_, h_, nq), jnp.float32),
        jax.ShapeDtypeStruct((b_, h_, nq), jnp.float32),
    )
    dq_body = _bwd_dq_body
    if with_counts:
        dq_body = _bwd_dq_body_counts
        dq_out_specs += (pl.BlockSpec((1, 1, 1, 3),
                                      lambda b, h, iq, u: (b, h, iq, 0)),
                         pl.BlockSpec((1, 1, 1, 3),
                                      lambda b, h, iq, u: (b, h, iq, 0)))
        dq_out_shape += (jax.ShapeDtypeStruct((b_, h_, nq, 3), jnp.float32),
                         jax.ShapeDtypeStruct((b_, h_, nq, 3), jnp.float32))
    dq_outs = pl.pallas_call(
        functools.partial(dq_body, n_heads=h_, bq=bq, bkv=bkv, nk=nk,
                          **fmt_kw),
        grid=(b_, h_, nq, 4 * nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dp), lambda b, h, iq, u: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bkv, dp), kv_index),
            pl.BlockSpec((1, 1, bkv, dp), kv_index),
            pl.BlockSpec((1, 1, bq, dp), lambda b, h, iq, u: (b, h, iq, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=dq_out_specs,
        out_shape=dq_out_shape,
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dp), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q8, k8, v8, do8, scal, seed)
    if with_counts:
        dq, m, l, rd, amax_dp, amax_ds, hdp, hds = dq_outs
    else:
        dq, m, l, rd, amax_dp, amax_ds = dq_outs

    def q_index(b, hkv_, j, t):
        # Shared by the q/do blocks AND the m/l/rd statistics blocks —
        # they must be sliced identically per (head, q-tile).
        imin, imax = _qspan(j, bq, bkv, nq, mask_mode, window)
        return (b, hkv_ * group + t // nq, jnp.clip(t % nq, imin, imax), 0)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_body, n_heads=h_, group=group, bq=bq,
                          bkv=bkv, nq=nq, nk=nk, **fmt_kw),
        grid=(b_, hkv, nk, group * nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dp), q_index),
            pl.BlockSpec((1, 1, bq, dp), q_index),
            pl.BlockSpec((1, 1, bkv, dp),
                         lambda b, hkv_, j, t: (b, hkv_, j, 0)),
            pl.BlockSpec((1, 1, bkv, dp),
                         lambda b, hkv_, j, t: (b, hkv_, j, 0)),
            pl.BlockSpec((1, 1, bq, 1), q_index),
            pl.BlockSpec((1, 1, bq, 1), q_index),
            pl.BlockSpec((1, 1, bq, 1), q_index),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bkv, dp),
                         lambda b, hkv_, j, t: (b, hkv_, j, 0)),
            pl.BlockSpec((1, 1, bkv, dp),
                         lambda b, hkv_, j, t: (b, hkv_, j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b_, hkv, sp, dp), jnp.float32),
            jax.ShapeDtypeStruct((b_, hkv, sp, dp), jnp.float32),
        ),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q8, do8, k8, v8, m, l, rd, scal, seed)
    if with_counts:
        return dq, dk, dv, amax_dp, amax_ds, hdp, hds
    return dq, dk, dv, amax_dp, amax_ds
