from repro.kernels.fp8_attention.ops import (fp8_attention_bwd,
                                             fp8_attention_fwd)
from repro.kernels.fp8_attention.ref import (LANE, TQ, fp8_attention_bwd_ref,
                                             fp8_attention_fwd_ref,
                                             kv_stripe_span, q_tile_span,
                                             sr_hash_bits)

__all__ = ["fp8_attention_fwd", "fp8_attention_bwd",
           "fp8_attention_fwd_ref", "fp8_attention_bwd_ref",
           "sr_hash_bits", "kv_stripe_span", "q_tile_span", "LANE", "TQ"]
