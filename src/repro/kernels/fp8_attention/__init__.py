from repro.kernels.fp8_attention.ops import (fp8_attention_bwd,
                                             fp8_attention_fwd)
from repro.kernels.fp8_attention.ref import (LANE, fp8_attention_bwd_ref,
                                             fp8_attention_fwd_ref,
                                             sr_hash_bits)

__all__ = ["fp8_attention_fwd", "fp8_attention_bwd",
           "fp8_attention_fwd_ref", "fp8_attention_bwd_ref",
           "sr_hash_bits", "LANE"]
