"""Jit'd public wrappers for the streamed-KV fused FP8 attention kernels.

Padding contract: Q pads to a block_q multiple, the KV length to a block_kv
multiple (block_kv itself a LANE multiple, capped at the padded length so
short sequences keep one stripe), the head dim to a LANE (128) multiple —
all with zeros, which the shared stripe math makes numerically invisible
(exact-0.0 contributions; observations masked to the attended region), so
outputs and amaxes are invariant to padding and to the block_q / block_kv
choices. SR bits come from a counter-based hash of absolute coordinates
(ref.sr_hash_bits), so no rand array is ever materialized and every tiling
draws identical bits.

VMEM residency is O(block_q * D + block_kv * D) per grid step — the
sequence length only grows the grid, so 32k+ contexts train and serve
through the same kernels; causal / sliding-window tiles skip their
fully-masked stripes entirely (ref.kv_stripe_span).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _at
from repro.kernels.fp8_attention import kernel as _k
from repro.kernels.fp8_attention import ref as _r


def _health_frac(h):
    """(B, H, nq, 3) [sat, flush, observed] counts -> (2,) fractions."""
    tot = jnp.sum(h.reshape(-1, 3), axis=0)
    return tot[:2] / jnp.maximum(tot[2], 1.0)


@functools.partial(jax.jit, static_argnames=(
    "mask_mode", "window", "block_q", "block_kv", "autotune", "fmt_s",
    "fmt_p", "rounding_s", "rounding_p", "saturate_s", "saturate_p",
    "with_counts", "interpret"))
def fp8_attention_fwd(q8, k8, v8, seed, scal, *, mask_mode: str = "causal",
                      window: int = 0, kv_mask=None, chunk_pos=None,
                      block_q: int = None,
                      block_kv: int = None,
                      autotune: str = "table",
                      fmt_s: str = "e5m2", fmt_p: str = "e5m2",
                      rounding_s: str = "sr", rounding_p: str = "sr",
                      saturate_s: bool = True, saturate_p: bool = True,
                      with_counts: bool = False,
                      interpret: bool = False):
    """Fused FP8 attention forward on logical fp8 payloads.

    q8 (B,H,Q,D); k8/v8 (B,Hkv,S,D) — any fp8 dtype (the FP8 KV cache's
    e5m2 payloads compose with an e4m3 recipe; tiles upcast to bf16 for the
    MXU); seed u32 scalar; scal (4,) f32 [f_s, s_s, f_p, f_o] (ref module
    docstring). kv_mask: (B, S) int8/bool validity for mask_mode='kv';
    (B, S) int32 slot POSITIONS (-1 = hole/padding) for mask_mode='chunk',
    which additionally takes chunk_pos (B, 2) int32 [start, n_valid] —
    q row r of batch b sits at absolute position start_b + r when
    r < n_valid_b and is fully masked (exact-zero output) otherwise: the
    causal condition on logical positions, for paged/gathered KV layouts.
    block_kv: kv-stripe rows resident in VMEM per grid step. Unset
    block_q/block_kv resolve through the autotuner winners table (see
    kernels.autotune; `autotune="off"` pins the built-in defaults) and
    fall back to the kernel defaults; explicit knobs always win and are
    validated (never silently clamped to a different schedule). Results
    are bit-invariant to both knobs, so the table only moves wall-clock.

    Returns (o (B,H,Q,D) bf16, amax_s, amax_p) — scalar amaxes of the
    quantized S/P tiles in grid units (multiply by s_s / s_p for real
    units), masked to the attended region: bit-identical to
    `fp8_amax_bits` over the masked logical payloads of the unfused
    composition.

    with_counts=True (training masks only) additionally returns
    (health_s, health_p): (2,) f32 [saturated_fraction, flushed_fraction]
    of the in-kernel quantized S / P tiles over the attended region — the
    repro.obs precision-health counters, read in the same VMEM epilogue as
    the amaxes (S/P never hit HBM). Counts on/off is bit-identical.
    """
    b_, h_, q_len, d = q8.shape
    s_len = k8.shape[2]
    block_q, block_kv = _at.resolve_attn_blocks(
        "fwd", mask_mode, q_len, s_len, d, block_q=block_q,
        block_kv=block_kv, autotune=autotune)
    bq = min(block_q, max(1, q_len))
    bkv = _r.resolve_block_kv(s_len, block_kv)
    qp, kp, vp = _r.pad_qkv(q8, k8, v8, bq, bkv)
    mask = None
    cpos = None
    if mask_mode == "kv":
        mask = _r._pad_to(kv_mask.astype(jnp.int8), 1, bkv)
    elif mask_mode == "chunk":
        # Slot positions pad with -1: 0 is a VALID position, so the usual
        # zero padding would alias slot 0 into every padded lane.
        mask = _r._pad_to(kv_mask.astype(jnp.int32), 1, bkv, -1)
        cpos = jnp.asarray(chunk_pos, jnp.int32)
    seed = jnp.asarray(seed, jnp.uint32).reshape((1,))
    scal = jnp.asarray(scal, jnp.float32).reshape((4,))
    outs = _k.fp8_attention_fwd_kernel(
        qp, kp, vp, mask, seed, scal, chunk_pos=cpos,
        block_q=bq, block_kv=bkv,
        mask_mode=mask_mode,
        window=window, q_len=q_len, s_len=s_len, fmt_s=fmt_s, fmt_p=fmt_p,
        rounding_s=rounding_s, rounding_p=rounding_p,
        saturate_s=saturate_s, saturate_p=saturate_p,
        with_counts=with_counts, interpret=interpret)
    if with_counts:
        o, amax_s, amax_p, hs, hp = outs
        return (o[:, :, :q_len, :d], jnp.max(amax_s), jnp.max(amax_p),
                _health_frac(hs), _health_frac(hp))
    o, amax_s, amax_p = outs
    return o[:, :, :q_len, :d], jnp.max(amax_s), jnp.max(amax_p)


@functools.partial(jax.jit, static_argnames=(
    "mask_mode", "window", "block_q", "block_kv", "autotune", "fmt_s",
    "fmt_p", "fmt_e", "rounding_s", "rounding_p", "rounding_e",
    "saturate_s", "saturate_p", "saturate_e", "with_counts", "interpret"))
def fp8_attention_bwd(q8, k8, v8, do8, seed, scal, *,
                      mask_mode: str = "causal", window: int = 0,
                      block_q: int = None,
                      block_kv: int = None,
                      autotune: str = "table",
                      fmt_s: str = "e5m2", fmt_p: str = "e5m2",
                      fmt_e: str = "e5m2",
                      rounding_s: str = "sr", rounding_p: str = "sr",
                      rounding_e: str = "sr",
                      saturate_s: bool = True, saturate_p: bool = True,
                      saturate_e: bool = False,
                      with_counts: bool = False,
                      interpret: bool = False):
    """Fused FP8 attention backward (training masks: 'causal'/'full').
    do8: the error-quantized output cotangent payload (B,H,Q,D). scal (10,)
    f32 (ref.bwd_q_tile). An explicit block_q must be a positive TQ (128)
    multiple — dK/dV contraction granularity is pinned to TQ rows, so a
    sub-TQ request is a schedule the kernel cannot honor and raises
    (never a silent clamp). Unset knobs resolve through the autotuner
    winners table, then the kernel defaults; results are invariant to
    both block knobs. Returns (dq (B,H,Q,D) f32,
    dk/dv (B,Hkv,S,D) f32, amax_dp, amax_ds) with amaxes in grid units.

    with_counts=True additionally returns (health_dp, health_ds): (2,) f32
    [saturated_fraction, flushed_fraction] of the in-kernel quantized
    dP / dS tiles, counted once in the dQ kernel (the dK/dV kernel replays
    the same tiles and is excluded). Counts on/off is bit-identical."""
    if mask_mode not in ("causal", "full"):
        raise ValueError(
            f"fused attention backward supports causal/full, not "
            f"{mask_mode!r}")
    b_, h_, q_len, d = q8.shape
    s_len = k8.shape[2]
    block_q, block_kv = _at.resolve_attn_blocks(
        "bwd", mask_mode, q_len, s_len, d, block_q=block_q,
        block_kv=block_kv, autotune=autotune)
    bq = block_q
    bkv = _r.resolve_block_kv(s_len, block_kv)
    qp, kp, vp = _r.pad_qkv(q8, k8, v8, bq, bkv)
    dop = _r._pad_to(_r._pad_to(do8, 2, bq), 3, _r.LANE)
    seed = jnp.asarray(seed, jnp.uint32).reshape((1,))
    scal = jnp.asarray(scal, jnp.float32).reshape((10,))
    outs = _k.fp8_attention_bwd_kernel(
        qp, kp, vp, dop, seed, scal, block_q=bq, block_kv=bkv,
        mask_mode=mask_mode, window=window,
        q_len=q_len, s_len=s_len, fmt_s=fmt_s, fmt_p=fmt_p, fmt_e=fmt_e,
        rounding_s=rounding_s, rounding_p=rounding_p, rounding_e=rounding_e,
        saturate_s=saturate_s, saturate_p=saturate_p, saturate_e=saturate_e,
        with_counts=with_counts, interpret=interpret)
    if with_counts:
        dq, dk, dv, amax_dp, amax_ds, hdp, hds = outs
        return (dq[:, :, :q_len, :d], dk[:, :, :s_len, :d],
                dv[:, :, :s_len, :d], jnp.max(amax_dp), jnp.max(amax_ds),
                _health_frac(hdp), _health_frac(hds))
    dq, dk, dv, amax_dp, amax_ds = outs
    return (dq[:, :, :q_len, :d], dk[:, :, :s_len, :d],
            dv[:, :, :s_len, :d], jnp.max(amax_dp), jnp.max(amax_ds))
