"""Shared stripe math + unfused oracle for the fused FP8 flash-attention path.

This module is the SINGLE SOURCE OF TRUTH for the fused-attention numerics:
the Pallas kernel bodies (kernel.py) and the unfused reference drivers below
call the *same* per-stripe pass functions (`fwd_stripe_online`,
`bwd_stripe_rd` / `bwd_stripe_dq` / `bwd_stripe_dkv`, with
`fwd_stripe_m` / `fwd_stripe_l` recomputing the softmax statistics for the
backward), so in interpret mode the kernel is bit-identical to the unfused
quantize -> matmul -> softmax -> quantize -> matmul composition by
construction — the same guarantee structure `sr_fp8_from_bits` gives the
fused GEMM kernels.

Semantics (the paper's Fig. 1a dataflow extended into attention, all four
tensor classes in FP8):

    forward (ONE-PASS online softmax — each K/V stripe is read once):
        per LANE column block j, in ascending column order:
          S8_j = Q_A((q8 . k8_j^T) * f_s)       f_s = s_q s_k sm / s_s
          x_j  = S8_j * s_s                     (masked lanes -1e30)
          m'   = max(m, rowmax(x_j));  c = exp(m - m')
          e_j  = exp(x_j - m')                  (masked lanes exact 0)
          E8_j = Q_A(e_j / s_p)    UNNORMALIZED probs vs the running max
          l    = l * c + rowsum(e_j)
          acc  = acc * c + E8_j . v8_j
          m    = m'
        O = acc * (s_p s_v) / l   -> bf16       (l -> 1 fully-masked rows)
    backward:  P8  = Q_A(exp(x - m_final) / l / s_p)   (normalized — the
               exact softmax rows, recomputed from the two-pass statistics)
               dP8 = Q_E((do8 . v8^T) * f_dp)       f_dp = s_do s_v / s_dp
               dS  = P_deq * (dP_deq - rowsum(P_deq * dP_deq))
               dS8 = Q_E(dS * sm / s_ds)
               dQ = (dS8 . k8)   * (s_ds s_k)
               dK = (dS8^T . q8) * (s_ds s_q)
               dV = (P8^T . do8) * (s_p s_do)

The forward quantizes its probs UNNORMALIZED against the running row max
(e_j <= 1 because the running max dominates every column seen so far, with
exact 1.0 at the row's max column — better FP8 range utilization than the
normalized p = e/l it replaces), while the backward recomputes the
NORMALIZED P8 from the exact final statistics — the standard FP8
flash-attention structure: quantization is straight-through in the adjoint
either way, and the forward E8 tiles never reach HBM to be reused. Both
the `#p.A` amax observation and the P payload/health counters therefore
refer to the forward's unnormalized E8 tiles.

Streamed-KV structure: the KV axis is partitioned into stripes of `block_kv`
rows and the (m, l, PV accumulator) carries cross stripe boundaries — ONE
visit per stripe (the PR-5 kernel needed three). Results are invariant to
the `block_kv` choice because the online recurrence advances in fixed
LANE-wide column blocks whose order is independent of how they are grouped
into stripes: the running max after block j is the prefix max over blocks
<= j under ANY stripe cut, so every e_j / E8_j / l / acc value is
identical. `kv_stripe_span` gives the static per-q-tile stripe range
outside which causal/sliding-window tiles are FULLY masked; both the
kernels (via block index maps + predication) and the reference drivers
skip those stripes, which is exact because a fully-masked stripe
contributes exact-0.0 to `e`/`l`/PV/dQ/dK/dV, leaves `m` unchanged (its
rescale factor is exp(m - m) = exp(0) = exact 1.0), and (see below)
nothing to any amax.

Stripe-skip observation semantics (changed from the PR-4 kernel): the fused
amax observations at `#qk.A` / `#p.A` / `#dp.E` / `#ds.E` are masked to the
*attended* region — (row < q_len) AND the mask-mode validity — not to the
full logical rectangle. Scores/dP values at positions the mask excludes are
never part of any inner product and, under the streamed grid, are never
computed for skipped stripes; observing them would make the observation
depend on the stripe partition. The reference drivers materialize their
payloads with masked positions zeroed, so `fp8_amax_bits(payload)` equals
the in-kernel observation exactly.

Determinism / tiling invariance: every cross-position reduction (softmax
normalizer, PV / dQ accumulation) advances in fixed LANE-wide steps, dK/dV
contraction granularity is pinned to TQ=128 query rows, and SR bits are
drawn from a counter-based hash of the *absolute* (head, row, col)
coordinates — so results are invariant to the query/kv block-size knobs, to
padding (zero-padded lanes contribute exact 0.0), and identical between the
kernel grids and the reference loops. (One theoretical caveat: a skipped
stripe cannot flip a -0.0 accumulator element to +0.0 the way an explicit
`+ 0.0` add would; that divergence needs an all-zero quantized-P row and is
shared by kernel and oracle, which skip identically.) Zero materialized S/P
ever reaches HBM on the kernel path; the reference drivers materialize them
(that is the point of an oracle) and also return the payloads for
observation checks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.fp8_formats import get_format
from repro.core.quantize import quantize_rne, sr_fp8_via_f16

# Fixed inner reduction width (TPU lane count). All KV-axis loops advance in
# LANE steps regardless of any block-size knob.
LANE = 128

# Fixed dK/dV contraction granularity in query rows: each (TQ, LANE) dS/P
# tile contributes one (LANE, D) partial dot, accumulated in (head, q-tile)
# order — pinning the f32 reduction grouping so dK/dV are invariant to the
# backward block_q knob.
TQ = 128

# SR draw channels: one salt per in-kernel Q node so S/P/dP/dS consume
# independent bit streams at the same coordinates.
SALT_S, SALT_P, SALT_DP, SALT_DS = 0x51, 0x52, 0x53, 0x54

_GOLD = 0x9E3779B9  # 2^32 / golden ratio


def _fmix32(x):
    """murmur3 finalizer: full avalanche on uint32."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def sr_hash_bits(seed, salt: int, bh, rows, cols):
    """Counter-based uint8 SR bits from absolute tile coordinates.

    Unlike the fused GEMM kernels (which stream a materialized rand8 array
    from HBM), attention draws its SR bits *in the kernel* from a stateless
    hash of (seed, salt, batch*head, row, col) — an S-shaped rand array in
    HBM would cost exactly the S materialization the kernel exists to avoid.
    Bits depend only on absolute coordinates, so any tiling/padding draws
    identical bits for a logical cell."""
    gold = jnp.uint32(_GOLD)
    s = _fmix32(jnp.asarray(seed, jnp.uint32)
                + jnp.uint32(salt) * gold)
    s = _fmix32(s + jnp.asarray(bh, jnp.uint32) * gold)
    h = _fmix32(s + rows.astype(jnp.uint32) * gold)
    h = _fmix32(h ^ (cols.astype(jnp.uint32) * gold))
    return (h & jnp.uint32(0xFF)).astype(jnp.uint8)


def _quant_tile(y, bits, fmt_name: str, rounding: str, saturate: bool):
    fmt = get_format(fmt_name)
    if rounding == "rne":
        return quantize_rne(y, fmt, saturate=saturate)
    return sr_fp8_via_f16(y, bits, fmt, saturate=saturate)


def _mask_block(mask_mode: str, rows, cols, s_len: int, window: int, kvmask,
                qpos=None):
    """Validity of one (bq, LANE) score tile: KV padding is always masked;
    'causal' adds the triangular (+ optional sliding-window) condition from
    absolute coordinates; 'kv' ANDs a runtime per-batch validity row;
    'chunk' compares a runtime int32 row of KV slot POSITIONS (-1 = hole /
    padding) against per-q-row absolute positions `qpos` (-1 = inactive
    row) — the causal condition on logical positions rather than physical
    columns, which is what a paged/gathered KV layout needs."""
    valid = cols < s_len
    if mask_mode == "causal":
        valid = valid & (cols <= rows)
        if window:
            valid = valid & (cols > rows - window)
    elif mask_mode == "kv":
        valid = valid & (kvmask != 0)
    elif mask_mode == "chunk":
        valid = valid & (kvmask >= 0) & (kvmask <= qpos)
        if window:
            valid = valid & (kvmask > qpos - window)
    elif mask_mode != "full":
        raise ValueError(f"unknown mask mode {mask_mode!r}")
    return valid


def _dot_f32(a8, b8, contract):
    return jax.lax.dot_general(a8.astype(jnp.bfloat16),
                               b8.astype(jnp.bfloat16),
                               (contract, ((), ())),
                               preferred_element_type=jnp.float32)


def _score_block(q8, k8_sub, bits, f_s, fmt_s, rounding_s, saturate_s):
    """(bq, LANE) quantized score tile: S8 = Q((q8 . k8_sub^T) * f_s)."""
    s = _dot_f32(q8, k8_sub, ((1,), (1,)))
    return _quant_tile(s * f_s, bits, fmt_s, rounding_s, saturate_s)


# ---------------------------------------------------------------------------
# stripe-skip spans (shared by kernel index maps, kernel bodies, drivers)
# ---------------------------------------------------------------------------

def kv_stripe_span(row0, bq: int, *, block_kv: int, n_kv: int,
                   mask_mode: str, window: int,
                   _max=max, _min=min):
    """Inclusive [jmin, jmax] kv-stripe range a q tile of rows
    [row0, row0+bq) can attend under `mask_mode`; stripes outside it are
    FULLY masked for every row of the tile and are skipped by both the
    kernels and the reference drivers (exact — see module docstring).

    Works on python ints (drivers, tests) and, with
    `_max=jnp.maximum, _min=jnp.minimum`, on traced grid indices (the
    kernel block index maps and `pl.when` predicates use the same
    formula)."""
    if mask_mode != "causal":
        # 'full' attends everything; 'kv' validity is runtime data.
        return row0 * 0, row0 * 0 + (n_kv - 1)
    jmax = _min((row0 + bq - 1) // block_kv, n_kv - 1)
    jmin = row0 * 0
    if window:
        jmin = _max(row0 - window + 1, 0) // block_kv
    return jmin, jmax


def q_tile_span(j, *, block_q: int, block_kv: int, n_q: int,
                mask_mode: str, window: int, _max=max, _min=min):
    """Inverse of `kv_stripe_span`: the inclusive [imin, imax] q-tile range
    for which kv stripe j is (partially) attended. Used by the dK/dV kernel
    to clamp its q/do block index maps over skipped iterations; the active
    q tiles of a stripe always form this contiguous interval because
    `kv_stripe_span` bounds are monotone in the tile index."""
    if mask_mode != "causal":
        return j * 0, j * 0 + (n_q - 1)
    # smallest i with i*bq + bq - 1 >= j*bkv  (the causal jmax condition)
    imin = _max((j * block_kv - block_q + 1 + block_q - 1) // block_q, 0)
    imax = j * 0 + (n_q - 1)
    if window:
        # largest i with max(0, i*bq - window + 1) <= (j+1)*bkv - 1
        imax = _min(((j + 1) * block_kv + window - 2) // block_q, n_q - 1)
    return imin, imax


# ---------------------------------------------------------------------------
# per-stripe pass functions (the tile math shared with the kernels)
# ---------------------------------------------------------------------------

def _zeros_like_fp8(x):
    return jnp.zeros_like(x)


def _health_counts(q8t, obs, fmt_name: str):
    """(3,) f32 [saturated, flushed, observed] counts of one quantized tile
    over its observed region — the precision-health counters (repro.obs)
    accumulated next to the amax observations, from values already in
    VMEM/registers. Saturated: |q| at/above the format ceiling, inf/nan
    included (non-saturating error tensors keep inf). Flushed: |q| below
    min_normal (exact zeros + subnormals)."""
    fmt = get_format(fmt_name)
    qf = q8t.astype(jnp.float32)
    a = jnp.abs(qf)
    sat = (a >= jnp.float32(fmt.max_normal)) | ~jnp.isfinite(qf)
    flush = a < jnp.float32(fmt.min_normal)
    return jnp.stack([jnp.sum(jnp.where(obs & sat, 1.0, 0.0)),
                      jnp.sum(jnp.where(obs & flush, 1.0, 0.0)),
                      jnp.sum(jnp.where(obs, 1.0, 0.0))])


def _sblocks(q8, k8s, kvmask_s, *, seed, bh, row0, col0, scal2,
             mask_mode, window, q_len, s_len,
             fmt_s, rounding_s, saturate_s, chunk=None):
    """Yield (jj, s8, valid, x, cols, obs) for each LANE-wide column block
    of one kv stripe. scal2 = (f_s, s_s). obs is the OBSERVED region:
    logical rows AND mask validity (stripe-skip semantics — see module
    docstring). chunk ('chunk' mode only): per-batch (start, n_valid) int32
    scalars — q row r sits at absolute position start + r when r < n_valid,
    and is inactive (fully masked, exact-zero output) otherwise. Chunk
    positions are affine in the row index by construction (a chunk is a
    run of consecutive tokens), so two scalars replace a per-row vector —
    no cross-lane transpose in the kernel."""
    f_s, s_s = scal2
    bq = q8.shape[0]
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    qpos = None
    if chunk is not None:
        start, n_valid = chunk
        qpos = jnp.where(rows < n_valid, start + rows, jnp.int32(-1))
    for jj in range(k8s.shape[0] // LANE):
        cols = col0 + jj * LANE \
            + jax.lax.broadcasted_iota(jnp.int32, (1, LANE), 1)
        bits = sr_hash_bits(seed, SALT_S, bh, rows, cols) \
            if rounding_s == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        s8 = _score_block(q8, k8s[jj * LANE:(jj + 1) * LANE], bits, f_s,
                          fmt_s, rounding_s, saturate_s)
        sub = None if kvmask_s is None \
            else kvmask_s[:, jj * LANE:(jj + 1) * LANE]
        valid = _mask_block(mask_mode, rows, cols, s_len, window, sub, qpos)
        x = jnp.where(valid, s8.astype(jnp.float32) * s_s,
                      jnp.float32(-1e30))
        obs = (rows < q_len) & valid
        yield jj, s8, valid, x, cols, obs


def fwd_stripe_m(q8, k8s, kvmask_s, m, amax_s, *, payload=False,
                 health=None, **kw):
    """Exact running row-max carry over one stripe + the S amax
    observation (masked to the attended region). The BACKWARD's statistics
    recompute (and the retained two-pass baseline `fwd_q_tile_two_pass`)
    use this; the forward kernel itself runs the one-pass
    `fwd_stripe_online`. Returns (m, amax_s, s8_tiles) — tiles only when
    payload=True (oracle use). With a (3,) `health` accumulator,
    additionally returns it advanced by this stripe's S precision-health
    counts (4-tuple; the observation-only extra output never perturbs the
    carries — counters on/off is bit-identical)."""
    tiles = []
    for jj, s8, valid, x, cols, obs in _sblocks(q8, k8s, kvmask_s, **kw):
        m = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
        amax_s = jnp.maximum(amax_s, jnp.max(
            jnp.where(obs, jnp.abs(s8.astype(jnp.float32)), 0.0)))
        if health is not None:
            health = health + _health_counts(s8, obs, kw["fmt_s"])
        if payload:
            tiles.append(jnp.where(valid, s8, _zeros_like_fp8(s8)))
    if health is not None:
        return m, amax_s, tiles, health
    return m, amax_s, tiles


def fwd_stripe_l(q8, k8s, kvmask_s, m, l, **kw):
    """Softmax normalizer carry over one stripe given the FINAL row max,
    accumulated in LANE-wide sequential steps (the fixed chain block_kv
    cannot change). Backward statistics recompute / two-pass baseline."""
    for jj, s8, valid, x, cols, obs in _sblocks(q8, k8s, kvmask_s, **kw):
        e = jnp.where(valid, jnp.exp(x - m), 0.0)
        l = l + jnp.sum(e, axis=-1, keepdims=True)
    return l


def fwd_stripe_online(q8, k8s, v8s, kvmask_s, m, l, acc, amax_s, amax_p, *,
                      seed, bh, f_p, fmt_p, rounding_p, saturate_p,
                      payload=False, health_s=None, health_p=None, **kw):
    """ONE pass over one stripe: the online-softmax recurrence (module
    docstring) advancing the (m, l, acc) carries per LANE column block,
    with both amax observations (masked to the attended region) taken in
    the same pass. This is the forward kernel's stripe body — each K/V
    stripe is read exactly once.

    Rescaling by exp(m - m') per LANE block (not per stripe) is what makes
    the result invariant to the stripe partition: the block chain is the
    same however the blocks are grouped. A fully-masked block leaves m
    unchanged, so its rescale factor is exp(0) = exact 1.0 and its
    e-contribution is exact 0.0 — stripe skipping stays exact. The probs
    are quantized UNNORMALIZED against the running max (e <= 1 by
    construction); normalization by the final l happens once at write-out.

    Returns (m, l, acc, amax_s, amax_p, s8_tiles, p8_tiles) — tile lists
    only when payload=True (oracle use). With (3,) `health_s`/`health_p`
    accumulators, additionally returns both advanced by this stripe's S/P
    precision-health counts (observation-only: carries are untouched, so
    counters on/off is bit-identical)."""
    s_tiles, p_tiles = [], []
    bq = q8.shape[0]
    rows = kw["row0"] + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    for jj, s8, valid, x, cols, obs in _sblocks(q8, k8s, kvmask_s,
                                                seed=seed, bh=bh, **kw):
        m_new = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
        amax_s = jnp.maximum(amax_s, jnp.max(
            jnp.where(obs, jnp.abs(s8.astype(jnp.float32)), 0.0)))
        if health_s is not None:
            health_s = health_s + _health_counts(s8, obs, kw["fmt_s"])
        corr = jnp.exp(m - m_new)
        e = jnp.where(valid, jnp.exp(x - m_new), 0.0)
        bits = sr_hash_bits(seed, SALT_P, bh, rows, cols) \
            if rounding_p == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        p8 = _quant_tile(e * f_p, bits, fmt_p, rounding_p, saturate_p)
        amax_p = jnp.maximum(amax_p, jnp.max(
            jnp.where(obs, jnp.abs(p8.astype(jnp.float32)), 0.0)))
        if health_p is not None:
            health_p = health_p + _health_counts(p8, obs, fmt_p)
        l = l * corr + jnp.sum(e, axis=-1, keepdims=True)
        acc = acc * corr + _dot_f32(p8, v8s[jj * LANE:(jj + 1) * LANE],
                                    ((1,), (0,)))
        m = m_new
        if payload:
            s_tiles.append(jnp.where(valid, s8, _zeros_like_fp8(s8)))
            p_tiles.append(jnp.where(valid, p8, _zeros_like_fp8(p8)))
    if health_s is not None:
        return (m, l, acc, amax_s, amax_p, s_tiles, p_tiles,
                health_s, health_p)
    return m, l, acc, amax_s, amax_p, s_tiles, p_tiles


def fwd_stripe_pv(q8, k8s, v8s, kvmask_s, m, d_safe, acc, amax_p, *,
                  seed, bh, f_p, fmt_p, rounding_p, saturate_p,
                  payload=False, health=None, **kw):
    """Two-pass PV stripe (NORMALIZED probs from the final statistics):
    quantized probs + P amax + PV accumulation. Retained as the two-pass
    baseline for the one-pass A/B bench and equivalence tests — the
    forward kernel runs `fwd_stripe_online`. Returns (acc, amax_p,
    p8_tiles) — plus the advanced (3,) P health counts when a `health`
    accumulator is given."""
    tiles = []
    bq = q8.shape[0]
    rows = kw["row0"] + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    for jj, s8, valid, x, cols, obs in _sblocks(q8, k8s, kvmask_s,
                                                seed=seed, bh=bh, **kw):
        e = jnp.where(valid, jnp.exp(x - m), 0.0)
        p = e / d_safe
        bits = sr_hash_bits(seed, SALT_P, bh, rows, cols) \
            if rounding_p == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        p8 = _quant_tile(p * f_p, bits, fmt_p, rounding_p, saturate_p)
        amax_p = jnp.maximum(amax_p, jnp.max(
            jnp.where(obs, jnp.abs(p8.astype(jnp.float32)), 0.0)))
        if health is not None:
            health = health + _health_counts(p8, obs, fmt_p)
        acc = acc + _dot_f32(p8, v8s[jj * LANE:(jj + 1) * LANE],
                             ((1,), (0,)))
        if payload:
            tiles.append(jnp.where(valid, p8, _zeros_like_fp8(p8)))
    if health is not None:
        return acc, amax_p, tiles, health
    return acc, amax_p, tiles


def _pdp_blocks(q8, k8s, v8s, do8, kvmask_s, m, d_safe, *, seed, bh,
                f_p, s_p, f_dp, s_dp, fmt_p, fmt_e,
                rounding_p, rounding_e, saturate_p, saturate_e, **kw):
    """Backward recomputation per LANE block of one stripe: yields
    (jj, p8, p_d, dp8, dp_d, cols, obs, valid) with S8/P8 recomputed
    bit-exactly from the FP8 residuals (identical hash bits)."""
    bq = q8.shape[0]
    rows = kw["row0"] + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    for jj, s8, valid, x, cols, obs in _sblocks(q8, k8s, kvmask_s,
                                                seed=seed, bh=bh, **kw):
        e = jnp.where(valid, jnp.exp(x - m), 0.0)
        p = e / d_safe
        bits_p = sr_hash_bits(seed, SALT_P, bh, rows, cols) \
            if rounding_p == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        p8 = _quant_tile(p * f_p, bits_p, fmt_p, rounding_p, saturate_p)
        p_d = p8.astype(jnp.float32) * s_p
        dp = _dot_f32(do8, v8s[jj * LANE:(jj + 1) * LANE], ((1,), (1,)))
        bits_dp = sr_hash_bits(seed, SALT_DP, bh, rows, cols) \
            if rounding_e == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        dp8 = _quant_tile(dp * f_dp, bits_dp, fmt_e, rounding_e, saturate_e)
        dp_d = dp8.astype(jnp.float32) * s_dp
        yield jj, p8, p_d, dp8, dp_d, cols, obs, valid


def bwd_stripe_rd(q8, k8s, v8s, do8, kvmask_s, m, d_safe, rd, amax_dp, *,
                  payload=False, health=None, **kw):
    """Backward pass A over one stripe: the softmax-VJP row reduction
    rowsum(P * dP) carry + the dP observation. Returns
    (rd, amax_dp, dp8_tiles) — plus the advanced (3,) dP health counts
    when a `health` accumulator is given."""
    tiles = []
    for jj, p8, p_d, dp8, dp_d, cols, obs, valid in _pdp_blocks(
            q8, k8s, v8s, do8, kvmask_s, m, d_safe, **kw):
        rd = rd + jnp.sum(p_d * dp_d, axis=-1, keepdims=True)
        amax_dp = jnp.maximum(amax_dp, jnp.max(
            jnp.where(obs, jnp.abs(dp8.astype(jnp.float32)), 0.0)))
        if health is not None:
            health = health + _health_counts(dp8, obs, kw["fmt_e"])
        if payload:
            tiles.append(jnp.where(valid, dp8, _zeros_like_fp8(dp8)))
    if health is not None:
        return rd, amax_dp, tiles, health
    return rd, amax_dp, tiles


def _ds_block(p_d, dp_d, rd, rows, cols, *, seed, bh, f_ds, fmt_e,
              rounding_e, saturate_e):
    ds = p_d * (dp_d - rd)
    bits = sr_hash_bits(seed, SALT_DS, bh, rows, cols) \
        if rounding_e == "sr" else jnp.zeros(ds.shape, jnp.uint8)
    return _quant_tile(ds * f_ds, bits, fmt_e, rounding_e, saturate_e)


def bwd_stripe_dq(q8, k8s, v8s, do8, kvmask_s, m, d_safe, rd,
                  dq_acc, amax_ds, *, f_ds, payload=False, health=None,
                  **kw):
    """Backward pass B (query side) over one stripe: dS quantization, the
    dQ accumulation, and the dS observation. Returns
    (dq_acc, amax_ds, ds8_tiles) — plus the advanced (3,) dS health counts
    when a `health` accumulator is given."""
    bq = q8.shape[0]
    rows = kw["row0"] + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    tiles = []
    for jj, p8, p_d, dp8, dp_d, cols, obs, valid in _pdp_blocks(
            q8, k8s, v8s, do8, kvmask_s, m, d_safe, **kw):
        ds8 = _ds_block(p_d, dp_d, rd, rows, cols, seed=kw["seed"],
                        bh=kw["bh"], f_ds=f_ds, fmt_e=kw["fmt_e"],
                        rounding_e=kw["rounding_e"],
                        saturate_e=kw["saturate_e"])
        amax_ds = jnp.maximum(amax_ds, jnp.max(
            jnp.where(obs, jnp.abs(ds8.astype(jnp.float32)), 0.0)))
        if health is not None:
            health = health + _health_counts(ds8, obs, kw["fmt_e"])
        dq_acc = dq_acc + _dot_f32(ds8, k8s[jj * LANE:(jj + 1) * LANE],
                                   ((1,), (0,)))
        if payload:
            tiles.append(jnp.where(valid, ds8, _zeros_like_fp8(ds8)))
    if health is not None:
        return dq_acc, amax_ds, tiles, health
    return dq_acc, amax_ds, tiles


def bwd_stripe_dkv(q8, k8s, v8s, do8, kvmask_s, m, d_safe, rd, *,
                   f_ds, **kw):
    """Backward pass B (kv side) for ONE TQ-row query tile against one
    stripe: per-LANE-slice (LANE, D) dK/dV contributions in RAW grid units.
    The caller accumulates slice jj into rows [jj*LANE, (jj+1)*LANE) of the
    stripe's dK/dV (summing over query tiles and GQA group members in a
    fixed order) and applies the f_dk / f_dv scale ONCE after the
    accumulation — scaling per part would let XLA fuse the multiply into
    the running add as an FMA, whose single rounding diverges from the
    unfused mul-then-add by one ulp (the scale-at-end shape is immune:
    (acc + x) * c has no FMA form)."""
    bq = q8.shape[0]
    rows = kw["row0"] + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    dk_parts, dv_parts = [], []
    for jj, p8, p_d, dp8, dp_d, cols, obs, valid in _pdp_blocks(
            q8, k8s, v8s, do8, kvmask_s, m, d_safe, **kw):
        ds8 = _ds_block(p_d, dp_d, rd, rows, cols, seed=kw["seed"],
                        bh=kw["bh"], f_ds=f_ds, fmt_e=kw["fmt_e"],
                        rounding_e=kw["rounding_e"],
                        saturate_e=kw["saturate_e"])
        dk_parts.append(_dot_f32(ds8, q8, ((0,), (0,))))
        dv_parts.append(_dot_f32(p8, do8, ((0,), (0,))))
    return dk_parts, dv_parts


# ---------------------------------------------------------------------------
# per-q-tile drivers (stripe loops; shared by the oracle drivers below)
# ---------------------------------------------------------------------------

def _stripe_kw(seed, bh, row0, scal2, mask_mode, window, q_len, s_len,
               fmt_s, rounding_s, saturate_s):
    return dict(seed=seed, bh=bh, row0=row0, scal2=scal2,
                mask_mode=mask_mode, window=window, q_len=q_len,
                s_len=s_len, fmt_s=fmt_s, rounding_s=rounding_s,
                saturate_s=saturate_s)


# The drivers call the stripe functions through a jit cache keyed on the
# static config: one compile per (function, config/shape) instead of tens
# of thousands of eager op dispatches at long context. Purely an execution-
# mode change for the ORACLE — coordinates (bh/row0/col0) and scales enter
# as traced arguments, so the op chain (and therefore every bit) is
# unchanged; the kernels keep calling the raw functions from their bodies.
_STATIC_KEYS = ("mask_mode", "window", "q_len", "s_len", "fmt_s",
                "rounding_s", "saturate_s", "fmt_p", "rounding_p",
                "saturate_p", "fmt_e", "rounding_e", "saturate_e",
                "payload")
_JIT_CACHE = {}


def _call_stripe(fn, *arrays, **kw):
    static = {k: v for k, v in kw.items() if k in _STATIC_KEYS}
    traced = {k: v for k, v in kw.items() if k not in _STATIC_KEYS}
    key = (fn.__name__, tuple(sorted(static.items())))
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(functools.partial(fn, **static))
    return _JIT_CACHE[key](*arrays, **traced)


def _mask_stripe(kvmask, j, bkv):
    return None if kvmask is None else kvmask[:, j * bkv:(j + 1) * bkv]


def fwd_q_tile(q8, k8, v8, kvmask, *, seed, bh, row0, scal,
               mask_mode: str, window: int, q_len: int, s_len: int,
               fmt_s: str, fmt_p: str, rounding_s: str, rounding_p: str,
               saturate_s: bool, saturate_p: bool,
               block_kv: int = 0, payload: bool = True, chunk=None):
    """Fused FP8 attention forward for one (bq, D) query tile against the
    full padded (Sp, D) K/V of its (batch, kv-head), streamed in
    `block_kv`-row stripes (0 = one stripe; fully-masked stripes skipped)
    with ONE pass per stripe (the online-softmax recurrence — see
    `fwd_stripe_online`).

    scal: indexable [f_s, s_s, f_p, f_o] (see module docstring).
    Returns (o_bf16 (bq, D), amax_s, amax_p, s8_tiles, p8_tiles) — the
    payload tile lists (one (bq, LANE) tile per LANE column block, masked
    positions zeroed, empty when payload=False; P tiles are the
    UNNORMALIZED E8 probs) are consumed by the reference drivers only.
    amaxes are in grid units over the attended region, exactly
    `fp8_amax_bits` over the masked logical payload."""
    f_s, s_s, f_p, f_o = scal[0], scal[1], scal[2], scal[3]
    bq = q8.shape[0]
    sp = k8.shape[0]
    bkv = sp if not block_kv else block_kv
    nk = sp // bkv
    jmin, jmax = kv_stripe_span(row0, bq, block_kv=bkv, n_kv=nk,
                                mask_mode=mask_mode, window=window)
    kw = _stripe_kw(seed, bh, row0, (f_s, s_s), mask_mode, window,
                    q_len, s_len, fmt_s, rounding_s, saturate_s)
    if chunk is not None:
        kw["chunk"] = chunk

    def stripes():
        for j in range(jmin, jmax + 1):
            yield (j, j * bkv, k8[j * bkv:(j + 1) * bkv],
                   v8[j * bkv:(j + 1) * bkv], _mask_stripe(kvmask, j, bkv))

    m = jnp.full((bq, 1), -1e30, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc = jnp.zeros((bq, v8.shape[1]), jnp.float32)
    amax_s = amax_p = jnp.float32(0.0)
    s8_j, p8_j = {}, {}
    for j, col0, ks, vs, ms in stripes():
        m, l, acc, amax_s, amax_p, s_tiles, p_tiles = _call_stripe(
            fwd_stripe_online, q8, ks, vs, ms, m, l, acc, amax_s, amax_p,
            f_p=f_p, fmt_p=fmt_p, rounding_p=rounding_p,
            saturate_p=saturate_p, payload=payload, **{**kw, "col0": col0})
        if payload:
            s8_j[j] = s_tiles
            p8_j[j] = p_tiles
    d_safe = jnp.where(l > 0, l, 1.0)   # fully-masked (padded) rows -> o = 0
    o = (acc * f_o / d_safe).astype(jnp.bfloat16)
    s8_tiles, p8_tiles = [], []
    if payload:
        # Skipped-stripe payload filler in the RESPECTIVE format (S8 and
        # P8 may differ, e.g. a mixed-format config).
        per_stripe = bkv // LANE
        zt_s = [jnp.zeros((bq, LANE), fmt_dtype(fmt_s))] * per_stripe
        zt_p = [jnp.zeros((bq, LANE), fmt_dtype(fmt_p))] * per_stripe
        for j in range(nk):
            s8_tiles += s8_j.get(j, zt_s)
            p8_tiles += p8_j.get(j, zt_p)
    return o, amax_s, amax_p, s8_tiles, p8_tiles


def fwd_q_tile_two_pass(q8, k8, v8, kvmask, *, seed, bh, row0, scal,
                        mask_mode: str, window: int, q_len: int, s_len: int,
                        fmt_s: str, fmt_p: str, rounding_s: str,
                        rounding_p: str, saturate_s: bool, saturate_p: bool,
                        block_kv: int = 0, chunk=None):
    """The PR-5 two-pass-per-stripe forward (final-max statistics first,
    then a normalized-P PV pass re-reading every stripe), retained as the
    baseline the one-pass rewrite is A/B-benched and equivalence-tested
    against. Returns (o_bf16, amax_s, l) — the normalized composition the
    one-pass output must match to within one final-divide rounding."""
    f_s, s_s, f_p, f_o = scal[0], scal[1], scal[2], scal[3]
    bq = q8.shape[0]
    sp = k8.shape[0]
    bkv = sp if not block_kv else block_kv
    nk = sp // bkv
    jmin, jmax = kv_stripe_span(row0, bq, block_kv=bkv, n_kv=nk,
                                mask_mode=mask_mode, window=window)
    kw = _stripe_kw(seed, bh, row0, (f_s, s_s), mask_mode, window,
                    q_len, s_len, fmt_s, rounding_s, saturate_s)
    if chunk is not None:
        kw["chunk"] = chunk

    def stripes():
        for j in range(jmin, jmax + 1):
            yield (j, j * bkv, k8[j * bkv:(j + 1) * bkv],
                   v8[j * bkv:(j + 1) * bkv], _mask_stripe(kvmask, j, bkv))

    m = jnp.full((bq, 1), -1e30, jnp.float32)
    amax_s = jnp.float32(0.0)
    for j, col0, ks, vs, ms in stripes():
        m, amax_s, _ = _call_stripe(fwd_stripe_m, q8, ks, ms, m, amax_s,
                                    payload=False, **{**kw, "col0": col0})
    l = jnp.zeros((bq, 1), jnp.float32)
    for j, col0, ks, vs, ms in stripes():
        l = _call_stripe(fwd_stripe_l, q8, ks, ms, m, l,
                         **{**kw, "col0": col0})
    d_safe = jnp.where(l > 0, l, 1.0)
    acc = jnp.zeros((bq, v8.shape[1]), jnp.float32)
    amax_p = jnp.float32(0.0)
    for j, col0, ks, vs, ms in stripes():
        acc, amax_p, _ = _call_stripe(
            fwd_stripe_pv, q8, ks, vs, ms, m, d_safe, acc, amax_p,
            f_p=f_p, fmt_p=fmt_p, rounding_p=rounding_p,
            saturate_p=saturate_p, payload=False, **{**kw, "col0": col0})
    o = (acc * f_o).astype(jnp.bfloat16)
    return o, amax_s, l


def bwd_q_tile(q8, k8, v8, do8, kvmask, *, seed, bh, row0, scal,
               mask_mode: str, window: int, q_len: int, s_len: int,
               fmt_s: str, fmt_p: str, fmt_e: str,
               rounding_s: str, rounding_p: str, rounding_e: str,
               saturate_s: bool, saturate_p: bool, saturate_e: bool,
               block_kv: int = 0, payload: bool = True):
    """Fused FP8 attention backward for one (bq, D) query tile: recomputes
    S8/P8 from the FP8 residuals (identical hash bits -> identical
    payloads), quantizes the dP and dS intermediates to the error format,
    and returns

        (dq (bq, D) f32, amax_dp, amax_ds, dp8_tiles, ds8_tiles,
         (m, d_safe, rd))

    The trailing stats tuple feeds the driver's dK/dV pass
    (`bwd_tile_dkv_stripe`), mirroring the kernel's two-stage structure
    (stats+dQ kernel, then dK/dV stripe kernel)."""
    (f_s, s_s, f_p, s_p, f_dp, s_dp, f_ds, f_dq, f_dk, f_dv) = (
        scal[0], scal[1], scal[2], scal[3], scal[4], scal[5], scal[6],
        scal[7], scal[8], scal[9])
    bq = q8.shape[0]
    sp = k8.shape[0]
    bkv = sp if not block_kv else block_kv
    nk = sp // bkv
    jmin, jmax = kv_stripe_span(row0, bq, block_kv=bkv, n_kv=nk,
                                mask_mode=mask_mode, window=window)
    kw = _stripe_kw(seed, bh, row0, (f_s, s_s), mask_mode, window,
                    q_len, s_len, fmt_s, rounding_s, saturate_s)
    bkw = dict(f_p=f_p, s_p=s_p, f_dp=f_dp, s_dp=s_dp, fmt_p=fmt_p,
               fmt_e=fmt_e, rounding_p=rounding_p, rounding_e=rounding_e,
               saturate_p=saturate_p, saturate_e=saturate_e)

    def stripes():
        for j in range(jmin, jmax + 1):
            yield (j, j * bkv, k8[j * bkv:(j + 1) * bkv],
                   v8[j * bkv:(j + 1) * bkv], _mask_stripe(kvmask, j, bkv))

    # Softmax statistics, recomputed bitwise (same ops, same bits).
    m = jnp.full((bq, 1), -1e30, jnp.float32)
    for j, col0, ks, vs, ms in stripes():
        m, _, _ = _call_stripe(fwd_stripe_m, q8, ks, ms, m,
                               jnp.float32(0.0), **{**kw, "col0": col0})
    l = jnp.zeros((bq, 1), jnp.float32)
    for j, col0, ks, vs, ms in stripes():
        l = _call_stripe(fwd_stripe_l, q8, ks, ms, m, l,
                         **{**kw, "col0": col0})
    d_safe = jnp.where(l > 0, l, 1.0)

    # Pass A: softmax-VJP row reduction rowsum(P * dP) + dP observation.
    rd = jnp.zeros((bq, 1), jnp.float32)
    amax_dp = jnp.float32(0.0)
    dp8_j = {}
    for j, col0, ks, vs, ms in stripes():
        rd, amax_dp, tiles = _call_stripe(
            bwd_stripe_rd, q8, ks, vs, do8, ms, m, d_safe, rd, amax_dp,
            payload=payload, **{**kw, "col0": col0}, **bkw)
        if payload:
            dp8_j[j] = tiles
    # Pass B (query side): dS quantization + the dQ accumulation.
    dq_acc = jnp.zeros((bq, q8.shape[1]), jnp.float32)
    amax_ds = jnp.float32(0.0)
    ds8_j = {}
    for j, col0, ks, vs, ms in stripes():
        dq_acc, amax_ds, tiles = _call_stripe(
            bwd_stripe_dq, q8, ks, vs, do8, ms, m, d_safe, rd, dq_acc,
            amax_ds, f_ds=f_ds, payload=payload,
            **{**kw, "col0": col0}, **bkw)
        if payload:
            ds8_j[j] = tiles
    dp8_tiles, ds8_tiles = [], []
    if payload:
        per_stripe = bkv // LANE
        zt = [jnp.zeros((bq, LANE), fmt_dtype(fmt_e))] * per_stripe
        for j in range(nk):
            dp8_tiles += dp8_j.get(j, zt)
            ds8_tiles += ds8_j.get(j, zt)
    return (dq_acc * f_dq, amax_dp, amax_ds, dp8_tiles, ds8_tiles,
            (m, d_safe, rd))


def bwd_tile_dkv_stripe(q8, k8s, v8s, do8, kvmask_s, m, d_safe, rd,
                        dk_s, dv_s, *, f_ds, **kw):
    """Accumulate one (bq, D) query tile's dK/dV contributions into one
    stripe's (bkv, D) RAW-grid-unit accumulators, TQ sub-tile by TQ
    sub-tile via lax.fori_loop — each per-LANE-slice part is added
    individually (the flat left-to-right chain the kernel's dK/dV grid
    performs; pre-summing per q block would regroup the f32 adds and
    break block_q invariance). The f_dk / f_dv scale is applied ONCE by
    the caller after ALL tiles/heads have contributed (see
    `bwd_stripe_dkv` on the FMA hazard)."""
    bq = q8.shape[0]
    row0 = kw.pop("row0")

    def t2_body(t2, carry):
        dk_s, dv_s = carry
        r0 = t2 * TQ

        def sl(x):
            return jax.lax.dynamic_slice_in_dim(x, r0, TQ, 0)

        pk, pv_ = bwd_stripe_dkv(sl(q8), k8s, v8s, sl(do8), kvmask_s,
                                 sl(m), sl(d_safe), sl(rd), f_ds=f_ds,
                                 **{**kw, "row0": row0 + r0})
        for jj, (a, b) in enumerate(zip(pk, pv_)):
            js = slice(jj * LANE, (jj + 1) * LANE)
            dk_s = dk_s.at[js].add(a)
            dv_s = dv_s.at[js].add(b)
        return dk_s, dv_s

    return jax.lax.fori_loop(0, max(1, bq // TQ), t2_body, (dk_s, dv_s))


def fmt_dtype(fmt_name: str):
    return {"e5m2": jnp.float8_e5m2, "e4m3": jnp.float8_e4m3fn}[fmt_name]


# ---------------------------------------------------------------------------
# unfused reference drivers (the oracle the kernels are locked against)
# ---------------------------------------------------------------------------

def _pad_to(x, axis: int, mult: int, value=0):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pad_qkv(q8, k8, v8, block_q: int, block_kv: int = LANE):
    """Zero-pad Q to a block_q multiple and S to a block_kv multiple (D to
    LANE). Padding is numerically invisible (exact-0.0 contributions,
    masked observations)."""
    qp = _pad_to(_pad_to(q8, 2, block_q), 3, LANE)
    kp = _pad_to(_pad_to(k8, 2, block_kv), 3, LANE)
    vp = _pad_to(_pad_to(v8, 2, block_kv), 3, LANE)
    return qp, kp, vp


def resolve_block_kv(s_len: int, block_kv) -> int:
    """The effective stripe size for a kv length: LANE-aligned, capped at
    the padded length (so short sequences keep a single stripe)."""
    if block_kv is None:
        block_kv = DEFAULT_BKV
    if block_kv % LANE:
        raise ValueError(f"block_kv must be a multiple of {LANE}, "
                         f"got {block_kv}")
    sp_lane = -(-max(s_len, 1) // LANE) * LANE
    return min(block_kv, sp_lane)


DEFAULT_BKV = 512


def fp8_attention_fwd_ref(q8, k8, v8, seed, scal, *, mask_mode="causal",
                          window: int = 0, kv_mask=None, chunk_pos=None,
                          block_q: int = LANE, block_kv=None,
                          fmt_s="e5m2", fmt_p="e5m2",
                          rounding_s="sr", rounding_p="sr",
                          saturate_s=True, saturate_p=True,
                          payload: bool = True):
    """Unfused composition oracle on logical (B,H,Q,D) / (B,Hkv,S,D) fp8
    payloads. Materializes and returns the S8/P8 payloads the fused kernel
    never writes (masked positions zeroed; payload=False skips them for
    long-context runs). Returns (o, amax_s, amax_p, s8, p8) with o
    (B,H,Q,D) bf16, payloads (B,H,Q,S) or None, amaxes in grid units.
    mask_mode='chunk': kv_mask is (B, S) int32 slot POSITIONS (-1 = hole)
    and chunk_pos is (B, 2) int32 [start, n_valid] per batch row."""
    b_, h_, q_len, d = q8.shape
    s_len = k8.shape[2]
    g = h_ // k8.shape[1]
    bkv = resolve_block_kv(s_len, block_kv)
    qp, kp, vp = pad_qkv(q8, k8, v8, block_q, bkv)
    nq = qp.shape[2] // block_q
    o = []
    s8_all, p8_all = [], []
    amax_s = amax_p = jnp.float32(0.0)
    for b in range(b_):
        o_h, s8_h, p8_h = [], [], []
        if mask_mode == "chunk":
            # Slot positions pad with -1 (slot 0 is a VALID position).
            mrow = _pad_to(kv_mask[b:b + 1].astype(jnp.int32), 1, bkv, -1)
            chunk = (chunk_pos[b, 0], chunk_pos[b, 1])
        else:
            mrow = None if kv_mask is None \
                else _pad_to(kv_mask[b:b + 1].astype(jnp.int8), 1, bkv)
            chunk = None
        for h in range(h_):
            o_t, s8_t, p8_t = [], [], []
            for iq in range(nq):
                qt = qp[b, h, iq * block_q:(iq + 1) * block_q]
                ot, a_s, a_p, s8s, p8s = fwd_q_tile(
                    qt, kp[b, h // g], vp[b, h // g], mrow,
                    seed=seed, bh=b * h_ + h, row0=iq * block_q, scal=scal,
                    mask_mode=mask_mode, window=window,
                    q_len=q_len, s_len=s_len,
                    fmt_s=fmt_s, fmt_p=fmt_p, rounding_s=rounding_s,
                    rounding_p=rounding_p, saturate_s=saturate_s,
                    saturate_p=saturate_p, block_kv=bkv, payload=payload,
                    chunk=chunk)
                amax_s = jnp.maximum(amax_s, a_s)
                amax_p = jnp.maximum(amax_p, a_p)
                o_t.append(ot)
                if payload:
                    s8_t.append(jnp.concatenate(s8s, axis=1))
                    p8_t.append(jnp.concatenate(p8s, axis=1))
            o_h.append(jnp.concatenate(o_t, axis=0)[None])
            if payload:
                s8_h.append(jnp.concatenate(s8_t, axis=0)[None])
                p8_h.append(jnp.concatenate(p8_t, axis=0)[None])
        o.append(jnp.concatenate(o_h, axis=0)[None])
        if payload:
            s8_all.append(jnp.concatenate(s8_h, axis=0)[None])
            p8_all.append(jnp.concatenate(p8_h, axis=0)[None])
    o = jnp.concatenate(o, axis=0)[:, :, :q_len, :d]
    s8 = p8 = None
    if payload:
        s8 = jnp.concatenate(s8_all, axis=0)[:, :, :q_len, :s_len]
        p8 = jnp.concatenate(p8_all, axis=0)[:, :, :q_len, :s_len]
    return o, amax_s, amax_p, s8, p8


def fp8_attention_bwd_ref(q8, k8, v8, do8, seed, scal, *,
                          mask_mode="causal", window: int = 0, kv_mask=None,
                          block_q: int = LANE, block_kv=None,
                          fmt_s="e5m2", fmt_p="e5m2", fmt_e="e5m2",
                          rounding_s="sr", rounding_p="sr", rounding_e="sr",
                          saturate_s=True, saturate_p=True,
                          saturate_e=False, payload: bool = True):
    """Unfused backward oracle. Returns (dq, dk, dv, amax_dp, amax_ds,
    dp8, ds8): dq (B,H,Q,D) f32, dk/dv (B,Hkv,S,D) f32 (GQA groups
    accumulated in head order), payloads (B,H,Q,S) or None."""
    b_, h_, q_len, d = q8.shape
    hkv, s_len = k8.shape[1], k8.shape[2]
    g = h_ // hkv
    bkv = resolve_block_kv(s_len, block_kv)
    qp, kp, vp = pad_qkv(q8, k8, v8, block_q, bkv)
    dop = _pad_to(_pad_to(do8, 2, block_q), 3, LANE)
    sp, dp_ = kp.shape[2], kp.shape[3]
    nq = qp.shape[2] // block_q
    dq = jnp.zeros(qp.shape, jnp.float32)
    dk = jnp.zeros((b_, hkv, sp, dp_), jnp.float32)
    dv = jnp.zeros((b_, hkv, sp, dp_), jnp.float32)
    amax_dp = amax_ds = jnp.float32(0.0)
    dp8_all, ds8_all = [], []
    for b in range(b_):
        dp8_h, ds8_h = [], []
        mrow = None if kv_mask is None \
            else _pad_to(kv_mask[b:b + 1].astype(jnp.int8), 1, bkv)
        for h in range(h_):
            dp8_t, ds8_t = [], []
            for iq in range(nq):
                sl = slice(iq * block_q, (iq + 1) * block_q)
                dq_t, a_dp, a_ds, dp8s, ds8s, (m_t, dsafe_t, rd_t) = \
                    bwd_q_tile(
                        qp[b, h, sl], kp[b, h // g], vp[b, h // g],
                        dop[b, h, sl], mrow,
                        seed=seed, bh=b * h_ + h, row0=iq * block_q,
                        scal=scal, mask_mode=mask_mode, window=window,
                        q_len=q_len, s_len=s_len,
                        fmt_s=fmt_s, fmt_p=fmt_p, fmt_e=fmt_e,
                        rounding_s=rounding_s, rounding_p=rounding_p,
                        rounding_e=rounding_e, saturate_s=saturate_s,
                        saturate_p=saturate_p, saturate_e=saturate_e,
                        block_kv=bkv, payload=payload)
                dq = dq.at[b, h, sl].set(dq_t)
                # dK/dV stripe pass (the kernel's second backward stage).
                jmin, jmax = kv_stripe_span(
                    iq * block_q, block_q, block_kv=bkv, n_kv=sp // bkv,
                    mask_mode=mask_mode, window=window)
                for j in range(jmin, jmax + 1):
                    sj = slice(j * bkv, (j + 1) * bkv)
                    ms_j = None if mrow is None else mrow[:, sj]
                    dk_s, dv_s = _call_stripe(
                        bwd_tile_dkv_stripe, qp[b, h, sl],
                        kp[b, h // g, sj], vp[b, h // g, sj],
                        dop[b, h, sl], ms_j, m_t, dsafe_t, rd_t,
                        dk[b, h // g, sj], dv[b, h // g, sj],
                        f_ds=scal[6], seed=seed, bh=b * h_ + h,
                        row0=iq * block_q, col0=j * bkv,
                        scal2=(scal[0], scal[1]), mask_mode=mask_mode,
                        window=window, q_len=q_len, s_len=s_len,
                        fmt_s=fmt_s, rounding_s=rounding_s,
                        saturate_s=saturate_s, f_p=scal[2], s_p=scal[3],
                        f_dp=scal[4], s_dp=scal[5], fmt_p=fmt_p,
                        fmt_e=fmt_e, rounding_p=rounding_p,
                        rounding_e=rounding_e, saturate_p=saturate_p,
                        saturate_e=saturate_e)
                    dk = dk.at[b, h // g, sj].set(dk_s)
                    dv = dv.at[b, h // g, sj].set(dv_s)
                amax_dp = jnp.maximum(amax_dp, a_dp)
                amax_ds = jnp.maximum(amax_ds, a_ds)
                if payload:
                    dp8_t.append(jnp.concatenate(dp8s, axis=1))
                    ds8_t.append(jnp.concatenate(ds8s, axis=1))
            if payload:
                dp8_h.append(jnp.concatenate(dp8_t, axis=0)[None])
                ds8_h.append(jnp.concatenate(ds8_t, axis=0)[None])
        if payload:
            dp8_all.append(jnp.concatenate(dp8_h, axis=0)[None])
            ds8_all.append(jnp.concatenate(ds8_h, axis=0)[None])
    # Raw-units accumulation, single scale multiply (see bwd_stripe_dkv).
    dq = dq[:, :, :q_len, :d]
    dk = dk[:, :, :s_len, :d] * scal[8]
    dv = dv[:, :, :s_len, :d] * scal[9]
    dp8 = ds8 = None
    if payload:
        dp8 = jnp.concatenate(dp8_all, axis=0)[:, :, :q_len, :s_len]
        ds8 = jnp.concatenate(ds8_all, axis=0)[:, :, :q_len, :s_len]
    return dq, dk, dv, amax_dp, amax_ds, dp8, ds8
