"""Shared tile math + unfused oracle for the fused FP8 flash-attention path.

This module is the SINGLE SOURCE OF TRUTH for the fused-attention numerics:
the Pallas kernel bodies (kernel.py) and the unfused reference drivers below
call the *same* per-tile functions (`fwd_q_tile` / `bwd_q_tile`), so in
interpret mode the kernel is bit-identical to the unfused quantize ->
matmul -> softmax -> quantize -> matmul composition by construction — the
same guarantee structure `sr_fp8_from_bits` gives the fused GEMM kernels.

Semantics (the paper's Fig. 1a dataflow extended into attention, all four
tensor classes in FP8):

    forward:   S8 = Q_A((q8 . k8^T) * f_s)          f_s = s_q s_k sm / s_s
               P  = softmax(S8 * s_s)  (rows; masked lanes exactly 0)
               P8 = Q_A(P / s_p)
               O  = (P8 . v8) * (s_p s_v)           -> bf16
    backward:  dP8 = Q_E((do8 . v8^T) * f_dp)       f_dp = s_do s_v / s_dp
               dS  = P_deq * (dP_deq - rowsum(P_deq * dP_deq))
               dS8 = Q_E(dS * sm / s_ds)
               dQ = (dS8 . k8)   * (s_ds s_k)
               dK = (dS8^T . q8) * (s_ds s_q)
               dV = (P8^T . do8) * (s_p s_do)

Determinism / tiling invariance: every cross-position reduction (softmax
denominator, PV / dQ accumulation) advances in fixed LANE-wide steps, and SR
bits are drawn from a counter-based hash of the *absolute* (head, row, col)
coordinates — so results are invariant to the query-block size, to KV/head
padding (zero-padded lanes contribute exact 0.0), and identical between the
kernel grid and the reference loops. Zero materialized S/P ever reaches HBM
on the kernel path; the reference drivers materialize them (that is the
point of an oracle) and also return the payloads for observation checks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fp8_formats import get_format
from repro.core.quantize import quantize_rne, sr_fp8_via_f16

# Fixed inner reduction width (TPU lane count). All KV-axis loops advance in
# LANE steps regardless of any block-size knob.
LANE = 128

# SR draw channels: one salt per in-kernel Q node so S/P/dP/dS consume
# independent bit streams at the same coordinates.
SALT_S, SALT_P, SALT_DP, SALT_DS = 0x51, 0x52, 0x53, 0x54

_GOLD = 0x9E3779B9  # 2^32 / golden ratio


def _fmix32(x):
    """murmur3 finalizer: full avalanche on uint32."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def sr_hash_bits(seed, salt: int, bh, rows, cols):
    """Counter-based uint8 SR bits from absolute tile coordinates.

    Unlike the fused GEMM kernels (which stream a materialized rand8 array
    from HBM), attention draws its SR bits *in the kernel* from a stateless
    hash of (seed, salt, batch*head, row, col) — an S-shaped rand array in
    HBM would cost exactly the S materialization the kernel exists to avoid.
    Bits depend only on absolute coordinates, so any tiling/padding draws
    identical bits for a logical cell."""
    gold = jnp.uint32(_GOLD)
    s = _fmix32(jnp.asarray(seed, jnp.uint32)
                + jnp.uint32(salt) * gold)
    s = _fmix32(s + jnp.asarray(bh, jnp.uint32) * gold)
    h = _fmix32(s + rows.astype(jnp.uint32) * gold)
    h = _fmix32(h ^ (cols.astype(jnp.uint32) * gold))
    return (h & jnp.uint32(0xFF)).astype(jnp.uint8)


def _quant_tile(y, bits, fmt_name: str, rounding: str, saturate: bool):
    fmt = get_format(fmt_name)
    if rounding == "rne":
        return quantize_rne(y, fmt, saturate=saturate)
    return sr_fp8_via_f16(y, bits, fmt, saturate=saturate)


def _mask_block(mask_mode: str, rows, cols, s_len: int, window: int, kvmask):
    """Validity of one (bq, LANE) score tile: KV padding is always masked;
    'causal' adds the triangular (+ optional sliding-window) condition from
    absolute coordinates; 'kv' ANDs a runtime per-batch validity row."""
    valid = cols < s_len
    if mask_mode == "causal":
        valid = valid & (cols <= rows)
        if window:
            valid = valid & (cols > rows - window)
    elif mask_mode == "kv":
        valid = valid & (kvmask != 0)
    elif mask_mode != "full":
        raise ValueError(f"unknown mask mode {mask_mode!r}")
    return valid


def _dot_f32(a8, b8, contract):
    return jax.lax.dot_general(a8.astype(jnp.bfloat16),
                               b8.astype(jnp.bfloat16),
                               (contract, ((), ())),
                               preferred_element_type=jnp.float32)


def _score_block(q8, k8_sub, bits, f_s, fmt_s, rounding_s, saturate_s):
    """(bq, LANE) quantized score tile: S8 = Q((q8 . k8_sub^T) * f_s)."""
    s = _dot_f32(q8, k8_sub, ((1,), (1,)))
    return _quant_tile(s * f_s, bits, fmt_s, rounding_s, saturate_s)


def fwd_q_tile(q8, k8, v8, kvmask, *, seed, bh, row0, scal,
               mask_mode: str, window: int, q_len: int, s_len: int,
               fmt_s: str, fmt_p: str, rounding_s: str, rounding_p: str,
               saturate_s: bool, saturate_p: bool):
    """Fused FP8 attention forward for one (bq, D) query tile against the
    full padded (Sp, D) K/V of its (batch, kv-head).

    scal: indexable [f_s, s_s, f_p, f_o] (see module docstring).
    Returns (o_bf16 (bq, D), amax_s, amax_p, s8_tiles, p8_tiles) — the
    payload tile lists are consumed by the reference drivers only (dead code
    in the kernel body). amaxes are in grid units, masked to the logical
    (q_len, s_len) region exactly like `fp8_amax_bits` over the materialized
    logical payload."""
    f_s, s_s, f_p, f_o = scal[0], scal[1], scal[2], scal[3]
    bq = q8.shape[0]
    nj = k8.shape[0] // LANE
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def sblock(j):
        cols = j * LANE + jax.lax.broadcasted_iota(jnp.int32, (1, LANE), 1)
        bits = sr_hash_bits(seed, SALT_S, bh, rows, cols) \
            if rounding_s == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        s8 = _score_block(q8, k8[j * LANE:(j + 1) * LANE], bits, f_s,
                          fmt_s, rounding_s, saturate_s)
        sub = None if kvmask is None else kvmask[:, j * LANE:(j + 1) * LANE]
        valid = _mask_block(mask_mode, rows, cols, s_len, window, sub)
        x = jnp.where(valid, s8.astype(jnp.float32) * s_s,
                      jnp.float32(-1e30))
        obs = (rows < q_len) & (cols < s_len)
        return s8, valid, x, cols, obs

    # Pass 1: exact running row-max (order-free) + S amax observation.
    m = jnp.full((bq, 1), -1e30, jnp.float32)
    amax_s = jnp.float32(0.0)
    s8_tiles = []
    for j in range(nj):
        s8, valid, x, cols, obs = sblock(j)
        m = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
        amax_s = jnp.maximum(amax_s, jnp.max(
            jnp.where(obs, jnp.abs(s8.astype(jnp.float32)), 0.0)))
        s8_tiles.append(s8)
    # Pass 2: denominator, accumulated in LANE-wide sequential steps.
    d = jnp.zeros((bq, 1), jnp.float32)
    for j in range(nj):
        _, valid, x, _, _ = sblock(j)
        e = jnp.where(valid, jnp.exp(x - m), 0.0)
        d = d + jnp.sum(e, axis=-1, keepdims=True)
    d_safe = jnp.where(d > 0, d, 1.0)   # fully-masked (padded) rows -> p = 0
    # Pass 3: quantized probs + P amax + PV accumulation.
    acc = jnp.zeros((bq, v8.shape[1]), jnp.float32)
    amax_p = jnp.float32(0.0)
    p8_tiles = []
    for j in range(nj):
        _, valid, x, cols, obs = sblock(j)
        e = jnp.where(valid, jnp.exp(x - m), 0.0)
        p = e / d_safe
        bits = sr_hash_bits(seed, SALT_P, bh, rows, cols) \
            if rounding_p == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        p8 = _quant_tile(p * f_p, bits, fmt_p, rounding_p, saturate_p)
        amax_p = jnp.maximum(amax_p, jnp.max(
            jnp.where(obs, jnp.abs(p8.astype(jnp.float32)), 0.0)))
        acc = acc + _dot_f32(p8, v8[j * LANE:(j + 1) * LANE], ((1,), (0,)))
        p8_tiles.append(p8)
    o = (acc * f_o).astype(jnp.bfloat16)
    return o, amax_s, amax_p, s8_tiles, p8_tiles


def bwd_q_tile(q8, k8, v8, do8, kvmask, *, seed, bh, row0, scal,
               mask_mode: str, window: int, q_len: int, s_len: int,
               fmt_s: str, fmt_p: str, fmt_e: str,
               rounding_s: str, rounding_p: str, rounding_e: str,
               saturate_s: bool, saturate_p: bool, saturate_e: bool):
    """Fused FP8 attention backward for one (bq, D) query tile: recomputes
    S8/P8 from the FP8 residuals (identical hash bits -> identical payloads),
    quantizes the dP and dS intermediates to the error format, and returns

        (dq (bq, D) f32, dk_parts, dv_parts, amax_dp, amax_ds,
         dp8_tiles, ds8_tiles)

    dk_parts/dv_parts are per-LANE-slice (LANE, D) f32 contributions in RAW
    grid units: the caller accumulates part j into rows [j*LANE, (j+1)*LANE)
    of dK/dV (summing over query tiles and GQA group members in a fixed
    order) and applies the f_dk / f_dv scale ONCE after the accumulation —
    scaling per part would let XLA fuse the multiply into the running add as
    an FMA, whose single rounding diverges from the unfused mul-then-add by
    one ulp (the scale-at-end shape is immune: (acc + x) * c has no FMA
    form)."""
    (f_s, s_s, f_p, s_p, f_dp, s_dp, f_ds, f_dq, f_dk, f_dv) = (
        scal[0], scal[1], scal[2], scal[3], scal[4], scal[5], scal[6],
        scal[7], scal[8], scal[9])
    bq = q8.shape[0]
    nj = k8.shape[0] // LANE
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def sblock(j):
        cols = j * LANE + jax.lax.broadcasted_iota(jnp.int32, (1, LANE), 1)
        bits = sr_hash_bits(seed, SALT_S, bh, rows, cols) \
            if rounding_s == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        s8 = _score_block(q8, k8[j * LANE:(j + 1) * LANE], bits, f_s,
                          fmt_s, rounding_s, saturate_s)
        sub = None if kvmask is None else kvmask[:, j * LANE:(j + 1) * LANE]
        valid = _mask_block(mask_mode, rows, cols, s_len, window, sub)
        x = jnp.where(valid, s8.astype(jnp.float32) * s_s,
                      jnp.float32(-1e30))
        obs = (rows < q_len) & (cols < s_len)
        return s8, valid, x, cols, obs

    # Recompute the forward softmax statistics (bitwise: same ops, same bits).
    m = jnp.full((bq, 1), -1e30, jnp.float32)
    for j in range(nj):
        _, _, x, _, _ = sblock(j)
        m = jnp.maximum(m, jnp.max(x, axis=-1, keepdims=True))
    d = jnp.zeros((bq, 1), jnp.float32)
    for j in range(nj):
        _, valid, x, _, _ = sblock(j)
        e = jnp.where(valid, jnp.exp(x - m), 0.0)
        d = d + jnp.sum(e, axis=-1, keepdims=True)
    d_safe = jnp.where(d > 0, d, 1.0)

    def pdp(j):
        """Recomputed (p8, p_deq, dp8, dp_deq) for LANE slice j."""
        _, valid, x, cols, obs = sblock(j)
        e = jnp.where(valid, jnp.exp(x - m), 0.0)
        p = e / d_safe
        bits_p = sr_hash_bits(seed, SALT_P, bh, rows, cols) \
            if rounding_p == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        p8 = _quant_tile(p * f_p, bits_p, fmt_p, rounding_p, saturate_p)
        p_d = p8.astype(jnp.float32) * s_p
        dp = _dot_f32(do8, v8[j * LANE:(j + 1) * LANE], ((1,), (1,)))
        bits_dp = sr_hash_bits(seed, SALT_DP, bh, rows, cols) \
            if rounding_e == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        dp8 = _quant_tile(dp * f_dp, bits_dp, fmt_e, rounding_e, saturate_e)
        dp_d = dp8.astype(jnp.float32) * s_dp
        return p8, p_d, dp8, dp_d, cols, obs

    # Pass A: softmax-VJP row reduction rowsum(P * dP) + dP observation.
    rd = jnp.zeros((bq, 1), jnp.float32)
    amax_dp = jnp.float32(0.0)
    dp8_tiles = []
    for j in range(nj):
        p8, p_d, dp8, dp_d, _, obs = pdp(j)
        rd = rd + jnp.sum(p_d * dp_d, axis=-1, keepdims=True)
        amax_dp = jnp.maximum(amax_dp, jnp.max(
            jnp.where(obs, jnp.abs(dp8.astype(jnp.float32)), 0.0)))
        dp8_tiles.append(dp8)
    # Pass B: dS quantization + the three adjoint GEMM accumulations.
    dq_acc = jnp.zeros((bq, q8.shape[1]), jnp.float32)
    amax_ds = jnp.float32(0.0)
    dk_parts, dv_parts, ds8_tiles = [], [], []
    for j in range(nj):
        p8, p_d, dp8, dp_d, cols, obs = pdp(j)
        ds = p_d * (dp_d - rd)
        bits_ds = sr_hash_bits(seed, SALT_DS, bh, rows, cols) \
            if rounding_e == "sr" else jnp.zeros((bq, LANE), jnp.uint8)
        ds8 = _quant_tile(ds * f_ds, bits_ds, fmt_e, rounding_e, saturate_e)
        amax_ds = jnp.maximum(amax_ds, jnp.max(
            jnp.where(obs, jnp.abs(ds8.astype(jnp.float32)), 0.0)))
        dq_acc = dq_acc + _dot_f32(ds8, k8[j * LANE:(j + 1) * LANE],
                                   ((1,), (0,)))
        dk_parts.append(_dot_f32(ds8, q8, ((0,), (0,))))
        dv_parts.append(_dot_f32(p8, do8, ((0,), (0,))))
        ds8_tiles.append(ds8)
    return (dq_acc * f_dq, dk_parts, dv_parts, amax_dp, amax_ds,
            dp8_tiles, ds8_tiles)


# ---------------------------------------------------------------------------
# unfused reference drivers (the oracle the kernels are locked against)
# ---------------------------------------------------------------------------

def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_qkv(q8, k8, v8, block_q: int):
    """Zero-pad Q to a block_q multiple and S/D to LANE multiples. Padding is
    numerically invisible (exact-0.0 contributions, masked observations)."""
    qp = _pad_to(_pad_to(q8, 2, block_q), 3, LANE)
    kp = _pad_to(_pad_to(k8, 2, LANE), 3, LANE)
    vp = _pad_to(_pad_to(v8, 2, LANE), 3, LANE)
    return qp, kp, vp


def fp8_attention_fwd_ref(q8, k8, v8, seed, scal, *, mask_mode="causal",
                          window: int = 0, kv_mask=None,
                          block_q: int = LANE,
                          fmt_s="e5m2", fmt_p="e5m2",
                          rounding_s="sr", rounding_p="sr",
                          saturate_s=True, saturate_p=True):
    """Unfused composition oracle on logical (B,H,Q,D) / (B,Hkv,S,D) fp8
    payloads. Materializes and returns the S8/P8 payloads the fused kernel
    never writes. Returns (o, amax_s, amax_p, s8, p8) with o (B,H,Q,D) bf16,
    payloads (B,H,Q,S), amaxes in grid units."""
    b_, h_, q_len, d = q8.shape
    s_len = k8.shape[2]
    g = h_ // k8.shape[1]
    qp, kp, vp = pad_qkv(q8, k8, v8, block_q)
    sp = kp.shape[2]
    nq = qp.shape[2] // block_q
    o = []
    s8_all, p8_all = [], []
    amax_s = amax_p = jnp.float32(0.0)
    for b in range(b_):
        o_h, s8_h, p8_h = [], [], []
        mrow = None if kv_mask is None \
            else _pad_to(kv_mask[b:b + 1].astype(jnp.int8), 1, LANE)
        for h in range(h_):
            o_t, s8_t, p8_t = [], [], []
            for iq in range(nq):
                qt = qp[b, h, iq * block_q:(iq + 1) * block_q]
                ot, a_s, a_p, s8s, p8s = fwd_q_tile(
                    qt, kp[b, h // g], vp[b, h // g], mrow,
                    seed=seed, bh=b * h_ + h, row0=iq * block_q, scal=scal,
                    mask_mode=mask_mode, window=window,
                    q_len=q_len, s_len=s_len,
                    fmt_s=fmt_s, fmt_p=fmt_p, rounding_s=rounding_s,
                    rounding_p=rounding_p, saturate_s=saturate_s,
                    saturate_p=saturate_p)
                amax_s = jnp.maximum(amax_s, a_s)
                amax_p = jnp.maximum(amax_p, a_p)
                o_t.append(ot)
                s8_t.append(jnp.concatenate(s8s, axis=1))
                p8_t.append(jnp.concatenate(p8s, axis=1))
            o_h.append(jnp.concatenate(o_t, axis=0)[None])
            s8_h.append(jnp.concatenate(s8_t, axis=0)[None])
            p8_h.append(jnp.concatenate(p8_t, axis=0)[None])
        o.append(jnp.concatenate(o_h, axis=0)[None])
        s8_all.append(jnp.concatenate(s8_h, axis=0)[None])
        p8_all.append(jnp.concatenate(p8_h, axis=0)[None])
    o = jnp.concatenate(o, axis=0)[:, :, :q_len, :d]
    s8 = jnp.concatenate(s8_all, axis=0)[:, :, :q_len, :s_len]
    p8 = jnp.concatenate(p8_all, axis=0)[:, :, :q_len, :s_len]
    return o, amax_s, amax_p, s8, p8


def fp8_attention_bwd_ref(q8, k8, v8, do8, seed, scal, *,
                          mask_mode="causal", window: int = 0, kv_mask=None,
                          block_q: int = LANE,
                          fmt_s="e5m2", fmt_p="e5m2", fmt_e="e5m2",
                          rounding_s="sr", rounding_p="sr", rounding_e="sr",
                          saturate_s=True, saturate_p=True,
                          saturate_e=False):
    """Unfused backward oracle. Returns (dq, dk, dv, amax_dp, amax_ds,
    dp8, ds8): dq (B,H,Q,D) f32, dk/dv (B,Hkv,S,D) f32 (GQA groups
    accumulated in head order), payloads (B,H,Q,S)."""
    b_, h_, q_len, d = q8.shape
    hkv, s_len = k8.shape[1], k8.shape[2]
    g = h_ // hkv
    qp, kp, vp = pad_qkv(q8, k8, v8, block_q)
    dop = _pad_to(_pad_to(do8, 2, block_q), 3, LANE)
    sp, dp_ = kp.shape[2], kp.shape[3]
    nq = qp.shape[2] // block_q
    dq = jnp.zeros(qp.shape, jnp.float32)
    dk = jnp.zeros((b_, hkv, sp, dp_), jnp.float32)
    dv = jnp.zeros((b_, hkv, sp, dp_), jnp.float32)
    amax_dp = amax_ds = jnp.float32(0.0)
    dp8_all, ds8_all = [], []
    for b in range(b_):
        dp8_h, ds8_h = [], []
        mrow = None if kv_mask is None \
            else _pad_to(kv_mask[b:b + 1].astype(jnp.int8), 1, LANE)
        for h in range(h_):
            dp8_t, ds8_t = [], []
            for iq in range(nq):
                sl = slice(iq * block_q, (iq + 1) * block_q)
                dq_t, dk_parts, dv_parts, a_dp, a_ds, dp8s, ds8s = bwd_q_tile(
                    qp[b, h, sl], kp[b, h // g], vp[b, h // g],
                    dop[b, h, sl], mrow,
                    seed=seed, bh=b * h_ + h, row0=iq * block_q, scal=scal,
                    mask_mode=mask_mode, window=window,
                    q_len=q_len, s_len=s_len,
                    fmt_s=fmt_s, fmt_p=fmt_p, fmt_e=fmt_e,
                    rounding_s=rounding_s, rounding_p=rounding_p,
                    rounding_e=rounding_e, saturate_s=saturate_s,
                    saturate_p=saturate_p, saturate_e=saturate_e)
                dq = dq.at[b, h, sl].set(dq_t)
                for j, (pk, pv_) in enumerate(zip(dk_parts, dv_parts)):
                    js = slice(j * LANE, (j + 1) * LANE)
                    dk = dk.at[b, h // g, js].add(pk)
                    dv = dv.at[b, h // g, js].add(pv_)
                amax_dp = jnp.maximum(amax_dp, a_dp)
                amax_ds = jnp.maximum(amax_ds, a_ds)
                dp8_t.append(jnp.concatenate(dp8s, axis=1))
                ds8_t.append(jnp.concatenate(ds8s, axis=1))
            dp8_h.append(jnp.concatenate(dp8_t, axis=0)[None])
            ds8_h.append(jnp.concatenate(ds8_t, axis=0)[None])
        dp8_all.append(jnp.concatenate(dp8_h, axis=0)[None])
        ds8_all.append(jnp.concatenate(ds8_h, axis=0)[None])
    # Raw-units accumulation, single scale multiply (see bwd_q_tile).
    dq = dq[:, :, :q_len, :d]
    dk = dk[:, :, :s_len, :d] * scal[8]
    dv = dv[:, :, :s_len, :d] * scal[9]
    dp8 = jnp.concatenate(dp8_all, axis=0)[:, :, :q_len, :s_len]
    ds8 = jnp.concatenate(ds8_all, axis=0)[:, :, :q_len, :s_len]
    return dq, dk, dv, amax_dp, amax_ds, dp8, ds8
