import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: jax.jit(step).lower(**ShapeDtypeStructs).compile() must succeed on
the single-pod (16 data x 16 model = 256 chips) mesh AND the multi-pod
(2 pods x 16 x 16 = 512 chips) mesh for every supported cell. The compiled
artifact supplies memory_analysis() (proves the cell fits per-device HBM)
and cost_analysis() + the HLO collective schedule for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh single --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

NOTE: the two os.environ lines above MUST stay the first statements — jax
locks the device count at first initialization.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.launch.mesh import (enter_mesh, jit_shardings,
                               make_production_mesh)
from repro.launch.specs import (GRID_ARCHS, SHAPES, build_cell,
                               cell_supported, parse_overrides)

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from compiled HLO text.

    Counts each op at its definition site (the `-start` line for async ops;
    plain form otherwise) and parses the output shape on the lhs, e.g.
      %ag = bf16[16,512,128]{...} all-gather(...)
    For while-loop bodies (scan-over-layers), ops inside loop computations
    are counted once — multiply by trip count in the analysis layer (the
    roofline path uses the UNROLLED lowering, where this is exact).
    """
    kinds = {}
    shape_re = re.compile(
        r"=\s+(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\]")
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "f8e5m2": 1, "f8e4m3fn": 1,
                   "s32": 4, "u32": 4, "s8": 1, "u8": 1, "pred": 1,
                   "f64": 8, "s64": 8, "u64": 8, "s16": 2, "u16": 2}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        if "-done" in line:
            continue                       # count -start only for async pairs
        kind = m.group(1)
        sm = shape_re.search(line)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        nbytes = dtype_bytes.get(dt, 4)
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        ent = kinds.setdefault(kind, {"count": 0, "bytes": 0})
        ent["count"] += 1
        ent["bytes"] += numel * nbytes
    return kinds


def run_cell(arch: str, shape: str, mesh_kind: str, *,
             unroll: bool = False, out_dir: Path,
             probe_groups: int = 0, overrides: dict = None) -> dict:
    """probe_groups > 0: compile an UNROLLED variant with that many pattern
    groups of layers (n_layers = groups * len(pattern)) — two probes give
    per-group cost deltas that the roofline analysis extrapolates to full
    depth (full-depth unrolled compiles are infeasible on one CPU core)."""
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = dict(arch=arch, shape=shape, mesh=mesh_kind, unroll=unroll,
               probe_groups=probe_groups,
               n_devices=mesh.devices.size, status="pending")
    t0 = time.time()
    overrides = dict(overrides or {})
    rec["overrides"] = overrides
    if probe_groups:
        from repro.models.registry import build_config
        full = build_config(arch)
        plen = len(full.pattern())
        overrides["n_layers"] = probe_groups * plen
        if full.is_encoder_decoder:
            overrides["n_encoder_layers"] = probe_groups
        unroll = True
        rec["unroll"] = True
    overrides = overrides or None
    try:
        with enter_mesh(mesh):
            cell = build_cell(arch, shape, mesh, unroll_layers=unroll,
                              overrides=overrides)
            rec["meta"] = cell["meta"]
            lowered = jax.jit(
                cell["fn"],
                in_shardings=jit_shardings(mesh, cell["in_shardings"]),
                out_shardings=jit_shardings(mesh, cell["out_shardings"]),
                donate_argnums=cell.get("donate_argnums", ()),
            ).lower(*cell["args"])
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            ma = compiled.memory_analysis()
            rec["memory"] = dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
                peak_bytes=int(ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
            )
            ca = compiled.cost_analysis()
            rec["cost"] = {k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed",
                                    "transcendentals")
                           or k.startswith("bytes accessed")}
            hlo = compiled.as_text()
            rec["collectives"] = parse_collectives(hlo)
            rec["status"] = "ok"
            print(f"[dryrun] OK   {arch:24s} {shape:12s} {mesh_kind:6s} "
                  f"unroll={unroll} compile={rec['compile_s']}s "
                  f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                  f"flops={rec['cost'].get('flops', 0):.3e}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {arch:24s} {shape:12s} {mesh_kind:6s}: "
              f"{rec['error'][:200]}")
    rec["total_s"] = round(time.time() - t0, 2)
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape}_{mesh_kind}"
    if probe_groups:
        tag += f"_probe{probe_groups}"
    elif unroll:
        tag += "_unroll"
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unrolled-layers lowering (roofline cost numbers)")
    ap.add_argument("--probe", action="store_true",
                    help="compile 1-group and 2-group unrolled probes "
                         "(roofline extrapolation inputs)")
    ap.add_argument("--set", nargs="*", default=[], dest="overrides",
                    help="key=value ModelConfig/policy overrides, e.g. "
                         "policy.quant.recipe=hybrid "
                         "policy.quant.scaling=delayed")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    overrides = parse_overrides(args.overrides)

    out_dir = Path(args.out)
    archs = GRID_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            ok, why = cell_supported(arch, shape)
            if not ok:
                print(f"[dryrun] SKIP {arch:24s} {shape:12s}: {why}")
                rec = dict(arch=arch, shape=shape, status="skipped",
                           reason=why)
                out_dir.mkdir(parents=True, exist_ok=True)
                (out_dir / f"{arch}_{shape}_skip.json").write_text(
                    json.dumps(rec, indent=1))
                continue
            for mk in meshes:
                if args.probe:
                    for g in (1, 2):
                        results.append(run_cell(arch, shape, mk,
                                                probe_groups=g,
                                                out_dir=out_dir,
                                                overrides=overrides))
                else:
                    results.append(run_cell(arch, shape, mk,
                                            unroll=args.unroll,
                                            out_dir=out_dir,
                                            overrides=overrides))
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled")
    if results and n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
