"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one v5e pod-slice class).
Multi-pod: (pod=2, data=16, model=16) — 512 chips; the 'pod' axis carries
data parallelism across the inter-pod (DCN/ICI) boundary, which is where
the FP8 wire formats pay off (ParallelPlan picks 'pod' as the wire axis;
see distributed/strategy.py).

These are FUNCTIONS, not module constants: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

from typing import Tuple

import jax

DATA_PARALLEL_AXES: Tuple[str, ...] = ("pod", "data")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    # axis_types / AxisType only exist in newer JAX; older versions default
    # every axis to auto sharding, which is exactly what we want anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def enter_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh, across JAX
    versions: jax.set_mesh where present, else the legacy `with mesh:`
    (Mesh is itself a context manager in older JAX)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def jit_shardings(mesh, tree):
    """PartitionSpec tree -> what jax.jit's in/out_shardings accepts on this
    JAX version: newer JAX takes bare PartitionSpecs (resolved against the
    ambient mesh); older JAX requires explicit NamedSharding objects."""
    if getattr(jax, "set_mesh", None) is not None:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, PartitionSpec)
        else s,
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


# Axis bookkeeping (dp axes present, per-axis sizes, wire-axis choice) lives
# on distributed.strategy.ParallelPlan — build one from (mesh, policy.dist)
# instead of reading mesh.shape by hand.
