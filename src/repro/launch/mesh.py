"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one v5e pod-slice class).
Multi-pod: (pod=2, data=16, model=16) — 512 chips; the 'pod' axis carries
data parallelism across the inter-pod (DCN/ICI) boundary, which is where the
FP8 gradient compression (distributed/grad_compress.py) pays off.

These are FUNCTIONS, not module constants: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

from typing import Tuple

import jax

DATA_PARALLEL_AXES: Tuple[str, ...] = ("pod", "data")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes present in a mesh ('pod' + 'data')."""
    return tuple(a for a in DATA_PARALLEL_AXES if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
