import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration runner for §Perf hillclimbing.

Compiles a named VARIANT of a dry-run cell (a dict of ModelConfig /
PrecisionPolicy overrides), derives the roofline terms, and appends the
record to experiments/perf/<arch>_<shape>.jsonl — the raw material for the
hypothesis -> change -> measure log. The roofline summary of each variant
is also merged into the repo-root BENCH_perf_<arch>_<shape>.json trajectory
file (one entry per variant) so fused-vs-unfused style A/B pairs are
directly comparable across PRs.

  PYTHONPATH=src python -m repro.launch.perf --arch mistral-large-123b \
      --shape decode_32k --variant kv_fp8 --set policy.kv_cache_format=e5m2

Fused-epilogue A/B (the quantize-in-epilogue GEMM path of core.qlinear):

  ... --variant fused   --set policy.quant.backend=pallas \
                              policy.quant.scaling=delayed
  ... --variant unfused --set policy.quant.backend=pallas \
                              policy.quant.scaling=delayed \
                              policy.quant.fuse_epilogue=false
"""
import argparse
import json
import time
from pathlib import Path

import jax

from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import (enter_mesh, jit_shardings,
                               make_production_mesh)
from repro.launch.specs import build_cell, parse_overrides
from repro.roofline.analysis import analyze_record


def _update_bench_trajectory(arch: str, shape: str, variant: str, rec: dict):
    """Merge one successful variant's roofline summary into the repo-root
    BENCH_perf_<arch>_<shape>.json (keyed by variant — re-running a variant
    overwrites its entry, so the file tracks the latest number per variant)."""
    path = Path(__file__).resolve().parents[3] \
        / f"BENCH_perf_{arch}_{shape}.json"
    try:
        current = json.loads(path.read_text()) if path.exists() else {}
    except (OSError, ValueError):
        current = {}
    r = rec["roofline"]
    current[variant] = dict(
        compute_s=r["compute_s"], memory_s=r["memory_s"],
        collective_s=r["collective_s"], dominant=r["dominant"],
        peak_gib=r["peak_gib"], overrides=rec.get("overrides", {}))
    path.write_text(json.dumps(current, indent=1) + "\n")


def run_variant(arch: str, shape: str, variant: str, overrides: dict, *,
                unroll: bool = False, out_dir: str = "experiments/perf"):
    mesh = make_production_mesh()
    rec = dict(arch=arch, shape=shape, mesh="single", variant=variant,
               overrides=overrides, unroll=unroll,
               n_devices=mesh.devices.size, status="pending")
    t0 = time.time()
    try:
        with enter_mesh(mesh):
            cell = build_cell(arch, shape, mesh, unroll_layers=unroll,
                              overrides=overrides)
            rec["meta"] = cell["meta"]
            compiled = jax.jit(
                cell["fn"],
                in_shardings=jit_shardings(mesh, cell["in_shardings"]),
                out_shardings=jit_shardings(mesh, cell["out_shardings"]),
                donate_argnums=cell.get("donate_argnums", ()),
            ).lower(*cell["args"]).compile()
            ma = compiled.memory_analysis()
            rec["memory"] = dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
                peak_bytes=int(ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes))
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # older jax: one dict/device
                ca = ca[0]
            rec["cost"] = {k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed",
                                    "transcendentals")}
            rec["collectives"] = parse_collectives(compiled.as_text())
            rec["status"] = "ok"
            rec["roofline"] = analyze_record(rec)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
    rec["total_s"] = round(time.time() - t0, 2)
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    with open(out / f"{arch}_{shape}.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")
    if rec["status"] == "ok":
        _update_bench_trajectory(arch, shape, variant, rec)
        r = rec["roofline"]
        print(f"[perf] {arch} {shape} {variant}: compute={r['compute_s']:.3e}"
              f" memory={r['memory_s']:.3e} coll={r['collective_s']:.3e}"
              f" dom={r['dominant']} peak={r['peak_gib']:.1f}GiB")
    else:
        print(f"[perf] {arch} {shape} {variant}: {rec['error'][:150]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="key=value ModelConfig/policy overrides")
    args = ap.parse_args()
    overrides = parse_overrides(args.set)
    run_variant(args.arch, args.shape, args.variant, overrides,
                unroll=args.unroll)


if __name__ == "__main__":
    main()
