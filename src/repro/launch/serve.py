"""Serving launcher: loads (or initializes) a model and runs a batched
greedy-decoding demo through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke
"""
import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--fp8-kv", action="store_true")
    ap.add_argument("--n-requests", type=int, default=6)
    args = ap.parse_args()

    import dataclasses

    from repro.checkpoint import Checkpointer
    from repro.models.registry import build_config
    from repro.models.transformer import init_lm
    from repro.serve import ServeConfig, ServeEngine

    cfg = build_config(args.arch, smoke=args.smoke)
    if args.fp8_kv:
        cfg = cfg.replace(policy=dataclasses.replace(
            cfg.policy, kv_cache_format="e5m2"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        if ck.latest_step() is not None:
            state_proto = jax.eval_shape(lambda p: p, params)
            params, step = ck.restore(state_proto)
            print(f"restored params at step {step}")

    eng = ServeEngine(cfg, params, ServeConfig(max_batch=args.max_batch,
                                               max_len=args.max_len))
    rng = np.random.default_rng(0)
    pending = [rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
               for _ in range(args.n_requests)]
    uid_to_req = {}
    i = 0
    while pending or any(eng.slots):
        while pending and eng.free_slots():
            p = pending.pop(0)
            uid = eng.add_request(p, max_new_tokens=16)
            uid_to_req[uid] = i
            i += 1
        for uid, toks in eng.step().items():
            print(f"request {uid_to_req[uid]}: generated {toks}")
    print("all requests served")


if __name__ == "__main__":
    main()
