"""Serving launcher: loads (or initializes) a model and runs a batched
decoding demo through the paged continuous-batching engine (chunked
prefill + paged KV + on-device sampling). `--legacy` selects the old
fixed-slot engine (the differential-parity oracle).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke
  PYTHONPATH=src python -m repro.launch.serve --smoke --temperature 0.8 \\
      --top-p 0.95 --page-size 8 --n-pages 32
"""
import argparse
import json

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--legacy", action="store_true",
                    help="use the fixed-slot ServeEngine instead of the "
                         "paged engine")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--fp8-kv", action="store_true")
    ap.add_argument("--n-requests", type=int, default=6)
    # -- paged-engine knobs --------------------------------------------------
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV tokens per page")
    ap.add_argument("--n-pages", type=int, default=64,
                    help="pool pages per layer (page 0 is the trash page)")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="prompt tokens prefilled per request per step")
    ap.add_argument("--no-prefix-cache", action="store_true")
    # -- sampling ------------------------------------------------------------
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 => greedy argmax (on device either way)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stats", action="store_true",
                    help="print the engine stats() snapshot at the end")
    args = ap.parse_args()

    import dataclasses

    from repro.checkpoint import Checkpointer
    from repro.models.registry import build_config
    from repro.models.transformer import init_lm
    from repro.serve import (PagedServeConfig, PagedServeEngine, ServeConfig,
                             ServeEngine)

    cfg = build_config(args.arch, smoke=args.smoke)
    if args.fp8_kv:
        cfg = cfg.replace(policy=dataclasses.replace(
            cfg.policy, kv_cache_format="e5m2"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        if ck.latest_step() is not None:
            state_proto = jax.eval_shape(lambda p: p, params)
            params, step = ck.restore(state_proto)
            print(f"restored params at step {step}")

    if args.legacy:
        eng = ServeEngine(cfg, params, ServeConfig(
            max_batch=args.max_batch, max_len=args.max_len,
            temperature=args.temperature, seed=args.seed))
    else:
        eng = PagedServeEngine(cfg, params, PagedServeConfig(
            max_batch=args.max_batch, max_len=args.max_len,
            n_pages=args.n_pages, page_size=args.page_size,
            chunk_size=args.chunk_size, temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p, seed=args.seed,
            prefix_cache=not args.no_prefix_cache))
    rng = np.random.default_rng(0)
    pending = [rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
               for _ in range(args.n_requests)]
    uid_to_req = {}
    i = 0

    def active():
        return any(s is not None for s in eng.slots)

    while pending or active():
        while pending and eng.free_slots():
            p = pending.pop(0)
            uid = eng.add_request(p, max_new_tokens=16)
            uid_to_req[uid] = i
            i += 1
        for uid, toks in eng.step().items():
            print(f"request {uid_to_req[uid]}: generated {toks}")
    print("all requests served")
    if args.stats:
        print(json.dumps(eng.stats(), indent=1))


if __name__ == "__main__":
    main()
