"""Input specifications for every (architecture x shape) dry-run cell.

ShapeDtypeStruct stand-ins only — weak-type-correct, shardable, no device
allocation. Each cell yields (fn, args, in_shardings, out_shardings, meta).

Shape cells (assigned):
  train_4k     seq=4096   global_batch=256   -> train_step
  prefill_32k  seq=32768  global_batch=32    -> serve_prefill
  decode_32k   seq=32768  global_batch=128   -> serve_decode (1 new token,
                                               KV cache of 32768)
  long_500k    seq=524288 global_batch=1     -> serve_decode; ONLY for
               sub-quadratic archs (ssm/hybrid) — full-attention archs are
               skipped per the assignment (see DESIGN.md §7).

Modality stubs per the assignment: llava gets precomputed patch embeddings,
seamless gets precomputed frame embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import replicated
from repro.distributed.strategy import ParallelPlan
from repro.models.config import ModelConfig
from repro.models.registry import build_config
from repro.models.transformer import (init_lm, init_paged_stack_state,
                                      init_stack_state)
from repro.train.step import (make_optimizer_for, make_serve_chunk,
                              make_serve_decode, make_serve_prefill,
                              make_train_step)

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode"),
}

# Archs from the assignment pool (paper workloads excluded from the grid).
GRID_ARCHS = [
    "internlm2-20b", "mistral-large-123b", "qwen2-1.5b", "codeqwen1.5-7b",
    "dbrx-132b", "moonshot-v1-16b-a3b", "llava-next-34b", "xlstm-125m",
    "recurrentgemma-9b", "seamless-m4t-large-v2",
]

SUBQUADRATIC = ("ssm", "hybrid")


def parse_overrides(pairs) -> Dict[str, Any]:
    """`--set key=value` strings -> build_cell overrides dict (shared by the
    dryrun and perf CLIs; int/float/bool coercion, strings otherwise)."""
    overrides: Dict[str, Any] = {}
    for kv in pairs:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v == "true":
            v = True
        elif v == "false":
            v = False
        overrides[k] = v
    return overrides


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    cfg = build_config(arch, smoke=True)   # family lookup only
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC:
        return False, ("full-attention arch: 512k dense-KV decode is "
                       "unbounded by construction (DESIGN.md §7)")
    return True, ""


def _token_batch(cfg: ModelConfig, batch: int, seq: int,
                 *, labels: bool) -> Dict[str, Any]:
    """ShapeDtypeStructs for one training/prefill batch."""
    sds = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {}
    text_len = seq
    if cfg.frontend == "patch_stub":
        text_len = seq - cfg.n_frontend_tokens
        out["extra_embeds"] = sds((batch, cfg.n_frontend_tokens, cfg.d_model),
                                  jnp.bfloat16)
    out["tokens"] = sds((batch, text_len), jnp.int32)
    if labels:
        out["labels"] = sds((batch, text_len), jnp.int32)
        out["loss_mask"] = sds((batch, text_len), jnp.float32)
    if cfg.is_encoder_decoder:
        out["enc_inputs"] = sds((batch, seq, cfg.d_model), jnp.bfloat16)
    return out


def _shaped(fn, *args):
    return jax.eval_shape(fn, *args)


def pick_microbatches(cfg: ModelConfig, batch: int, seq: int, dp: int,
                      *, residual_budget: float = 2.0e9) -> int:
    """Gradient-accumulation factor sized so the per-device layer-residual
    footprint (L x B_mb/dp x S x D x 2 bytes, the scan bwd carry) stays
    under `residual_budget`. Powers of two, capped so B_mb >= dp.
    `dp` is the total data-parallel degree (ParallelPlan.dp_size)."""
    total_layers = cfg.n_layers + cfg.n_encoder_layers
    per_mb = lambda n: (total_layers * (batch / (dp * n)) * seq
                        * cfg.d_model * 2.0)
    n = 1
    while per_mb(n) > residual_budget and batch // (n * 2) >= dp:
        n *= 2
    return n


@functools.lru_cache(maxsize=None)
def _cfg_for_cell(arch: str, shape: str) -> ModelConfig:
    cfg = build_config(arch)
    seq = SHAPES[shape]["seq"]
    return cfg.replace(max_seq_len=max(cfg.max_seq_len, seq))


def _apply_overrides(cfg: ModelConfig, overrides: Optional[Dict[str, Any]]
                     ) -> Tuple[ModelConfig, Any, Any, Dict[str, Any]]:
    """Apply dotted-key cell overrides to a ModelConfig.

    Returns (cfg, force_n_microbatches, force_sequence_parallel,
    serve_kwargs).  'policy.quant.*' / 'policy.dist.*' / 'policy.*' keys
    replace into the nested policy dataclasses; 'serve.*' keys are
    returned for the serving-step builder; everything else replaces
    directly on the ModelConfig.
    """
    force_nmb = None
    force_sp = None
    serve_kw: Dict[str, Any] = {}
    if overrides:
        overrides = dict(overrides)
        force_nmb = overrides.pop("n_microbatches", None)
        force_sp = overrides.pop("force_sequence_parallel", None)
        serve_kw = {k.split(".", 1)[1]: v for k, v in overrides.items()
                    if k.startswith("serve.")}
        pol_kw = {k.split(".", 1)[1]: v for k, v in overrides.items()
                  if k.startswith("policy.")}
        cfg_kw = {k: v for k, v in overrides.items()
                  if not k.startswith(("policy.", "serve."))}
        if pol_kw:
            qkw = {k.split(".", 1)[1]: v for k, v in pol_kw.items()
                   if k.startswith("quant.")}
            dkw = {k.split(".", 1)[1]: v for k, v in pol_kw.items()
                   if k.startswith("dist.")}
            pol_kw = {k: v for k, v in pol_kw.items()
                      if not k.startswith(("quant.", "dist."))}
            pol = cfg.policy
            if qkw:
                pol = dataclasses.replace(pol, quant=dataclasses.replace(
                    pol.quant, **qkw))
            if dkw:
                pol = dataclasses.replace(pol, dist=dataclasses.replace(
                    pol.dist, **dkw))
            cfg = cfg.replace(policy=dataclasses.replace(pol, **pol_kw))
        if cfg_kw:
            cfg = cfg.replace(**cfg_kw)
    return cfg, force_nmb, force_sp, serve_kw


def cell_config(arch: str, shape: str, *,
                overrides: Optional[Dict[str, Any]] = None) -> ModelConfig:
    """The ModelConfig a cell is built with (shape-adjusted, overrides
    applied) — the same resolution path `build_cell` takes, without
    building anything.  Used by `repro.analysis.precision_lint` to
    classify jaxpr findings against the cell's actual knobs."""
    cfg = _cfg_for_cell(arch, shape)
    return _apply_overrides(cfg, overrides)[0]


def build_cell(arch: str, shape: str, mesh, *,
               unroll_layers: bool = False,
               overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Returns dict(fn, args, in_shardings, out_shardings, meta).

    unroll_layers=True disables scan-over-layers so cost_analysis counts
    every layer (roofline lowering); the default scan lowering is used for
    the memory-fit proof and the multi-pod pass.

    overrides: perf-iteration knobs applied to the ModelConfig; keys starting
    with 'policy.' modify the PrecisionPolicy (e.g. {'policy.kv_cache_format':
    'e5m2', 'attn_chunk_size': 512, 'capacity_factor': 1.0}). Keys starting
    with 'serve.' select/configure the paged serving step for decode cells
    ({'serve.paged': True, 'serve.page_size': 64, 'serve.chunk_size': 1,
    'serve.n_pages': N}) — KV memory then scales with the page pool, not
    batch * max_len.
    """
    ok, why = cell_supported(arch, shape)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape}) skipped: {why}")
    info = SHAPES[shape]
    seq, batch, mode = info["seq"], info["batch"], info["mode"]
    cfg = _cfg_for_cell(arch, shape)
    cfg, force_nmb, force_sp, serve_kw = _apply_overrides(cfg, overrides)
    if unroll_layers:
        cfg = cfg.replace(scan_layers=False)
    # The plan owns every sharding decision from here on: dp/zero1/tp axes,
    # PartitionSpecs, wire-format collectives.
    plan = ParallelPlan.build(mesh, cfg.policy.dist)

    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_s = _shaped(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    if mode != "train":
        # Production serving stores bf16 weights (FP8 at the qeinsum level).
        params_s = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), params_s)
    pspecs = plan.param_specs(params_s)

    meta = dict(arch=arch, shape=shape, mode=mode, n_layers=cfg.n_layers,
                n_encoder_layers=cfg.n_encoder_layers,
                d_model=cfg.d_model, seq=seq, batch=batch,
                family=cfg.family, scan_layers=cfg.scan_layers,
                n_experts=cfg.n_experts,
                experts_per_token=cfg.experts_per_token,
                d_ff=cfg.d_ff, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim,
                vocab=cfg.padded_vocab_size,
                pattern=",".join(cfg.pattern()),
                window=cfg.window,
                dist=plan.describe())

    if mode == "train":
        opt = make_optimizer_for(cfg)
        state_s = _shaped(opt.init, params_s)
        state_specs_tree = plan.train_state_specs(state_s)
        batch_s = _token_batch(cfg, batch, seq, labels=True)
        bspecs = plan.batch_specs(batch_s)
        # Roofline (unrolled) lowering: single microbatch so per-step FLOPs
        # are fully visible to cost_analysis (a microbatch scan body would be
        # counted once); memory fit is proven by the scan lowering instead.
        n_mb = 1 if unroll_layers \
            else pick_microbatches(cfg, batch, seq, plan.dp_size)
        if force_nmb is not None:
            n_mb = force_nmb
        meta["n_microbatches"] = n_mb
        # Sequence parallelism: shards the residual stream + norm/GEMM f32
        # transients over 'model'; always on for train when a model axis
        # exists (pure win: memory / TP-degree, small extra gather volume).
        if plan.tp_size > 1 and seq % plan.tp_size == 0 \
                and force_sp is not False:
            cfg = cfg.replace(sequence_parallel=True)
            meta["sequence_parallel"] = True
        # Delayed per-tensor scaling at production shapes: when the cell's
        # QuantConfig asks for it (e.g. overrides {'policy.quant.scaling':
        # 'delayed', 'policy.quant.recipe': 'hybrid'}), discover the site
        # registry from one abstract trace and thread a ScaleState through
        # the step — the dry-run then proves the hybrid delayed recipe
        # lowers, shards, and fits alongside everything else.
        scaling = None
        meta["recipe"] = cfg.policy.quant.recipe
        meta["scaling"] = cfg.policy.quant.scaling
        meta["fuse_epilogue"] = cfg.policy.quant.fuse_epilogue
        meta["fuse_attention"] = cfg.policy.quant.fuse_attention
        # Precision-health counters (obs subsystem): recorded so dry-run
        # artifacts document whether the cell's step carries the per-site
        # saturation/flush observations (overridable per cell via
        # {'policy.quant.track_health': True}).
        meta["track_health"] = cfg.policy.quant.track_health
        if cfg.policy.quant.fuse_attention:
            # Streamed-KV knobs (results are bit-invariant to them; they
            # set the kernel's VMEM working set per grid step). Unset
            # knobs resolve through the autotuner winners table exactly
            # as the kernel op will at trace time, so the dry-run artifact
            # records the schedule the cell actually runs.
            from repro.kernels import autotune as _autotune
            from repro.kernels.fp8_attention import ref as _attn_ref
            _bq, _bkv = _autotune.resolve_attn_blocks(
                "fwd", "causal", seq, seq, cfg.resolved_head_dim,
                block_q=cfg.policy.quant.attn_block_q,
                block_kv=cfg.policy.quant.attn_block_kv,
                autotune=cfg.policy.quant.autotune)
            meta["attn_block_q"] = _bq
            meta["attn_block_kv"] = _attn_ref.resolve_block_kv(seq, _bkv)
            meta["autotune"] = cfg.policy.quant.autotune
            if cfg.policy.quant.attn_block_q is not None \
                    or cfg.policy.quant.attn_block_kv is not None:
                # Explicit knobs are checked against the analytic VMEM
                # model here, at spec-build time, so an oversized config
                # fails with the modeled footprint instead of an opaque
                # Mosaic allocation error hours into a launch.
                from repro.analysis import vmem as _vmem
                _vmem.check_attn_blocks(
                    meta["attn_block_q"], meta["attn_block_kv"],
                    cfg.resolved_head_dim,
                    label=f"explicit attention blocks for cell "
                          f"({arch}, {shape})")
        if cfg.policy.quant.scaling == "delayed":
            from repro.scaling.calibrate import discover_lm_sites
            from repro.scaling.state import DelayedScaling
            registry = discover_lm_sites(cfg, params_s, batch_s)
            scaling = DelayedScaling(registry, qcfg=cfg.policy.quant)
            meta["scale_rows"] = len(registry)
        fn = make_train_step(cfg, opt, n_microbatches=n_mb,
                             scaling=scaling, plan=plan)
        wire = plan.compresses
        if wire:
            # The fp8-on-the-wire step threads the error-feedback residual
            # pytree (stacked per-wire-device, sharded over the wire axis).
            meta["wire_bytes"] = plan.wire_bytes(params_s)
            err_s = plan.wire_state_struct(state_s.master)
            espec = plan.wire_state_specs(err_s)
        if scaling is not None:
            sstate_s = _shaped(scaling.init)
            if wire:
                metrics_s = _shaped(fn, state_s, sstate_s, err_s, batch_s,
                                    jax.random.PRNGKey(0))[1]
                return dict(
                    fn=fn, args=(state_s, sstate_s, err_s, batch_s, key_s),
                    in_shardings=(state_specs_tree, replicated(sstate_s),
                                  espec, bspecs, P()),
                    out_shardings=((state_specs_tree, replicated(sstate_s),
                                    espec), replicated(metrics_s)),
                    donate_argnums=(0, 1, 2),
                    meta=meta)
            metrics_s = _shaped(fn, state_s, sstate_s, batch_s,
                                jax.random.PRNGKey(0))[1]
            return dict(
                fn=fn, args=(state_s, sstate_s, batch_s, key_s),
                in_shardings=(state_specs_tree, replicated(sstate_s),
                              bspecs, P()),
                out_shardings=((state_specs_tree, replicated(sstate_s)),
                               replicated(metrics_s)),
                donate_argnums=(0, 1),
                meta=meta)
        if wire:
            metrics_s = _shaped(fn, state_s, err_s, batch_s,
                                jax.random.PRNGKey(0))[1]
            return dict(
                fn=fn, args=(state_s, err_s, batch_s, key_s),
                in_shardings=(state_specs_tree, espec, bspecs, P()),
                out_shardings=((state_specs_tree, espec),
                               replicated(metrics_s)),
                donate_argnums=(0, 1),
                meta=meta)
        metrics_s = _shaped(fn, state_s, batch_s, jax.random.PRNGKey(0))[1]
        return dict(
            fn=fn, args=(state_s, batch_s, key_s),
            in_shardings=(state_specs_tree, bspecs, P()),
            out_shardings=(state_specs_tree, replicated(metrics_s)),
            donate_argnums=(0,),   # optimizer state updated in place
            meta=meta)

    # ---- serving cells ------------------------------------------------------
    if mode == "prefill" and plan.tp_size > 1 and seq % plan.tp_size == 0:
        cfg = cfg.replace(sequence_parallel=True)
        meta["sequence_parallel"] = True
    cache_len = min(seq, 32768) if shape != "long_500k" else cfg.window or 1
    meta["recipe"] = cfg.policy.quant.recipe
    meta["kv_cache_format"] = cfg.policy.kv_cache_format
    meta["fuse_attention"] = cfg.policy.quant.fuse_attention
    paged = bool(serve_kw.get("paged"))
    if mode == "prefill":
        states_s = _shaped(
            lambda: init_stack_state(cfg, batch, max_len=seq,
                                     n_layers=cfg.n_layers))
        batch_s = _token_batch(cfg, batch, seq, labels=False)
        fn = make_serve_prefill(cfg)
    elif paged:
        # Paged-KV decode cell: the PagedServeEngine step minus sampling —
        # block-table gather over a flat slot pool, per-row [start, n_valid]
        # chunk bounds. KV memory scales with the pool (n_pages * page_size
        # slots), not batch * max_len; chunk_size > 1 dry-runs the chunked-
        # prefill shape of the same program.
        if cfg.is_encoder_decoder:
            raise ValueError("paged serving cells do not support "
                             "encoder-decoder archs")
        psize = int(serve_kw.get("page_size", 64))
        tchunk = int(serve_kw.get("chunk_size", 1))
        n_pages = int(serve_kw.get("n_pages",
                                   batch * (cache_len // psize) + 1))
        capacity = -(-cache_len // psize) * psize
        n_slots = n_pages * psize
        states_s = _shaped(
            lambda: init_paged_stack_state(cfg, n_slots,
                                           n_layers=cfg.n_layers))
        sds = jax.ShapeDtypeStruct
        batch_s = {"tokens": sds((batch, tchunk), jnp.int32),
                   "positions": sds((batch, tchunk), jnp.int32),
                   "write_slots": sds((batch, tchunk), jnp.int32),
                   "read_slots": sds((batch, capacity), jnp.int32),
                   "slot_pos": sds((batch, capacity), jnp.int32),
                   "chunk_pos": sds((batch, 2), jnp.int32),
                   "last_row": sds((batch,), jnp.int32)}
        fn = make_serve_chunk(cfg)
        meta["paged"] = dict(page_size=psize, chunk_size=tchunk,
                             n_pages=n_pages, capacity=capacity,
                             kv_pool_tokens=n_slots)
    else:  # decode
        states_s = _shaped(
            lambda: init_stack_state(cfg, batch, max_len=cache_len,
                                     n_layers=cfg.n_layers))
        batch_s = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                   "positions": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
        if cfg.is_encoder_decoder:
            batch_s["enc_out"] = jax.ShapeDtypeStruct(
                (batch, 4096, cfg.d_model), jnp.bfloat16)
        fn = make_serve_decode(cfg)

    sspecs = plan.serve_state_specs(states_s, paged=paged)
    bspecs = plan.batch_specs(batch_s)
    logits_spec = plan.logits_spec(batch, cfg.padded_vocab_size)
    # Serving params are ZeRO-sharded over 'data' on top of TP (FSDP-style
    # per-layer gather) — a 123B bf16 model does not fit at TP-16 alone.
    serve_pspecs = plan.master_specs(params_s, pspecs)
    return dict(
        fn=fn, args=(params_s, batch_s, states_s),
        in_shardings=(serve_pspecs, bspecs, sspecs),
        out_shardings=(logits_spec, sspecs),
        donate_argnums=(2,),   # caches/states updated in place
        meta=meta)
