"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --steps 100 --smoke          # CPU-scale
  # On a real fleet the same entry point runs under your cluster launcher
  # (one process per host); jax.distributed.initialize() is called when
  # COORDINATOR_ADDRESS is set, and the mesh comes from launch.mesh.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--wire", default="full", choices=["full", "fp8_ef"],
                    help="DP gradient reduction wire format "
                         "(policy.dist.wire): fp8_ef = e5m2-compressed "
                         "all-reduce with error feedback")
    ap.add_argument("--zero-gather", default="full", choices=["full", "fp8"],
                    help="ZeRO-1 weight all-gather wire format "
                         "(policy.dist.wire_zero_gather)")
    args = ap.parse_args()

    import dataclasses

    import jax

    if os.environ.get("COORDINATOR_ADDRESS"):
        jax.distributed.initialize()   # multi-host fleet entry

    from repro.core.loss_scale import LossScaler
    from repro.data import DataConfig, synthetic_lm_batches
    from repro.models.registry import build_config
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.train.step import make_optimizer_for

    cfg = build_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(remat=False)
    plan = None
    n_dev = jax.device_count()
    if n_dev > 1:
        # Pure data-parallel launcher mesh; the full pod/data/model grids
        # come from launch.mesh.make_production_mesh under the dry-run.
        from repro.distributed.strategy import ParallelPlan
        from repro.launch.mesh import make_mesh
        dist = dataclasses.replace(cfg.policy.dist, wire=args.wire,
                                   wire_zero_gather=args.zero_gather)
        cfg = cfg.replace(policy=dataclasses.replace(cfg.policy, dist=dist))
        plan = ParallelPlan.build(make_mesh((n_dev,), ("data",)), dist)
        print(f"[train] parallel plan: {plan.describe()}")
    elif args.wire != "full" or args.zero_gather != "full":
        print("[train] single device: wire format flags ignored")
    opt = make_optimizer_for(cfg, name="adam", learning_rate=args.lr,
                             scaler=LossScaler(mode="enhanced",
                                               init_scale=2.0**13))
    data = synthetic_lm_batches(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch, seed=0))
    loop = TrainLoop(cfg, opt, data,
                     LoopConfig(total_steps=args.steps,
                                checkpoint_every=max(10, args.steps // 4),
                                checkpoint_dir=args.ckpt_dir,
                                metrics_path=f"{args.ckpt_dir}/metrics.jsonl",
                                n_microbatches=args.microbatches),
                     plan=plan)
    loop.install_signal_handlers()
    out = loop.run()
    print(f"finished step {out['last_step']} loss="
          f"{out['metrics'].get('loss', float('nan')):.4f}")


if __name__ == "__main__":
    main()
