"""Loss scaling: constant, dynamic back-off, and the paper's ENHANCED scheme.

Paper §3.1: e5m2 keeps fp16's exponent range but has a 256x smaller subnormal
range (min subnormal 1.52e-5 vs 5.96e-8), so error gradients underflow much
earlier than in fp16 training:

 * ConvNets: constant scaling works but needs a much larger factor —
   ResNet-50 diverges at 1000 (the fp16 folk value), converges at 10000.
 * GNMT/Transformer: standard dynamic "back-off" scaling handles overflow but
   not the more-frequent-in-fp8 underflow; more frequent growth destabilizes.
   The paper instead raises the *minimum threshold* of the dynamic scale on a
   schedule (8K after 40K iters, 32K at ~150K — Fig. 2b).

Everything here is jit-compatible: scaler configs are static dataclasses;
state is a small pytree updated with lax.cond-free arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LossScaleState:
    scale: Array          # f32 scalar, current loss scale
    growth_count: Array   # i32, consecutive finite steps since last change
    step: Array           # i32, global step (drives the min-threshold schedule)
    overflow_count: Array  # i32, total overflow events (telemetry)

    @classmethod
    def create(cls, init_scale: float) -> "LossScaleState":
        return cls(scale=jnp.asarray(init_scale, jnp.float32),
                   growth_count=jnp.asarray(0, jnp.int32),
                   step=jnp.asarray(0, jnp.int32),
                   overflow_count=jnp.asarray(0, jnp.int32))


def all_finite(tree) -> Array:
    """True iff every leaf of the gradient pytree is finite (overflow probe)."""
    leaves = [jnp.isfinite(x.astype(jnp.float32)).all()
              for x in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return jnp.asarray(True)
    return jnp.stack(leaves).all()


@dataclasses.dataclass(frozen=True)
class LossScaler:
    """Unified scaler. mode selects the behavior:

    'constant'  — fixed scale (paper's convnet recipe; use init_scale=10000).
    'dynamic'   — back-off dynamic scaling [Kuchaiev et al. 2018].
    'enhanced'  — dynamic + growing minimum threshold (the paper's method).
    """
    mode: str = "enhanced"
    init_scale: float = 2.0 ** 13          # 8192: paper's GNMT starting point
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    growth_interval: int = 2000
    max_scale: float = 2.0 ** 24
    min_scale: float = 1.0
    # Enhanced: (step, min_scale) knots; paper Fig. 2b used
    # ((40_000, 8192), (150_000, 32768)) for GNMT on WMT16.
    min_scale_schedule: Tuple[Tuple[int, float], ...] = \
        ((40_000, 8192.0), (150_000, 32768.0))

    def init(self) -> LossScaleState:
        return LossScaleState.create(self.init_scale)

    # -- schedule ------------------------------------------------------------
    def min_scale_at(self, step: Array) -> Array:
        floor = jnp.asarray(self.min_scale, jnp.float32)
        if self.mode != "enhanced":
            return floor
        for knot_step, knot_min in self.min_scale_schedule:
            floor = jnp.where(step >= knot_step,
                              jnp.asarray(knot_min, jnp.float32), floor)
        return floor

    # -- api -----------------------------------------------------------------
    def scale_loss(self, state: LossScaleState, loss: Array) -> Array:
        return loss * state.scale.astype(loss.dtype)

    def unscale(self, state: LossScaleState, grads):
        """Divide gradients by the scale **in full precision** (paper Fig. 1b:
        'performed in full precision to prevent underflow')."""
        inv = (1.0 / state.scale).astype(jnp.float32)
        return jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * inv), grads)

    def update(self, state: LossScaleState, grads_finite: Array) -> LossScaleState:
        if self.mode == "constant":
            return LossScaleState(scale=state.scale,
                                  growth_count=state.growth_count,
                                  step=state.step + 1,
                                  overflow_count=state.overflow_count
                                  + (~grads_finite).astype(jnp.int32))
        grew = state.growth_count + 1 >= self.growth_interval
        new_scale_ok = jnp.where(
            grew, jnp.minimum(state.scale * self.growth_factor, self.max_scale),
            state.scale)
        new_count_ok = jnp.where(grew, 0, state.growth_count + 1)
        new_scale_bad = state.scale * self.backoff_factor
        scale = jnp.where(grads_finite, new_scale_ok, new_scale_bad)
        # Enhanced: clamp to the scheduled minimum threshold, preventing the
        # back-off from dropping into the underflow regime (paper Fig. 2b).
        # The floor is evaluated at the POST-increment step (the step this
        # update produces): a knot at step S must bound the scale from the
        # update that lands on S, not one update later.
        floor = self.min_scale_at(state.step + 1)
        scale = jnp.maximum(scale, floor)
        return LossScaleState(
            scale=scale,
            growth_count=jnp.where(grads_finite, new_count_ok, 0)
            .astype(jnp.int32),
            step=state.step + 1,
            overflow_count=state.overflow_count
            + (~grads_finite).astype(jnp.int32))


# Paper-recipe scalers --------------------------------------------------------

def convnet_scaler(scale: float = 10_000.0) -> LossScaler:
    """Paper Fig. 2a: ResNet-50 requires constant scale 10000 under e5m2."""
    return LossScaler(mode="constant", init_scale=scale)


def gnmt_scaler() -> LossScaler:
    """Paper Fig. 2b: dynamic with growing min threshold (8K@40K, 32K@150K)."""
    return LossScaler(mode="enhanced")


def transformer_scaler() -> LossScaler:
    return LossScaler(mode="enhanced", init_scale=2.0 ** 13)


def underflow_fraction(tree, *, threshold: float) -> Array:
    """Fraction of gradient entries whose magnitude would flush to zero in a
    format with min-subnormal `threshold` — the measurement behind Fig. 2a."""
    num = jnp.asarray(0, jnp.int32)
    tot = jnp.asarray(0, jnp.int32)
    for g in jax.tree_util.tree_leaves(tree):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            continue
        gf = jnp.abs(g.astype(jnp.float32))
        nz = gf > 0
        under = nz & (gf < threshold / 2)  # RNE flushes below half min-sub
        num = num + under.sum().astype(jnp.int32)
        tot = tot + nz.sum().astype(jnp.int32)
    return num.astype(jnp.float32) / jnp.maximum(tot, 1).astype(jnp.float32)
