"""Quantized 2-D convolution (the paper's ResNet workloads).

TPU convs lower to implicit GEMM; we make that explicit: extract patches with
lax.conv_general_dilated_patches, then run the (patches x filters) GEMM
through qeinsum — so the paper's W/A/E/G quantization covers convolutions
with the exact same Q-node dataflow as dense layers (forward, error and
weight-gradient GEMMs all take FP8 operands, f32 accumulation). The patch
extraction/scatter itself is index movement, not arithmetic, and stays
unquantized — as in the paper, where quantization applies to GEMM inputs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision_policy import PAPER_FP8, QuantConfig
from repro.core.qlinear import qeinsum

Array = jax.Array


def conv_init(key, kh: int, kw: int, c_in: int, c_out: int, *,
              dtype=jnp.float32) -> Array:
    fan_in = kh * kw * c_in
    std = (2.0 / fan_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0,
                                        (kh, kw, c_in, c_out), jnp.float32)
            * std).astype(dtype)


def qconv2d(x: Array, w: Array, *, stride: Tuple[int, int] = (1, 1),
            padding: str = "SAME", key: Optional[Array] = None,
            cfg: QuantConfig = PAPER_FP8,
            site: Optional[str] = None) -> Array:
    """x: (B, H, W, C_in), w: (kh, kw, C_in, C_out) -> (B, H', W', C_out).

    site: delayed-scaling site name for the implicit GEMM (see qeinsum)."""
    kh, kw, c_in, c_out = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), stride, padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches returns channels ordered (C_in, kh, kw)
    # on the last axis; reorder the filter to match.
    w_flat = w.transpose(2, 0, 1, 3).reshape(c_in * kh * kw, c_out)
    b, ho, wo, _ = patches.shape
    y = qeinsum("bhwk,kn->bhwn", patches, w_flat, key=key, cfg=cfg, site=site)
    return y
