"""FP16 master weights with FP32 update math — paper Fig. 1b.

The paper halves the master-copy footprint by storing it in FP16 and
performing the weight update as: up-convert FP16 -> FP32, unscale gradients in
FP32, run the (momentum/Adam) update in FP32, down-convert back to FP16 for
storage. Since the update is bandwidth-bound, the FP32 math is free; the FP16
storage halves HBM traffic and memory.

This module is optimizer-agnostic: it wraps any (init, update) pair from
repro.optim and adds (a) the storage-dtype round-trip, (b) gradient
unscaling, (c) the overflow-skip (a non-finite gradient step is dropped and
the loss scaler backs off — standard dynamic-scaling contract).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.loss_scale import LossScaleState, LossScaler, all_finite
from repro.core.precision_policy import dtype_of

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MixedPrecisionState:
    master: Any          # master weights, stored at master_dtype (paper: fp16)
    opt_state: Any       # inner optimizer state (fp32)
    loss_scale: LossScaleState


@dataclasses.dataclass(frozen=True)
class MixedPrecisionOptimizer:
    """Wraps an inner optimizer with the paper's Fig. 1b update rule.

    If (accum_names, leaf_update) are provided, apply_gradients runs the
    FUSED path: the entire unscale -> update -> overflow-select -> downcast
    pipeline executes in one tree_map, so FP32 temporaries are per-leaf
    instead of per-tree (essential at 100B+ parameters)."""
    inner_init: Callable[[Any], Any]
    inner_update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (g,s,p)->(u,s)
    scaler: LossScaler
    master_dtype: str = "float16"     # paper: FP16 master copy
    update_dtype: str = "float32"     # paper: update math in FP32
    compute_dtype: str = "bfloat16"   # dtype of the params handed to the model
    accum_names: Tuple[str, ...] = ()
    leaf_update: Optional[Callable] = None

    def init(self, params) -> MixedPrecisionState:
        mdt = dtype_of(self.master_dtype)
        master = jax.tree_util.tree_map(
            lambda p: p.astype(mdt), params)
        # Optimizer state (momentum etc.) stays fp32: it accumulates small
        # increments and the paper only reduces the *master copy* precision.
        opt_state = self.inner_init(
            jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params))
        return MixedPrecisionState(master=master, opt_state=opt_state,
                                   loss_scale=self.scaler.init())

    def compute_params(self, state: MixedPrecisionState):
        """Model-facing params: master cast to compute dtype (bf16). The
        model's qeinsum then quantizes these to FP8 per the W policy."""
        cdt = dtype_of(self.compute_dtype)
        return jax.tree_util.tree_map(lambda p: p.astype(cdt), state.master)

    def apply_gradients(self, state: MixedPrecisionState, grads
                        ) -> Tuple[MixedPrecisionState, dict]:
        if self.leaf_update is not None:
            return self._apply_gradients_fused(state, grads)
        udt = dtype_of(self.update_dtype)
        mdt = dtype_of(self.master_dtype)
        # 1. Overflow probe on the raw (still loss-scaled) gradients.
        finite = all_finite(grads)
        # 2. Unscale in full precision (paper: prevents underflow).
        grads32 = self.scaler.unscale(state.loss_scale, grads)
        # 3. Up-convert master to FP32 and update.
        master32 = jax.tree_util.tree_map(lambda p: p.astype(udt), state.master)
        updates, new_opt_state = self.inner_update(grads32, state.opt_state,
                                                   master32)
        new_master32 = jax.tree_util.tree_map(lambda p, u: p + u,
                                              master32, updates)
        # 4. Skip the step entirely on overflow (keep old master/opt state).
        def select(new, old):
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(finite, n, o), new, old)
        new_master32 = select(new_master32, master32)
        new_opt_state = select(new_opt_state, state.opt_state)
        # 5. Down-convert master back to FP16 storage.
        new_master = jax.tree_util.tree_map(lambda p: p.astype(mdt),
                                            new_master32)
        new_scale_state = self.scaler.update(state.loss_scale, finite)
        metrics = {
            "grads_finite": finite,
            "loss_scale": new_scale_state.scale,
            "overflow_count": new_scale_state.overflow_count,
        }
        return MixedPrecisionState(master=new_master, opt_state=new_opt_state,
                                   loss_scale=new_scale_state), metrics

    # -- fused leaf-wise path -------------------------------------------------
    def _apply_gradients_fused(self, state: MixedPrecisionState, grads
                               ) -> Tuple[MixedPrecisionState, dict]:
        udt = dtype_of(self.update_dtype)
        mdt = dtype_of(self.master_dtype)
        names = self.accum_names
        finite = all_finite(grads)
        inv = (1.0 / state.loss_scale.scale).astype(jnp.float32)
        count = jnp.where(finite, state.opt_state["count"] + 1,
                          state.opt_state["count"]).astype(jnp.int32)

        def leaf_fn(g, m, *accs):
            g32 = g.astype(udt) * inv            # unscale in full precision
            accums = dict(zip(names, accs))
            p32 = m.astype(udt)                  # fp16 master -> fp32
            upd, new_acc = self.leaf_update(g32, accums, count, p32)
            m32 = p32 + upd                      # update in fp32 (Fig. 1b)
            new_m = jnp.where(finite, m32, p32).astype(mdt)
            outs = (new_m,)
            for n, a in zip(names, accs):
                outs += (jnp.where(finite, new_acc[n], a),)
            return outs

        packed = jax.tree_util.tree_map(
            leaf_fn, grads, state.master,
            *(state.opt_state[n] for n in names))
        is_tup = lambda x: isinstance(x, tuple)  # noqa: E731
        new_master = jax.tree_util.tree_map(lambda t: t[0], packed,
                                            is_leaf=is_tup)
        new_opt = {"count": count}
        for i, n in enumerate(names):
            new_opt[n] = jax.tree_util.tree_map(lambda t, i=i: t[1 + i],
                                                packed, is_leaf=is_tup)
        new_scale_state = self.scaler.update(state.loss_scale, finite)
        metrics = {"grads_finite": finite,
                   "loss_scale": new_scale_state.scale,
                   "overflow_count": new_scale_state.overflow_count}
        return MixedPrecisionState(master=new_master, opt_state=new_opt,
                                   loss_scale=new_scale_state), metrics
