"""Core FP8 mixed-precision training primitives (the paper's contribution).

Mellempudi et al. 2019: FP8 (e5m2) weights/activations/errors/gradients with
FP32 accumulation, FP16 master weights, enhanced loss scaling, stochastic
rounding.
"""
from repro.core.fp8_formats import (BF16, E4M3, E5M2, FP16, FP32, FORMATS,
                                    FloatFormat, get_format, table1)
# NOTE: `repro.core.quantize` stays bound to the MODULE; the quantize()
# function is accessed as repro.core.quantize.quantize (or via the re-exports
# below, which deliberately exclude the clashing name).
from repro.core.quantize import (QTensor, amax_scale, dequantize, fake_quant,
                                 quantize_rne, quantize_sr, quantize_sr_e5m2,
                                 quantize_sr_fp8, quantize_sr_grid,
                                 sr_e5m2_from_bits, sr_fp8_from_bits,
                                 sr_fp8_via_f16)
from repro.core import quantize  # noqa: F401  (rebind name to the module)

__all__ = [
    "BF16", "E4M3", "E5M2", "FP16", "FP32", "FORMATS", "FloatFormat",
    "get_format", "table1", "QTensor", "amax_scale", "dequantize",
    "fake_quant", "quantize", "quantize_rne", "quantize_sr",
    "quantize_sr_e5m2", "quantize_sr_fp8", "quantize_sr_grid",
    "sr_e5m2_from_bits", "sr_fp8_from_bits", "sr_fp8_via_f16",
]
