"""Quantization primitives: RNE and stochastic rounding into FP8.

This is the software realization of the paper's `Q` nodes (Fig. 1a): each GEMM
produces a 32-bit result which is down-converted + rounded to FP8 before the
next op. Two rounding modes, per paper §3.2:

 * RNE  (round-to-nearest-even): what commodity hardware implements; shown by
   the paper to be sufficient for small nets but to cause generalization loss
   on ResNet-50 (unconstrained parameter growth).
 * SR   (stochastic rounding): round(x) = floor(x) + eps with probability
   (x - floor(x))/eps. The paper applies SR to activations and gradients and
   recovers (slightly beats) the FP32 baseline.

SR is implemented *exactly* with an fp16 bit-twiddle for BOTH fp8 formats.
E5M2 is the top byte of an IEEE fp16, so adding a uniform 8-bit integer to
the fp16 bit pattern and truncating the low byte performs stochastic rounding
on the real line (bit patterns are monotone in magnitude, and mantissa
carries propagate into the exponent, handling binade crossings and the
subnormal/normal boundary for free). E4M3 embeds the same way after a
power-of-two prescale (x * 2^-8) that aligns its subnormal threshold with
fp16's: every e4m3 grid point then maps to an fp16 pattern whose low 7 bits
are zero — including the subnormals, which land in fp16's fixed-point
subnormal range — so adding 7 uniform random bits and truncating is again
exact SR. See `sr_fp8_from_bits` / `sr_fp8_via_f16`, the single bit-twiddle
source of truth shared verbatim with the Pallas kernels
(kernels/stochastic_round, kernels/fused_quant_matmul) and their ref
oracles, so ops and kernels are bit-identical by construction.

Note on double rounding: inputs are first converted f32->f16 with RNE, then
stochastically rounded f16->e5m2. The intermediate RNE step contributes a
relative error <= 2^-11, i.e. 256x smaller than the e5m2 machine epsilon
(2^-2); the residual bias is far below the quantization noise floor and is
bounded in tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fp8_formats import E4M3, E5M2, FloatFormat, get_format

Array = jax.Array

_F16_EXP_MASK = 0x7C00  # fp16 exponent field (all-ones => inf/nan)
_F16_MAG_MASK = 0x7FFF
_F16_SIGN_MASK = 0x8000


def _f16_bits(x: Array) -> Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.float16), jnp.uint16)


def _bits_f16(b: Array) -> Array:
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint16), jnp.float16)


# ---------------------------------------------------------------------------
# RNE quantization
# ---------------------------------------------------------------------------

def rne_overflow_threshold(fmt: FloatFormat) -> float:
    """Smallest |x| that RNE rounds to infinity (midpoint of max_normal and
    the next power of two)."""
    return (fmt.max_normal + 2.0 ** (fmt.max_exp + 1)) / 2.0


def _rne_on_grid_f32(x: Array, fmt: FloatFormat) -> Array:
    """Correctly-rounded (single-rounding) RNE of f32 onto fmt's value grid.

    XLA lowers f32 -> fp8 casts through an f16 intermediate, which double-
    rounds values near fp8 halfway points (~0.1% of a log-uniform sample).
    This decomposes |x| into (ulp, multiple-of-ulp) exactly — ulp is a power
    of two and the multiple fits in the f32 mantissa — and applies
    ties-to-even on the exact ratio, matching ml_dtypes bit-for-bit. The
    returned value is on-grid (or the next power of two on binade carry), so
    the subsequent storage-dtype cast is exact."""
    xf = x.astype(jnp.float32)
    ax = jnp.abs(xf)
    xb = jax.lax.bitcast_convert_type(ax, jnp.uint32)
    e = jnp.maximum((xb >> 23).astype(jnp.int32) - 127, fmt.min_exp)
    ulp = jnp.exp2((e - fmt.man_bits).astype(jnp.float32))
    # copysign (not sign*) so signed zero survives the round trip.
    return jnp.copysign(jnp.round(ax / ulp) * ulp, xf)


def quantize_rne(x: Array, fmt: FloatFormat = E5M2, *, saturate: bool = True) -> Array:
    """Round-to-nearest-even down-conversion into `fmt`'s storage dtype.

    saturate=True clamps overflow to +-max_normal (forward tensors);
    saturate=False lets overflow become +-inf (error/grad tensors, so the
    dynamic loss scaler can detect it and back off — paper §3.1).
    """
    if fmt.dtype is None:
        raise ValueError(f"format {fmt.name} has no storage dtype")
    # Dtype-preserving: all elementwise work stays in x's dtype (bf16 grads
    # would otherwise materialize f32 copies of every weight-grad tensor —
    # measured as the dominant training-memory term at 123B scale). The fp8
    # grid bounds are exactly representable in bf16/f16/f32.
    if not jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(jnp.float32)
    if x.dtype in (jnp.float16, jnp.bfloat16):
        # Narrow inputs convert correctly through XLA's cast chain (bf16 ->
        # f16 is exact at fp8-surviving magnitudes, f16 -> fp8 rounds once).
        q = x.astype(fmt.dtype)
        rounded = x   # clamping below re-rounds via the same exact chain
    else:
        # Wide inputs need the explicit single-rounding grid path (XLA's
        # cast would double-round through f16 — see _rne_on_grid_f32).
        on_grid = _rne_on_grid_f32(x, fmt)
        rounded = jnp.where(jnp.isfinite(x), on_grid, x.astype(jnp.float32))
        q = rounded.astype(fmt.dtype)
    if saturate:
        # XLA's f32->f8 conversion saturates for e5m2 and produces NaN for
        # e4m3fn overflow; normalize both to explicit clamping (of the
        # already-rounded value, so clamping never re-rounds inexactly).
        lo = jnp.asarray(-fmt.max_normal, rounded.dtype)
        hi = jnp.asarray(fmt.max_normal, rounded.dtype)
        clamped = jnp.clip(rounded, lo, hi)
        q = jnp.where(jnp.isfinite(x), clamped.astype(fmt.dtype), q)
    else:
        thresh = jnp.asarray(rne_overflow_threshold(fmt), jnp.float32)
        overflow = jnp.abs(x.astype(jnp.float32)) >= thresh \
            if x.dtype == jnp.float16 else jnp.abs(x) >= thresh.astype(x.dtype)
        inf = jnp.asarray(jnp.inf, x.dtype) * jnp.sign(x)
        # e4m3fn has no inf encoding; overflow becomes NaN (still non-finite,
        # still detectable by the loss scaler).
        q = jnp.where(overflow & jnp.isfinite(x),
                      inf.astype(fmt.dtype) if fmt.has_inf
                      else jnp.asarray(jnp.nan, fmt.dtype),
                      q)
    return q


# ---------------------------------------------------------------------------
# Stochastic rounding
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SRSpec:
    """fp16-embedding constants for exact SR into one fp8 format.

    An fp8 format with m mantissa bits embeds into fp16 under the
    power-of-two prescale 2**pre_exp that moves its subnormal threshold onto
    fp16's (min_exp -> -14): every grid point of the prescaled format is then
    an fp16 bit pattern whose low (10 - m) bits are zero, subnormals
    included, and SR = add (10 - m) uniform random bits + truncate.
    """
    pre_exp: int      # prescale exponent: twiddle on bits of x * 2**pre_exp
    drop_bits: int    # 10 - man_bits: random/truncated low mantissa bits
    max_bits: int     # fp16 pattern of the prescaled fmt.max_normal
    ovf_bits: int     # pattern on round-up past max: inf (IEEE) / NaN (fn)


@functools.lru_cache(maxsize=None)
def sr_spec(fmt: FloatFormat) -> SRSpec:
    pre_exp = -14 - fmt.min_exp
    if fmt.man_bits > 10 or fmt.max_normal * 2.0 ** pre_exp > 65504.0:
        raise ValueError(f"format {fmt.name} does not embed in fp16")
    max_bits = int(np.float16(fmt.max_normal * 2.0 ** pre_exp)
                   .view(np.uint16))
    return SRSpec(pre_exp=pre_exp, drop_bits=10 - fmt.man_bits,
                  max_bits=max_bits,
                  ovf_bits=_F16_EXP_MASK if fmt.has_inf else 0x7E00)


def sr_fp8_from_bits(h_bits: Array, rand: Array, fmt: FloatFormat = E5M2, *,
                     saturate: bool = True) -> Array:
    """Exact fp8 stochastic rounding given *prescaled* fp16 bit patterns plus
    random bits (only the low `drop_bits` are used; masking a wider uniform
    draw is fine). Pure uint16 math — shared verbatim with the Pallas
    kernels (ref oracles and kernel bodies all call this). The result is the
    prescaled fp16 pattern; undo the prescale before casting to fmt.dtype
    (`sr_fp8_via_f16` does both ends).
    """
    spec = sr_spec(fmt)
    mask = jnp.uint16((1 << spec.drop_bits) - 1)
    h_bits = h_bits.astype(jnp.uint16)
    sign = h_bits & _F16_SIGN_MASK
    mag = h_bits & _F16_MAG_MASK
    finite = mag < _F16_EXP_MASK
    bumped = mag + (rand.astype(jnp.uint16) & mask)
    trunc = bumped & ~mask
    if saturate:
        trunc = jnp.minimum(trunc, jnp.uint16(spec.max_bits))
    else:
        # Rounding up past max normal overflows: to the inf pattern for IEEE
        # formats (e5m2: 0x7B00 + 0x100 lands exactly on 0x7C00), to a NaN
        # pattern for the inf-less fn formats (e4m3).
        trunc = jnp.where(trunc > jnp.uint16(spec.max_bits),
                          jnp.uint16(spec.ovf_bits), trunc)
    out_mag = jnp.where(finite, trunc, mag & ~mask | (mag & jnp.uint16(0x0200)))
    # (non-finite: preserve inf/nan; keep a nan-signalling mantissa bit)
    return sign | out_mag


def sr_e5m2_from_bits(h_bits: Array, rand8: Array, *,
                      saturate: bool = True) -> Array:
    """Back-compat alias for the e5m2-hardwired helper name."""
    return sr_fp8_from_bits(h_bits, rand8, E5M2, saturate=saturate)


def sr_fp8_via_f16(x: Array, rand: Array, fmt: FloatFormat = E5M2, *,
                   saturate: bool = True) -> Array:
    """Stochastically round `x` into fmt.dtype via the exact fp16 bit-twiddle
    (prescale -> twiddle -> unscale -> storage cast). `rand` supplies the
    random bits (uint; low `sr_spec(fmt).drop_bits` used)."""
    spec = sr_spec(fmt)
    if saturate:
        # Clamp before the f16 step so |x| beyond fp16 range cannot escape to
        # inf around the bit-twiddle's finite-only path. Dtype-preserving:
        # the fp8 max normals are exact in bf16/f16/f32.
        lo = jnp.asarray(-fmt.max_normal, x.dtype)
        hi = jnp.asarray(fmt.max_normal, x.dtype)
        x = jnp.where(jnp.isnan(x), x, jnp.clip(x, lo, hi))
    if spec.pre_exp:
        x = x * jnp.asarray(2.0 ** spec.pre_exp, x.dtype)
    h = x.astype(jnp.float16)
    out_bits = sr_fp8_from_bits(_f16_bits(h), rand, fmt, saturate=saturate)
    out = _bits_f16(out_bits)
    if spec.pre_exp:
        # Exact: every prescaled grid point times 2**-pre_exp is on the fmt
        # grid and representable in fp16 (max_normal <= 448 <= f16 max).
        out = out * jnp.float16(2.0 ** -spec.pre_exp)
    return out.astype(fmt.dtype)


def quantize_sr_fp8(x: Array, key: Array, fmt: FloatFormat = E5M2, *,
                    saturate: bool = True) -> Array:
    """Stochastically round into an fp16-embeddable fp8 format (exact on the
    fp16 grid — the paper's SR, format-generalized)."""
    rand = jax.random.bits(key, x.shape, jnp.uint16)
    return sr_fp8_via_f16(x, rand, fmt, saturate=saturate)


def quantize_sr_e5m2(x: Array, key: Array, *, saturate: bool = True) -> Array:
    """Back-compat alias: SR into e5m2 (the paper's format)."""
    return quantize_sr_fp8(x, key, E5M2, saturate=saturate)


def quantize_sr_grid(x: Array, fmt: FloatFormat, key: Array, *,
                     saturate: bool = True) -> Array:
    """Generic grid-based stochastic rounding (any format, e.g. E4M3).

    Decomposes |x| into (ulp, multiple-of-ulp) using the f32 exponent field,
    adds U[0,1) before flooring. All grid arithmetic is exact in f32 because
    ulp is a power of two and the mantissa multiple fits in 24 bits.
    """
    xf = x.astype(jnp.float32)
    ax = jnp.abs(xf)
    sgn = jnp.sign(xf)
    xb = jax.lax.bitcast_convert_type(ax, jnp.uint32)
    e_unb = (xb >> 23).astype(jnp.int32) - 127
    e = jnp.maximum(e_unb, fmt.min_exp)
    ulp_exp = e - fmt.man_bits
    ulp = jnp.exp2(ulp_exp.astype(jnp.float32))
    r = jax.random.uniform(key, xf.shape, jnp.float32)
    q = jnp.floor(ax / ulp + r) * ulp
    if saturate:
        q = jnp.minimum(q, fmt.max_normal)
    else:
        q = jnp.where(q > fmt.max_normal, jnp.inf, q)
    q = jnp.where(jnp.isfinite(xf), sgn * q, xf)
    out = q.astype(fmt.dtype)
    if not saturate and not fmt.has_inf:
        out = jnp.where(jnp.isinf(q), jnp.asarray(jnp.nan, fmt.dtype), out)
    return out


def quantize_sr(x: Array, fmt: FloatFormat, key: Array, *,
                saturate: bool = True) -> Array:
    # Both fp8 storage formats use the exact fp16 bit-twiddle (one source of
    # truth with the Pallas kernels); the float grid path covers formats
    # without an fp16 embedding (emulation-only ablations).
    if fmt.name in ("e5m2", "e4m3"):
        return quantize_sr_fp8(x, key, fmt, saturate=saturate)
    return quantize_sr_grid(x, fmt, key, saturate=saturate)


# ---------------------------------------------------------------------------
# Scaled quantization (QTensor)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """An FP8 payload plus a dequantization scale: x ~= data.astype(f32) * scale.

    `scale` is a scalar (per-tensor). The paper's loss scaling is *global*
    (applied to the loss), so training-path QTensors usually carry scale=1;
    per-tensor amax scaling (beyond-paper, cf. FP8-LM) sets
    scale = amax / fmt.max_normal.
    """
    data: Array
    scale: Array

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def dequantize(self, dtype=jnp.float32) -> Array:
        return self.data.astype(jnp.float32) * self.scale.astype(jnp.float32) \
            if self.scale.ndim == 0 else \
            self.data.astype(jnp.float32) * self.scale[..., None].astype(jnp.float32)


def fp8_amax_bits(data: Array) -> Array:
    """amax of an FP8 tensor via its bit patterns — the delayed-scaling
    observation primitive. For sign-cleared fp8 encodings the bit pattern is
    monotone in magnitude, so the max over uint8 views IS the max magnitude:
    the reduction runs on 1-byte integers (no float upcast pass over the
    tensor, and in the jaxpr no reduce_max over a >=16-bit float appears —
    the property the hot-path op-count test checks). NaN payloads sort above
    inf and therefore propagate, which the history update guards against."""
    bits = jax.lax.bitcast_convert_type(data, jnp.uint8) & jnp.uint8(0x7F)
    return jax.lax.bitcast_convert_type(jnp.max(bits), data.dtype) \
        .astype(jnp.float32)


def amax_scale(x: Array, fmt: FloatFormat, *, margin: float = 1.0) -> Array:
    """Per-tensor scale mapping amax -> fmt.max_normal / margin. The abs/max
    reduce stays in x's dtype (no f32 copy); only the scalar is f32."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    amax = jnp.maximum(amax, 1e-12)
    return amax * margin / fmt.max_normal


def quantize(x: Array, fmt: Union[str, FloatFormat] = E5M2, *,
             rounding: str = "rne",
             key: Optional[Array] = None,
             scale: Optional[Array] = None,
             use_amax_scale: bool = False,
             saturate: bool = True) -> QTensor:
    """Quantize into a QTensor. rounding in {'rne','sr'}; 'sr' requires key."""
    if isinstance(fmt, str):
        fmt = get_format(fmt)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(jnp.float32)
    explicit_scale = scale is not None
    if scale is None:
        scale = amax_scale(x, fmt) if use_amax_scale \
            else jnp.asarray(1.0, jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    if use_amax_scale or explicit_scale \
            or (hasattr(scale, "shape") and scale.shape != ()):
        # Reciprocal-multiply path — shared by jit-amax and delayed scaling
        # so the two modes are bitwise identical given the same scale value.
        xs = x * (1.0 / scale).astype(x.dtype)
    else:
        # scale may be the static 1.0 default: keep the division but in
        # x's dtype so no f32 copy of the tensor is materialized.
        xs = x / scale.astype(x.dtype)
    if rounding == "rne":
        data = quantize_rne(xs, fmt, saturate=saturate)
    elif rounding == "sr":
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        data = quantize_sr(xs, fmt, key, saturate=saturate)
    else:
        raise ValueError(f"unknown rounding mode {rounding!r}")
    return QTensor(data=data, scale=scale)


def dequantize(q: QTensor, dtype=jnp.float32) -> Array:
    # Dequantize directly in the target dtype (no f32 intermediate copy).
    return q.data.astype(dtype) * q.scale.astype(dtype)


# Convenience: fake-quantize (quantize-dequantize) in one call — used by the
# emulation path on CPU and by tests as the semantic reference.
def fake_quant(x: Array, fmt: Union[str, FloatFormat] = E5M2, *,
               rounding: str = "rne", key: Optional[Array] = None,
               scale: Optional[Array] = None, saturate: bool = True) -> Array:
    q = quantize(x, fmt, rounding=rounding, key=key, scale=scale,
                 saturate=saturate)
    return dequantize(q, dtype=x.dtype)
