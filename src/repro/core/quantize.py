"""Quantization primitives: RNE and stochastic rounding into FP8.

This is the software realization of the paper's `Q` nodes (Fig. 1a): each GEMM
produces a 32-bit result which is down-converted + rounded to FP8 before the
next op. Two rounding modes, per paper §3.2:

 * RNE  (round-to-nearest-even): what commodity hardware implements; shown by
   the paper to be sufficient for small nets but to cause generalization loss
   on ResNet-50 (unconstrained parameter growth).
 * SR   (stochastic rounding): round(x) = floor(x) + eps with probability
   (x - floor(x))/eps. The paper applies SR to activations and gradients and
   recovers (slightly beats) the FP32 baseline.

For E5M2 — the paper's format — SR is implemented *exactly* with the fp16
bit-twiddle: e5m2 is the top byte of an IEEE fp16, so adding a uniform 8-bit
integer to the fp16 bit pattern and truncating the low byte performs
stochastic rounding on the real line (bit patterns are monotone in magnitude,
and mantissa carries propagate into the exponent, handling binade crossings
and the subnormal/normal boundary for free). This is also exactly what the
Pallas kernel does on-TPU (kernels/stochastic_round), so ops and kernels are
bit-identical by construction.

Note on double rounding: inputs are first converted f32->f16 with RNE, then
stochastically rounded f16->e5m2. The intermediate RNE step contributes a
relative error <= 2^-11, i.e. 256x smaller than the e5m2 machine epsilon
(2^-2); the residual bias is far below the quantization noise floor and is
bounded in tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fp8_formats import E4M3, E5M2, FloatFormat, get_format

Array = jax.Array

_F16_EXP_MASK = 0x7C00  # fp16 exponent field (all-ones => inf/nan)
_F16_MAG_MASK = 0x7FFF
_F16_SIGN_MASK = 0x8000
_E5M2_MAX_F16_BITS = 0x7B00  # |57344| as fp16 bits — e5m2 max normal


def _f16_bits(x: Array) -> Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.float16), jnp.uint16)


def _bits_f16(b: Array) -> Array:
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint16), jnp.float16)


# ---------------------------------------------------------------------------
# RNE quantization
# ---------------------------------------------------------------------------

def rne_overflow_threshold(fmt: FloatFormat) -> float:
    """Smallest |x| that RNE rounds to infinity (midpoint of max_normal and
    the next power of two)."""
    return (fmt.max_normal + 2.0 ** (fmt.max_exp + 1)) / 2.0


def _rne_on_grid_f32(x: Array, fmt: FloatFormat) -> Array:
    """Correctly-rounded (single-rounding) RNE of f32 onto fmt's value grid.

    XLA lowers f32 -> fp8 casts through an f16 intermediate, which double-
    rounds values near fp8 halfway points (~0.1% of a log-uniform sample).
    This decomposes |x| into (ulp, multiple-of-ulp) exactly — ulp is a power
    of two and the multiple fits in the f32 mantissa — and applies
    ties-to-even on the exact ratio, matching ml_dtypes bit-for-bit. The
    returned value is on-grid (or the next power of two on binade carry), so
    the subsequent storage-dtype cast is exact."""
    xf = x.astype(jnp.float32)
    ax = jnp.abs(xf)
    xb = jax.lax.bitcast_convert_type(ax, jnp.uint32)
    e = jnp.maximum((xb >> 23).astype(jnp.int32) - 127, fmt.min_exp)
    ulp = jnp.exp2((e - fmt.man_bits).astype(jnp.float32))
    return jnp.sign(xf) * jnp.round(ax / ulp) * ulp


def quantize_rne(x: Array, fmt: FloatFormat = E5M2, *, saturate: bool = True) -> Array:
    """Round-to-nearest-even down-conversion into `fmt`'s storage dtype.

    saturate=True clamps overflow to +-max_normal (forward tensors);
    saturate=False lets overflow become +-inf (error/grad tensors, so the
    dynamic loss scaler can detect it and back off — paper §3.1).
    """
    if fmt.dtype is None:
        raise ValueError(f"format {fmt.name} has no storage dtype")
    # Dtype-preserving: all elementwise work stays in x's dtype (bf16 grads
    # would otherwise materialize f32 copies of every weight-grad tensor —
    # measured as the dominant training-memory term at 123B scale). The fp8
    # grid bounds are exactly representable in bf16/f16/f32.
    if not jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(jnp.float32)
    if x.dtype in (jnp.float16, jnp.bfloat16):
        # Narrow inputs convert correctly through XLA's cast chain (bf16 ->
        # f16 is exact at fp8-surviving magnitudes, f16 -> fp8 rounds once).
        q = x.astype(fmt.dtype)
        rounded = x   # clamping below re-rounds via the same exact chain
    else:
        # Wide inputs need the explicit single-rounding grid path (XLA's
        # cast would double-round through f16 — see _rne_on_grid_f32).
        on_grid = _rne_on_grid_f32(x, fmt)
        rounded = jnp.where(jnp.isfinite(x), on_grid, x.astype(jnp.float32))
        q = rounded.astype(fmt.dtype)
    if saturate:
        # XLA's f32->f8 conversion saturates for e5m2 and produces NaN for
        # e4m3fn overflow; normalize both to explicit clamping (of the
        # already-rounded value, so clamping never re-rounds inexactly).
        lo = jnp.asarray(-fmt.max_normal, rounded.dtype)
        hi = jnp.asarray(fmt.max_normal, rounded.dtype)
        clamped = jnp.clip(rounded, lo, hi)
        q = jnp.where(jnp.isfinite(x), clamped.astype(fmt.dtype), q)
    else:
        thresh = jnp.asarray(rne_overflow_threshold(fmt), jnp.float32)
        overflow = jnp.abs(x.astype(jnp.float32)) >= thresh \
            if x.dtype == jnp.float16 else jnp.abs(x) >= thresh.astype(x.dtype)
        inf = jnp.asarray(jnp.inf, x.dtype) * jnp.sign(x)
        # e4m3fn has no inf encoding; overflow becomes NaN (still non-finite,
        # still detectable by the loss scaler).
        q = jnp.where(overflow & jnp.isfinite(x),
                      inf.astype(fmt.dtype) if fmt.has_inf
                      else jnp.asarray(jnp.nan, fmt.dtype),
                      q)
    return q


# ---------------------------------------------------------------------------
# Stochastic rounding
# ---------------------------------------------------------------------------

def sr_e5m2_from_bits(h_bits: Array, rand8: Array, *, saturate: bool = True) -> Array:
    """Exact E5M2 stochastic rounding given fp16 bit patterns + 8 random bits.

    Pure uint16 math — shared verbatim with the Pallas kernel (ref oracle and
    kernel body both call this). rand8 must be uniform in [0, 256).
    """
    h_bits = h_bits.astype(jnp.uint16)
    sign = h_bits & _F16_SIGN_MASK
    mag = h_bits & _F16_MAG_MASK
    finite = mag < _F16_EXP_MASK
    bumped = mag + (rand8.astype(jnp.uint16) & jnp.uint16(0xFF))
    trunc = bumped & jnp.uint16(0xFF00)
    if saturate:
        trunc = jnp.minimum(trunc, jnp.uint16(_E5M2_MAX_F16_BITS))
    else:
        # Rounding up past max normal lands exactly on the inf pattern 0x7C00.
        trunc = jnp.minimum(trunc, jnp.uint16(_F16_EXP_MASK))
    out_mag = jnp.where(finite, trunc, mag & jnp.uint16(0xFF00) | (mag & jnp.uint16(0x0200)))
    # (non-finite: preserve inf/nan; keep a nan-signalling mantissa bit)
    return sign | out_mag


def quantize_sr_e5m2(x: Array, key: Array, *, saturate: bool = True) -> Array:
    """Stochastically round into e5m2 (the paper's SR, exact on the fp16 grid)."""
    if saturate:
        # Clamp before the f16 step so |x| beyond fp16 range cannot escape to
        # inf around the bit-twiddle's finite-only path. Dtype-preserving:
        # 57344 is exact in bf16/f16/f32.
        lo = jnp.asarray(-E5M2.max_normal, x.dtype)
        hi = jnp.asarray(E5M2.max_normal, x.dtype)
        x = jnp.where(jnp.isnan(x), x, jnp.clip(x, lo, hi))
    h = x.astype(jnp.float16)
    bits = _f16_bits(h)
    rand8 = jax.random.bits(key, bits.shape, jnp.uint16)
    out_bits = sr_e5m2_from_bits(bits, rand8, saturate=saturate)
    return _bits_f16(out_bits).astype(jnp.float8_e5m2)


def quantize_sr_grid(x: Array, fmt: FloatFormat, key: Array, *,
                     saturate: bool = True) -> Array:
    """Generic grid-based stochastic rounding (any format, e.g. E4M3).

    Decomposes |x| into (ulp, multiple-of-ulp) using the f32 exponent field,
    adds U[0,1) before flooring. All grid arithmetic is exact in f32 because
    ulp is a power of two and the mantissa multiple fits in 24 bits.
    """
    xf = x.astype(jnp.float32)
    ax = jnp.abs(xf)
    sgn = jnp.sign(xf)
    xb = jax.lax.bitcast_convert_type(ax, jnp.uint32)
    e_unb = (xb >> 23).astype(jnp.int32) - 127
    e = jnp.maximum(e_unb, fmt.min_exp)
    ulp_exp = e - fmt.man_bits
    ulp = jnp.exp2(ulp_exp.astype(jnp.float32))
    r = jax.random.uniform(key, xf.shape, jnp.float32)
    q = jnp.floor(ax / ulp + r) * ulp
    if saturate:
        q = jnp.minimum(q, fmt.max_normal)
    else:
        q = jnp.where(q > fmt.max_normal, jnp.inf, q)
    q = jnp.where(jnp.isfinite(xf), sgn * q, xf)
    out = q.astype(fmt.dtype)
    if not saturate and not fmt.has_inf:
        out = jnp.where(jnp.isinf(q), jnp.asarray(jnp.nan, fmt.dtype), out)
    return out


def quantize_sr(x: Array, fmt: FloatFormat, key: Array, *,
                saturate: bool = True) -> Array:
    if fmt.name == "e5m2":
        return quantize_sr_e5m2(x, key, saturate=saturate)
    return quantize_sr_grid(x, fmt, key, saturate=saturate)


# ---------------------------------------------------------------------------
# Scaled quantization (QTensor)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """An FP8 payload plus a dequantization scale: x ~= data.astype(f32) * scale.

    `scale` is a scalar (per-tensor). The paper's loss scaling is *global*
    (applied to the loss), so training-path QTensors usually carry scale=1;
    per-tensor amax scaling (beyond-paper, cf. FP8-LM) sets
    scale = amax / fmt.max_normal.
    """
    data: Array
    scale: Array

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def dequantize(self, dtype=jnp.float32) -> Array:
        return self.data.astype(jnp.float32) * self.scale.astype(jnp.float32) \
            if self.scale.ndim == 0 else \
            self.data.astype(jnp.float32) * self.scale[..., None].astype(jnp.float32)


def fp8_amax_bits(data: Array) -> Array:
    """amax of an FP8 tensor via its bit patterns — the delayed-scaling
    observation primitive. For sign-cleared fp8 encodings the bit pattern is
    monotone in magnitude, so the max over uint8 views IS the max magnitude:
    the reduction runs on 1-byte integers (no float upcast pass over the
    tensor, and in the jaxpr no reduce_max over a >=16-bit float appears —
    the property the hot-path op-count test checks). NaN payloads sort above
    inf and therefore propagate, which the history update guards against."""
    bits = jax.lax.bitcast_convert_type(data, jnp.uint8) & jnp.uint8(0x7F)
    return jax.lax.bitcast_convert_type(jnp.max(bits), data.dtype) \
        .astype(jnp.float32)


def amax_scale(x: Array, fmt: FloatFormat, *, margin: float = 1.0) -> Array:
    """Per-tensor scale mapping amax -> fmt.max_normal / margin. The abs/max
    reduce stays in x's dtype (no f32 copy); only the scalar is f32."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    amax = jnp.maximum(amax, 1e-12)
    return amax * margin / fmt.max_normal


def quantize(x: Array, fmt: Union[str, FloatFormat] = E5M2, *,
             rounding: str = "rne",
             key: Optional[Array] = None,
             scale: Optional[Array] = None,
             use_amax_scale: bool = False,
             saturate: bool = True) -> QTensor:
    """Quantize into a QTensor. rounding in {'rne','sr'}; 'sr' requires key."""
    if isinstance(fmt, str):
        fmt = get_format(fmt)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(jnp.float32)
    explicit_scale = scale is not None
    if scale is None:
        scale = amax_scale(x, fmt) if use_amax_scale \
            else jnp.asarray(1.0, jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    if use_amax_scale or explicit_scale \
            or (hasattr(scale, "shape") and scale.shape != ()):
        # Reciprocal-multiply path — shared by jit-amax and delayed scaling
        # so the two modes are bitwise identical given the same scale value.
        xs = x * (1.0 / scale).astype(x.dtype)
    else:
        # scale may be the static 1.0 default: keep the division but in
        # x's dtype so no f32 copy of the tensor is materialized.
        xs = x / scale.astype(x.dtype)
    if rounding == "rne":
        data = quantize_rne(xs, fmt, saturate=saturate)
    elif rounding == "sr":
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        data = quantize_sr(xs, fmt, key, saturate=saturate)
    else:
        raise ValueError(f"unknown rounding mode {rounding!r}")
    return QTensor(data=data, scale=scale)


def dequantize(q: QTensor, dtype=jnp.float32) -> Array:
    # Dequantize directly in the target dtype (no f32 intermediate copy).
    return q.data.astype(dtype) * q.scale.astype(dtype)


# Convenience: fake-quantize (quantize-dequantize) in one call — used by the
# emulation path on CPU and by tests as the semantic reference.
def fake_quant(x: Array, fmt: Union[str, FloatFormat] = E5M2, *,
               rounding: str = "rne", key: Optional[Array] = None,
               scale: Optional[Array] = None, saturate: bool = True) -> Array:
    q = quantize(x, fmt, rounding=rounding, key=key, scale=scale,
                 saturate=saturate)
    return dequantize(q, dtype=x.dtype)
