"""Floating-point format metadata for reduced-precision training.

Reproduces Table 1 of Mellempudi et al. 2019 ("Mixed Precision Training With
8-bit Floating Point"): the proposed FP8 format is (s=1, e=5, m=2), sharing the
FP16 exponent range but with a drastically reduced subnormal range
(min subnormal 1.52e-5 vs 5.96e-8) — the motivation for enhanced loss scaling.

All values here are exact powers of two / dyadic rationals; tests check them
bit-for-bit against ml_dtypes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import ml_dtypes
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """Metadata for a (sign, exponent, mantissa) floating-point format."""

    name: str
    exp_bits: int
    man_bits: int
    # Exponent bias. IEEE-style: 2**(e-1) - 1 unless overridden (e4m3fn keeps
    # the IEEE bias of 7 but reclaims the top exponent for finite values).
    bias: int
    # Whether the format reserves the all-ones exponent for inf/nan (IEEE) or
    # reclaims it for finite values (the "fn" variants).
    has_inf: bool
    # Storage dtype on the JAX side (None => no native dtype; emulation only).
    dtype: Optional[jnp.dtype] = None

    # ---- derived quantities (paper Table 1) -------------------------------
    @property
    def max_exp(self) -> int:
        # Largest biased exponent that encodes a finite normal number.
        raw = (1 << self.exp_bits) - 1
        return (raw - 1 if self.has_inf else raw) - self.bias

    @property
    def min_exp(self) -> int:
        return 1 - self.bias

    @property
    def max_normal(self) -> float:
        frac = 2.0 - 2.0 ** (-self.man_bits)
        if not self.has_inf:
            # fn formats: top mantissa pattern is NaN, so max frac loses one ulp.
            frac = 2.0 - 2.0 ** (1 - self.man_bits)
        return frac * 2.0 ** self.max_exp

    @property
    def min_normal(self) -> float:
        return 2.0 ** self.min_exp

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.min_exp - self.man_bits)

    @property
    def eps(self) -> float:
        """Machine epsilon: spacing of numbers in [1, 2)."""
        return 2.0 ** (-self.man_bits)

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits


# The paper's proposed format: s=1, e=5, m=2. Same exponent range as FP16 =>
# loss-scaling experience transfers; tiny subnormal range => enhanced scaling.
E5M2 = FloatFormat("e5m2", exp_bits=5, man_bits=2, bias=15, has_inf=True,
                   dtype=jnp.float8_e5m2)
# The "more mantissa" alternative the paper rejected for error tensors (too
# little dynamic range for back-prop); kept for weights/activations ablations.
E4M3 = FloatFormat("e4m3", exp_bits=4, man_bits=3, bias=7, has_inf=False,
                   dtype=jnp.float8_e4m3fn)
FP16 = FloatFormat("fp16", exp_bits=5, man_bits=10, bias=15, has_inf=True,
                   dtype=jnp.float16)
BF16 = FloatFormat("bf16", exp_bits=8, man_bits=7, bias=127, has_inf=True,
                   dtype=jnp.bfloat16)
FP32 = FloatFormat("fp32", exp_bits=8, man_bits=23, bias=127, has_inf=True,
                   dtype=jnp.float32)

FORMATS = {f.name: f for f in (E5M2, E4M3, FP16, BF16, FP32)}


def get_format(name: str) -> FloatFormat:
    try:
        return FORMATS[name]
    except KeyError as e:
        raise ValueError(f"unknown float format {name!r}; have {sorted(FORMATS)}") from e


def table1() -> dict:
    """Paper Table 1: dynamic range comparison (exact values)."""
    rows = {}
    for f in (FP32, FP16, E5M2):
        rows[f.name] = dict(
            bit_format=(1, f.exp_bits, f.man_bits),
            max_normal=f.max_normal,
            min_normal=f.min_normal,
            min_subnormal=f.min_subnormal,
        )
    return rows


def ml_dtype_of(fmt: FloatFormat):
    """The numpy-compatible ml_dtypes dtype, for bit-level cross-checks."""
    return {
        "e5m2": ml_dtypes.float8_e5m2,
        "e4m3": ml_dtypes.float8_e4m3fn,
        "fp16": np.float16,
        "bf16": ml_dtypes.bfloat16,
        "fp32": np.float32,
    }[fmt.name]
