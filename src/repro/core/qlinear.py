"""Quantized GEMM with the paper's Fig. 1a dataflow, as a composable JAX op.

`qeinsum(spec, a, b)` is an einsum whose *forward and backward* GEMMs all take
FP8 operands and accumulate in FP32:

    forward:   Y  = Q_A(a) . Q_W(b)                 (fp8 x fp8 -> fp32)
    backward:  dA = Q_E(dY) . Q_W(b)^T              (fp8 x fp8 -> fp32)
               dW = Q_A(a)^T . Q_E(dY), then Q_G    (fp8 x fp8 -> fp32 -> fp8)

Q_A/Q_W/Q_E/Q_G are the quantization nodes for activations / weights / errors
/ weight-gradients with per-class rounding (paper: SR for A, E, G; RNE for W)
and per-class overflow behavior (errors keep inf so dynamic loss scaling can
back off).

The residuals saved for backward are the *quantized* fp8 tensors — a 4x
activation-memory saving relative to an f32-residual baseline, mirroring the
paper's storage story.

On TPU the inner computes route to the Pallas kernels in
repro.kernels.{fp8_matmul,fused_quant_matmul}; on CPU (and for the dry-run)
they run an XLA path that upcasts fp8 -> bf16 and issues a dot with
preferred_element_type=f32, which is exactly the MXU dataflow the kernels
implement (bf16 multiplies into an f32 accumulator).

Under a Pallas backend with delayed scaling the projection GEMMs take the
FUSED quantize-in-epilogue path (see `_fused_epilogue`): each of the three
GEMMs applies its output Q node inside the kernel epilogue (fwd Y = Q_A(A.W)
via the 'nn' layout, dgrad dA = Q_E(dY.W^T) via 'nt', wgrad dW = Q_G(A^T.dY)
via 'tn' — no materialized transposes), writing FP8 straight from the VMEM
accumulator and observing the delayed-scaling amax in the same pass. The
output Q nodes quantize against their own scale sites ("#y.A", "#da.E",
"#G" — see scaling.context.fused_output_keys); the fused observations are
bit-identical to the `_observe` bit-pattern reduction over the payloads
(tests/test_fused_epilogue.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QTensor, fp8_amax_bits
from repro.core.quantize import dequantize as _dequantize
from repro.core.quantize import quantize as _quantize
from repro.core.fp8_formats import get_format
from repro.core.precision_policy import (ACT, ERROR, GRAD, WEIGHT, PAPER_FP8,
                                         QuantConfig, dtype_of)
from repro.obs.counters import payload_health
from repro.scaling import context as scale_ctx

Array = jax.Array

# Per-site scale-vector layout fed into _qeinsum:
#   [a, b, E, G, Y, dA_err] — operands, error, FP8-stored weight grad, and
#   the two fused-epilogue output sites (Y forward, error-class dgrad).
N_SCALES = 6


# ---------------------------------------------------------------------------
# einsum spec utilities
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def parse_spec(spec: str) -> Tuple[str, str, str]:
    spec = spec.replace(" ", "")
    lhs, out = spec.split("->")
    a, b = lhs.split(",")
    if "." in spec:
        raise ValueError(f"qeinsum does not support ellipsis specs: {spec!r}")
    return a, b, out


@functools.lru_cache(maxsize=None)
def adjoint_specs(spec: str) -> Tuple[str, str]:
    """Derive the einsum specs computing dA and dB for `spec`.

    For Y = einsum('A,B->O', a, b):  dA = einsum('O,B->A', dy, b) and
    dB = einsum('A,O->B', a, dy).  Valid as long as every index of each
    operand appears in the union of the output and the other operand (true
    for every GEMM-like contraction; sum-only indices are rejected).
    """
    a, b, o = parse_spec(spec)
    for idx in a:
        if idx not in o and idx not in b:
            raise ValueError(f"index {idx!r} of lhs is summed-only in {spec!r}")
    for idx in b:
        if idx not in o and idx not in a:
            raise ValueError(f"index {idx!r} of rhs is summed-only in {spec!r}")
    return f"{o},{b}->{a}", f"{a},{o}->{b}"


# ---------------------------------------------------------------------------
# operand quantization + fp8 compute
# ---------------------------------------------------------------------------

def _quant_operand(x: Array, cls: str, cfg: QuantConfig, key: Array,
                   scale: Optional[Array] = None) -> QTensor:
    """Quantize one operand. With delayed scaling, `scale` is the
    history-derived per-site scale (an explicit input — no amax reduction
    over x happens here); otherwise the legacy jit-amax / unit-scale path."""
    fmt = get_format(cfg.format_for(cls))
    if cfg.delayed:
        return _quantize(
            x, fmt,
            rounding=cfg.rounding_for(cls),
            key=key,
            scale=jnp.float32(1.0) if scale is None else scale,
            saturate=cfg.saturate_for(cls),
        )
    return _quantize(
        x, fmt,
        rounding=cfg.rounding_for(cls),
        key=key,
        use_amax_scale=cfg.amax_for(cls),
        saturate=cfg.saturate_for(cls),
    )


def _pallas_matmul_spec(spec: str) -> bool:
    """True for '...k,kn->...n'-shaped contractions the fp8_matmul kernel covers."""
    a, b, o = parse_spec(spec)
    return (len(b) == 2 and a[-1] == b[0] and o == a[:-1] + b[1]
            and b[1] not in a and b[0] not in o)


def _fused_epilogue(spec: str, classes: Tuple[str, str],
                    cfg: QuantConfig) -> bool:
    """True when this qeinsum routes its three GEMMs (fwd, dgrad, wgrad)
    through the output-quantizing fused Pallas kernels: the paper's Fig. 1a
    dataflow with each Q node IN the GEMM epilogue (output written straight
    to FP8 from the VMEM accumulator, amax observed in the same pass).

    Requires a Pallas backend + delayed scaling (output Q nodes need
    history-derived scales) on a '...k,kn->...n' contraction with a weight
    operand — which covers every projection GEMM; the 4D attention
    contractions keep the unfused path."""
    return (cfg.enabled and cfg.delayed and cfg.fuse_epilogue
            and cfg.backend.startswith("pallas")
            and WEIGHT in classes and _pallas_matmul_spec(spec))


def _fused_gemm(x8: Array, w8: Array, sx: Array, sw: Array, s_out: Array,
                cfg: QuantConfig, key: Array, out_cls: str, dims: str):
    """One fused output-quantizing GEMM: fp8 operands (2D) in, fp8 output +
    grid-amax observation out — plus a (2,) [sat_frac, flush_frac] health
    pair from the kernel's count epilogue under cfg.track_health (None
    otherwise; counted in VMEM next to the amax, zero extra HBM passes).

    Value semantics: out8 = Q_cls((x8.w8 * sx * sw) / s_out), computed as
    Q((x8.w8) / (s_out / (sx*sw))) so the scaling collapses into the
    epilogue's single reciprocal multiply. The returned observation is the
    fused-epilogue amax de-scaled to real units — bit-identical to the
    `_observe` bit-pattern reduction over the materialized payload."""
    from repro.kernels.fused_quant_matmul import ops as fq_ops  # lazy
    s_prod = (sx * sw).astype(jnp.float32)
    kscale = s_out.astype(jnp.float32) / s_prod
    res = fq_ops.fused_quant_matmul(
        x8, w8, key, kscale, dims=dims,
        out_format=cfg.format_for(out_cls),
        rounding=cfg.rounding_for(out_cls),
        saturate=cfg.saturate_for(out_cls),
        with_amax=True, with_counts=_track(cfg), amax_units="grid",
        interpret=cfg.backend == "pallas_interpret")
    if _track(cfg):
        out8, amax_grid, health = res
    else:
        (out8, amax_grid), health = res, None
    return out8, amax_grid * s_out.astype(jnp.float32), health


def _fused_dequant(out8: Array, s_out: Array, cfg: QuantConfig) -> Array:
    return (out8.astype(jnp.float32) * s_out.astype(jnp.float32)) \
        .astype(dtype_of(cfg.output_dtype))


def _compute(spec: str, qa: QTensor, qb: QTensor, cfg: QuantConfig) -> Array:
    """fp8 x fp8 -> f32 (accumulate) -> output_dtype, optionally via Pallas."""
    compute_dtype = dtype_of(cfg.compute_dtype)
    out_scale = (qa.scale * qb.scale).astype(jnp.float32)
    if cfg.backend.startswith("pallas") and _pallas_matmul_spec(spec):
        from repro.kernels.fp8_matmul import ops as mm_ops  # lazy: no cycle
        a2 = qa.data.reshape((-1, qa.data.shape[-1]))
        y = mm_ops.fp8_matmul(a2, qb.data,
                              interpret=cfg.backend == "pallas_interpret")
        y = y.reshape(qa.data.shape[:-1] + (qb.data.shape[-1],))
    else:
        y = jnp.einsum(spec, qa.data.astype(compute_dtype),
                       qb.data.astype(compute_dtype),
                       preferred_element_type=jnp.float32)
    y = y * out_scale
    return y.astype(dtype_of(cfg.output_dtype))


def _plain_einsum(spec: str, a: Array, b: Array, cfg: QuantConfig) -> Array:
    compute_dtype = dtype_of(cfg.compute_dtype)
    y = jnp.einsum(spec, a.astype(compute_dtype), b.astype(compute_dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(dtype_of(cfg.output_dtype))


# ---------------------------------------------------------------------------
# custom_vjp core
# ---------------------------------------------------------------------------

def _observe(q: QTensor, cfg: QuantConfig) -> Array:
    """Observed amax of a quantized operand, from the FP8 payload's bit
    patterns (uint8 reduce — no pass over the high-precision tensor)."""
    if not cfg.delayed:
        return jnp.float32(0.0)
    return fp8_amax_bits(q.data) * q.scale.astype(jnp.float32)


def _track(cfg: QuantConfig) -> bool:
    """Precision-health counters on? (delayed scaling only — the counters
    ride the delayed-scaling observation channels)."""
    return cfg.track_health and cfg.delayed


def _health(q: QTensor, cfg: QuantConfig, cls: str) -> Array:
    """(sat_frac, flush_frac) of a quantized operand, from the same uint8
    payload read `_observe` performs — XLA fuses the two reductions into
    one pass over the 1-byte payload."""
    return payload_health(q.data, cfg.format_for(cls))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _qeinsum(spec: str, classes: Tuple[str, str], cfg: QuantConfig,
             a: Array, b: Array, key: Array, scales: Array,
             token: Array) -> Tuple[Array, Array]:
    """Returns (y, fwd_obs) where fwd_obs = [amax_a, amax_b] — plus
    [amax_y] on the fused-epilogue path — (zeros unless cfg.scaling ==
    'delayed').

    scales: f32[6] per-site quantization scales [a, b, E, G, Y, dA_err]
    (history-derived under delayed scaling; ones otherwise — the last two
    are only consumed by the fused quantize-in-epilogue path). token:
    f32[TOKEN_CHANNELS] observation channel whose *cotangent* is defined as
    [amax_E, amax_G, amax_dA_err] — the backward-pass observations ride the
    gradient of this input out of value_and_grad.
    """
    out, _ = _qeinsum_fwd(spec, classes, cfg, a, b, key, scales, token)
    return out


def _qeinsum_fwd(spec, classes, cfg, a, b, key, scales, token):
    fused = _fused_epilogue(spec, classes, cfg)
    if fused:
        k_a, k_b, k_bwd, k_y = jax.random.split(key, 4)
    else:
        k_a, k_b, k_bwd = jax.random.split(key, 3)
    qa = _quant_operand(a, classes[0], cfg, k_a, scale=scales[0])
    qb = _quant_operand(b, classes[1], cfg, k_b, scale=scales[1])
    if fused:
        # Y = Q_A(A.W) with the Q node + amax observation in the epilogue.
        a2 = qa.data.reshape((-1, qa.data.shape[-1]))
        y8, obs_y, hy = _fused_gemm(a2, qb.data, qa.scale, qb.scale,
                                    scales[4], cfg, k_y, ACT, "nn")
        y = _fused_dequant(y8, scales[4], cfg) \
            .reshape(qa.data.shape[:-1] + (qb.data.shape[-1],))
        obs = jnp.stack([_observe(qa, cfg), _observe(qb, cfg), obs_y])
        if _track(cfg):
            # Health pairs ride behind the amaxes in the fwd_obs vector:
            # [.., ha(2), hb(2), hy(2)] — operand pairs from the payload
            # bits, the output pair from the kernel's count epilogue.
            obs = jnp.concatenate([obs, _health(qa, cfg, classes[0]),
                                   _health(qb, cfg, classes[1]), hy])
    else:
        y = _compute(spec, qa, qb, cfg)
        obs = jnp.stack([_observe(qa, cfg), _observe(qb, cfg)])
        if _track(cfg):
            obs = jnp.concatenate([obs, _health(qa, cfg, classes[0]),
                                   _health(qb, cfg, classes[1])])
    # Zero-size dtype witnesses so bwd can emit cotangents in primal dtypes.
    return (y, obs), (qa, qb, k_bwd, scales,
                      jnp.zeros((0,), a.dtype), jnp.zeros((0,), b.dtype))


def _qeinsum_bwd(spec, classes, cfg, res, ct):
    dy, _ = ct   # cotangent of the fwd_obs output is discarded
    qa, qb, k_bwd, scales, a_wit, b_wit = res
    a_dtype, b_dtype = a_wit.dtype, b_wit.dtype
    if _fused_epilogue(spec, classes, cfg):
        return _qeinsum_bwd_fused(spec, classes, cfg, qa, qb, k_bwd, scales,
                                  a_dtype, b_dtype, dy)
    k_e, k_ga, k_gb = jax.random.split(k_bwd, 3)
    qdy = _quant_operand(dy, ERROR, cfg, k_e, scale=scales[2])
    da_spec, db_spec = adjoint_specs(spec)
    da = _compute(da_spec, qdy, qb, cfg)
    db = _compute(db_spec, qa, qdy, cfg)
    # Weight gradients are stored in FP8 (tensor class G, paper Fig. 1b).
    # Implemented as fake-quant here; the optimizer unscales in FP32.
    obs_g = jnp.float32(0.0)
    h_g = jnp.zeros((2,), jnp.float32) if _track(cfg) else None
    if classes[0] == WEIGHT:
        da, og, hg = _fake_quant_grad(da, cfg, k_ga, scale=scales[3])
        obs_g = jnp.maximum(obs_g, og)
        h_g = jnp.maximum(h_g, hg) if h_g is not None else None
    if classes[1] == WEIGHT:
        db, og, hg = _fake_quant_grad(db, cfg, k_gb, scale=scales[3])
        obs_g = jnp.maximum(obs_g, og)
        h_g = jnp.maximum(h_g, hg) if h_g is not None else None
    health = scale_ctx.health_pairs(
        [_health(qdy, cfg, ERROR), h_g, None, None, None]) \
        if _track(cfg) else None
    token_ct = scale_ctx.token_cotangent(e=_observe(qdy, cfg), g=obs_g,
                                         health=health)
    # Cotangents match primal dtypes; the integer PRNG key gets float0 zeros.
    return (da.astype(a_dtype), db.astype(b_dtype),
            np.zeros(np.shape(k_bwd), dtype=jax.dtypes.float0),
            jnp.zeros((N_SCALES,), jnp.float32), token_ct)


def _qeinsum_bwd_fused(spec, classes, cfg, qa, qb, k_bwd, scales,
                       a_dtype, b_dtype, dy):
    """Backward of the fused quantize-in-epilogue path: both adjoint GEMMs
    write FP8 straight from the accumulator (dgrad via the 'nt' layout,
    wgrad via 'tn' — no materialized transpose), replacing the separate
    `_fake_quant_grad` pass and its extra full-precision HBM round-trip."""
    k_e, k_da, k_db = jax.random.split(k_bwd, 3)
    qdy = _quant_operand(dy, ERROR, cfg, k_e, scale=scales[2])
    dy2 = qdy.data.reshape((-1, qdy.data.shape[-1]))
    a2 = qa.data.reshape((-1, qa.data.shape[-1]))
    # Output class / scale site of each adjoint: the weight operand's
    # gradient is FP8-stored (class G); the activation operand receives the
    # error-class dgrad output (its own "#d{a,b}.E" site).
    cls_a = GRAD if classes[0] == WEIGHT else ERROR
    cls_b = GRAD if classes[1] == WEIGHT else ERROR
    s_da = scales[3] if cls_a == GRAD else scales[5]
    s_db = scales[3] if cls_b == GRAD else scales[5]
    # dA = Q(dY . W^T): (M, N) x (K, N) -> (M, K)
    da8, obs_da, h_da = _fused_gemm(dy2, qb.data, qdy.scale, qb.scale, s_da,
                                    cfg, k_da, cls_a, "nt")
    da = _fused_dequant(da8, s_da, cfg).reshape(qa.data.shape)
    # dW = Q(A^T . dY): (M, K) x (M, N) -> (K, N)
    db8, obs_db, h_db = _fused_gemm(a2, dy2, qa.scale, qdy.scale, s_db,
                                    cfg, k_db, cls_b, "tn")
    db = _fused_dequant(db8, s_db, cfg).reshape(qb.data.shape)
    obs_g = jnp.float32(0.0)
    obs_err = jnp.float32(0.0)
    track = _track(cfg)
    h_g = jnp.zeros((2,), jnp.float32) if track else None
    h_err = None
    if cls_a == GRAD:
        obs_g = jnp.maximum(obs_g, obs_da)
        h_g = jnp.maximum(h_g, h_da) if track else None
    else:
        obs_err = obs_da
        h_err = h_da
    if cls_b == GRAD:
        obs_g = jnp.maximum(obs_g, obs_db)
        h_g = jnp.maximum(h_g, h_db) if track else None
    else:
        obs_err = obs_db
        h_err = h_db
    health = scale_ctx.health_pairs(
        [_health(qdy, cfg, ERROR), h_g, h_err, None, None]) \
        if track else None
    token_ct = scale_ctx.token_cotangent(e=_observe(qdy, cfg), g=obs_g,
                                         err=obs_err, health=health)
    return (da.astype(a_dtype), db.astype(b_dtype),
            np.zeros(np.shape(k_bwd), dtype=jax.dtypes.float0),
            jnp.zeros((N_SCALES,), jnp.float32), token_ct)


def _fake_quant_grad(g: Array, cfg: QuantConfig, key: Array,
                     scale: Optional[Array] = None):
    fmt = get_format(cfg.format_for(GRAD))
    if cfg.delayed:
        q = _quantize(g, fmt, rounding=cfg.rounding_for(GRAD), key=key,
                      scale=scale, saturate=cfg.saturate_for(GRAD))
    else:
        q = _quantize(g, fmt, rounding=cfg.rounding_for(GRAD), key=key,
                      use_amax_scale=cfg.amax_for(GRAD),
                      saturate=cfg.saturate_for(GRAD))
    h = _health(q, cfg, GRAD) if _track(cfg) else None
    return _dequantize(q, dtype=g.dtype), _observe(q, cfg), h


_qeinsum.defvjp(_qeinsum_fwd, _qeinsum_bwd)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def qeinsum(spec: str, a: Array, b: Array, *,
            key: Optional[Array] = None,
            cfg: QuantConfig = PAPER_FP8,
            classes: Tuple[str, str] = (ACT, WEIGHT),
            site: Optional[str] = None) -> Array:
    """Quantized einsum (see module docstring). classes tags each operand as
    'act' or 'weight', selecting its rounding/format and whether its gradient
    is additionally stored as FP8 (weights only).

    site: stable name of this call site (scoped by scaling.context.scope).
    Under cfg.scaling == 'delayed' with an active ScaleContext, the operand
    scales are read from ScaleState history for this site and the observed
    amaxes are recorded back (forward classes via the context/aux channel,
    error/grad classes via the site token's cotangent). Without a site or
    context, delayed mode degrades to unit scales (the paper's global-loss-
    scale recipe).
    """
    parse_spec(spec)  # validate early
    if not cfg.enabled:
        return _plain_einsum(spec, a, b, cfg)
    if key is None:
        if cfg.needs_key:
            raise ValueError(
                f"QuantConfig uses stochastic rounding; qeinsum({spec!r}) "
                "needs a PRNG key")
        key = jax.random.PRNGKey(0)
    classes = tuple(classes)
    ctx = scale_ctx.current()
    if cfg.delayed and ctx is not None and site is not None:
        fused = _fused_epilogue(spec, classes, cfg)
        skey = ctx.site_key(site)
        keys = scale_ctx.operand_keys(skey, classes)
        ctx.register(keys["a"])
        ctx.register(keys["b"])
        ctx.register(keys["E"])
        if WEIGHT in classes:
            ctx.register(keys["G"])
        s_y = jnp.float32(1.0)
        s_err = jnp.float32(1.0)
        fkeys = {}
        if fused:
            fkeys = scale_ctx.fused_output_keys(skey, classes)
            ctx.register(fkeys["y"])
            s_y = ctx.scale_for(fkeys["y"])
            if "err" in fkeys:
                ctx.register(fkeys["err"])
                s_err = ctx.scale_for(fkeys["err"])
        scales = jnp.stack([
            ctx.scale_for(keys["a"]), ctx.scale_for(keys["b"]),
            ctx.scale_for(keys["E"]), ctx.scale_for(keys["G"]),
            s_y, s_err])
        token = ctx.token_for(skey)
        y, obs = _qeinsum(spec, classes, cfg, a, b, key, scales, token)
        ctx.record(keys["a"], obs[0])
        ctx.record(keys["b"], obs[1])
        if fused:
            ctx.record(fkeys["y"], obs[2])
        if _track(cfg):
            base = 3 if fused else 2
            ctx.record_health(keys["a"], obs[base:base + 2])
            ctx.record_health(keys["b"], obs[base + 2:base + 4])
            if fused:
                ctx.record_health(fkeys["y"], obs[base + 4:base + 6])
        return y
    y, _ = _qeinsum(spec, classes, cfg, a, b, key,
                    jnp.ones((N_SCALES,), jnp.float32),
                    jnp.zeros((scale_ctx.token_width(_track(cfg)),),
                              jnp.float32))
    return y


def qmatmul(a: Array, w: Array, *, key: Optional[Array] = None,
            cfg: QuantConfig = PAPER_FP8,
            site: Optional[str] = None) -> Array:
    """x @ w for x: (..., K), w: (K, N) — the layer-projection fast path."""
    if a.ndim == 2:
        return qeinsum("mk,kn->mn", a, w, key=key, cfg=cfg, site=site)
    if a.ndim == 3:
        return qeinsum("bsk,kn->bsn", a, w, key=key, cfg=cfg, site=site)
    lead = "abcdefg"[: a.ndim - 1]
    return qeinsum(f"{lead}k,kn->{lead}n", a, w, key=key, cfg=cfg, site=site)
