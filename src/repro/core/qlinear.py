"""Quantized GEMM with the paper's Fig. 1a dataflow, as a composable JAX op.

`qeinsum(spec, a, b)` is an einsum whose *forward and backward* GEMMs all take
FP8 operands and accumulate in FP32:

    forward:   Y  = Q_A(a) . Q_W(b)                 (fp8 x fp8 -> fp32)
    backward:  dA = Q_E(dY) . Q_W(b)^T              (fp8 x fp8 -> fp32)
               dW = Q_A(a)^T . Q_E(dY), then Q_G    (fp8 x fp8 -> fp32 -> fp8)

Q_A/Q_W/Q_E/Q_G are the quantization nodes for activations / weights / errors
/ weight-gradients with per-class rounding (paper: SR for A, E, G; RNE for W)
and per-class overflow behavior (errors keep inf so dynamic loss scaling can
back off).

The residuals saved for backward are the *quantized* fp8 tensors — a 4x
activation-memory saving relative to an f32-residual baseline, mirroring the
paper's storage story.

On TPU the inner computes route to the Pallas kernels in
repro.kernels.{fp8_matmul,fused_quant_matmul}; on CPU (and for the dry-run)
they run an XLA path that upcasts fp8 -> bf16 and issues a dot with
preferred_element_type=f32, which is exactly the MXU dataflow the kernels
implement (bf16 multiplies into an f32 accumulator).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QTensor, fp8_amax_bits
from repro.core.quantize import dequantize as _dequantize
from repro.core.quantize import quantize as _quantize
from repro.core.fp8_formats import get_format
from repro.core.precision_policy import (ACT, ERROR, GRAD, WEIGHT, PAPER_FP8,
                                         QuantConfig, dtype_of)
from repro.scaling import context as scale_ctx

Array = jax.Array


# ---------------------------------------------------------------------------
# einsum spec utilities
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def parse_spec(spec: str) -> Tuple[str, str, str]:
    spec = spec.replace(" ", "")
    lhs, out = spec.split("->")
    a, b = lhs.split(",")
    if "." in spec:
        raise ValueError(f"qeinsum does not support ellipsis specs: {spec!r}")
    return a, b, out


@functools.lru_cache(maxsize=None)
def adjoint_specs(spec: str) -> Tuple[str, str]:
    """Derive the einsum specs computing dA and dB for `spec`.

    For Y = einsum('A,B->O', a, b):  dA = einsum('O,B->A', dy, b) and
    dB = einsum('A,O->B', a, dy).  Valid as long as every index of each
    operand appears in the union of the output and the other operand (true
    for every GEMM-like contraction; sum-only indices are rejected).
    """
    a, b, o = parse_spec(spec)
    for idx in a:
        if idx not in o and idx not in b:
            raise ValueError(f"index {idx!r} of lhs is summed-only in {spec!r}")
    for idx in b:
        if idx not in o and idx not in a:
            raise ValueError(f"index {idx!r} of rhs is summed-only in {spec!r}")
    return f"{o},{b}->{a}", f"{a},{o}->{b}"


# ---------------------------------------------------------------------------
# operand quantization + fp8 compute
# ---------------------------------------------------------------------------

def _quant_operand(x: Array, cls: str, cfg: QuantConfig, key: Array,
                   scale: Optional[Array] = None) -> QTensor:
    """Quantize one operand. With delayed scaling, `scale` is the
    history-derived per-site scale (an explicit input — no amax reduction
    over x happens here); otherwise the legacy jit-amax / unit-scale path."""
    fmt = get_format(cfg.format_for(cls))
    if cfg.delayed:
        return _quantize(
            x, fmt,
            rounding=cfg.rounding_for(cls),
            key=key,
            scale=jnp.float32(1.0) if scale is None else scale,
            saturate=cfg.saturate_for(cls),
        )
    return _quantize(
        x, fmt,
        rounding=cfg.rounding_for(cls),
        key=key,
        use_amax_scale=cfg.amax_for(cls),
        saturate=cfg.saturate_for(cls),
    )


def _pallas_matmul_spec(spec: str) -> bool:
    """True for '...k,kn->...n'-shaped contractions the fp8_matmul kernel covers."""
    a, b, o = parse_spec(spec)
    return (len(b) == 2 and a[-1] == b[0] and o == a[:-1] + b[1]
            and b[1] not in a and b[0] not in o)


def _compute(spec: str, qa: QTensor, qb: QTensor, cfg: QuantConfig) -> Array:
    """fp8 x fp8 -> f32 (accumulate) -> output_dtype, optionally via Pallas."""
    compute_dtype = dtype_of(cfg.compute_dtype)
    out_scale = (qa.scale * qb.scale).astype(jnp.float32)
    if cfg.backend.startswith("pallas") and _pallas_matmul_spec(spec):
        from repro.kernels.fp8_matmul import ops as mm_ops  # lazy: no cycle
        a2 = qa.data.reshape((-1, qa.data.shape[-1]))
        y = mm_ops.fp8_matmul(a2, qb.data,
                              interpret=cfg.backend == "pallas_interpret")
        y = y.reshape(qa.data.shape[:-1] + (qb.data.shape[-1],))
    else:
        y = jnp.einsum(spec, qa.data.astype(compute_dtype),
                       qb.data.astype(compute_dtype),
                       preferred_element_type=jnp.float32)
    y = y * out_scale
    return y.astype(dtype_of(cfg.output_dtype))


def _plain_einsum(spec: str, a: Array, b: Array, cfg: QuantConfig) -> Array:
    compute_dtype = dtype_of(cfg.compute_dtype)
    y = jnp.einsum(spec, a.astype(compute_dtype), b.astype(compute_dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(dtype_of(cfg.output_dtype))


# ---------------------------------------------------------------------------
# custom_vjp core
# ---------------------------------------------------------------------------

def _observe(q: QTensor, cfg: QuantConfig) -> Array:
    """Observed amax of a quantized operand, from the FP8 payload's bit
    patterns (uint8 reduce — no pass over the high-precision tensor)."""
    if not cfg.delayed:
        return jnp.float32(0.0)
    return fp8_amax_bits(q.data) * q.scale.astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _qeinsum(spec: str, classes: Tuple[str, str], cfg: QuantConfig,
             a: Array, b: Array, key: Array, scales: Array,
             token: Array) -> Tuple[Array, Array]:
    """Returns (y, fwd_obs) where fwd_obs = [amax_a, amax_b] (zeros unless
    cfg.scaling == 'delayed').

    scales: f32[4] per-site quantization scales [a, b, E, G] (history-derived
    under delayed scaling; ones otherwise). token: f32[2] observation channel
    whose *cotangent* is defined as [amax_E, amax_G] — the backward-pass
    observations ride the gradient of this input out of value_and_grad.
    """
    out, _ = _qeinsum_fwd(spec, classes, cfg, a, b, key, scales, token)
    return out


def _qeinsum_fwd(spec, classes, cfg, a, b, key, scales, token):
    k_a, k_b, k_bwd = jax.random.split(key, 3)
    qa = _quant_operand(a, classes[0], cfg, k_a, scale=scales[0])
    qb = _quant_operand(b, classes[1], cfg, k_b, scale=scales[1])
    y = _compute(spec, qa, qb, cfg)
    obs = jnp.stack([_observe(qa, cfg), _observe(qb, cfg)])
    # Zero-size dtype witnesses so bwd can emit cotangents in primal dtypes.
    return (y, obs), (qa, qb, k_bwd, scales,
                      jnp.zeros((0,), a.dtype), jnp.zeros((0,), b.dtype))


def _qeinsum_bwd(spec, classes, cfg, res, ct):
    dy, _ = ct   # cotangent of the fwd_obs output is discarded
    qa, qb, k_bwd, scales, a_wit, b_wit = res
    a_dtype, b_dtype = a_wit.dtype, b_wit.dtype
    k_e, k_ga, k_gb = jax.random.split(k_bwd, 3)
    qdy = _quant_operand(dy, ERROR, cfg, k_e, scale=scales[2])
    da_spec, db_spec = adjoint_specs(spec)
    da = _compute(da_spec, qdy, qb, cfg)
    db = _compute(db_spec, qa, qdy, cfg)
    # Weight gradients are stored in FP8 (tensor class G, paper Fig. 1b).
    # Implemented as fake-quant here; the optimizer unscales in FP32.
    obs_g = jnp.float32(0.0)
    if classes[0] == WEIGHT:
        da, og = _fake_quant_grad(da, cfg, k_ga, scale=scales[3])
        obs_g = jnp.maximum(obs_g, og)
    if classes[1] == WEIGHT:
        db, og = _fake_quant_grad(db, cfg, k_gb, scale=scales[3])
        obs_g = jnp.maximum(obs_g, og)
    token_ct = jnp.stack([_observe(qdy, cfg), obs_g])
    # Cotangents match primal dtypes; the integer PRNG key gets float0 zeros.
    return (da.astype(a_dtype), db.astype(b_dtype),
            np.zeros(np.shape(k_bwd), dtype=jax.dtypes.float0),
            jnp.zeros((4,), jnp.float32), token_ct)


def _fake_quant_grad(g: Array, cfg: QuantConfig, key: Array,
                     scale: Optional[Array] = None) -> Tuple[Array, Array]:
    fmt = get_format(cfg.format_for(GRAD))
    if cfg.delayed:
        q = _quantize(g, fmt, rounding=cfg.rounding_for(GRAD), key=key,
                      scale=scale, saturate=cfg.saturate_for(GRAD))
    else:
        q = _quantize(g, fmt, rounding=cfg.rounding_for(GRAD), key=key,
                      use_amax_scale=cfg.amax_for(GRAD),
                      saturate=cfg.saturate_for(GRAD))
    return _dequantize(q, dtype=g.dtype), _observe(q, cfg)


_qeinsum.defvjp(_qeinsum_fwd, _qeinsum_bwd)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def qeinsum(spec: str, a: Array, b: Array, *,
            key: Optional[Array] = None,
            cfg: QuantConfig = PAPER_FP8,
            classes: Tuple[str, str] = (ACT, WEIGHT),
            site: Optional[str] = None) -> Array:
    """Quantized einsum (see module docstring). classes tags each operand as
    'act' or 'weight', selecting its rounding/format and whether its gradient
    is additionally stored as FP8 (weights only).

    site: stable name of this call site (scoped by scaling.context.scope).
    Under cfg.scaling == 'delayed' with an active ScaleContext, the operand
    scales are read from ScaleState history for this site and the observed
    amaxes are recorded back (forward classes via the context/aux channel,
    error/grad classes via the site token's cotangent). Without a site or
    context, delayed mode degrades to unit scales (the paper's global-loss-
    scale recipe).
    """
    parse_spec(spec)  # validate early
    if not cfg.enabled:
        return _plain_einsum(spec, a, b, cfg)
    if key is None:
        if cfg.needs_key:
            raise ValueError(
                f"QuantConfig uses stochastic rounding; qeinsum({spec!r}) "
                "needs a PRNG key")
        key = jax.random.PRNGKey(0)
    classes = tuple(classes)
    ctx = scale_ctx.current()
    if cfg.delayed and ctx is not None and site is not None:
        skey = ctx.site_key(site)
        keys = scale_ctx.operand_keys(skey, classes)
        ctx.register(keys["a"])
        ctx.register(keys["b"])
        ctx.register(keys["E"])
        if WEIGHT in classes:
            ctx.register(keys["G"])
        scales = jnp.stack([
            ctx.scale_for(keys["a"]), ctx.scale_for(keys["b"]),
            ctx.scale_for(keys["E"]), ctx.scale_for(keys["G"])])
        token = ctx.token_for(skey)
        y, obs = _qeinsum(spec, classes, cfg, a, b, key, scales, token)
        ctx.record(keys["a"], obs[0])
        ctx.record(keys["b"], obs[1])
        return y
    y, _ = _qeinsum(spec, classes, cfg, a, b, key,
                    jnp.ones((4,), jnp.float32), jnp.zeros((2,), jnp.float32))
    return y


def qmatmul(a: Array, w: Array, *, key: Optional[Array] = None,
            cfg: QuantConfig = PAPER_FP8,
            site: Optional[str] = None) -> Array:
    """x @ w for x: (..., K), w: (K, N) — the layer-projection fast path."""
    if a.ndim == 2:
        return qeinsum("mk,kn->mn", a, w, key=key, cfg=cfg, site=site)
    if a.ndim == 3:
        return qeinsum("bsk,kn->bsn", a, w, key=key, cfg=cfg, site=site)
    lead = "abcdefg"[: a.ndim - 1]
    return qeinsum(f"{lead}k,kn->{lead}n", a, w, key=key, cfg=cfg, site=site)
