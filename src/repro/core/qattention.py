"""Fused FP8 flash-attention as a composable JAX op (the attention analogue
of core.qlinear's fused quantize-in-epilogue path).

`fp8_sdpa(q, k, v)` is scaled-dot-product attention whose forward and
backward inner products all take FP8 operands, with the score matrix S, the
softmax probs P, and the backward dP/dS intermediates quantized *inside* the
Pallas kernel (delayed-scaling amax observed in the same pass) — S and P are
never materialized in HBM, and the FP8 q/k/v payloads double as the
flash-style backward residuals. K/V stream through the kernels in
`QuantConfig.attn_block_kv`-row stripes (VMEM footprint independent of the
sequence length; fully-masked stripes of causal/sliding-window tiles are
skipped), so 32k+ contexts train and serve through the same kernels; the
amax observations are masked to the attended region so they cannot depend
on the stripe partition. Class assignment follows the recipe: S and P
are activations (saturating e4m3 under `hybrid`, Noune et al. 2206.02915);
dO/dP/dS are errors (e5m2, inf kept so the dynamic loss scaler of
Micikevicius et al. 1710.03740 sees overflow).

Scale-site grammar (scaling.context.attention_keys): one "sdpa" site
replaces the unfused path's qk/pv qeinsum pair, with operand sites
{#q,#k,#v}.A, in-kernel forward sites #qk.A / #p.A, and error sites
#E (dO) / #dp.E / #ds.E riding the token cotangent channels 0/3/4.

`fp8_sdpa_decode` is the serving-side forward: deterministic RNE, frozen
scales, and — when the KV cache is FP8 — the cache payloads feed the kernel
DIRECTLY with their frozen per-site scales, eliminating the
dequantize -> requantize round trip of the unfused decode path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision_policy import ACT, ERROR, QuantConfig, dtype_of
from repro.core.qlinear import _health, _observe, _quant_operand, _track
from repro.scaling import context as scale_ctx

Array = jax.Array

# Per-site scale-vector layout: operands q/k/v, in-kernel forward sites
# S ("#qk.A") and P ("#p.A"), then the error-class dO ("#E") and in-kernel
# dP/dS ("#dp.E"/"#ds.E").
ATTN_SCALES = 8
_ORDER = ("q", "k", "v", "s", "p", "do", "dp", "ds")


def fuse_attention(cfg: QuantConfig) -> bool:
    """True when attention routes through the fused FP8 flash kernel:
    Pallas backend + delayed scaling (the in-kernel Q nodes need
    history-derived scales), attention quantization on, and the
    `fuse_attention` knob not switched off."""
    return (cfg.enabled and cfg.quantize_attention and cfg.delayed
            and cfg.fuse_attention and cfg.backend.startswith("pallas"))


def _fwd_factors(scales: Array, sm_scale: float):
    """(4,) f32 kernel factors [f_s, s_s, f_p, f_o] from the site scales.
    Single-multiply form: the kernel (and the unfused oracle) apply each
    collapsed factor once, mirroring `_fused_gemm`'s kscale convention."""
    f_s = scales[0] * scales[1] * jnp.float32(sm_scale) / scales[3]
    return jnp.stack([f_s, scales[3], 1.0 / scales[4],
                      scales[4] * scales[2]])


def _bwd_factors(scales: Array, sm_scale: float):
    """(10,) f32 backward factors (see kernels.fp8_attention.ref
    bwd_q_tile): [f_s, s_s, f_p, s_p, f_dp, s_dp, f_ds, f_dq, f_dk, f_dv].
    """
    f_s = scales[0] * scales[1] * jnp.float32(sm_scale) / scales[3]
    return jnp.stack([
        f_s, scales[3], 1.0 / scales[4], scales[4],
        scales[5] * scales[2] / scales[6], scales[6],
        jnp.float32(sm_scale) / scales[7],
        scales[7] * scales[1], scales[7] * scales[0],
        scales[4] * scales[5]])


def _kernel_kwargs(cfg: QuantConfig):
    return dict(fmt_s=cfg.format_for(ACT), fmt_p=cfg.format_for(ACT),
                rounding_s=cfg.rounding_for(ACT),
                rounding_p=cfg.rounding_for(ACT),
                saturate_s=cfg.saturate_for(ACT),
                saturate_p=cfg.saturate_for(ACT),
                block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
                autotune=cfg.autotune,
                interpret=cfg.backend == "pallas_interpret")


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _fp8_sdpa(cfg: QuantConfig, mask_mode: str, window: int,
              sm_scale: float, q: Array, k: Array, v: Array, key: Array,
              scales: Array, token: Array):
    """Returns (o, fwd_obs) with fwd_obs = [amax_q, amax_k, amax_v,
    amax_s, amax_p] in real units (zeros unless cfg.scaling == 'delayed');
    when cfg.track_health, fwd_obs extends to (15,) with the (sat, flush)
    fraction pairs of q/k/v (payload reads) and in-kernel S/P. token:
    f32[token_width] whose cotangent carries
    [amax_dO, 0, 0, amax_dP, amax_dS] (+ health pairs when tracking)."""
    out, _ = _fp8_sdpa_fwd(cfg, mask_mode, window, sm_scale, q, k, v, key,
                           scales, token)
    return out


def _fp8_sdpa_fwd(cfg, mask_mode, window, sm_scale, q, k, v, key, scales,
                  token):
    from repro.kernels.fp8_attention import ops as attn_ops  # lazy
    k_q, k_k, k_v, k_seed, k_bwd = jax.random.split(key, 5)
    q8 = _quant_operand(q, ACT, cfg, k_q, scale=scales[0])
    k8 = _quant_operand(k, ACT, cfg, k_k, scale=scales[1])
    v8 = _quant_operand(v, ACT, cfg, k_v, scale=scales[2])
    # In-kernel SR bits come from a counter hash of this seed + absolute
    # coordinates (no rand array in HBM; bits are tiling-invariant).
    seed = jax.random.bits(k_seed, (), jnp.uint32)
    outs = attn_ops.fp8_attention_fwd(
        q8.data, k8.data, v8.data, seed, _fwd_factors(scales, sm_scale),
        mask_mode=mask_mode, window=window, with_counts=_track(cfg),
        **_kernel_kwargs(cfg))
    if _track(cfg):
        o, amax_s, amax_p, hs, hp = outs
    else:
        o, amax_s, amax_p = outs
    obs = jnp.stack([_observe(q8, cfg), _observe(k8, cfg),
                     _observe(v8, cfg), amax_s * scales[3],
                     amax_p * scales[4]])
    if _track(cfg):
        obs = jnp.concatenate([obs, _health(q8, cfg, ACT),
                               _health(k8, cfg, ACT),
                               _health(v8, cfg, ACT), hs, hp])
    res = (q8, k8, v8, seed, scales, k_bwd,
           jnp.zeros((0,), q.dtype), jnp.zeros((0,), k.dtype),
           jnp.zeros((0,), v.dtype))
    return (o.astype(dtype_of(cfg.output_dtype)), obs), res


def _fp8_sdpa_bwd(cfg, mask_mode, window, sm_scale, res, ct):
    from repro.kernels.fp8_attention import ops as attn_ops  # lazy
    dy, _ = ct   # fwd_obs cotangent discarded
    q8, k8, v8, seed, scales, k_bwd, q_wit, k_wit, v_wit = res
    qdo = _quant_operand(dy, ERROR, cfg, k_bwd, scale=scales[5])
    outs = attn_ops.fp8_attention_bwd(
        q8.data, k8.data, v8.data, qdo.data, seed,
        _bwd_factors(scales, sm_scale),
        mask_mode=mask_mode, window=window,
        fmt_e=cfg.format_for(ERROR), rounding_e=cfg.rounding_for(ERROR),
        saturate_e=cfg.saturate_for(ERROR), with_counts=_track(cfg),
        **_kernel_kwargs(cfg))
    health = None
    if _track(cfg):
        dq, dk, dv, amax_dp, amax_ds, hdp, hds = outs
        health = scale_ctx.health_pairs(
            [_health(qdo, cfg, ERROR), None, None, hdp, hds])
    else:
        dq, dk, dv, amax_dp, amax_ds = outs
    token_ct = scale_ctx.token_cotangent(
        e=_observe(qdo, cfg), dp=amax_dp * scales[6],
        ds=amax_ds * scales[7], health=health)
    return (dq.astype(q_wit.dtype), dk.astype(k_wit.dtype),
            dv.astype(v_wit.dtype),
            np.zeros(np.shape(k_bwd), dtype=jax.dtypes.float0),
            jnp.zeros((ATTN_SCALES,), jnp.float32), token_ct)


_fp8_sdpa.defvjp(_fp8_sdpa_fwd, _fp8_sdpa_bwd)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _check_frozen_sites(ctx, keys):
    """Frozen serving must not fall back to silent unit scales for the
    fused-attention sites (the same failure class _kv_scales refuses for
    the FP8 KV cache): a frozen-scales file calibrated before this path
    existed — or with fuse_attention=False — lacks the {#q,#k,#v,#qk,#p}.A
    sites, and the in-kernel Q nodes would quantize with wrong constants
    burned into the jitted program."""
    if ctx.mode != "frozen":
        return
    missing = [keys[n] for n in ("q", "k", "v", "s", "p")
               if not ctx.has_scale(keys[n])]
    if missing:
        raise ValueError(
            f"frozen serving through the fused FP8 attention kernel, but "
            f"site(s) {missing} have no calibrated scale — the in-kernel "
            "S/P Q nodes would use silent unit scales; recalibrate with "
            "fuse_attention enabled or serve with "
            "QuantConfig(fuse_attention=False)")


def fp8_sdpa(q: Array, k: Array, v: Array, *, key: Optional[Array],
             cfg: QuantConfig, sm_scale: float, mask_mode: str = "causal",
             window: int = 0, site: Optional[str] = None) -> Array:
    """Fused FP8 attention over (B,H,Q,dh) queries and UNREPEATED
    (B,Hkv,S,dh) keys/values — GQA grouping happens in the kernel's block
    index maps, so the `_repeat_kv` copies of the unfused path are never
    materialized. mask_mode: 'causal' (with optional sliding `window`) or
    'full'.

    Under an active ScaleContext with a site name, operand scales come from
    ScaleState history, forward amaxes (q/k/v + in-kernel S/P) are recorded,
    and the dO/dP/dS error observations ride the site token's cotangent.
    """
    if key is None:
        if cfg.needs_key:
            raise ValueError("QuantConfig uses stochastic rounding; "
                             "fp8_sdpa needs a PRNG key")
        key = jax.random.PRNGKey(0)
    ctx = scale_ctx.current()
    if cfg.delayed and ctx is not None and site is not None:
        skey = ctx.site_key(site)
        keys = scale_ctx.attention_keys(skey)
        for kk in keys.values():
            ctx.register(kk)
        _check_frozen_sites(ctx, keys)
        scales = jnp.stack([ctx.scale_for(keys[n]) for n in _ORDER])
        token = ctx.token_for(skey)
        o, obs = _fp8_sdpa(cfg, mask_mode, window, sm_scale, q, k, v, key,
                           scales, token)
        for i, n in enumerate(_ORDER[:5]):
            ctx.record(keys[n], obs[i])
        if _track(cfg):
            # Health pairs follow the 5 amaxes: q/k/v payloads, then the
            # in-kernel S/P tiles.
            for i, n in enumerate(_ORDER[:5]):
                ctx.record_health(keys[n], obs[5 + 2 * i: 7 + 2 * i])
        return o
    o, _ = _fp8_sdpa(cfg, mask_mode, window, sm_scale, q, k, v, key,
                     jnp.ones((ATTN_SCALES,), jnp.float32),
                     jnp.zeros((scale_ctx.token_width(_track(cfg)),),
                               jnp.float32))
    return o


def fp8_sdpa_decode(q: Array, k_cached: Array, v_cached: Array,
                    valid: Array, *, cfg: QuantConfig, sm_scale: float,
                    key: Optional[Array] = None,
                    k_cache_scale=1.0, v_cache_scale=1.0,
                    site: Optional[str] = None) -> Array:
    """Serving decode through the fused kernel (forward only, 'kv' mask).

    q: (B,H,1,dh) high precision. k_cached/v_cached: (B,Hkv,C,dh) — FP8 KV
    cache payloads are consumed DIRECTLY with their frozen per-site cache
    scales (k_cache_scale/v_cache_scale, the `.../kv/{k,v}#A` constants): no
    dequantize -> requantize round trip, and the kernel never materializes
    the repeated GQA copies. bf16 caches are quantized here at the #k.A/#v.A
    sites. valid: (B, C) slot-validity mask."""
    ctx = scale_ctx.current()
    keys = None
    one = jnp.float32(1.0)
    s_q = s_s = s_p = one
    if cfg.delayed and ctx is not None and site is not None:
        skey = ctx.site_key(site)
        keys = scale_ctx.attention_keys(skey)
        for n in ("q", "k", "v", "s", "p"):
            ctx.register(keys[n])
        _check_frozen_sites(ctx, keys)
        s_q = ctx.scale_for(keys["q"])
        s_s = ctx.scale_for(keys["s"])
        s_p = ctx.scale_for(keys["p"])
    if key is None:
        key = jax.random.PRNGKey(0)
    k_q, k_k, k_v, k_seed = jax.random.split(key, 4)
    q8 = _quant_operand(q, ACT, cfg, k_q, scale=s_q)
    if k_cached.dtype in (jnp.float8_e5m2, jnp.float8_e4m3fn):
        k8d, v8d = k_cached, v_cached
        s_k = jnp.asarray(k_cache_scale, jnp.float32)
        s_v = jnp.asarray(v_cache_scale, jnp.float32)
    else:
        s_k = ctx.scale_for(keys["k"]) if keys is not None else one
        s_v = ctx.scale_for(keys["v"]) if keys is not None else one
        qk8 = _quant_operand(k_cached, ACT, cfg, k_k, scale=s_k)
        qv8 = _quant_operand(v_cached, ACT, cfg, k_v, scale=s_v)
        k8d, v8d = qk8.data, qv8.data
    from repro.kernels.fp8_attention import ops as attn_ops  # lazy
    seed = jax.random.bits(k_seed, (), jnp.uint32)
    f_s = s_q * s_k * jnp.float32(sm_scale) / s_s
    scal = jnp.stack([f_s, s_s, 1.0 / s_p, s_p * s_v])
    o, amax_s, amax_p = attn_ops.fp8_attention_fwd(
        q8.data, k8d, v8d, seed, scal, mask_mode="kv",
        kv_mask=valid.astype(jnp.int8), **_kernel_kwargs(cfg))
    if keys is not None:
        ctx.record(keys["q"], _observe(q8, cfg))
        ctx.record(keys["s"], amax_s * s_s)
        ctx.record(keys["p"], amax_p * s_p)
    return o.astype(dtype_of(cfg.output_dtype))


def fp8_sdpa_chunk(q: Array, k_cached: Array, v_cached: Array,
                   slot_pos: Array, chunk_pos: Array, *, cfg: QuantConfig,
                   sm_scale: float, window: int = 0,
                   key: Optional[Array] = None,
                   k_cache_scale=1.0, v_cache_scale=1.0,
                   site: Optional[str] = None) -> Array:
    """Serving chunk step through the fused kernel (forward only, 'chunk'
    mask): T consecutive tokens per request attend a paged/gathered KV
    layout in ONE kernel call — the chunked-prefill + decode unified path
    (decode is the T=1 special case; the mask reduces exactly to the 'kv'
    decode condition then).

    q: (B,H,T,dh) high precision — the chunk's queries. k_cached/v_cached:
    (B,Hkv,C,dh) gathered cache rows, FP8 payloads consumed DIRECTLY with
    their frozen cache scales, bf16 quantized here at the #k.A/#v.A sites
    (identical to `fp8_sdpa_decode`). slot_pos: (B,C) int32 absolute
    position held by each gathered column (-1 = hole). chunk_pos: (B,2)
    int32 [start, n_valid] — q row r of request b sits at position
    start_b + r when r < n_valid_b, and is fully masked (exact-zero
    output row) otherwise, so ragged chunks batch under one static shape.
    Validity is (slot >= 0) & (slot <= qpos) [& window band] — in-chunk
    causality emerges from the position comparison, with no separate
    causal mask."""
    ctx = scale_ctx.current()
    keys = None
    one = jnp.float32(1.0)
    s_q = s_s = s_p = one
    if cfg.delayed and ctx is not None and site is not None:
        skey = ctx.site_key(site)
        keys = scale_ctx.attention_keys(skey)
        for n in ("q", "k", "v", "s", "p"):
            ctx.register(keys[n])
        _check_frozen_sites(ctx, keys)
        s_q = ctx.scale_for(keys["q"])
        s_s = ctx.scale_for(keys["s"])
        s_p = ctx.scale_for(keys["p"])
    if key is None:
        key = jax.random.PRNGKey(0)
    k_q, k_k, k_v, k_seed = jax.random.split(key, 4)
    q8 = _quant_operand(q, ACT, cfg, k_q, scale=s_q)
    if k_cached.dtype in (jnp.float8_e5m2, jnp.float8_e4m3fn):
        k8d, v8d = k_cached, v_cached
        s_k = jnp.asarray(k_cache_scale, jnp.float32)
        s_v = jnp.asarray(v_cache_scale, jnp.float32)
    else:
        s_k = ctx.scale_for(keys["k"]) if keys is not None else one
        s_v = ctx.scale_for(keys["v"]) if keys is not None else one
        qk8 = _quant_operand(k_cached, ACT, cfg, k_k, scale=s_k)
        qv8 = _quant_operand(v_cached, ACT, cfg, k_v, scale=s_v)
        k8d, v8d = qk8.data, qv8.data
    from repro.kernels.fp8_attention import ops as attn_ops  # lazy
    seed = jax.random.bits(k_seed, (), jnp.uint32)
    f_s = s_q * s_k * jnp.float32(sm_scale) / s_s
    scal = jnp.stack([f_s, s_s, 1.0 / s_p, s_p * s_v])
    o, amax_s, amax_p = attn_ops.fp8_attention_fwd(
        q8.data, k8d, v8d, seed, scal, mask_mode="chunk", window=window,
        kv_mask=slot_pos.astype(jnp.int32),
        chunk_pos=chunk_pos.astype(jnp.int32), **_kernel_kwargs(cfg))
    if keys is not None:
        ctx.record(keys["q"], _observe(q8, cfg))
        ctx.record(keys["s"], amax_s * s_s)
        ctx.record(keys["p"], amax_p * s_p)
    return o.astype(dtype_of(cfg.output_dtype))
