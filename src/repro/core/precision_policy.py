"""Precision policy: which tensor gets which format/rounding/saturation.

Encodes the paper's recipe:
  * W, A, E, G (weights, activations, errors, weight-gradients) in FP8 e5m2.
  * Stochastic rounding on activations and gradients (paper §3.2), RNE on
    weights.
  * Error/grad tensors do NOT saturate on overflow — overflow must surface as
    inf so the dynamic loss scaler can back off (paper §3.1).
  * First/last layers (embedding + logits head here; first conv / last FC in
    the paper's convnets) stay at 16-bit.
  * Master weights at FP16, update math at FP32 (paper Fig. 1b).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# Tensor classes, paper Table 3 nomenclature: W, A, E, G.
WEIGHT, ACT, ERROR, GRAD = "weight", "act", "error", "grad"


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static (hashable) quantization configuration for qeinsum/QDense.

    The defaults are the paper's recipe. `enabled=False` produces the FP32/
    BF16 baseline with an identical call graph (for apples-to-apples
    benchmarks).
    """
    enabled: bool = True
    # Format recipe preset (per-tensor-class formats):
    #   "paper_e5m2" — the paper's single-format recipe: e5m2 everywhere,
    #                  surviving the 2-bit mantissa via loss scaling.
    #   "hybrid"     — the accuracy-robust hybrid of the follow-on work
    #                  (Noune et al. 2206.02915; Wang et al. 1812.08011):
    #                  high-precision e4m3 for forward tensors (W/A), wide-
    #                  range e5m2 for errors/gradients (E/G).
    # The recipe OWNS fwd_format/bwd_format: __post_init__ pins both to the
    # preset's values, so the recipe label and the actual formats can never
    # disagree. Saturation semantics stay per-direction (the forward format
    # saturates, e5m2 E/G keep propagating inf so the loss scaler sees it).
    recipe: str = "paper_e5m2"
    fwd_format: str = "e5m2"      # W and A storage format
    bwd_format: str = "e5m2"      # E and G storage format
    weight_rounding: str = "rne"
    act_rounding: str = "sr"
    error_rounding: str = "sr"
    grad_rounding: str = "sr"
    saturate_fwd: bool = True
    saturate_bwd: bool = False    # keep inf -> dynamic loss scaling sees it
    # Per-tensor scaling mode (beyond-paper; the paper relies on global loss
    # scaling only):
    #   "none"     — scale 1.0 everywhere (the paper's recipe).
    #   "jit_amax" — just-in-time per-tensor amax scaling: an extra
    #                full-tensor reduction on every quantize (cf. FP8-LM).
    #   "delayed"  — stateful delayed scaling: scales come from a ScaleState
    #                history of recent amax observations (repro.scaling),
    #                removing the in-line reduction from the hot path
    #                (cf. Transformer Engine).
    scaling: str = "none"
    # Deprecated back-compat shim for the old per-direction bools; setting
    # either forces scaling="jit_amax" (see __post_init__).
    amax_scale_fwd: bool = False
    amax_scale_bwd: bool = False
    compute_dtype: str = "bfloat16"   # MXU operand dtype after dequant
    output_dtype: str = "bfloat16"    # GEMM epilogue output
    accum_dtype: str = "float32"      # paper: FP32 accumulator, always
    backend: str = "xla"              # xla | pallas | pallas_interpret
    # Whether activation-activation GEMMs (attention QK^T / PV) are quantized.
    quantize_attention: bool = True
    # Fused quantize-in-epilogue GEMMs (Pallas backends + delayed scaling
    # only): the fwd/dgrad/wgrad GEMMs of qeinsum write FP8 directly from
    # the accumulator tile in VMEM, with the delayed-scaling amax
    # observation taken in the same epilogue — no separate Q pass over HBM.
    # False keeps the quantize->matmul composition (the A/B side of the
    # fused-vs-unfused benchmark).
    fuse_epilogue: bool = True
    # Fused FP8 flash-attention (Pallas backends + delayed scaling only):
    # the attention inner products route through the chunked flash kernel —
    # S = Q_A(QK^T) and the softmax probs P are quantized IN the kernel
    # (with fused amax observation at the "#qk.A"/"#p.A" sites) and never
    # materialized in HBM; the custom-VJP backward recomputes them from the
    # FP8 residuals and quantizes the dP/dS intermediates to the error
    # format ("#dp.E"/"#ds.E"). False keeps the unfused _sdpa composition
    # (XLA fake-quant with full-precision S/P round trips).
    fuse_attention: bool = True
    # Streamed-KV knobs for the fused flash kernel: rows of the query block
    # and of the kv stripe resident in VMEM per grid step. The kernel's
    # VMEM footprint is O(attn_block_q*D + attn_block_kv*D) — independent
    # of the sequence length — and results are bit-invariant to both knobs
    # (LANE-stepped reductions, TQ-pinned dK/dV contraction, absolute-
    # coordinate SR bits), so they only move wall-clock. None (default)
    # resolves per shape through the block-size autotuner winners table
    # (kernels.autotune, controlled by `autotune` below), falling back to
    # the kernel defaults. Explicit ints always win and are validated:
    # attn_block_q must be a multiple of 128 when larger than 128 (and a
    # 128-multiple outright for the backward), attn_block_kv a multiple
    # of 128.
    attn_block_q: Optional[int] = None
    attn_block_kv: Optional[int] = None
    # Block-size autotuner mode for unset block knobs (GEMM bm/bk/bn and
    # the attn_block_* above): "table" consults the shipped winners table
    # (or $REPRO_AUTOTUNE_TABLE), "off" pins the built-in defaults, any
    # other string is read as a path to an alternative table.
    autotune: str = "table"
    # Precision-health counters (repro.obs): per-site saturation / flush
    # fractions observed next to the delayed-scaling amax reads — payload
    # bit patterns on the XLA side, VMEM tile counts in the fused kernel
    # epilogues. Telemetry only: enabling it changes no computed bits
    # (parity-locked in tests/test_obs.py). Requires scaling="delayed".
    track_health: bool = False

    def __post_init__(self):
        # The recipe OWNS the per-class formats (idempotent under
        # dataclasses.replace, e.g. eval_mode()); switching recipe on an
        # existing config therefore always re-pins both formats — a hybrid
        # config replaced back to "paper_e5m2" returns to e5m2 everywhere.
        if self.recipe == "paper_e5m2":
            object.__setattr__(self, "fwd_format", "e5m2")
            object.__setattr__(self, "bwd_format", "e5m2")
        elif self.recipe == "hybrid":
            object.__setattr__(self, "fwd_format", "e4m3")
            object.__setattr__(self, "bwd_format", "e5m2")
        else:
            raise ValueError(f"unknown format recipe {self.recipe!r}")
        if self.scaling not in ("none", "jit_amax", "delayed"):
            raise ValueError(f"unknown scaling mode {self.scaling!r}")
        if self.scaling == "none" and (self.amax_scale_fwd
                                       or self.amax_scale_bwd):
            object.__setattr__(self, "scaling", "jit_amax")

    # -- helpers ------------------------------------------------------------
    def rounding_for(self, cls: str) -> str:
        return {WEIGHT: self.weight_rounding, ACT: self.act_rounding,
                ERROR: self.error_rounding, GRAD: self.grad_rounding}[cls]

    def format_for(self, cls: str) -> str:
        return self.fwd_format if cls in (WEIGHT, ACT) else self.bwd_format

    def saturate_for(self, cls: str) -> bool:
        return self.saturate_fwd if cls in (WEIGHT, ACT) else self.saturate_bwd

    def amax_for(self, cls: str) -> bool:
        """Just-in-time amax scaling for `cls`? (delayed mode never computes
        amax inline — scales come from ScaleState history instead)."""
        if self.scaling != "jit_amax":
            return False
        if not (self.amax_scale_fwd or self.amax_scale_bwd):
            return True   # scaling="jit_amax" given directly: all classes
        return self.amax_scale_fwd if cls in (WEIGHT, ACT) \
            else self.amax_scale_bwd

    @property
    def delayed(self) -> bool:
        return self.scaling == "delayed"

    @property
    def needs_key(self) -> bool:
        return self.enabled and "sr" in (self.weight_rounding, self.act_rounding,
                                         self.error_rounding, self.grad_rounding)

    def eval_mode(self) -> "QuantConfig":
        """Deterministic inference variant: RNE everywhere, saturating."""
        return dataclasses.replace(self, act_rounding="rne", error_rounding="rne",
                                   grad_rounding="rne", saturate_bwd=True)

    def baseline(self) -> "QuantConfig":
        return dataclasses.replace(self, enabled=False)

    def recipe_table(self) -> dict:
        """Per-tensor-class precision recipe: {class: (format, rounding,
        saturate)} — the README's precision-recipe table, from code."""
        return {cls: dict(format=self.format_for(cls),
                          rounding=self.rounding_for(cls),
                          saturate=self.saturate_for(cls))
                for cls in (WEIGHT, ACT, ERROR, GRAD)}


# Canonical configs ---------------------------------------------------------

PAPER_FP8 = QuantConfig()                      # the paper's recipe
PAPER_FP8_RNE = dataclasses.replace(            # ablation: RNE-only (Fig. 3)
    PAPER_FP8, act_rounding="rne", error_rounding="rne", grad_rounding="rne")
BASELINE = QuantConfig(enabled=False)          # FP32/BF16 baseline
AMAX_FP8 = dataclasses.replace(                # beyond-paper per-tensor scaling
    PAPER_FP8, amax_scale_fwd=True, amax_scale_bwd=True)
DELAYED_FP8 = dataclasses.replace(              # history-based delayed scaling
    PAPER_FP8, scaling="delayed")
HYBRID_FP8 = QuantConfig(recipe="hybrid")       # e4m3 W/A + e5m2 E/G
HYBRID_DELAYED_FP8 = QuantConfig(               # the production recipe:
    recipe="hybrid", scaling="delayed")         # hybrid formats over delayed
#                                                 per-tensor scaling


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Static parallelism policy — which strategies compose into the
    ParallelPlan (distributed.strategy) and what format the collectives put
    on the wire.

    The plan object owns the mesh-specific derivations (PartitionSpecs,
    collective implementations); this config is the pure-policy half that
    rides on PrecisionPolicy so launch overrides spell it
    `--set policy.dist.wire=fp8_ef` exactly like the quant knobs.
    """
    dp: bool = True              # data parallelism over ('pod', 'data')
    zero1: bool = True           # ZeRO-1: master+optimizer sharded over 'data'
    tp: bool = True              # Megatron tensor parallelism over 'model'
    # Wire format of the data-parallel gradient reduction:
    #   "full"   — XLA's native all-reduce (bf16/f32 on the wire).
    #   "fp8_ef" — e5m2-compressed all-reduce with error feedback
    #              (distributed.grad_compress): half the bytes of bf16 on
    #              the slowest (inter-pod) link; the residual pytree rides
    #              the train state and is checkpointed.
    wire: str = "full"
    # ZeRO-1 weight all-gather leg (master shards -> full compute params):
    #   "full" — bf16 gather (XLA native).
    #   "fp8"  — e4m3 payload gather with a shared per-leaf scale: the
    #            frozen-format weight shards move at 1 byte/element.
    wire_zero_gather: str = "full"
    # Mesh axis the compressed reduction runs over. None = the slowest
    # data-parallel link present ('pod' if in the mesh, else 'data'); the
    # remaining dp axes reduce in full precision first (fast intra-pod ICI).
    wire_axis: Optional[str] = None

    def __post_init__(self):
        if self.wire not in ("full", "fp8_ef"):
            raise ValueError(f"unknown wire format {self.wire!r}")
        if self.wire_zero_gather not in ("full", "fp8"):
            raise ValueError(
                f"unknown zero-gather format {self.wire_zero_gather!r}")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Model-level policy: where FP8 applies and master-weight precision."""
    quant: QuantConfig = PAPER_FP8
    # Parallelism policy: strategy composition + collective wire formats
    # (consumed by distributed.strategy.ParallelPlan.build).
    dist: DistConfig = DistConfig()
    # Paper §4: first conv & last FC stay at 16-bit. LM analogue: embedding
    # table and logits head.
    quantize_embedding: bool = False
    quantize_logits_head: bool = False
    # Paper Fig. 1b: master copy of weights at FP16, update math in FP32.
    master_weight_dtype: str = "float16"
    update_dtype: str = "float32"
    # Model compute dtype for non-GEMM ops (norms/softmax run in f32 anyway).
    activation_dtype: str = "bfloat16"
    # Beyond-paper: FP8 KV-cache for serving.
    kv_cache_format: Optional[str] = None     # None | "e5m2" | "e4m3"

    def quant_for_layer(self, *, is_embedding: bool = False,
                        is_head: bool = False) -> QuantConfig:
        if (is_embedding and not self.quantize_embedding) or \
           (is_head and not self.quantize_logits_head):
            return self.quant.baseline()
        return self.quant


PAPER_POLICY = PrecisionPolicy()
BASELINE_POLICY = PrecisionPolicy(quant=BASELINE, master_weight_dtype="float32")


def dtype_of(name: str) -> jnp.dtype:
    return jnp.dtype({"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                      "float16": jnp.float16}[name])
