"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517; unverified]. Pattern: 3 mLSTM : 1 sLSTM (the
repeating unit scans cleanly; the xLSTM paper places a handful of sLSTM
blocks among mLSTM ones). d_ff=0: blocks carry their own projections."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        ssm_proj_factor=2.0,
        act="gelu", max_seq_len=1_048_576,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                          vocab_size=512, max_seq_len=512)
