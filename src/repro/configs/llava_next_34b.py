"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]. The vision tower is a STUB per the assignment: input_specs()
provides precomputed anyres patch embeddings (B, P, D) that the backbone
prepends to the token embeddings."""
from repro.models.config import ModelConfig

# anyres 2x2 tiles + base view, 24x24 patches each -> 576 * 5 = 2880; we use
# one base view (576) to keep the train_4k text budget dominant.
N_PATCHES = 576


def full() -> ModelConfig:
    return ModelConfig(
        arch="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab_size=64000,
        frontend="patch_stub", n_frontend_tokens=N_PATCHES,
        act="silu", rope_theta=5_000_000.0, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=256, vocab_size=512, n_frontend_tokens=16,
                          max_seq_len=256)
