"""Architecture configs (one module per assigned arch + paper workloads)."""
