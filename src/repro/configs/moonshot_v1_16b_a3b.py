"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight)
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        n_experts=64, experts_per_token=6,
        act="silu", rope_theta=50_000.0, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          d_ff=64, vocab_size=512, n_experts=8,
                          experts_per_token=2, max_seq_len=256)
