"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — encoder-decoder, multimodal [arXiv:2308.11596; hf].
The speech frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, T, D) consumed directly by the encoder;
24 encoder + 24 decoder layers."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=256206,
        is_encoder_decoder=True, n_encoder_layers=24,
        frontend="audio_stub",
        act="gelu", max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=2, n_encoder_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
                          max_seq_len=256)
