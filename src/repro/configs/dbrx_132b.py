"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base; unverified]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        n_experts=16, experts_per_token=4,
        act="silu", rope_theta=500_000.0, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=128, vocab_size=512, n_experts=4,
                          experts_per_token=2, max_seq_len=256)
