"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (GQA kv=32, i.e. MHA)
d_ff=13440 vocab=92416 — qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=13440, vocab_size=92416, qkv_bias=True,
        act="silu", rope_theta=1_000_000.0, max_seq_len=65536,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
                          d_ff=256, vocab_size=512, max_seq_len=256)
