"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
        act="silu", rope_theta=1_000_000.0, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=4, d_model=96, n_heads=6, n_kv_heads=2,
                          d_ff=192, vocab_size=512, max_seq_len=256)
