"""The paper's own Transformer workload (6-layer, ~200M params, WMT16
En->De; paper Table 4) at config level — exercised at reduced scale by the
benchmarks (synthetic seq2seq data; the paper's BLEU-parity claim maps to
loss-parity FP8 vs FP32 here)."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    # Transformer-big-ish: 6 layers, d=1024, 16 heads, ff 4096 (~210M).
    return ModelConfig(
        arch="paper-transformer", family="dense",
        n_layers=6, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=32000,
        is_encoder_decoder=True, n_encoder_layers=6,
        act="gelu", max_seq_len=1024,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=2, n_encoder_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
                          max_seq_len=128)
