"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention, pattern 2 recurrent : 1
local-attn [arXiv:2402.19427; unverified]. Window 2048 per Griffin."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000,
        block_pattern=("rglru", "rglru", "local_attn"),
        window=2048, lru_dim=4096,
        act="gelu", max_seq_len=1_048_576,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=3, d_model=128, n_heads=4, n_kv_heads=1,
                          d_ff=256, vocab_size=512, window=64, lru_dim=128,
                          max_seq_len=512)
