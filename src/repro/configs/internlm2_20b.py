"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA [arXiv:2403.17297; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92544,
        act="silu", rope_theta=1_000_000.0, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=4, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=256, vocab_size=512, max_seq_len=256)
