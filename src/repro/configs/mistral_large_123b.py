"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="mistral-large-123b", family="dense",
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=28672, vocab_size=32768,
        act="silu", rope_theta=1_000_000.0, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(n_layers=4, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=256, vocab_size=512, max_seq_len=256)
