"""The paper's convnet workload family (ResNet-18/34/50 on Imagenet-1K) at
reduced CIFAR scale. Returned as a ModelConfig stub for registry uniformity;
the actual conv model lives in repro.models.resnet (ResNetConfig) and is
driven by the paper benchmarks."""
from repro.models.config import ModelConfig
from repro.models.resnet import ResNetConfig


def full() -> ModelConfig:
    # Placeholder LM-shaped entry so the registry stays uniform; conv
    # experiments use resnet_config() below.
    return ModelConfig(arch="paper-resnet", family="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                       vocab_size=512)


def smoke() -> ModelConfig:
    return full()


def resnet_config(**kw) -> ResNetConfig:
    return ResNetConfig(**kw)
