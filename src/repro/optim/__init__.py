from repro.optim.optimizers import (AdamConfig, MomentumConfig, adam,
                                    momentum_sgd, make_optimizer,
                                    l2_regularization_loss)

__all__ = ["AdamConfig", "MomentumConfig", "adam", "momentum_sgd",
           "make_optimizer", "l2_regularization_loss"]
