"""Optimizers used by the paper's workloads, as pure (init, update) pairs.

 * momentum SGD + L2 regularization — ResNet-18/34/50 (paper §3.2 leans on
   the interaction of L2 loss with quantization noise, so L2 is implemented
   both as a loss term — Eq. (1) — and as decoupled weight decay).
 * Adam — GNMT / Transformer ("same hyper parameters as the FP32 baseline").

Update functions return *updates* (deltas to add to params), so the
MixedPrecisionOptimizer wrapper controls the storage-dtype round trip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
tmap = jax.tree_util.tree_map


def l2_regularization_loss(params, weight_decay: float) -> Array:
    """Paper Eq. (1): L2_loss = lambda * sum_i w_i^2 (the quantity whose
    unconstrained growth under RNE the paper diagnoses in Fig. 3c)."""
    sq = [jnp.sum(jnp.square(p.astype(jnp.float32)))
          for p in jax.tree_util.tree_leaves(params)
          if jnp.issubdtype(p.dtype, jnp.floating)]
    total = jnp.asarray(0.0, jnp.float32)
    for s in sq:
        total = total + s
    return weight_decay * total


# ---------------------------------------------------------------------------
# Momentum SGD
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MomentumConfig:
    learning_rate: float = 0.1
    momentum: float = 0.9
    nesterov: bool = False
    # Decoupled weight decay (0.0 when L2 is included in the loss instead).
    weight_decay: float = 0.0


def momentum_sgd(cfg: MomentumConfig,
                 lr_schedule: Optional[Callable[[Array], Array]] = None):
    def init(params):
        return {"mu": tmap(jnp.zeros_like, params),
                "count": jnp.asarray(0, jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        lr = lr_schedule(count) if lr_schedule is not None \
            else jnp.asarray(cfg.learning_rate, jnp.float32)
        if cfg.weight_decay:
            grads = tmap(lambda g, p: g + cfg.weight_decay * p, grads, params)
        mu = tmap(lambda m, g: cfg.momentum * m + g, state["mu"], grads)
        if cfg.nesterov:
            upd = tmap(lambda m, g: -(lr * (cfg.momentum * m + g)), mu, grads)
        else:
            upd = tmap(lambda m: -(lr * m), mu)
        return upd, {"mu": mu, "count": count}

    return init, update


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamConfig:
    learning_rate: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


def adam(cfg: AdamConfig,
         lr_schedule: Optional[Callable[[Array], Array]] = None):
    def init(params):
        return {"mu": tmap(jnp.zeros_like, params),
                "nu": tmap(jnp.zeros_like, params),
                "count": jnp.asarray(0, jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        lr = lr_schedule(count) if lr_schedule is not None \
            else jnp.asarray(cfg.learning_rate, jnp.float32)
        if cfg.weight_decay:
            grads = tmap(lambda g, p: g + cfg.weight_decay * p, grads, params)
        mu = tmap(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                  state["mu"], grads)
        nu = tmap(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g),
                  state["nu"], grads)
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - cfg.b1 ** c)
        nu_hat_scale = 1.0 / (1 - cfg.b2 ** c)
        upd = tmap(lambda m, v: -(lr * (m * mu_hat_scale)
                                  / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)),
                   mu, nu)
        return upd, {"mu": mu, "nu": nu, "count": count}

    return init, update


def warmup_rsqrt_schedule(base_lr: float, warmup_steps: int = 4000):
    """The Transformer LR schedule (paper trains with baseline hparams)."""
    def sched(count):
        c = jnp.maximum(count.astype(jnp.float32), 1.0)
        return base_lr * jnp.minimum(c * warmup_steps ** -1.5, c ** -0.5)
    return sched


def make_optimizer(name: str, **kwargs):
    """Registry entry point used by configs: 'momentum' | 'adam'."""
    if name == "momentum":
        lr_schedule = kwargs.pop("lr_schedule", None)
        return momentum_sgd(MomentumConfig(**kwargs), lr_schedule)
    if name == "adam":
        lr_schedule = kwargs.pop("lr_schedule", None)
        return adam(AdamConfig(**kwargs), lr_schedule)
    raise ValueError(f"unknown optimizer {name!r}")


# ---------------------------------------------------------------------------
# leaf-wise variants: the whole update for one parameter leaf in one function
# so the mixed-precision wrapper can fuse unscale+update+select+downcast into
# a single tree_map — f32 temporaries then live per-leaf, not per-tree (the
# difference between ~2 GiB and ~12 GiB of optimizer temps on a 123B model).
# ---------------------------------------------------------------------------

def momentum_leafwise(cfg: MomentumConfig,
                      lr_schedule: Optional[Callable] = None):
    names = ("mu",)

    def leaf(g32, accums, count, p32):
        lr = lr_schedule(count) if lr_schedule is not None \
            else jnp.asarray(cfg.learning_rate, jnp.float32)
        if cfg.weight_decay:
            g32 = g32 + cfg.weight_decay * p32
        mu = cfg.momentum * accums["mu"] + g32
        upd = -(lr * (cfg.momentum * mu + g32)) if cfg.nesterov \
            else -(lr * mu)
        return upd, {"mu": mu}

    return names, leaf


def adam_leafwise(cfg: AdamConfig, lr_schedule: Optional[Callable] = None):
    names = ("mu", "nu")

    def leaf(g32, accums, count, p32):
        lr = lr_schedule(count) if lr_schedule is not None \
            else jnp.asarray(cfg.learning_rate, jnp.float32)
        if cfg.weight_decay:
            g32 = g32 + cfg.weight_decay * p32
        mu = cfg.b1 * accums["mu"] + (1 - cfg.b1) * g32
        nu = cfg.b2 * accums["nu"] + (1 - cfg.b2) * jnp.square(g32)
        c = count.astype(jnp.float32)
        mu_hat = mu / (1 - cfg.b1 ** c)
        nu_hat = nu / (1 - cfg.b2 ** c)
        upd = -(lr * mu_hat / (jnp.sqrt(nu_hat) + cfg.eps))
        return upd, {"mu": mu, "nu": nu}

    return names, leaf


def make_leafwise(name: str, **kwargs):
    if name == "momentum":
        lr_schedule = kwargs.pop("lr_schedule", None)
        return momentum_leafwise(MomentumConfig(**kwargs), lr_schedule)
    if name == "adam":
        lr_schedule = kwargs.pop("lr_schedule", None)
        return adam_leafwise(AdamConfig(**kwargs), lr_schedule)
    raise ValueError(f"unknown optimizer {name!r}")
