"""Three-term roofline analysis from the compiled dry-run artifacts.

Hardware model (TPU v5e-class, per chip):
  peak bf16 compute:   197 TFLOP/s
  HBM bandwidth:       819 GB/s
  ICI per-link:        ~50 GB/s

Terms (seconds per step, per chip — cost_analysis numbers are per-device,
verified empirically):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO_bytes_accessed / 819e9
  collective = wire_bytes / 50e9

wire_bytes applies standard ring-algorithm factors to the per-device HLO
operand sizes: all-reduce 2(N-1)/N, all-gather/reduce-scatter/all-to-all
(N-1)/N, collective-permute 1x, where N is the device count of the mesh
axis involved (approximated by the largest axis — conservative).

MODEL_FLOPS uses the 6ND rule (train) or 2ND (inference fwd), with N the
*active* parameter count for MoE. The MODEL/HLO ratio surfaces remat and
redundancy waste; ratios > 1 mean HLO under-counts (e.g. scan bodies) and
the unrolled lowering should be used instead.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_WIRE_FACTOR = {
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def active_params(meta: dict) -> float:
    """Active-parameter estimate from cell meta (MoE counts top-k experts)."""
    d, dh = meta["d_model"], meta["head_dim"]
    h, hkv = meta["n_heads"], meta["n_kv_heads"]
    vocab, ff = meta["vocab"], meta["d_ff"]
    kinds = []
    pattern = meta.get("pattern", "attn").split(",")
    for i in range(meta["n_layers"]):
        kinds.append(pattern[i % len(pattern)])
    total = vocab * d * 2  # embed + head
    for kind in kinds:
        if kind in ("attn", "local_attn"):
            total += d * dh * (h + 2 * hkv) + h * dh * d
        elif kind == "rglru":
            w = d
            total += 2 * d * w + 2 * w * w + w * d
        elif kind in ("mlstm",):
            inner = int(d * 2)
            total += 2 * d * inner + 3 * inner * inner + inner * d
        elif kind in ("slstm",):
            total += d * 4 * d + 2 * d * int(d * 4 / 3) + int(d * 4 / 3) * d
        if kind in ("attn", "local_attn", "rglru"):
            if meta.get("n_experts"):
                total += meta["experts_per_token"] * 3 * d * ff
            elif ff:
                total += 3 * d * ff
    for _ in range(meta.get("n_encoder_layers", 0)):
        total += 4 * d * d + 3 * d * ff
    return float(total)


def model_flops(meta: dict, n_devices: int) -> float:
    """Per-device useful model FLOPs for the step."""
    n = active_params(meta)
    if meta["mode"] == "train":
        tokens = meta["batch"] * meta["seq"]
        return 6.0 * n * tokens / n_devices
    if meta["mode"] == "prefill":
        tokens = meta["batch"] * meta["seq"]
        return 2.0 * n * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n * meta["batch"] / n_devices


def analyze_record(rec: dict, *, axis_n: Optional[int] = None) -> dict:
    """Compute roofline terms for one dry-run JSON record."""
    if rec.get("status") != "ok":
        return {"status": rec.get("status", "missing"), **{
            k: rec.get(k) for k in ("arch", "shape", "mesh", "reason")}}
    n_dev = rec["n_devices"]
    if axis_n is None:
        axis_n = 16  # largest mesh axis (16x16 / 2x16x16)
    flops = rec["cost"].get("flops", 0.0)
    bytes_acc = rec["cost"].get("bytes accessed", 0.0)
    wire = 0.0
    for kind, ent in rec.get("collectives", {}).items():
        wire += _WIRE_FACTOR[kind](axis_n) * ent["bytes"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["meta"], n_dev)
    step_time = max(terms.values())
    useful_frac = mf / PEAK_FLOPS / step_time if step_time > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok",
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops": flops, "hlo_bytes": bytes_acc, "wire_bytes": wire,
        "model_flops": mf,
        "model_hlo_ratio": (mf / flops) if flops else 0.0,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        # roofline fraction: useful model FLOP/s achieved at the bound
        # implied by the dominant term, relative to peak compute.
        "roofline_fraction": useful_frac,
        "n_microbatches": rec["meta"].get("n_microbatches", 1),
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["model_hlo_ratio"] < 0.5:
            return ("compute-bound with low useful fraction: cut remat "
                    "recompute / attention overcompute (chunked prefix "
                    "instead of masked-full)")
        return ("compute-bound near useful peak: only larger per-chip "
                "batch or lower-precision MXU paths move this")
    if d == "memory":
        return ("memory-bound: shrink bytes/step — FP8 residuals & KV "
                "(1B vs 2B), fuse quantize epilogue, larger per-step "
                "arithmetic intensity (bigger microbatch)")
    return ("collective-bound: overlap collectives with compute, shard to "
            "reduce gather volume (SP), compress gradient/KV wire bytes "
            "to FP8")


def extrapolate_probes(p1: dict, p2: dict, scan: dict) -> Optional[dict]:
    """Combine 1-group and 2-group unrolled probes into a full-depth cost
    record: per-group delta = probe2 - probe1; total = probe1 +
    delta * (total_groups - 1). Meta/memory come from the full-scale scan
    record. Linear-in-depth holds because every group is structurally
    identical (same sharding, same collectives)."""
    if not (p1 and p2 and scan) or \
            any(r.get("status") != "ok" for r in (p1, p2, scan)):
        return None
    meta = scan["meta"]
    pattern_len = max(1, len(meta.get("pattern", "attn").split(",")))
    # effective group count incl. the remainder layers (fractional groups)
    groups = meta["n_layers"] / pattern_len
    if meta.get("n_encoder_layers"):
        # probes scale encoder with groups: 1 enc layer per group
        pass  # the linear model absorbs it (enc layers scale with groups)
    rec = dict(scan)  # meta, memory, mesh, arch, shape from the scan record
    rec = {**rec, "cost": {}, "collectives": {}, "unroll": True,
           "extrapolated": True}
    for k in set(p1["cost"]) | set(p2["cost"]):
        a, b = p1["cost"].get(k, 0.0), p2["cost"].get(k, 0.0)
        rec["cost"][k] = a + (b - a) * (groups - 1)
    kinds = set(p1.get("collectives", {})) | set(p2.get("collectives", {}))
    for k in kinds:
        a = p1.get("collectives", {}).get(k, {"count": 0, "bytes": 0})
        b = p2.get("collectives", {}).get(k, {"count": 0, "bytes": 0})
        rec["collectives"][k] = {
            "count": int(round(a["count"]
                               + (b["count"] - a["count"]) * (groups - 1))),
            "bytes": int(a["bytes"] + (b["bytes"] - a["bytes"])
                         * (groups - 1)),
        }
    return rec


def build_table(dryrun_dir: str, *, mesh: str = "single",
                prefer_unroll: bool = True) -> List[dict]:
    """Aggregate all records for `mesh`. Cost-number priority: full unrolled
    record > probe extrapolation > raw scan record. Memory always comes from
    the full-depth scan record."""
    d = Path(dryrun_dir)
    rows = []
    by_key: Dict[tuple, dict] = {}
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "skipped":
            by_key.setdefault((rec["arch"], rec["shape"], "skip"), rec)
            continue
        if rec.get("mesh") != mesh:
            continue
        if rec.get("probe_groups"):
            kind = f"probe{rec['probe_groups']}"
        elif rec.get("unroll"):
            kind = "unroll"
        else:
            kind = "scan"
        by_key[(rec["arch"], rec["shape"], kind)] = rec
    archs = sorted({k[0] for k in by_key})
    for arch in archs:
        shapes = sorted({k[1] for k in by_key if k[0] == arch})
        for shape in shapes:
            skip = by_key.get((arch, shape, "skip"))
            if skip is not None:
                rows.append({"arch": arch, "shape": shape,
                             "status": "skipped",
                             "reason": skip.get("reason", "")})
                continue
            un = by_key.get((arch, shape, "unroll"))
            sc = by_key.get((arch, shape, "scan"))
            ex = extrapolate_probes(by_key.get((arch, shape, "probe1")),
                                    by_key.get((arch, shape, "probe2")), sc)
            if prefer_unroll and un and un.get("status") == "ok":
                rec, src = un, "unroll"
            elif ex is not None:
                rec, src = ex, "probe-extrapolated"
            elif sc is not None:
                rec, src = sc, "scan(body x1!)"
            else:
                continue
            row = analyze_record(rec)
            if sc and sc.get("status") == "ok":
                row["peak_gib"] = sc["memory"]["peak_bytes"] / 2**30
                row["fits_16g"] = row["peak_gib"] <= 16.0
            row["cost_source"] = src
            row["suggestion"] = suggestion(row) if row.get(
                "status") == "ok" else ""
            rows.append(row)
    return rows


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | peak GiB | roofline frac | source |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                       f"| — | — | — | {r.get('reason', '')[:40]} |\n")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ? | ? | ? | "
                       f"{r.get('status')} | — | — | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['model_hlo_ratio']:.2f} | "
            f"{r.get('peak_gib', 0):.1f} | {r['roofline_fraction']:.2%} | "
            f"{r.get('cost_source')} |\n")
    return "".join(out)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = build_table(args.dir, mesh=args.mesh)
    print(to_markdown(rows))
    for r in rows:
        if r.get("status") == "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} -> {r['dominant']:10s} "
                  f"{r['suggestion'][:80]}")
