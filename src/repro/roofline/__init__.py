from repro.roofline.analysis import analyze_record, build_table

__all__ = ["analyze_record", "build_table"]
