"""Fault-tolerant checkpointing: atomic commit, async save, elastic restore.

Design for the 1000-node regime:
 * Atomic commit — a checkpoint directory is written under a tmp name and
   renamed into place; a crash mid-save can never corrupt the latest-good
   checkpoint. Restore always picks the newest *committed* step.
 * Async save — serialization happens on a background thread while training
   continues; `wait()` joins before the next save or at exit.
 * Elastic restore — leaves are stored as full (unsharded) host arrays plus
   a pytree manifest. Restoring onto a *different* mesh/device-count simply
   re-applies the new shardings via jax.device_put: grow or shrink the mesh
   between runs without conversion tooling. (On true multi-host fleets the
   per-leaf save would switch to per-host shard files + the same manifest;
   the commit protocol and manifest format already support it.)
 * keep_last_k garbage collection.

Leaves are keyed by their pytree path, so checkpoints survive superficial
model-code refactors as long as parameter names are stable.
"""
from __future__ import annotations

import atexit
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"
_COMMITTED = "COMMITTED"

_UINT_OF_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


_NATIVE = {"float16", "float32", "float64", "int8", "int16", "int32",
           "int64", "uint8", "uint16", "uint32", "uint64", "bool"}


def _to_native(v: np.ndarray) -> np.ndarray:
    """Bit-pattern view for dtypes numpy can't savez/cast (bf16, fp8)."""
    if v.dtype.name in _NATIVE:
        return v
    return v.view(_UINT_OF_WIDTH[v.dtype.itemsize])


def _from_native(v: np.ndarray, dtype_name: str) -> np.ndarray:
    if v.dtype.name == dtype_name:
        return v
    import ml_dtypes
    target = {"bfloat16": ml_dtypes.bfloat16,
              "float8_e5m2": ml_dtypes.float8_e5m2,
              "float8_e4m3fn": ml_dtypes.float8_e4m3fn}.get(dtype_name)
    if target is None:
        return v.astype(np.dtype(dtype_name))
    return v.view(target)


def _path_key(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# np.savez forbids "/" in archive names, so path keys are escaped. The v1
# scheme ("/" -> "__") collided with literal "__" in leaf names (a module
# named "w__gate" vs a nested path "w/gate" mangle identically — one leaf
# silently overwrites the other and restore mis-assigns or KeyErrors).
# v2 escapes "_" -> "_u" FIRST, so every "__" in the escaped form can only
# come from "/" and the decode ("__" -> "/" then "_u" -> "_") is exact.
_KEY_ESCAPE = "v2"


def _escape_key(key: str) -> str:
    return key.replace("_", "_u").replace("/", "__")


def _unescape_key(name: str, scheme) -> str:
    if scheme == _KEY_ESCAPE:
        return name.replace("__", "/").replace("_u", "_")
    # Legacy (pre-v2) checkpoints: lossy inverse, kept for reading old
    # manifests (which carry no "key_escape" field).
    return name.replace("__", "/")


class Checkpointer:
    def __init__(self, directory: str, *, keep_last_k: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last_k = keep_last_k
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        # Serializes the commit/GC step on the writer thread against
        # all_steps()/restore() directory scans on the main thread.
        self._lock = threading.Lock()
        # Belt and braces with the non-daemon writer thread below: a
        # process exiting right after the final save() still joins the
        # in-flight write instead of dropping the last checkpoint.
        atexit.register(self.wait)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        """Snapshot `tree` at `step`. Gathers to host, then (optionally)
        writes on a background thread."""
        self.wait()
        leaves = {}
        dtypes = {}
        flat = jax.tree_util.tree_map_with_path(
            lambda p, x: leaves.setdefault(_path_key(p), np.asarray(x)), tree)
        for k, v in leaves.items():
            dtypes[k] = str(v.dtype)
        manifest = {"step": int(step), "time": time.time(),
                    "keys": sorted(leaves), "dtypes": dtypes,
                    "key_escape": _KEY_ESCAPE,
                    "extra": extra or {}}

        def _write():
            tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            # Non-numpy-native dtypes (bf16, fp8) are stored as their bit
            # patterns (same-width uint view); the manifest records the real
            # dtype and restore views them back.
            np.savez(tmp / "leaves.npz",
                     **{_escape_key(k): _to_native(v)
                        for k, v in leaves.items()})
            (tmp / _MANIFEST).write_text(json.dumps(manifest))
            (tmp / _COMMITTED).write_text("ok")
            with self._lock:
                final = self.dir / f"step_{step:010d}"
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                self._gc_locked()

        if self.async_save:
            # Non-daemon: interpreter shutdown joins in-flight writers, so
            # a process exiting right after the final step can never drop
            # its last checkpoint (the old daemon thread could die
            # mid-write with only a .tmp dir left behind).
            self._thread = threading.Thread(target=_write, daemon=False)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        with self._lock:
            self._gc_locked()

    def _gc_locked(self):
        steps = self._all_steps_locked()
        for s in steps[:-self.keep_last_k] if self.keep_last_k else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _all_steps_locked(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / _COMMITTED).exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def all_steps(self):
        with self._lock:
            return self._all_steps_locked()

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, *, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of `target` (arrays or
        ShapeDtypeStructs). shardings: matching tree of NamedSharding (or
        None => host arrays / default placement). Elastic: shardings may
        come from a different mesh than the one that saved."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        # Hold the lock through the leaf reads: the async writer's GC must
        # not delete a just-listed step directory mid-load.
        with self._lock:
            d = self.dir / f"step_{step:010d}"
            data = np.load(d / "leaves.npz")
            man = json.loads((d / _MANIFEST).read_text())
            dtypes = man["dtypes"]
            scheme = man.get("key_escape")
            leaves = {}
            for k in data.files:
                key = _unescape_key(k, scheme)
                leaves[key] = _from_native(data[k], dtypes[key])

        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree_util.tree_leaves(shardings)
        flat_with_path, treedef = jax.tree_util.tree_flatten_with_path(target)
        out = []
        for i, (path, proto) in enumerate(flat_with_path):
            key = _path_key(path)
            if key not in leaves:
                raise KeyError(f"checkpoint step {step} missing leaf {key}")
            arr = leaves[key]
            if arr.dtype != np.dtype(proto.dtype):
                arr = arr.astype(np.dtype(proto.dtype))
            if arr.shape != tuple(proto.shape):
                raise ValueError(
                    f"leaf {key}: checkpoint shape {arr.shape} != target "
                    f"{tuple(proto.shape)}")
            if shard_flat is not None:
                out.append(jax.device_put(arr, shard_flat[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step

    def manifest(self, step: Optional[int] = None) -> dict:
        if step is None:
            step = self.latest_step()
        d = self.dir / f"step_{step:010d}"
        return json.loads((d / _MANIFEST).read_text())
