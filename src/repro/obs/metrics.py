"""Typed metrics pipeline: versioned-schema jsonl sink + rolling windows.

MetricsLogger replaces TrainLoop's inline `json.dumps`:

 * scalar/vector-aware serialization — python/np/jax scalars become floats,
   per-layer vectors (scanned-stack amax/health trajectories) become lists;
   nothing raises on a vector metric (the old `float(np.asarray(v))` bug).
 * versioned schema — every record carries `"v": SCHEMA_VERSION`; the field
   reference lives in docs/metrics_schema.md. A sidecar `<path>.meta.json`
   records the schema version plus run metadata (site registry order,
   recipe, …) WITHOUT polluting the one-record-per-step jsonl stream.
 * rolling-window aggregation — bounded deques per scalar key for
   percentile / mean queries (healthdash, straggler baselines) with no
   unbounded memory growth.

The logger is a context manager; `close()` is idempotent and flush happens
on every write (preemption may kill the process at any step).
"""
from __future__ import annotations

import collections
import json
import math
from pathlib import Path
from typing import Any, Dict, Iterable, Optional

import numpy as np

SCHEMA_VERSION = 1


def jsonable(v: Any) -> Any:
    """Scalar/vector-aware: scalars -> float/int, arrays -> (nested) lists."""
    if isinstance(v, (bool, int, str)) or v is None:
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else repr(v)
    if isinstance(v, (list, tuple)):
        return [jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: jsonable(x) for k, x in v.items()}
    arr = np.asarray(v)
    if arr.ndim == 0:
        if np.issubdtype(arr.dtype, np.integer):
            return int(arr)
        if np.issubdtype(arr.dtype, np.bool_):
            return bool(arr)
        return jsonable(float(arr))
    return [jsonable(x) for x in arr.astype(np.float64).tolist()]


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, *,
                 meta: Optional[Dict[str, Any]] = None,
                 window: int = 64):
        self.path = path
        self.window = window
        self._f = None
        self._windows: Dict[str, collections.deque] = {}
        self.n_records = 0
        if path:
            Path(path).parent.mkdir(parents=True, exist_ok=True)
            self._f = open(path, "a")
            meta_rec = {"schema_version": SCHEMA_VERSION,
                        **jsonable(meta or {})}
            Path(str(path) + ".meta.json").write_text(json.dumps(meta_rec))

    # -- sink -----------------------------------------------------------------
    def log(self, record: Dict[str, Any]) -> Dict[str, Any]:
        """Serialize + write one jsonl record; returns the serialized dict."""
        rec = {"v": SCHEMA_VERSION}
        rec.update({k: jsonable(v) for k, v in record.items()})
        for k, v in rec.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                self._windows.setdefault(
                    k, collections.deque(maxlen=self.window)).append(float(v))
        self.n_records += 1
        if self._f:
            self._f.write(json.dumps(rec) + "\n")
            self._f.flush()
        return rec

    # -- rolling windows ------------------------------------------------------
    def values(self, key: str) -> Iterable[float]:
        return tuple(self._windows.get(key, ()))

    def mean(self, key: str) -> Optional[float]:
        w = self._windows.get(key)
        return float(np.mean(w)) if w else None

    def percentile(self, key: str, q: float) -> Optional[float]:
        w = self._windows.get(key)
        return float(np.percentile(np.asarray(w), q)) if w else None

    # -- lifecycle ------------------------------------------------------------
    def flush(self):
        if self._f:
            self._f.flush()

    def close(self):
        if self._f:
            self._f.flush()
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
