"""Per-site FP8 precision-health counters.

Two observation flavors, one semantics:

 * `payload_health(data, fmt)` — host/XLA side, for tensors whose FP8
   payload is already materialized (quantized operands, fused-GEMM outputs,
   error cotangents). Reads the same `& 0x7F` bit patterns the delayed-
   scaling `_observe` amax reduction reads, so XLA fuses the counts into
   the pass that consumes the payload anyway: zero extra HBM traffic.
 * `value_counts(q, fmt, mask)` — kernel side, for tensors that never hit
   HBM (attention S/P/dP/dS tiles, fused-GEMM epilogue tiles). Counts in
   VMEM from the just-quantized values, next to the amax epilogue.

Definitions (per tensor, per use):
  saturation fraction — |q| at the format's max-normal or beyond
    (incl. inf/nan payloads): the per-tensor scale is too LARGE for the
    format's range, values are clipping (Noune et al. 2206.02915's
    format-fit signal).
  flush fraction — |q| below the format's min-normal (exact zeros and
    subnormals): values parked in (or below) the subnormal range where
    e5m2 keeps only 2 mantissa bits — the paper's Fig. 2a underflow regime.

Both are fractions of the observed region so microbatch / multi-use
averaging is well-defined.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.fp8_formats import FloatFormat, get_format

_ML_DTYPE = {"e5m2": ml_dtypes.float8_e5m2, "e4m3": ml_dtypes.float8_e4m3fn}


@functools.lru_cache(maxsize=None)
def payload_thresholds(fmt_name: str) -> Tuple[int, int]:
    """(min_normal_bits, max_normal_bits) of the |payload| (sign stripped).

    Payload magnitudes order like their bit patterns, so
      bits <  lo  <=> zero or subnormal (flush)
      bits >= hi  <=> max-normal or inf/nan (saturated)
    """
    fmt = get_format(fmt_name)
    dt = _ML_DTYPE[fmt_name]
    lo = int(np.asarray(fmt.min_normal, dt).view(np.uint8))
    hi = int(np.asarray(fmt.max_normal, dt).view(np.uint8))
    return lo, hi


def payload_health(data: jax.Array, fmt_name: str) -> jax.Array:
    """(2,) f32 [sat_frac, flush_frac] from an FP8 payload's bit patterns."""
    lo, hi = payload_thresholds(fmt_name)
    bits = jax.lax.bitcast_convert_type(data, jnp.uint8) & jnp.uint8(0x7F)
    n = jnp.float32(max(1, bits.size))
    sat = (bits >= jnp.uint8(hi)).sum().astype(jnp.float32) / n
    flush = (bits < jnp.uint8(lo)).sum().astype(jnp.float32) / n
    return jnp.stack([sat, flush])


def value_counts(q: jax.Array, fmt: FloatFormat,
                 mask: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """(sat_count, flush_count) f32 scalars from just-quantized values.

    For kernel epilogues: `q` is the quantized tile still in VMEM (any
    float dtype). `mask` restricts to the logical/observed region.
    """
    a = jnp.abs(q.astype(jnp.float32))
    sat = (a >= jnp.float32(fmt.max_normal)) | ~jnp.isfinite(a)
    flush = a < jnp.float32(fmt.min_normal)
    if mask is not None:
        sat = sat & mask
        flush = flush & mask
    return (sat.sum().astype(jnp.float32), flush.sum().astype(jnp.float32))


def counts_to_frac(counts: jax.Array) -> jax.Array:
    """(…, 3) [sat, flush, n] count triples -> (…, 2) [sat_frac, flush_frac]."""
    n = jnp.maximum(counts[..., 2], 1.0)
    return jnp.stack([counts[..., 0] / n, counts[..., 1] / n], axis=-1)
