"""Anomaly detectors over the metrics stream.

`HealthMonitor.observe(step, record)` runs after each step on the
host-side (already serialized) metrics record and returns a list of
structured `health_events` dicts — TrainLoop attaches them to the same
jsonl record, so the anomaly stream is joinable with the metric that
triggered it.

Detectors (each a paper-operational failure mode):
 * overflow        — `overflow_count` incremented: a loss-scale back-off
                     event (normal under dynamic scaling; the trajectory
                     is the Fig. 2b signal).
 * scale_floor     — an overflow landed the scale ON the enhanced
                     schedule's minimum threshold: the paper's Fig. 2b
                     mechanism engaging (needs the scaler's schedule).
 * loss_scale_flapping — >= `flap_min_changes` direction changes of the
                     loss scale inside `flap_window` steps: growth
                     interval and overflow rate are fighting.
 * saturation      — a site's saturation fraction above `sat_threshold`:
                     its per-tensor scale is too large for the format.
 * underflow       — a site's flush fraction above `flush_threshold`.
 * range_overflow  — saturation AND flush high simultaneously: the site's
                     dynamic range exceeds what ONE per-tensor scale can
                     place inside the format (per-channel scaling or a
                     wider format needed).
 * stuck_amax      — a site's amax bit-identical for `stuck_window`
                     consecutive steps (dead site / frozen-scale leak).
 * nan_amax        — a site observed a non-finite amax.
 * straggler_streak — `stragglers` incremented on `straggler_streak`
                     consecutive steps: persistent slow host, not noise.

Per-(kind, site) cooldown (`cooldown` steps) keeps a persistent condition
from emitting one event per step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

HEALTH_METRIC_PREFIX = "health/"


@dataclasses.dataclass
class HealthConfig:
    flap_window: int = 20
    flap_min_changes: int = 6
    sat_threshold: float = 0.05
    flush_threshold: float = 0.9
    stuck_window: int = 25
    straggler_streak: int = 3
    cooldown: int = 20


class HealthMonitor:
    def __init__(self, cfg: Optional[HealthConfig] = None, *,
                 site_names: Optional[Sequence[str]] = None,
                 scaler=None):
        """site_names: registry row order of the dense `health/amax_sites`
        vector (DelayedScaling.registry row order — logger meta carries the
        same list). scaler: optional LossScaler for the schedule-floor
        detector."""
        self.cfg = cfg or HealthConfig()
        self.site_names = list(site_names) if site_names else None
        self.scaler = scaler
        self._scales: List[float] = []
        self._last_overflow: Optional[float] = None
        self._amax_prev: Optional[np.ndarray] = None
        self._amax_stuck: Optional[np.ndarray] = None
        self._last_stragglers: Optional[float] = None
        self._straggler_run = 0
        self._last_emit: Dict[Any, int] = {}

    # -- helpers --------------------------------------------------------------
    def _emit(self, events, step, kind, site=None, value=None, msg=""):
        key = (kind, site)
        last = self._last_emit.get(key)
        if last is not None and step - last < self.cfg.cooldown:
            return
        self._last_emit[key] = step
        ev: Dict[str, Any] = {"step": int(step), "kind": kind}
        if site is not None:
            ev["site"] = site
        if value is not None:
            ev["value"] = float(value)
        if msg:
            ev["msg"] = msg
        events.append(ev)

    def _site(self, i: int) -> str:
        if self.site_names and i < len(self.site_names):
            return self.site_names[i]
        return f"row{i}"

    # -- main -----------------------------------------------------------------
    def observe(self, step: int, record: Dict[str, Any]) -> List[Dict]:
        events: List[Dict] = []
        cfg = self.cfg

        # overflow + schedule floor
        oc = record.get("overflow_count")
        scale = record.get("loss_scale")
        if oc is not None:
            oc = float(oc)
            if self._last_overflow is not None and oc > self._last_overflow:
                self._emit(events, step, "overflow", value=oc,
                           msg="loss-scale overflow event")
                if self.scaler is not None and scale is not None \
                        and getattr(self.scaler, "mode", "") == "enhanced":
                    floor = float(np.asarray(
                        self.scaler.min_scale_at(np.asarray(step))))
                    if floor > float(self.scaler.min_scale) \
                            and float(scale) <= floor:
                        self._emit(events, step, "scale_floor", value=floor,
                                   msg="overflow clamped to the enhanced "
                                       "min-scale schedule floor")
            self._last_overflow = oc

        # loss-scale flapping
        if scale is not None:
            self._scales.append(float(scale))
            self._scales = self._scales[-(cfg.flap_window + 1):]
            d = np.sign(np.diff(np.asarray(self._scales)))
            d = d[d != 0]
            changes = int((d[1:] != d[:-1]).sum()) if d.size > 1 else 0
            if changes >= cfg.flap_min_changes:
                self._emit(events, step, "loss_scale_flapping", value=changes,
                           msg=f"{changes} scale direction changes in "
                               f"{cfg.flap_window} steps")

        # per-site saturation / flush fractions
        for k, v in record.items():
            if not k.startswith(HEALTH_METRIC_PREFIX) or k == "health/amax_sites":
                continue
            arr = np.asarray(v, np.float64)
            if arr.ndim == 0 or arr.shape[-1] != 2:
                continue
            site = k[len(HEALTH_METRIC_PREFIX):]
            sat = float(arr[..., 0].max())
            flush = float(arr[..., 1].max())
            if sat > cfg.sat_threshold and flush > cfg.flush_threshold:
                self._emit(events, step, "range_overflow", site=site,
                           value=sat,
                           msg="saturation and flush high simultaneously: "
                               "per-tensor scaling insufficient for this site")
            elif sat > cfg.sat_threshold:
                self._emit(events, step, "saturation", site=site, value=sat)
            elif flush > cfg.flush_threshold:
                self._emit(events, step, "underflow", site=site, value=flush)

        # stuck / NaN amax (dense per-registry-row vector)
        amax = record.get("health/amax_sites")
        if amax is not None:
            amax = np.asarray(amax, np.float64).reshape(-1)
            bad = ~np.isfinite(amax)
            for i in np.nonzero(bad)[0]:
                self._emit(events, step, "nan_amax", site=self._site(i))
            if self._amax_prev is not None \
                    and amax.shape == self._amax_prev.shape:
                same = (amax == self._amax_prev) & (amax > 0) & ~bad
                self._amax_stuck = np.where(
                    same, self._amax_stuck + 1, 0)
            if self._amax_stuck is None \
                    or self._amax_stuck.shape != amax.shape:
                self._amax_stuck = np.zeros(amax.shape, np.int64)
            for i in np.nonzero(self._amax_stuck >= cfg.stuck_window)[0]:
                self._emit(events, step, "stuck_amax", site=self._site(i),
                           value=amax[i])
            self._amax_prev = amax

        # straggler streaks
        st = record.get("stragglers")
        if st is not None:
            st = float(st)
            if self._last_stragglers is not None:
                self._straggler_run = self._straggler_run + 1 \
                    if st > self._last_stragglers else 0
            if self._straggler_run >= cfg.straggler_streak:
                self._emit(events, step, "straggler_streak",
                           value=self._straggler_run)
            self._last_stragglers = st

        return events
