"""Phase spans with a perfetto-compatible trace export.

TrainLoop (and ServeEngine) wrap their phases in `Tracer.span(name)`:

  data_wait      — blocking on `next(data)` (input pipeline health)
  step_dispatch  — the jitted step call (async dispatch + host work)
  device_sync    — blocking on device results (true device time tail)
  checkpoint     — snapshot + (async) serialization handoff

Span durations feed the per-step metrics record as `span/<name>_s`; the
full event list exports as Chrome/Perfetto "trace event" JSON
(`{"traceEvents": [...]}`, "X" complete events, µs timestamps) loadable in
ui.perfetto.dev — the standard way to see data-wait vs device-time phase
structure across steps.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from pathlib import Path
from typing import Any, Dict, List, Optional


class Tracer:
    def __init__(self, path: Optional[str] = None, *, max_events: int = 200_000):
        self.path = path
        self.max_events = max_events
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._pending: Dict[str, float] = {}

    @contextlib.contextmanager
    def span(self, name: str, **args):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dur = time.perf_counter() - t0
            self._pending[name] = self._pending.get(name, 0.0) + dur
            if len(self.events) < self.max_events:
                ev = {"name": name, "ph": "X", "pid": os.getpid(), "tid": 0,
                      "ts": round((t0 - self._t0) * 1e6, 1),
                      "dur": round(dur * 1e6, 1)}
                if args:
                    ev["args"] = args
                self.events.append(ev)

    def durations(self) -> Dict[str, float]:
        """Pop the span durations accumulated since the last call — one
        step's phase breakdown, keyed `span/<name>_s`."""
        out = {f"span/{k}_s": round(v, 6) for k, v in self._pending.items()}
        self._pending = {}
        return out

    def export(self, path: Optional[str] = None) -> Optional[str]:
        path = path or self.path
        if not path:
            return None
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        Path(path).write_text(json.dumps(
            {"traceEvents": self.events,
             "displayTimeUnit": "ms"}))
        return path
