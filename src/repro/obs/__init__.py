"""Precision-health observability: counters, metrics pipeline, spans,
anomaly detectors.

The subsystem has four layers (see docs/metrics_schema.md):
 * counters  — per-site FP8 saturation / flush fractions computed from the
   same payload bit patterns the delayed-scaling epilogues already read
   (zero extra HBM passes; kernel paths count in VMEM next to amax).
 * metrics   — typed MetricsLogger: versioned-schema jsonl sink with
   scalar/vector-aware serialization and rolling-window aggregation.
 * trace     — phase spans (data-wait / step-dispatch / device-sync /
   checkpoint) with a perfetto-compatible trace export.
 * health    — anomaly detectors over the metrics stream (loss-scale
   flapping, saturation, stuck/NaN amax, straggler streaks), surfaced as
   structured `health_events` records.

Law: enabling the counters changes no computed bits — the telemetry rides
next to the training math, never inside it (parity-locked in
tests/test_obs.py).
"""
from repro.obs.counters import (counts_to_frac, payload_health,
                                payload_thresholds, value_counts)
from repro.obs.health import HealthConfig, HealthMonitor
from repro.obs.metrics import SCHEMA_VERSION, MetricsLogger
from repro.obs.trace import Tracer

__all__ = [
    "counts_to_frac", "payload_health", "payload_thresholds", "value_counts",
    "HealthConfig", "HealthMonitor",
    "SCHEMA_VERSION", "MetricsLogger",
    "Tracer",
]
