"""Nightly metrics-enabled training smoke for the obs subsystem.

  PYTHONPATH=src python -m repro.tools.obs_smoke [out_dir] [--steps N]

Runs a short delayed-scaling FP8 training with precision-health counters ON
(QuantConfig.track_health): per-site saturation/flush fractions flow from
the payload-bit readers and kernel epilogues through the metrics pipeline,
phase spans and health events land in the jsonl, and the perfetto trace
exports next to it. Artifacts (uploaded by CI, consumed by healthdash):

  <out_dir>/nightly_smoke.jsonl            one record per step
  <out_dir>/nightly_smoke.jsonl.meta.json  schema version + run meta
  <out_dir>/nightly_smoke_trace.json       perfetto trace events
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out_dir", nargs="?", default="experiments/obs")
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args(argv)
    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import paper_transformer
    from repro.core.loss_scale import LossScaler
    from repro.data import DataConfig, synthetic_lm_batches
    from repro.scaling.calibrate import (_delayed_quant_model,
                                         discover_lm_sites)
    from repro.scaling.state import DelayedScaling
    from repro.models.transformer import init_lm
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.train.step import make_optimizer_for

    cfg = paper_transformer.smoke().replace(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab_size=128, max_seq_len=32)
    cfg = _delayed_quant_model(cfg)
    q = dataclasses.replace(cfg.policy.quant, track_health=True)
    cfg = cfg.replace(policy=dataclasses.replace(cfg.policy, quant=q))

    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    proto = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32),
             "enc_inputs": jnp.zeros((B, 8, cfg.d_model), jnp.float32)}
    registry = discover_lm_sites(cfg, params, proto)
    del params
    scaling = DelayedScaling(registry, qcfg=cfg.policy.quant)
    # A deliberately huge init scale forces early overflow back-off events,
    # so the nightly artifact always exercises the overflow detector.
    opt = make_optimizer_for(cfg, name="adam", learning_rate=1e-3,
                             scaler=LossScaler(mode="dynamic",
                                               init_scale=2.0 ** 30))

    def data_at(step: int):
        it = synthetic_lm_batches(DataConfig(
            vocab_size=128, seq_len=S, batch_size=B, seed=0),
            start_step=step)
        for batch in it:
            yield {"tokens": batch["tokens"], "labels": batch["labels"],
                   "enc_inputs": jnp.zeros((B, 8, cfg.d_model), jnp.float32)}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = LoopConfig(
            total_steps=args.steps, checkpoint_every=max(4, args.steps // 2),
            checkpoint_dir=ckpt_dir, log_every=5,
            metrics_path=str(out / "nightly_smoke.jsonl"),
            trace_path=str(out / "nightly_smoke_trace.json"))
        result = TrainLoop(cfg, opt, data_at, loop, seed=0,
                           scaling=scaling).run()
    rec = result["metrics"]
    n_health = sum(k.startswith("health/") for k in rec)
    print(f"[obs_smoke] {result['last_step']} steps, "
          f"{n_health} health keys in the final record, "
          f"loss={rec.get('loss'):.4f}")
    if n_health < 3:
        print("[obs_smoke] FAIL: expected per-site health counters in the "
              "metrics record (track_health on)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
