"""Regenerate EXPERIMENTS.md from the artifacts under experiments/.

  PYTHONPATH=src python -m repro.tools.report
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.roofline.analysis import analyze_record, build_table, suggestion

DRYRUN = Path("experiments/dryrun")
BENCH = Path("experiments/bench")
PERF = Path("experiments/perf")
OBS = Path("experiments/obs")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(p: Path) -> dict:
    return json.loads(p.read_text())


def _fmt_coll(c: dict) -> str:
    if not c:
        return "—"
    return " ".join(f"{k.split('-')[0][:2]}{k.split('-')[1][:3]}:"
                    f"{v['count']}/{v['bytes'] / 2**30:.2f}G"
                    for k, v in sorted(c.items()))


def dryrun_section() -> str:
    recs = {}
    skips = {}
    for p in sorted(DRYRUN.glob("*.json")):
        r = _load(p)
        if r.get("status") == "skipped":
            skips[(r["arch"], r["shape"])] = r.get("reason", "")
        elif not r.get("unroll"):
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    archs = sorted({k[0] for k in recs})
    lines = [
        "Both meshes lower + compile for every supported cell "
        "(`.lower().compile()` on 16x16=256 and 2x16x16=512 host devices); "
        "`peak` is `memory_analysis()` per-device bytes "
        "(argument+output+temp-alias).\n",
        "| arch | shape | mesh | peak GiB | compile s | µbatch | SP | "
        "collectives (count/GiB out) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_tot = 0
    for arch in archs:
        for shape in SHAPE_ORDER:
            if (arch, shape) in skips:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                             f"SKIP: {skips[(arch, shape)][:60]} |")
                continue
            for mesh in ("single", "multi"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    continue
                n_tot += 1
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | FAIL | — | "
                                 f"— | — | {r.get('error', '')[:60]} |")
                    continue
                n_ok += 1
                m = r["meta"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | "
                    f"{r['memory']['peak_bytes'] / 2**30:.1f} | "
                    f"{r['compile_s']} | {m.get('n_microbatches', '—')} | "
                    f"{'Y' if m.get('sequence_parallel') else '—'} | "
                    f"{_fmt_coll(r.get('collectives', {}))} |")
    lines.insert(1, f"\n**{n_ok}/{n_tot} cells compile** "
                    f"({len(skips)} skipped per the long_500k rule).\n")
    return "\n".join(lines)


def roofline_section() -> str:
    rows = build_table(str(DRYRUN), mesh="single")
    lines = [
        "Per-chip roofline terms from the UNROLLED single-pod lowering "
        "(cost_analysis FLOPs/bytes are per-device; collective wire bytes "
        "from the compiled HLO with ring factors, N=16). Hardware model: "
        "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.\n",
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | peak GiB | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | "
                         f"— | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ? | ? | ? | "
                         f"{r.get('status')} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['model_hlo_ratio']:.2f} | "
            f"{r.get('peak_gib', 0):.1f} | {r['roofline_fraction']:.1%} |")
    lines.append("\nPer-cell bottleneck notes:\n")
    for r in rows:
        if r.get("status") == "ok":
            lines.append(f"- **{r['arch']} / {r['shape']}** — "
                         f"{suggestion(r)}")
    return "\n".join(lines)


def validation_section() -> str:
    out = ["Paper-claim reproduction at CPU scale (synthetic data; see "
           "benchmarks/). JSON artifacts under experiments/bench/.\n"]

    def get(name):
        p = BENCH / f"{name}.json"
        return _load(p) if p.exists() else None

    t1 = get("table1")
    if t1:
        out.append(f"**Table 1 (dynamic range)** — computed ranges match the "
                   f"paper exactly: `{t1['matches_paper']}` "
                   f"(e5m2: max 57344, min-normal 6.1e-5, "
                   f"min-subnormal 1.52e-5).")
    f2a = get("fig2a")
    if f2a:
        out.append("\n**Fig. 2a (constant loss-scale sweep, FP8 convnet)** — "
                   "paper: ResNet-50 fails at scale 1000, converges at "
                   "10000. Reduced-scale reproduction:\n")
        out.append("| scale | val acc | grad underflow frac |")
        out.append("|---|---|---|")
        for k in ["1", "1000", "4000", "10000"]:
            if k in f2a:
                out.append(f"| {k} | {f2a[k]['final_val_acc']:.3f} | "
                           f"{f2a[k]['mean_underflow_frac']:.4f} |")
    f2b = get("fig2b")
    if f2b:
        out.append("\n**Fig. 2b (enhanced dynamic scaling)** — the scheduled "
                   "minimum threshold holds the scale up after an overflow "
                   "event:\n")
        out.append("| step | scheduled floor | scale after overflow |")
        out.append("|---|---|---|")
        for t in f2b["trace"]:
            out.append(f"| {t['step']} | {t['floor']:.0f} | "
                       f"{t['scale_after_overflow']:.0f} |")
    f34 = get("fig3_fig4")
    if f34:
        out.append("\n**Fig. 3/4 (rounding vs generalization)** — paper: RNE "
                   "causes a validation gap driven by L2 growth; SR+L2 "
                   "recovers the baseline:\n")
        out.append("| run | val acc | val-train gap | final L2 |")
        out.append("|---|---|---|---|")
        for k, v in f34.items():
            out.append(f"| {k} | {v['final_val_acc']:.3f} | "
                       f"{v['val_gap']:+.3f} | "
                       f"{v['l2_trajectory'][-1]:.4f} |")
    t2 = get("table2")
    if t2:
        out.append(f"\n**Table 2 (FP8 vs FP32 accuracy)** — fp32 "
                   f"{t2['fp32']:.3f} vs fp8 {t2['fp8']:.3f} "
                   f"(delta {t2['fp8_minus_fp32']:+.3f}; paper reports FP8 "
                   f"slightly above baseline).")
    t3 = get("table3")
    if t3:
        out.append(f"\n**Table 3 (recipe comparison)** — top-1 error: "
                   f"ours(SR) {t3['ours_sr']['val_err']:.3f} vs RNE-only "
                   f"{t3['rne_only']['val_err']:.3f}. The paper finds SR "
                   f"strictly better at ImageNet/ResNet-50 scale, where "
                   f"RNE's L2 blow-up develops over many epochs; at our "
                   f"150-step CIFAR scale the single-seed gap is within "
                   f"run-to-run noise (see Fig. 3/4 rows for the matched-"
                   f"seed comparison where SR ties the FP32 baseline).")
    t4 = get("table4")
    if t4:
        out.append(f"\n**Table 4 (seq2seq parity)** — final loss fp32 "
                   f"{t4['fp32']['final_loss']:.4f} vs fp8 "
                   f"{t4['fp8']['final_loss']:.4f} "
                   f"(ratio {t4['ratio']:.3f}; paper: BLEU parity).")
    kb = get("kernels")
    if kb:
        out.append(f"\n**Kernels** — Pallas interpret-mode max abs err vs "
                   f"oracle: {kb['pallas_interpret_max_abs_err']:.2e}.")
    return "\n".join(out)


def obs_section() -> str:
    """Precision-health dashboards for every metrics stream under
    experiments/obs/ (written by the nightly metrics-enabled smoke; see
    repro.tools.healthdash for the standalone CLI)."""
    from repro.tools import healthdash
    streams = sorted(OBS.glob("*.jsonl"))
    if not streams:
        return ("_No metrics streams under experiments/obs/ — run a "
                "metrics-enabled training (LoopConfig.metrics_path) and "
                "rerun the report._")
    out = []
    for p in streams:
        records, meta = healthdash.load_metrics(str(p))
        serve_path = p.with_suffix(".serve.json")
        serve = _load(serve_path) if serve_path.exists() else None
        md = healthdash.render(records, meta, serve, title=f"`{p.stem}`")
        # demote two levels: dashboard "# title"/"## section" nest under
        # this file's "## §Observability"
        md = md.replace("\n## ", "\n#### ").replace("# ", "### ", 1)
        out.append(md)
    return "\n".join(out)


def perf_section() -> str:
    out = ["Hypothesis -> change -> measure iterations on the three chosen "
           "cells (launch/perf.py records under experiments/perf/). Terms "
           "are per-chip step seconds.\n"]
    for p in sorted(PERF.glob("*.jsonl")):
        out.append(f"### {p.stem}\n")
        out.append("| variant | compute s | memory s | collective s | "
                   "dominant | peak GiB |")
        out.append("|---|---|---|---|---|---|")
        for line in p.read_text().splitlines():
            r = json.loads(line)
            if r["status"] != "ok":
                out.append(f"| {r['variant']} | FAIL | | | | |")
                continue
            rr = r["roofline"]
            out.append(f"| {r['variant']} | {rr['compute_s']:.3e} | "
                       f"{rr['memory_s']:.3e} | {rr['collective_s']:.3e} | "
                       f"{rr['dominant']} | {rr['peak_gib']:.1f} |")
        out.append("")
    return "\n".join(out)


HEADER = """# EXPERIMENTS

Paper: *Mixed Precision Training With 8-bit Floating Point* (Mellempudi et
al., 2019). Framework: `repro` (JAX + Pallas) — see DESIGN.md for the
paper->TPU mapping and README.md for entry points.

Artifacts: `experiments/dryrun/*.json` (lower+compile records),
`experiments/bench/*.json` (paper-table reproductions),
`experiments/perf/*.jsonl` (hillclimb iterations). Regenerate this file with
`PYTHONPATH=src python -m repro.tools.report`.

Caveats on the memory numbers (documented once, applies throughout): the
dry-run compiles with the XLA *CPU* backend (512 emulated host devices).
Its buffer assignment lacks the TPU backend's memory-aware scheduling,
donation-aware while-loop carries, and fusion of dtype converts into
GEMM/collective epilogues, so `peak` figures are conservative upper bounds —
several cells a few GiB above the 16 GiB v5e budget on CPU analysis fit
under TPU compilation; every cell fits a 95 GiB v5p-class part outright.
"""


def main():
    doc = [HEADER]
    doc.append("\n## §Validation — paper-claim reproduction\n")
    doc.append(validation_section())
    doc.append("\n\n## §Dry-run — multi-pod lower/compile proof\n")
    doc.append(dryrun_section())
    doc.append("\n\n## §Roofline — three-term analysis (single pod)\n")
    doc.append(roofline_section())
    doc.append("\n\n## §Observability — precision-health telemetry\n")
    doc.append(obs_section())
    doc.append("\n\n## §Perf — hillclimb log\n")
    doc.append(perf_section())
    manual = Path("experiments/PERF_NOTES.md")
    if manual.exists():
        doc.append(manual.read_text())
    Path("EXPERIMENTS.md").write_text("\n".join(doc) + "\n")
    print("EXPERIMENTS.md regenerated")


if __name__ == "__main__":
    main()
