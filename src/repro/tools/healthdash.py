"""Render a precision-health dashboard (markdown) from a metrics jsonl.

  PYTHONPATH=src python -m repro.tools.healthdash experiments/obs/metrics.jsonl
  PYTHONPATH=src python -m repro.tools.healthdash metrics.jsonl --out dash.md
  PYTHONPATH=src python -m repro.tools.healthdash metrics.jsonl --validate

Consumes the MetricsLogger stream (one record per step, sidecar
`<path>.meta.json` for run metadata — see docs/metrics_schema.md): run
summary, step-time percentiles with the span/phase breakdown, the per-site
FP8 saturation/flush table, the health-event log, and (when a serve-stats
json is passed) the serving counters. `--validate` checks every record
against the versioned schema and exits non-zero on violations — CI runs it
over the nightly smoke's artifacts.

Doubles as a library: report.py calls `render(...)` for the EXPERIMENTS.md
observability section, tests call `validate_records(...)`.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import SCHEMA_VERSION

HEALTH_PREFIX = "health/"
COMM_PREFIX = "comm/"
# health/* keys that are NOT per-site [sat, flush] pairs: the dense per-site
# amax vector and the scalar scale-churn rate (fraction of sites whose scale
# moved this step).
_NON_PAIR_KEYS = ("health/amax_sites", "health/scale_churn")
# comm/* keys that carry strings (the wire-format name), not numbers.
_COMM_STR_KEYS = ("comm/wire",)


def load_metrics(path: str) -> Tuple[List[Dict[str, Any]], Dict[str, Any]]:
    """(records, meta) from a jsonl file and its sidecar meta json."""
    records = [json.loads(line)
               for line in Path(path).read_text().splitlines() if line]
    meta_path = Path(str(path) + ".meta.json")
    meta = json.loads(meta_path.read_text()) if meta_path.exists() else {}
    return records, meta


# ---------------------------------------------------------------------------
# schema validation (CI gate)
# ---------------------------------------------------------------------------

def validate_records(records: List[Dict[str, Any]],
                     meta: Optional[Dict[str, Any]] = None) -> List[str]:
    """Schema violations as human-readable strings ([] == valid)."""
    errors: List[str] = []
    if meta and meta.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"meta schema_version {meta.get('schema_version')!r} "
                      f"!= {SCHEMA_VERSION}")
    prev_step = None
    for i, rec in enumerate(records):
        where = f"record {i}"
        if rec.get("v") != SCHEMA_VERSION:
            errors.append(f"{where}: v={rec.get('v')!r} != {SCHEMA_VERSION}")
        if not isinstance(rec.get("step"), int):
            errors.append(f"{where}: missing/non-int 'step'")
        else:
            if prev_step is not None and rec["step"] <= prev_step:
                errors.append(f"{where}: step {rec['step']} not increasing "
                              f"(prev {prev_step})")
            prev_step = rec["step"]
        for k in ("step_time_s", "stragglers"):
            if k in rec and not isinstance(rec[k], (int, float)):
                errors.append(f"{where}: {k} not numeric")
        for k, v in rec.items():
            if k.startswith(HEALTH_PREFIX) and k not in _NON_PAIR_KEYS:
                arr = np.asarray(v, dtype=np.float64)
                if arr.shape[-1:] != (2,):
                    errors.append(f"{where}: {k} last dim != 2 "
                                  f"(shape {arr.shape})")
            if k.startswith(COMM_PREFIX) and k not in _COMM_STR_KEYS \
                    and not isinstance(v, (int, float)):
                errors.append(f"{where}: {k} not numeric ({v!r})")
        for ev in rec.get("health_events", []):
            if "kind" not in ev or "step" not in ev:
                errors.append(f"{where}: malformed health_event {ev!r}")
    return errors


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def _pct(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if len(vals) else None


def _fmt(v, spec=".4g"):
    return "—" if v is None else format(v, spec)


def _site_table(records: List[Dict[str, Any]], top: int = 12) -> List[str]:
    """Worst sites by max saturation/flush over the run. Vector-valued
    (per-layer) series reduce with max — the dashboard flags the worst
    layer; the jsonl keeps the full trajectory."""
    agg: Dict[str, Dict[str, float]] = {}
    for rec in records:
        for k, v in rec.items():
            if not k.startswith(HEALTH_PREFIX) or k in _NON_PAIR_KEYS:
                continue
            arr = np.asarray(v, np.float64).reshape(-1, 2)
            a = agg.setdefault(k[len(HEALTH_PREFIX):],
                               {"sat": 0.0, "flush": 0.0,
                                "last_sat": 0.0, "last_flush": 0.0})
            a["sat"] = max(a["sat"], float(arr[:, 0].max()))
            a["flush"] = max(a["flush"], float(arr[:, 1].max()))
            a["last_sat"] = float(arr[:, 0].max())
            a["last_flush"] = float(arr[:, 1].max())
    if not agg:
        return ["_No per-site health counters in this run "
                "(QuantConfig.track_health off)._"]
    ranked = sorted(agg.items(),
                    key=lambda kv: kv[1]["sat"] + kv[1]["flush"],
                    reverse=True)
    lines = [f"{len(agg)} sites tracked; worst {min(top, len(ranked))} by "
             "peak saturation+flush:",
             "",
             "| site | peak sat | peak flush | last sat | last flush |",
             "|---|---|---|---|---|"]
    for site, a in ranked[:top]:
        lines.append(f"| `{site}` | {a['sat']:.4f} | {a['flush']:.4f} | "
                     f"{a['last_sat']:.4f} | {a['last_flush']:.4f} |")
    return lines


def _events_section(records: List[Dict[str, Any]], cap: int = 40) -> List[str]:
    events = [ev for rec in records for ev in rec.get("health_events", [])]
    if not events:
        return ["_No health events._"]
    by_kind: Dict[str, int] = {}
    for ev in events:
        by_kind[ev["kind"]] = by_kind.get(ev["kind"], 0) + 1
    lines = [" ".join(f"`{k}`×{n}" for k, n in sorted(by_kind.items())), ""]
    for ev in events[:cap]:
        site = f" site=`{ev['site']}`" if "site" in ev else ""
        val = f" value={ev['value']:.4g}" if "value" in ev else ""
        msg = f" — {ev['msg']}" if ev.get("msg") else ""
        lines.append(f"- step {ev['step']}: **{ev['kind']}**{site}{val}{msg}")
    if len(events) > cap:
        lines.append(f"- … {len(events) - cap} more")
    return lines


def _comms_section(records: List[Dict[str, Any]],
                   meta: Optional[Dict[str, Any]] = None) -> List[str]:
    """Wire-format communication stream (distributed runs): per-step wire
    bytes of the DP gradient reduction plus the sampled span/allreduce_s
    timing probe. Absent entirely for single-device runs."""
    comm_keys = sorted({k for r in records for k in r
                        if k.startswith(COMM_PREFIX)})
    if not comm_keys:
        return []
    last = next((r for r in reversed(records)
                 if any(k in r for k in comm_keys)), {})
    dist = (meta or {}).get("dist") or {}
    lines = ["", "## Comms", ""]
    if dist:
        lines.append(
            f"- plan: dp={dist.get('dp_axes')} (size {dist.get('dp_size')}), "
            f"zero1={dist.get('zero1_axis')}, tp={dist.get('tp_axis')}, "
            f"wire={dist.get('wire')} over axis {dist.get('wire_axis')!r}")
    bps = last.get("comm/bytes_per_step")
    ratio = last.get("comm/ratio_fp8_vs_bf16")
    n_steps = sum(1 for r in records if "comm/bytes_per_step" in r)
    if isinstance(bps, (int, float)):
        lines.append(f"- DP reduction wire bytes/step: {_fmt(bps, '.4g')} "
                     f"({_fmt(bps * n_steps, '.4g')} over {n_steps} steps)")
    if isinstance(ratio, (int, float)):
        lines.append(f"- fp8_ef vs bf16 wire ratio: {_fmt(ratio, '.3f')}")
    ar = [r["span/allreduce_s"] for r in records
          if isinstance(r.get("span/allreduce_s"), (int, float))]
    if ar:
        lines.append(f"- allreduce probe: p50 {_fmt(_pct(ar, 50))} s, "
                     f"p99 {_fmt(_pct(ar, 99))} s (n={len(ar)} samples)")
    return lines


def render(records: List[Dict[str, Any]],
           meta: Optional[Dict[str, Any]] = None,
           serve_stats: Optional[Dict[str, Any]] = None,
           title: str = "Precision-health dashboard") -> str:
    meta = meta or {}
    lines = [f"# {title}", ""]
    if meta:
        bits = [f"{k}={meta[k]!r}" for k in
                ("arch", "recipe", "track_health", "n_microbatches")
                if k in meta]
        if "sites" in meta:
            bits.append(f"sites={len(meta['sites'])}")
        lines += ["Run: " + ", ".join(bits) if bits else "Run: (no meta)", ""]
    if records:
        steps = [r.get("step") for r in records]
        losses = [r["loss"] for r in records
                  if isinstance(r.get("loss"), (int, float))]
        times = [r["step_time_s"] for r in records
                 if isinstance(r.get("step_time_s"), (int, float))]
        oflow = [r["overflow_count"] for r in records
                 if isinstance(r.get("overflow_count"), (int, float))]
        lines += [
            "## Run summary", "",
            f"- steps: {len(records)} "
            f"(step {steps[0]} → {steps[-1]})",
            f"- loss: first {_fmt(losses[0] if losses else None)}, "
            f"last {_fmt(losses[-1] if losses else None)}",
            f"- overflow_count: "
            f"{_fmt(oflow[-1] if oflow else None, '.0f')}",
            f"- stragglers: "
            f"{records[-1].get('stragglers', 0)}",
            "", "## Step time", "",
            f"- p50 {_fmt(_pct(times, 50))} s, "
            f"p99 {_fmt(_pct(times, 99))} s "
            f"(n={len(times)}, compile step included)",
        ]
        span_keys = sorted({k for r in records for k in r
                            if k.startswith("span/")})
        if span_keys:
            lines += ["", "| span | mean s | p99 s |", "|---|---|---|"]
            for k in span_keys:
                vals = [r[k] for r in records
                        if isinstance(r.get(k), (int, float))]
                lines.append(
                    f"| {k[len('span/'):-2]} | "
                    f"{_fmt(float(np.mean(vals)) if vals else None)} | "
                    f"{_fmt(_pct(vals, 99))} |")
        lines += _comms_section(records, meta)
        lines += ["", "## FP8 site health", ""] + _site_table(records)
        lines += ["", "## Health events", ""] + _events_section(records)
    else:
        lines += ["_Empty metrics stream._"]
    if serve_stats:
        lines += ["", "## Serving", ""]
        lines += [
            f"- requests: {serve_stats.get('requests')} "
            f"({serve_stats.get('finished')} finished, "
            f"{serve_stats.get('active')} active)",
            f"- KV-slot occupancy: "
            f"{_fmt(serve_stats.get('kv_slot_occupancy'), '.2f')} "
            f"of max_batch={serve_stats.get('max_batch')}",
            f"- decode: {serve_stats.get('decode_tokens')} tokens at "
            f"{_fmt(serve_stats.get('decode_tokens_per_s'), '.1f')} tok/s",
        ]
        for name, label in (("prefill_latency_s", "prefill latency"),
                            ("decode_step_s", "decode step"),
                            ("request_latency_s", "request latency")):
            d = serve_stats.get(name) or {}
            lines.append(f"- {label}: p50 {_fmt(d.get('p50'))} s, "
                         f"p99 {_fmt(d.get('p99'))} s")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("metrics", help="metrics jsonl path (MetricsLogger sink)")
    ap.add_argument("--serve", help="serve-stats json (ServeEngine.stats())")
    ap.add_argument("--out", help="write markdown here (default: stdout)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate only; exit 1 on violations")
    args = ap.parse_args(argv)
    records, meta = load_metrics(args.metrics)
    if args.validate:
        errors = validate_records(records, meta)
        for e in errors:
            print(f"[healthdash] SCHEMA: {e}", file=sys.stderr)
        print(f"[healthdash] {len(records)} records, "
              f"{len(errors)} schema violations")
        return 1 if errors else 0
    serve_stats = json.loads(Path(args.serve).read_text()) \
        if args.serve else None
    md = render(records, meta, serve_stats)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(md)
        print(f"[healthdash] wrote {args.out}")
    else:
        print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
