import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Precision-flow lint CLI: run `repro.analysis.precision_lint` over the
config zoo and emit structured JSON findings + a markdown report.

Each cell is built exactly as `launch.dryrun` builds it (same mesh, same
override path), its step jaxpr is traced — never compiled — and the lint
passes check the FP8 invariants the test suite proves on toy steps:
fused-path coverage, real-f8 payloads, site-registry bijection,
token-channel widths, double-rounding chains, and analytic VMEM fit.

Usage:
  # CI tier-1 gate: the two paper configs, both recipes
  PYTHONPATH=src python -m repro.tools.lint --arch paper-transformer \
      --arch paper-resnet --shape train_4k

  # nightly: full zoo, both recipes, artifacts next to BENCH_*.json
  PYTHONPATH=src python -m repro.tools.lint --all \
      --out experiments/lint/findings.json --md experiments/lint/report.md

Exit status 1 iff any unsuppressed error-severity finding remains.

NOTE: the two os.environ lines above MUST stay the first statements — jax
locks the device count at first initialization.
"""
import argparse
import json
import time
from pathlib import Path

from repro.analysis import precision_lint as pl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (GRID_ARCHS, SHAPES, cell_supported,
                                parse_overrides)

PAPER_ARCHS = ("paper-transformer", "paper-resnet")

# The two recipes under which every cell must lint clean: the paper's
# all-e5m2 recipe and the hybrid (e4m3fn fwd / e5m2 bwd) recipe, both on
# the delayed-scaling fused-pallas path the lint's laws are about.
RECIPES = ("paper_e5m2", "hybrid")


def recipe_overrides(recipe: str) -> dict:
    return {"policy.quant.scaling": "delayed",
            "policy.quant.backend": "pallas",
            "policy.quant.recipe": recipe}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="arch to lint (repeatable); default: the two "
                         "paper configs")
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true",
                    help="full config zoo (grid archs + paper configs), "
                         "every shape")
    ap.add_argument("--recipe", default="both",
                    choices=list(RECIPES) + ["both"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--set", nargs="*", default=[], dest="overrides",
                    help="extra key=value overrides layered on top of "
                         "the recipe overrides")
    ap.add_argument("--suppressions", default=None,
                    help="suppression-rule JSON (default: the shipped "
                         "src/repro/analysis/lint_suppressions.json)")
    ap.add_argument("--out", default="experiments/lint/findings.json")
    ap.add_argument("--md", default="experiments/lint/report.md")
    args = ap.parse_args()

    if args.all:
        archs = list(GRID_ARCHS) + [a for a in PAPER_ARCHS
                                    if a not in GRID_ARCHS]
    else:
        archs = args.arch or list(PAPER_ARCHS)
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    recipes = RECIPES if args.recipe == "both" else (args.recipe,)
    user_overrides = parse_overrides(args.overrides)
    rules = pl.load_suppressions(args.suppressions)

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    findings = []
    cells = []
    t0 = time.time()
    for arch in archs:
        for shape in shapes:
            ok, why = cell_supported(arch, shape)
            if not ok:
                cells.append(dict(arch=arch, shape=shape,
                                  status="skipped", reason=why))
                print(f"[lint] SKIP {arch:24s} {shape:12s}: {why}")
                continue
            for recipe in recipes:
                cell_id = f"{arch}/{shape}@{recipe}"
                overrides = {**recipe_overrides(recipe), **user_overrides}
                t1 = time.time()
                fs = pl.lint_cell(arch, shape, mesh, overrides=overrides,
                                  cell_id=cell_id)
                fs = pl.apply_suppressions(fs, rules)
                findings.extend(fs)
                s = pl.summarize(fs)
                cells.append(dict(arch=arch, shape=shape, recipe=recipe,
                                  cell=cell_id, status="ok", **s,
                                  wall_s=round(time.time() - t1, 1)))
                badge = "FAIL" if s["error"] else "ok  "
                print(f"[lint] {badge} {cell_id:44s} "
                      f"errors={s['error']} warnings={s['warning']} "
                      f"info={s['info']} suppressed={s['suppressed']} "
                      f"({cells[-1]['wall_s']}s)")

    summary = pl.summarize(findings)
    summary["cells"] = len(cells)
    report = dict(generated_by="repro.tools.lint",
                  mesh=args.mesh, recipes=list(recipes),
                  wall_s=round(time.time() - t0, 1),
                  summary=summary, cells=cells,
                  findings=[f.to_dict() for f in findings])
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=1))
    md = Path(args.md)
    md.parent.mkdir(parents=True, exist_ok=True)
    md.write_text(pl.to_markdown(findings, summary))
    print(f"[lint] {summary['error']} error(s), {summary['warning']} "
          f"warning(s), {summary['info']} info, "
          f"{summary['suppressed']} suppressed across {len(cells)} "
          f"cell(s) -> {out} / {md}")
    raise SystemExit(1 if summary["error"] else 0)


if __name__ == "__main__":
    main()
