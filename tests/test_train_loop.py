"""Training loop: convergence, checkpoint/restart resume, preemption."""
import numpy as np
import pytest

from repro.core.loss_scale import LossScaler
from repro.data import DataConfig, synthetic_lm_batches
from repro.models.registry import build_config
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import make_optimizer_for


def _loop(tmp_path, total_steps, vocab=128, seed=0, metrics=None):
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=vocab, remat=False)
    opt = make_optimizer_for(cfg, name="adam", learning_rate=3e-3,
                             scaler=LossScaler(mode="dynamic",
                                               init_scale=128.0))
    data = synthetic_lm_batches(DataConfig(
        vocab_size=vocab, seq_len=32, batch_size=8, seed=seed))
    loop = LoopConfig(total_steps=total_steps, checkpoint_every=5,
                      checkpoint_dir=str(tmp_path / "ckpt"),
                      log_every=100, metrics_path=metrics)
    return TrainLoop(cfg, opt, data, loop, seed=seed)


def test_loss_decreases(tmp_path):
    out = _loop(tmp_path, 30).run()
    assert out["metrics"]["loss"] < np.log(128) * 0.9


def test_restart_resumes_from_checkpoint(tmp_path):
    out1 = _loop(tmp_path, 10).run()
    assert out1["last_step"] == 10
    # new loop instance, same dir: resumes at step 10, ends at 15
    lp = _loop(tmp_path, 15)
    out2 = lp.run()
    assert out2["last_step"] == 15
    assert lp.ckpt.latest_step() == 15


def test_restart_is_bitwise_continuous(tmp_path):
    """Loss at step N equals loss at step N of an uninterrupted run."""
    full = _loop(tmp_path / "a", 12).run()
    _loop(tmp_path / "b", 6).run()
    resumed = _loop(tmp_path / "b", 12).run()
    np.testing.assert_allclose(full["metrics"]["loss"],
                               resumed["metrics"]["loss"], rtol=1e-5)


def test_preemption_checkpoints_and_stops(tmp_path):
    lp = _loop(tmp_path, 100)
    lp._stop = False

    orig_fn = lp._step_fn
    calls = {"n": 0}

    def wrapped(*a):
        calls["n"] += 1
        if calls["n"] == 3:
            lp._stop = True   # simulate SIGTERM mid-run
        return orig_fn(*a)

    lp._step_fn = wrapped
    out = lp.run()
    assert out["last_step"] < 100          # stopped early
    assert lp.ckpt.latest_step() is not None   # but checkpointed first


def test_metrics_jsonl_written(tmp_path):
    mpath = str(tmp_path / "metrics.jsonl")
    _loop(tmp_path, 5, metrics=mpath).run()
    import json
    lines = [json.loads(l) for l in open(mpath)]
    assert len(lines) == 5
    assert all("loss" in l and "loss_scale" in l for l in lines)


def test_straggler_detection(tmp_path):
    import time
    lp = _loop(tmp_path, 8)
    hits = []
    lp.on_straggler = lambda step, dt: hits.append(step)
    lp.loop.straggler_factor = 1.5

    orig_fn = lp._step_fn
    calls = {"n": 0}

    def wrapped(*a):
        calls["n"] += 1
        if calls["n"] == 6:
            time.sleep(0.5)
        return orig_fn(*a)

    lp._step_fn = wrapped
    out = lp.run()
    assert out["stragglers"] >= 1
