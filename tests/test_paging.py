"""Differential serving-parity suite for the paged engine.

Locks the paged serving rebuild's guarantees against the legacy fixed-slot
engine (kept as the oracle):

  1. Paged decode is BIT-IDENTICAL to the legacy engine — with quantization
     disabled, and under frozen calibrated scales through the fused Pallas
     path for BOTH recipes (bf16 KV: the full stream matches for any chunk
     size; FP8 KV: the decode step matches given the same cache payloads).
  2. Chunked prefill == monolithic prefill for every chunk size (in-chunk
     tokens roundtrip through the pool, so the gathered layout IS the
     contiguous layout).
  3. The page allocator never aliases live pages and its accounting always
     balances (hypothesis property tests, slow-marked).
  4. A prompt that needs more pages than the pool can grant is REFUSED with
     a structured `PagesExhausted` (and admission rolls back cleanly) —
     never silently truncated.
  5. An exact prefix-cache hit produces the same stream as a cold prefill.
  6. The jitted paged step syncs ONE token id per row — its jaxpr has no
     vocab-dim output (no per-token host logits transfer).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyputil import given, settings, st

from repro.core.precision_policy import (BASELINE_POLICY, PrecisionPolicy,
                                         QuantConfig)
from repro.models.config import ModelConfig
from repro.models.registry import build_config
from repro.models.transformer import init_lm
from repro.scaling import context as sc
from repro.scaling.calibrate import calibrate, freeze
from repro.scaling.state import ScalingConfig
from repro.serve import (PagedServeConfig, PagedServeEngine, PageAllocator,
                         PagesExhausted, ServeConfig, ServeEngine)
from repro.serve.paging import TRASH_PAGE, flat_slots, gather_plan
from repro.serve.prefix_cache import PrefixCache, scale_fingerprint

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# PageAllocator unit + property tests
# ---------------------------------------------------------------------------

class TestPageAllocator:
    def test_deterministic_ascending_handout(self):
        a = PageAllocator(8, 4)
        assert a.alloc(3) == [1, 2, 3]
        a.release([2])
        a.release([1])
        # freed pages come back in ascending order too
        assert a.alloc(3) == [1, 2, 4]

    def test_trash_page_never_handed_out(self):
        a = PageAllocator(4, 2)
        assert TRASH_PAGE not in a.alloc(3)
        with pytest.raises(AssertionError):
            a.release([TRASH_PAGE])

    def test_all_or_nothing_refusal(self):
        a = PageAllocator(5, 8)
        a.alloc(2)
        free_before = a.n_free
        with pytest.raises(PagesExhausted) as ei:
            a.alloc(3)
        assert (ei.value.needed, ei.value.free) == (3, 2)
        assert (ei.value.n_pages, ei.value.page_size) == (5, 8)
        assert a.n_free == free_before      # no partial grant
        a.check()

    def test_refcount_sharing(self):
        a = PageAllocator(4, 2)
        pages = a.alloc(2)
        a.retain(pages)                     # second owner (prefix cache)
        a.release(pages)
        assert a.n_free == 1                # still held once
        a.release(pages)
        assert a.n_free == 3
        with pytest.raises(AssertionError):
            a.release([pages[0]])           # double release
        a.check()

    def test_pages_for(self):
        a = PageAllocator(8, 16)
        assert [a.pages_for(n) for n in (0, 1, 16, 17, 32)] == [0, 1, 1, 2, 2]

    @pytest.mark.slow
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["alloc", "release", "retain"]),
                              st.integers(0, 6)), max_size=60),
           st.integers(2, 9), st.integers(1, 8))
    def test_allocator_never_aliases_and_balances(self, ops, n_pages, psize):
        """Random alloc/retain/release interleavings: a page handed out is
        never simultaneously live elsewhere, refcount accounting matches a
        ground-truth shadow model, and check() always passes."""
        a = PageAllocator(n_pages, psize)
        shadow = {}                          # page -> refcount ground truth
        holdings = []                        # alloc'd page lists, refs > 0
        for op, arg in ops:
            if op == "alloc":
                try:
                    pages = a.alloc(arg)
                except PagesExhausted:
                    assert arg > a.n_free    # refusal was genuine
                    continue
                for p in pages:
                    assert p != TRASH_PAGE
                    assert shadow.get(p, 0) == 0, f"aliased live page {p}"
                    shadow[p] = 1
                if pages:
                    holdings.append(pages)
            elif holdings:
                # retain/release whole holdings, so refcounts stay uniform
                # within each list and never hit zero while still held
                h = holdings[arg % len(holdings)]
                if op == "retain":
                    a.retain(h)
                    for p in h:
                        shadow[p] += 1
                else:
                    a.release(h)
                    for p in h:
                        shadow[p] -= 1
                    if shadow[h[0]] == 0:
                        holdings.remove(h)
            a.check()
            live_truth = {p for p, c in shadow.items() if c > 0}
            assert a.n_live == len(live_truth)
            assert a.n_free == (n_pages - 1) - len(live_truth)
            assert a.stats()["page_occupancy"] == pytest.approx(
                len(live_truth) / (n_pages - 1))


# ---------------------------------------------------------------------------
# gather plans
# ---------------------------------------------------------------------------

class TestGatherPlan:
    def test_flat_slots_noncontiguous_table(self):
        # position p lives at table[p // psize] * psize + p % psize
        got = flat_slots([5, 2, 7], 4, start=2, count=8)
        expect = [22, 23, 8, 9, 10, 11, 28, 29]
        assert got.tolist() == expect

    def test_gather_plan_positions_and_holes(self):
        read, spos = gather_plan([[3, 1], [2]], [6, 2], page_size=4,
                                 capacity=8)
        # gathered column i == logical position i
        assert read[0, :6].tolist() == [12, 13, 14, 15, 4, 5]
        assert spos[0].tolist() == [0, 1, 2, 3, 4, 5, -1, -1]
        assert read[1, :2].tolist() == [8, 9]
        assert spos[1, 2:].tolist() == [-1] * 6
        # holes read the trash page (slot 0 region) and are masked by -1
        assert (read[0, 6:] == 0).all() and (read[1, 2:] == 0).all()


# ---------------------------------------------------------------------------
# prefix cache bookkeeping
# ---------------------------------------------------------------------------

class TestPrefixCache:
    def test_fingerprint_sensitivity(self):
        base = scale_fingerprint({"a#x.A": 0.5}, None, recipe="hybrid",
                                 kv_format="e5m2")
        assert base != scale_fingerprint({"a#x.A": 0.25}, None,
                                         recipe="hybrid", kv_format="e5m2")
        assert base != scale_fingerprint({"a#x.A": 0.5}, None,
                                         recipe="paper_e5m2",
                                         kv_format="e5m2")
        assert base != scale_fingerprint({"a#x.A": 0.5}, None,
                                         recipe="hybrid", kv_format=None)

    def test_shareable_pages_leaves_last_token(self):
        c = PrefixCache(PageAllocator(8, 4), "fp")
        # a prompt of exactly one page shares nothing: its last token's
        # logits seed generation and must be recomputed
        assert [c.shareable_pages(n) for n in (1, 4, 5, 8, 9)] \
            == [0, 0, 1, 1, 2]

    def test_lookup_retains_and_accounting_balances(self):
        a = PageAllocator(8, 4)
        c = PrefixCache(a, "fp")
        table = a.alloc(3)                   # 10-token prompt: 3 pages
        prompt = list(range(10))
        c.insert(prompt, table)              # cache retains table[:2]
        a.release(table)                     # request finished
        assert a.n_live == 2                 # cache still holds the prefix
        pages, n_tok = c.lookup(prompt)
        assert (pages, n_tok) == (table[:2], 8)
        assert c.hits == 1
        a.release(pages)                     # second request finished
        c.clear()
        assert a.n_free == 7 and a.n_live == 0
        a.check()

    def test_evict_for_frees_lru(self):
        a = PageAllocator(6, 4)
        c = PrefixCache(a, "fp")
        for i in range(2):
            t = a.alloc(2)
            c.insert([i * 100 + j for j in range(6)], t)
            a.release(t)
        assert a.n_free == 3                 # cache pins one page per prompt
        assert c.evict_for(5)                # forces both entries out, LRU up
        assert a.n_free == 5
        a.check()


# ---------------------------------------------------------------------------
# engine differential parity (quantization disabled: exact by construction)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=[True, False],
                ids=["scan", "unscanned"])
def baseline_setup(request):
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, policy=BASELINE_POLICY,
        scan_layers=request.param)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve_paged(cfg, params, prompts, *, max_new=4, chunk_size=8,
                 page_size=4, n_pages=48, prefix_cache=False, **kw):
    eng = PagedServeEngine(cfg, params, PagedServeConfig(
        max_batch=max(len(prompts), 1), max_len=64, n_pages=n_pages,
        page_size=page_size, chunk_size=chunk_size,
        prefix_cache=prefix_cache), **kw)
    uids = [eng.add_request(p, max_new_tokens=max_new) for p in prompts]
    out = eng.run_to_completion()
    return [out[u] for u in uids], eng


def _serve_legacy(cfg, params, prompts, *, max_new=4, **kw):
    eng = ServeEngine(cfg, params, ServeConfig(
        max_batch=max(len(prompts), 1), max_len=64), **kw)
    uids = [eng.add_request(p, max_new_tokens=max_new) for p in prompts]
    out = eng.run_to_completion()
    return [out[u] for u in uids], eng


class TestPagedLegacyParity:
    def test_paged_matches_legacy_bitwise(self, baseline_setup):
        """Same prompts, both engines, greedy: identical token streams —
        for the scanned AND unscanned stack layouts."""
        cfg, params = baseline_setup
        prompts = [np.arange(9) % cfg.vocab_size,
                   (np.arange(6) * 3 + 1) % cfg.vocab_size]
        ref, _ = _serve_legacy(cfg, params, prompts)
        got, eng = _serve_paged(cfg, params, prompts)
        assert got == ref
        # all pages returned to the pool afterwards
        assert eng.pager.n_live == 0
        eng.pager.check()

    def test_chunked_equals_monolithic_prefill(self, baseline_setup):
        """The chunk size is invisible: 1-token, ragged, and whole-prompt
        prefill chunks produce the same stream."""
        cfg, params = baseline_setup
        if not cfg.scan_layers:
            pytest.skip("layout-independent; scanned fixture covers it")
        prompts = [np.arange(11) % cfg.vocab_size]
        streams = [_serve_paged(cfg, params, prompts, chunk_size=c)[0]
                   for c in (1, 3, 16)]
        assert streams[0] == streams[1] == streams[2]

    def test_merge_slot_unscanned_regression(self, baseline_setup):
        """`_merge_slot` must slice the BATCH dim of every state leaf:
        dim 1 for scanned `stack_*` groups (leading group dim), dim 0 for
        unscanned `layer_*`/`rem_*` leaves. (The old code guessed from leaf
        rank — always dim 1 — so unscanned KV caches merged along their
        LENGTH axis: every slot kept only its first cached token and decode
        walked off garbage; caught by the paged-vs-legacy differential.)"""
        cfg, _ = baseline_setup
        if not cfg.scan_layers:
            pytest.skip("covers both layouts itself; run once")
        from repro.models.transformer import init_stack_state
        from repro.serve.engine import _merge_slot
        for scan in (True, False):
            old = init_stack_state(cfg.replace(scan_layers=scan), 2,
                                   max_len=16, n_layers=cfg.n_layers)
            new = jax.tree_util.tree_map(jnp.ones_like, old)
            merged = _merge_slot(old, new, 1)
            keys = set(merged)
            assert any(k.startswith("stack_" if scan else "layer_")
                       for k in keys), keys
            for key, sub in merged.items():
                bdim = 1 if key.startswith("stack_") else 0
                for leaf, was in zip(jax.tree_util.tree_leaves(sub),
                                     jax.tree_util.tree_leaves(old[key])):
                    if leaf.ndim <= bdim or leaf.shape[bdim] != 2:
                        continue
                    got = np.moveaxis(np.asarray(leaf, np.float32), bdim, 0)
                    before = np.moveaxis(np.asarray(was, np.float32),
                                         bdim, 0)
                    assert (got[1] == 1).all(), f"{key}: slot 1 not merged"
                    assert (got[0] == before[0]).all(), \
                        f"{key}: slot 0 clobbered (wrong batch dim)"

    def test_prefix_cache_hit_equals_cold(self, baseline_setup):
        """Second serve of the same prompt splices cached pages — and
        produces the identical stream."""
        cfg, params = baseline_setup
        if not cfg.scan_layers:
            pytest.skip("layout-independent; scanned fixture covers it")
        prompt = np.arange(13) % cfg.vocab_size
        eng = PagedServeEngine(cfg, params, PagedServeConfig(
            max_batch=1, max_len=64, n_pages=48, page_size=4,
            chunk_size=8, prefix_cache=True))
        u1 = eng.add_request(prompt, max_new_tokens=4)
        cold = eng.run_to_completion()[u1]
        u2 = eng.add_request(prompt, max_new_tokens=4)
        warm = eng.run_to_completion()[u2]
        assert warm == cold
        s = eng.stats()
        assert s["prefix_cache_hits"] == 1
        assert s["prefix_cache_hit_rate"] == pytest.approx(0.5)

    def test_pages_exhausted_refusal_and_rollback(self, baseline_setup):
        """A prompt needing more pages than allocatable is refused with the
        structured error; the engine state rolls back (slot free, allocator
        balanced) and smaller requests still admit."""
        cfg, params = baseline_setup
        if not cfg.scan_layers:
            pytest.skip("layout-independent; scanned fixture covers it")
        eng = PagedServeEngine(cfg, params, PagedServeConfig(
            max_batch=2, max_len=64, n_pages=4, page_size=4,
            chunk_size=8, prefix_cache=True))
        with pytest.raises(PagesExhausted) as ei:
            eng.add_request(np.arange(20), max_new_tokens=2)
        assert ei.value.needed == 5 and ei.value.free == 3
        assert len(eng.free_slots()) == 2       # admission rolled back
        eng.pager.check()
        assert eng.pager.n_live == 0
        uid = eng.add_request(np.arange(6), max_new_tokens=2)
        assert uid in eng.run_to_completion()

    def test_step_jaxpr_has_no_logits_output(self, baseline_setup):
        """The no-host-sync proof: the jitted step's output avals contain
        the (B,) sampled tokens and the KV pools — NO vocab-dim array ever
        crosses the jit boundary, so decode cannot be doing a per-token
        host logits transfer."""
        cfg, params = baseline_setup
        if not cfg.scan_layers:
            pytest.skip("layout-independent; scanned fixture covers it")
        eng = PagedServeEngine(cfg, params, PagedServeConfig(
            max_batch=2, max_len=64, n_pages=12, page_size=4,
            chunk_size=8))
        b, t, cap = 2, 8, eng.capacity
        sds = jnp.zeros
        batch = {"tokens": sds((b, t), jnp.int32),
                 "positions": sds((b, t), jnp.int32),
                 "write_slots": sds((b, t), jnp.int32),
                 "read_slots": sds((b, cap), jnp.int32),
                 "slot_pos": sds((b, cap), jnp.int32),
                 "chunk_pos": sds((b, 2), jnp.int32),
                 "last_row": sds((b,), jnp.int32),
                 "seeds": sds((b,), jnp.int32),
                 "steps": sds((b,), jnp.int32)}
        jaxpr = jax.make_jaxpr(
            lambda p, s, bt: eng._step.__wrapped__(p, s, bt))(
            params, eng.states, batch)
        vocab = cfg.padded_vocab_size
        bad = [a for a in jaxpr.out_avals
               if len(a.shape) >= 2 and a.shape[-1] == vocab]
        assert not bad, f"vocab-dim outputs leak from the step: {bad}"
        assert jaxpr.out_avals[0].shape == (b,)    # the sampled tokens


# ---------------------------------------------------------------------------
# frozen-scale fused parity (the production FP8 serving path, both recipes)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["hybrid", "paper_e5m2"])
def frozen_setup(request):
    """Tiny unscanned LM on the fused Pallas path (interpret backend),
    calibrated and frozen — deterministic RNE serving, bf16 KV cache (the
    configuration under which paged/legacy parity is exact for the FULL
    stream; FP8-KV chunked prefill reads payload bytes where legacy prefill
    attends raw bf16, a documented semantic difference)."""
    quant = QuantConfig(recipe=request.param, scaling="delayed",
                        backend="pallas_interpret")
    pol = PrecisionPolicy(quant=quant)
    cfg = ModelConfig(arch="t", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=64,
                      max_seq_len=64, policy=pol, remat=False,
                      scan_layers=False)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    batches = [{"tokens": jnp.asarray(rng.integers(0, 64, (2, 12)),
                                      jnp.int32)} for _ in range(2)]
    ds, state = calibrate(params, cfg, batches,
                          scaling_cfg=ScalingConfig(margin=1.0))
    return cfg, params, freeze(ds, state)


class TestFrozenFusedParity:
    def test_paged_matches_legacy_bitwise(self, frozen_setup):
        """THE acceptance criterion: under frozen calibrated scales the
        paged engine's streams are bit-identical to the legacy engine's,
        through the fused FP8 kernel, for both recipes and for decode-only
        (chunk=1) AND chunked-prefill schedules."""
        cfg, params, frozen = frozen_setup
        prompts = [np.array([3, 5, 7, 11, 13, 17, 19], np.int32),
                   np.array([2, 4, 6], np.int32)]
        ref, _ = _serve_legacy(cfg, params, prompts, max_new=4,
                               frozen_scales=frozen)
        for chunk in (1, 16):
            got, _ = _serve_paged(cfg, params, prompts, max_new=4,
                                  chunk_size=chunk,
                                  frozen_scales=frozen)
            assert got == ref, f"stream diverged at chunk_size={chunk}"

    def test_fp8_kv_decode_step_parity(self, frozen_setup):
        """FP8 KV: given the SAME cache payload bytes, the paged chunk op
        at T=1 is bitwise the legacy decode op — the paged layout adds
        nothing on top of the payloads (op-level cache injection; the
        engine-level stream comparison is bf16-KV because chunked prefill
        reads payloads where legacy prefill attends raw K/V)."""
        cfg, params, frozen = frozen_setup
        from repro.core.qattention import fp8_sdpa_chunk, fp8_sdpa_decode
        qcfg = cfg.policy.quant.eval_mode()
        qcfg = dataclasses.replace(qcfg, scaling="delayed")
        b, h, hkv, dh, c = 2, 4, 2, 16, 24
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.normal(size=(b, h, 1, dh)) * 0.3, jnp.bfloat16)
        k8, v8 = [jnp.asarray(rng.normal(size=(b, hkv, c, dh)) * 0.3,
                              jnp.bfloat16).astype(jnp.float8_e5m2)
                  for _ in range(2)]
        lengths = jnp.array([13, 20])
        scales = {f"sdpa#{n}.A": s for n, s in
                  zip(("q", "k", "v", "qk", "p"),
                      (0.5, 0.5, 0.5, 4.0, 1.0))}
        with sc.activate(sc.frozen_context(scales)):
            valid = jnp.arange(c)[None, :] < lengths[:, None]
            o_dec = fp8_sdpa_decode(q, k8, v8, valid, cfg=qcfg,
                                    sm_scale=0.25, key=jax.random.PRNGKey(3),
                                    k_cache_scale=0.7, v_cache_scale=0.9,
                                    site="sdpa")
            # paged view: positions where valid, -1 holes; q at pos len-1
            spos = jnp.where(valid, jnp.arange(c)[None, :], -1)
            cpos = jnp.stack([lengths - 1, jnp.ones_like(lengths)], 1)
            o_chunk = fp8_sdpa_chunk(q, k8, v8, spos.astype(jnp.int32),
                                     cpos.astype(jnp.int32), cfg=qcfg,
                                     sm_scale=0.25,
                                     key=jax.random.PRNGKey(3),
                                     k_cache_scale=0.7, v_cache_scale=0.9,
                                     site="sdpa")
        np.testing.assert_array_equal(
            np.asarray(o_dec).view(np.uint16),
            np.asarray(o_chunk).view(np.uint16))

    def test_prefix_cache_hit_equals_cold_frozen(self, frozen_setup):
        cfg, params, frozen = frozen_setup
        prompt = np.array([9, 8, 7, 6, 5, 4, 3, 2, 1], np.int32)
        eng = PagedServeEngine(cfg, params, PagedServeConfig(
            max_batch=1, max_len=64, n_pages=48, page_size=4,
            chunk_size=8, prefix_cache=True), frozen_scales=frozen)
        u1 = eng.add_request(prompt, max_new_tokens=3)
        cold = eng.run_to_completion()[u1]
        u2 = eng.add_request(prompt, max_new_tokens=3)
        warm = eng.run_to_completion()[u2]
        assert warm == cold
        assert eng.stats()["prefix_cache_hits"] == 1
