"""Data pipeline: determinism, shardability, learnable structure."""
import numpy as np

from repro.data import (DataConfig, synthetic_image_batches,
                        synthetic_lm_batches, synthetic_seq2seq_batches)
from repro.data.pipeline import host_shard


def test_deterministic_replay():
    cfg = DataConfig(vocab_size=128, seq_len=16, batch_size=4, seed=3)
    a = [next(synthetic_lm_batches(cfg)) for _ in range(1)][0]
    b = [next(synthetic_lm_batches(cfg)) for _ in range(1)][0]
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_restart_from_step_matches():
    cfg = DataConfig(vocab_size=128, seq_len=16, batch_size=4, seed=3)
    it = synthetic_lm_batches(cfg)
    batches = [next(it) for _ in range(5)]
    it2 = synthetic_lm_batches(cfg, start_step=3)
    np.testing.assert_array_equal(batches[3]["tokens"],
                                  next(it2)["tokens"])


def test_bigram_structure_learnable():
    """Most transitions follow the deterministic bigram map."""
    cfg = DataConfig(vocab_size=64, seq_len=64, batch_size=8, seed=0,
                     temperature=0.2)
    batch = next(synthetic_lm_batches(cfg))
    toks, labels = batch["tokens"], batch["labels"]
    from repro.data.pipeline import _bigram_params
    a, b = _bigram_params(64, 0)
    det = (a * toks + b) % 64
    frac = (det == labels).mean()
    assert frac > 0.7


def test_host_shard_is_pure_slice():
    cfg = DataConfig(vocab_size=64, seq_len=8, batch_size=8)
    batch = next(synthetic_lm_batches(cfg))
    s0 = host_shard(batch, 0, 2)
    s1 = host_shard(batch, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), batch["tokens"])


def test_seq2seq_targets_follow_source():
    cfg = DataConfig(vocab_size=64, seq_len=16, batch_size=4)
    b = next(synthetic_seq2seq_batches(cfg, d_model=32))
    assert b["enc_inputs"].shape == (4, 16, 32)
    assert b["tokens"].shape == (4, 15)


def test_images_class_dependent():
    it = synthetic_image_batches(batch_size=64, image_size=16, seed=1)
    b = next(it)
    assert b["image"].shape == (64, 16, 16, 3)
    # same-class images correlate more than cross-class
    img, lab = b["image"], b["label"]
    cls0 = img[lab == lab[0]]
    if len(cls0) >= 2:
        same = np.corrcoef(cls0[0].ravel(), cls0[1].ravel())[0, 1]
        assert same > 0.15
