"""Serving engine: continuous batching, greedy parity with the model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import BASELINE_POLICY
from repro.models.registry import build_config
from repro.models.transformer import forward, init_lm
from repro.serve import (PagedServeConfig, PagedServeEngine, ServeConfig,
                         ServeEngine)
from repro.train.step import _eval_cfg


@pytest.fixture(scope="module")
def setup():
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, policy=BASELINE_POLICY)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_greedy_matches_full_forward(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=64))
    prompt = np.arange(10) % cfg.vocab_size
    eng.add_request(prompt, max_new_tokens=5)
    out = eng.run_to_completion()
    gen = list(out.values())[0]
    assert len(gen) == 5
    # reference: greedy decode with full forward each step
    ecfg = _eval_cfg(cfg)
    toks = list(prompt)
    for t in range(5):
        logits, _, _ = forward(params, jnp.asarray([toks]), cfg=ecfg,
                               mode="train")
        nxt = int(np.asarray(logits)[0, -1, :cfg.vocab_size].argmax())
        assert nxt == gen[t], f"token {t}: {nxt} != {gen[t]}"
        toks.append(nxt)


def test_slots_recycle(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=64))
    eng.add_request(np.arange(4), max_new_tokens=3)
    eng.add_request(np.arange(5), max_new_tokens=3)
    assert not eng.free_slots()
    done = eng.run_to_completion()
    assert len(done) == 2
    assert len(eng.free_slots()) == 2
    # a third request reuses a freed slot
    uid = eng.add_request(np.arange(6), max_new_tokens=2)
    done = eng.run_to_completion()
    assert uid in done


def test_concurrent_requests_isolated(setup):
    """Two different prompts decoded together match their solo decodes."""
    cfg, params = setup
    p1 = np.arange(8) % cfg.vocab_size
    p2 = (np.arange(8) * 3 + 1) % cfg.vocab_size

    def solo(prompt):
        e = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=64))
        e.add_request(prompt, max_new_tokens=4)
        return list(e.run_to_completion().values())[0]

    ref1, ref2 = solo(p1), solo(p2)
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=64))
    u1 = eng.add_request(p1, max_new_tokens=4)
    u2 = eng.add_request(p2, max_new_tokens=4)
    out = eng.run_to_completion()
    assert out[u1] == ref1 and out[u2] == ref2


def test_fp8_kv_cache_close_to_bf16(setup):
    import dataclasses
    cfg, params = setup
    pol8 = dataclasses.replace(cfg.policy, kv_cache_format="e5m2")
    cfg8 = cfg.replace(policy=pol8)
    e16 = ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=64))
    e8 = ServeEngine(cfg8, params, ServeConfig(max_batch=1, max_len=64))
    prompt = np.arange(12) % cfg.vocab_size
    u16 = e16.add_request(prompt, max_new_tokens=8)
    u8 = e8.add_request(prompt, max_new_tokens=8)
    g16 = e16.run_to_completion()[u16]
    g8 = e8.run_to_completion()[u8]
    agree = np.mean([a == b for a, b in zip(g16, g8)])
    assert agree >= 0.5   # fp8 KV may flip argmax near-ties occasionally


# ---------------------------------------------------------------------------
# paged engine vs the legacy oracle (see tests/test_paging.py for the full
# differential suite; these lock the user-visible contracts)
# ---------------------------------------------------------------------------

def test_paged_on_device_greedy_matches_legacy_host_argmax(setup):
    """The paged engine samples greedily ON DEVICE (argmax inside the
    jitted step); the legacy engine syncs logits and argmaxes on the host.
    Same prompts => identical streams."""
    cfg, params = setup
    prompts = [np.arange(10) % cfg.vocab_size,
               (np.arange(7) * 5 + 2) % cfg.vocab_size]
    legacy = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=64))
    paged = PagedServeEngine(cfg, params, PagedServeConfig(
        max_batch=2, max_len=64, n_pages=48, page_size=4, chunk_size=8))
    uids_l = [legacy.add_request(p, max_new_tokens=5) for p in prompts]
    uids_p = [paged.add_request(p, max_new_tokens=5) for p in prompts]
    out_l = legacy.run_to_completion()
    out_p = paged.run_to_completion()
    for ul, up in zip(uids_l, uids_p):
        assert out_p[up] == out_l[ul]


def test_paged_sampled_decoding_is_reproducible(setup):
    """temperature > 0: the per-request PRNG stream is a function of
    (seed, uid, n_generated) — re-serving the same workload reproduces the
    tokens exactly, in any admission order."""
    cfg, params = setup
    prompts = [np.arange(6) % cfg.vocab_size,
               np.arange(9)[::-1] % cfg.vocab_size]

    def run(order):
        eng = PagedServeEngine(cfg, params, PagedServeConfig(
            max_batch=2, max_len=64, n_pages=48, page_size=4, chunk_size=8,
            temperature=0.8, top_k=16, top_p=0.9, seed=11))
        uids = [eng.add_request(prompts[i], max_new_tokens=6)
                for i in order]
        out = eng.run_to_completion()
        return {i: out[u] for i, u in zip(order, uids)}

    a, b = run([0, 1]), run([0, 1])
    assert a == b
    # the streams actually vary across requests (not stuck on argmax)
    assert len({tuple(v) for v in a.values()}) == 2


def test_paged_stats_shape(setup):
    cfg, params = setup
    eng = PagedServeEngine(cfg, params, PagedServeConfig(
        max_batch=2, max_len=64, n_pages=48, page_size=4, chunk_size=8))
    eng.add_request(np.arange(8), max_new_tokens=3)
    eng.run_to_completion()
    s = eng.stats()
    for k in ("requests", "finished", "decode_tokens_per_s",
              "page_occupancy", "pages_free", "prefix_cache_hit_rate",
              "request_latency_s", "slot_occupancy"):
        assert k in s, k
    assert s["finished"] == 1 and s["pages_live"] >= 0
