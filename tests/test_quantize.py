"""FP8 quantization numerics: bit-exactness, SR unbiasedness, paper Table 1."""
import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.core import fp8_formats as F
from repro.core import quantize as Q


class TestTable1:
    """Paper Table 1 dynamic ranges, exactly."""

    def test_e5m2(self):
        assert F.E5M2.max_normal == 57344.0
        assert F.E5M2.min_normal == 6.103515625e-05
        assert F.E5M2.min_subnormal == 1.52587890625e-05
        assert F.E5M2.eps == 0.25

    def test_fp16(self):
        assert F.FP16.max_normal == 65504.0
        assert F.FP16.min_subnormal == 5.960464477539063e-08

    def test_fp32(self):
        assert np.isclose(F.FP32.max_normal, 3.4028235e38)

    def test_against_ml_dtypes(self):
        fi = ml_dtypes.finfo(ml_dtypes.float8_e5m2)
        assert float(fi.max) == F.E5M2.max_normal
        assert float(fi.smallest_normal) == F.E5M2.min_normal
        assert float(fi.smallest_subnormal) == F.E5M2.min_subnormal
        fi4 = ml_dtypes.finfo(ml_dtypes.float8_e4m3fn)
        assert float(fi4.max) == F.E4M3.max_normal


class TestRNE:
    @pytest.mark.parametrize("fmt,mldt", [
        (F.E5M2, ml_dtypes.float8_e5m2),
        (F.E4M3, ml_dtypes.float8_e4m3fn),
    ])
    def test_bit_exact_vs_ml_dtypes(self, fmt, mldt):
        rng = np.random.default_rng(0)
        x = (rng.standard_normal(20000)
             * np.exp(rng.uniform(-14, 10, 20000))).astype(np.float32)
        ours = np.asarray(Q.quantize_rne(jnp.array(x), fmt, saturate=True)
                          ).astype(np.float32)
        ref = np.clip(x, -fmt.max_normal, fmt.max_normal).astype(mldt)\
            .astype(np.float32)
        np.testing.assert_array_equal(ours, ref)

    def test_overflow_to_inf_when_not_saturating(self):
        x = jnp.array([1e6, -1e6, 60000.0], jnp.float32)
        q = Q.quantize_rne(x, F.E5M2, saturate=False).astype(jnp.float32)
        assert np.isinf(q[0]) and np.isinf(q[1])
        assert q[0] > 0 and q[1] < 0

    def test_nan_passthrough(self):
        q = Q.quantize_rne(jnp.array([np.nan]), F.E5M2).astype(jnp.float32)
        assert np.isnan(q[0])


class TestStochasticRounding:
    def test_exact_values_unchanged(self):
        vals = jnp.array([0.0, 1.0, -1.25, 0.5, 57344.0, 6.103515625e-05,
                          1.52587890625e-05], jnp.float32)
        q = Q.quantize_sr_e5m2(vals, jax.random.PRNGKey(0))
        np.testing.assert_array_equal(np.asarray(q, np.float32),
                                      np.asarray(vals))

    @pytest.mark.parametrize("val,lo,hi", [
        (1.1, 1.0, 1.25),
        (-3.3, -3.5, -3.0),
        (2.2e-5, 1.52587890625e-05, 3.0517578125e-05),  # subnormal regime
    ])
    def test_rounds_to_neighbors_and_unbiased(self, val, lo, hi):
        n = 200_000
        q = Q.quantize_sr_e5m2(jnp.full((n,), val, jnp.float32),
                               jax.random.PRNGKey(1)).astype(jnp.float32)
        vals = np.unique(np.asarray(q))
        assert set(vals).issubset({np.float32(lo), np.float32(hi)})
        mean = float(q.mean())
        se = (hi - lo) / np.sqrt(n) * 3
        assert abs(mean - val) < se + 1e-7 * abs(val), (mean, val)

    def test_saturate_clamps_everything(self):
        x = jnp.array([60000.0, 70000.0, 1e20, -1e20], jnp.float32)
        q = Q.quantize_sr_e5m2(x, jax.random.PRNGKey(0), saturate=True)
        assert np.abs(np.asarray(q, np.float32)).max() <= 57344.0

    def test_no_saturate_overflows_to_inf(self):
        x = jnp.full((1000,), 60000.0, jnp.float32)
        q = Q.quantize_sr_e5m2(x, jax.random.PRNGKey(0), saturate=False)
        q = np.asarray(q, np.float32)
        # 60000 lies between 57344 and inf: SR must produce both.
        assert np.isinf(q).any() and np.isfinite(q).any()

    def test_grid_sr_e4m3_unbiased(self):
        n = 200_000
        q = Q.quantize_sr_grid(jnp.full((n,), 1.05, jnp.float32), F.E4M3,
                               jax.random.PRNGKey(2)).astype(jnp.float32)
        assert abs(float(q.mean()) - 1.05) < 3 * 0.125 / np.sqrt(n)

    @settings(max_examples=30, deadline=None)
    @given(st.floats(min_value=-5e4, max_value=5e4,
                     allow_nan=False, allow_infinity=False))
    def test_sr_lands_on_e5m2_grid(self, val):
        """Property: SR output is always exactly representable in e5m2."""
        q = Q.quantize_sr_e5m2(jnp.array([val], jnp.float32),
                               jax.random.PRNGKey(3)).astype(jnp.float32)
        back = np.asarray(q).astype(ml_dtypes.float8_e5m2).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(q), back)

    @settings(max_examples=30, deadline=None)
    @given(st.floats(min_value=1e-6, max_value=5e4,
                     allow_nan=False, allow_infinity=False))
    def test_sr_bounded_by_neighbors(self, val):
        """Property: SR never moves more than one e5m2 ulp."""
        q = np.asarray(Q.quantize_sr_e5m2(
            jnp.full((64,), val, jnp.float32),
            jax.random.PRNGKey(4))).astype(np.float32)
        down = np.float32(val).astype(ml_dtypes.float8_e5m2).astype(np.float32)
        # neighbors of the RNE value bound the SR outputs
        ulp = max(abs(down) * 0.25, F.E5M2.min_subnormal)
        assert np.all(np.abs(q - val) <= ulp + 1e-12)


class TestScaledQuant:
    def test_amax_scale_uses_full_range(self):
        x = jnp.array([1e-3, -5e-4, 2e-3], jnp.float32)
        qt = Q.quantize(x, F.E5M2, use_amax_scale=True)
        deq = Q.dequantize(qt)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(x), rtol=0.13)

    def test_fake_quant_idempotent(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        once = Q.fake_quant(x, "e5m2")
        twice = Q.fake_quant(once, "e5m2")
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))

    def test_qtensor_pytree(self):
        qt = Q.quantize(jnp.ones((4,)), F.E5M2)
        leaves = jax.tree_util.tree_leaves(qt)
        assert len(leaves) == 2
