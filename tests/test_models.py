"""Per-architecture smoke tests + model behavior (reduced configs, 1 CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import BASELINE_POLICY
from repro.models.registry import build_config, list_archs
from repro.models.transformer import (forward, init_lm, init_stack_state,
                                      lm_loss)

ARCHS = [a for a in list_archs() if a != "paper-resnet"]


def _batch(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens,
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.is_encoder_decoder:
        batch["enc_inputs"] = jax.random.normal(key, (b, 16, cfg.d_model))
    if cfg.frontend == "patch_stub":
        batch["extra_embeds"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_train_step(self, arch, rng):
        """One forward + loss + grad step on the reduced config: output
        shapes correct, everything finite."""
        cfg = build_config(arch, smoke=True)
        params = init_lm(rng, cfg)
        batch = _batch(cfg, rng)

        def loss_fn(p):
            return lm_loss(p, batch, cfg=cfg, qkey=jax.random.PRNGKey(1))[0]

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert np.isfinite(float(loss))
        assert float(loss) < 2 * np.log(cfg.vocab_size)
        for leaf in jax.tree_util.tree_leaves(grads):
            assert bool(jnp.isfinite(leaf).all())

    def test_logits_shape(self, arch, rng):
        cfg = build_config(arch, smoke=True)
        params = init_lm(rng, cfg)
        batch = _batch(cfg, rng)
        enc_out = None
        if cfg.is_encoder_decoder:
            from repro.models.transformer import encode
            enc_out = encode(params, batch["enc_inputs"], cfg=cfg,
                             qkey=jax.random.PRNGKey(2))
        logits, _, _ = forward(params, batch["tokens"], cfg=cfg, mode="train",
                               extra_embeds=batch.get("extra_embeds"),
                               enc_out=enc_out,
                               qkey=jax.random.PRNGKey(1))
        extra = cfg.n_frontend_tokens if cfg.frontend == "patch_stub" else 0
        assert logits.shape == (2, 32 + extra, cfg.padded_vocab_size)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-9b",
                                  "xlstm-125m", "seamless-m4t-large-v2"])
def test_decode_matches_train(arch, rng):
    """prefill->decode equals the full forward (baseline numerics)."""
    cfg = build_config(arch, smoke=True).replace(policy=BASELINE_POLICY)
    params = init_lm(rng, cfg)
    b, s = 2, 24
    tokens = jax.random.randint(rng, (b, s + 1), 0, cfg.vocab_size)
    enc_out = None
    if cfg.is_encoder_decoder:
        from repro.models.transformer import encode
        enc = jax.random.normal(rng, (b, 16, cfg.d_model))
        enc_out = encode(params, enc, cfg=cfg)
    states = init_stack_state(cfg, b, max_len=64, n_layers=cfg.n_layers)
    _, states, _ = forward(params, tokens[:, :s], cfg=cfg, mode="prefill",
                           states=states, enc_out=enc_out)
    pos = jnp.full((b, 1), s, jnp.int32)
    ld, _, _ = forward(params, tokens[:, s:s + 1], cfg=cfg, mode="decode",
                       states=states, positions=pos, enc_out=enc_out)
    lf, _, _ = forward(params, tokens[:, :s + 1], cfg=cfg, mode="train",
                       enc_out=enc_out)
    scale = float(jnp.abs(lf[:, -1]).max())
    assert float(jnp.abs(lf[:, -1] - ld[:, 0]).max()) < max(0.05 * scale,
                                                            0.05)


def test_moe_aux_losses_and_capacity(rng):
    cfg = build_config("dbrx-132b", smoke=True)
    from repro.models.moe import capacity, init_moe, moe_ffn
    p = init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = moe_ffn(p, x, cfg=cfg, qcfg=cfg.policy.quant,
                     qkey=jax.random.PRNGKey(1))
    assert y.shape == x.shape
    assert float(aux["lb_loss"]) > 0
    assert 0.0 <= float(aux["dropped_frac"]) < 1.0
    assert capacity(32, cfg) % 8 == 0


def test_chunked_attention_matches_dense(rng):
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        policy=BASELINE_POLICY)
    params = init_lm(rng, cfg)
    tokens = jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)
    lg_dense, _, _ = forward(params, tokens,
                             cfg=cfg.replace(attn_chunk_threshold=4096),
                             mode="train")
    lg_chunk, _, _ = forward(params, tokens,
                             cfg=cfg.replace(attn_chunk_threshold=16,
                                             attn_chunk_size=16),
                             mode="train")
    np.testing.assert_allclose(np.asarray(lg_dense, np.float32),
                               np.asarray(lg_chunk, np.float32),
                               atol=0.06, rtol=0.05)


def test_local_window_attention_masks_far_tokens(rng):
    """recurrentgemma local attention: context beyond the window is dead."""
    cfg = build_config("recurrentgemma-9b", smoke=True).replace(
        policy=BASELINE_POLICY, block_pattern=("local_attn",), n_layers=1,
        window=8)
    params = init_lm(rng, cfg)
    t1 = jax.random.randint(rng, (1, 32), 0, cfg.vocab_size)
    t2 = t1.at[0, 0:8].set((t1[0, 0:8] + 7) % cfg.vocab_size)
    l1, _, _ = forward(params, t1, cfg=cfg, mode="train")
    l2, _, _ = forward(params, t2, cfg=cfg, mode="train")
    # last position attends only to the last 8 tokens -> unchanged
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-2)


def test_vocab_padding_masked_in_loss(rng):
    cfg = build_config("seamless-m4t-large-v2", smoke=True).replace(
        vocab_size=510)   # padded to 512
    assert cfg.padded_vocab_size == 512
    params = init_lm(rng, cfg)
    batch = _batch(cfg, rng)
    loss, _ = lm_loss(params, batch, cfg=cfg, qkey=jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))
    # loss close to log(510), not log(512-with-garbage)
    assert float(loss) < 1.5 * np.log(510)
