"""Differential attention-parity suite for the fused FP8 flash-attention
Pallas path (kernels/fp8_attention + core.qattention).

Locks the three guarantees of the fused path (backend="pallas*" + delayed
scaling + QuantConfig.fuse_attention):

  1. Routing: the whole attention block lowers to Pallas calls — the S/P
     path never falls back to an XLA dot_general.
  2. Numerics: fused forward outputs, all three input grads, and every amax
     observation bit-match the unfused quantize -> matmul -> softmax ->
     quantize -> matmul composition (the `_sdpa` dataflow with the S/P Q
     nodes made explicit — kernels.fp8_attention.ref) under BOTH recipes.
  3. Invariance: outputs/grads/observations are invariant to the query
     block size, to GQA group counts, head dims, and non-divisible sequence
     lengths (zero-padding is exactly invisible; SR bits are drawn from
     absolute coordinates).

Plus: decode-mode ('kv' mask) parity, frozen-KV serving through the kernel,
and slow property tests (softmax row sums within FP8 quantization error, SR
unbiasedness of the in-kernel hash bits, chunked-vs-full causal
equivalence).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyputil import given, settings, st

from repro.core.precision_policy import ACT, ERROR, QuantConfig
from repro.core.qattention import (_bwd_factors, _fwd_factors, fp8_sdpa,
                                   fuse_attention)
from repro.core.qlinear import _quant_operand
from repro.core.quantize import fp8_amax_bits
from repro.kernels.fp8_attention import (fp8_attention_bwd,
                                         fp8_attention_bwd_ref,
                                         fp8_attention_fwd,
                                         fp8_attention_fwd_ref,
                                         sr_hash_bits)
from repro.kernels.fp8_attention import ref as attn_ref
from repro.scaling import context as sc
from repro.scaling.state import (DelayedScaling, ScalingConfig, SiteRegistry,
                                 split_observations)

jax.config.update("jax_platform_name", "cpu")

SM = 0.125


def _cfg(recipe):
    return QuantConfig(recipe=recipe, scaling="delayed",
                       backend="pallas_interpret")


def _site_bundle(cfg):
    keys = sc.attention_keys("s")
    reg = SiteRegistry(list(keys.values()), ("s",))
    ds = DelayedScaling(reg, ScalingConfig(), qcfg=cfg)
    return keys, reg, ds


def _qkv(b=2, h=4, hkv=2, s=100, d=64, dtype=jnp.bfloat16):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), dtype)
    return q, k, v


def _run_step(ds, state, cfg, q, k, v, key, **kw):
    """One fused step through fp8_sdpa; returns (o, (dq, dk, dv), obs)."""
    def loss(q, k, v, tokens):
        with ds.collect(state, tokens):
            o = fp8_sdpa(q, k, v, key=key, cfg=cfg, sm_scale=SM, site="s",
                         **kw)
            aux = sc.drain_aux()
        return o.astype(jnp.float32).sum(), (o, aux)

    (_, (o, aux)), grads = jax.value_and_grad(
        loss, argnums=(0, 1, 2, 3), has_aux=True)(q, k, v, ds.zero_tokens())
    obs = split_observations(dict(aux), grads[3], ds.registry)
    return o, grads[:3], obs


def _ref_composition(cfg, scales_dict, keys, q, k, v, key, *,
                     mask_mode="causal", window=0, block_q=128):
    """The unfused `_sdpa` composition with explicit S/P/dP/dS Q nodes,
    built from the same operands, per-site scales and SR draws as the fused
    path. Returns outputs, grads, and the materialized FP8 payloads the
    fused kernel never writes."""
    order = ("q", "k", "v", "s", "p", "do", "dp", "ds")
    scales = jnp.stack([jnp.float32(scales_dict[keys[n]]) for n in order])
    k_q, k_k, k_v, k_seed, k_bwd = jax.random.split(key, 5)
    q8 = _quant_operand(q, ACT, cfg, k_q, scale=scales[0])
    k8 = _quant_operand(k, ACT, cfg, k_k, scale=scales[1])
    v8 = _quant_operand(v, ACT, cfg, k_v, scale=scales[2])
    seed = jax.random.bits(k_seed, (), jnp.uint32)
    fmt_a, rnd_a = cfg.format_for(ACT), cfg.rounding_for(ACT)
    sat_a = cfg.saturate_for(ACT)
    o, amax_s, amax_p, s8, p8 = fp8_attention_fwd_ref(
        q8.data, k8.data, v8.data, seed, _fwd_factors(scales, SM),
        mask_mode=mask_mode, window=window, block_q=block_q,
        fmt_s=fmt_a, fmt_p=fmt_a, rounding_s=rnd_a, rounding_p=rnd_a,
        saturate_s=sat_a, saturate_p=sat_a)
    dy = jnp.ones(o.shape, jnp.bfloat16)   # cotangent of .sum()
    qdo = _quant_operand(dy, ERROR, cfg, k_bwd, scale=scales[5])
    dq, dk, dv, amax_dp, amax_ds, dp8, ds8 = fp8_attention_bwd_ref(
        q8.data, k8.data, v8.data, qdo.data, seed,
        _bwd_factors(scales, SM), mask_mode=mask_mode, window=window,
        block_q=block_q, fmt_s=fmt_a, fmt_p=fmt_a,
        fmt_e=cfg.format_for(ERROR), rounding_s=rnd_a, rounding_p=rnd_a,
        rounding_e=cfg.rounding_for(ERROR), saturate_s=sat_a,
        saturate_p=sat_a, saturate_e=cfg.saturate_for(ERROR))
    payloads = dict(q8=q8, k8=k8, v8=v8, qdo=qdo, s8=s8, p8=p8,
                    dp8=dp8, ds8=ds8)
    scalars = dict(amax_s=amax_s, amax_p=amax_p, amax_dp=amax_dp,
                   amax_ds=amax_ds, scales=scales)
    return o, (dq, dk, dv), payloads, scalars


def _bits(x):
    return np.asarray(x).view(np.uint8)


# ---------------------------------------------------------------------------
# 1. routing: the attention block lowers to Pallas, no XLA dots
# ---------------------------------------------------------------------------

def _count_prims(jaxpr, inside_pallas=False, counts=None):
    if counts is None:
        counts = {"pallas": 0, "outside_dot": 0}
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            counts["pallas"] += 1
        elif name == "dot_general" and not inside_pallas:
            counts["outside_dot"] += 1
        inner = inside_pallas or name == "pallas_call"
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: hasattr(x, "eqns")
                    or hasattr(x, "jaxpr")):
                if hasattr(sub, "jaxpr"):
                    _count_prims(sub.jaxpr, inner, counts)
                elif hasattr(sub, "eqns"):
                    _count_prims(sub, inner, counts)
    return counts


class TestFusedLowering:
    @pytest.mark.parametrize("recipe", ["paper_e5m2", "hybrid"])
    def test_fwd_bwd_lower_to_pallas_no_xla_dots(self, recipe):
        cfg = _cfg(recipe)
        _, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv(s=32)
        state = ds.init()

        def step(q, k, v, tokens):
            def loss(q, k, v, tokens):
                with ds.collect(state, tokens):
                    o = fp8_sdpa(q, k, v, key=jax.random.PRNGKey(2),
                                 cfg=cfg, sm_scale=SM, site="s")
                    sc.drain_aux()
                return o.astype(jnp.float32).sum()
            return jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, tokens)

        counts = _count_prims(jax.make_jaxpr(step)(
            q, k, v, ds.zero_tokens()).jaxpr)
        # One fused forward kernel + one fused backward kernel; every inner
        # product (QK^T, PV, dP, dQ, dK, dV) lives inside them.
        assert counts["pallas"] == 2, counts
        assert counts["outside_dot"] == 0, counts

    def test_attention_block_has_no_xla_dots(self):
        """The full attention block (projection qeinsums through the fused
        GEMM kernels + the flash kernel pair) leaves NO dot_general on the
        XLA side — the last FP32-bandwidth hot path is closed."""
        from repro.core.precision_policy import PrecisionPolicy
        from repro.models.attention import attention, init_attention
        from repro.models.config import ModelConfig
        quant = _cfg("hybrid")
        cfg = ModelConfig(arch="t", n_layers=1, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=64,
                          max_seq_len=32,
                          policy=PrecisionPolicy(quant=quant), remat=False)
        params = init_attention(jax.random.PRNGKey(0), cfg)
        keys = sc.attention_keys("attn/sdpa")
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64),
                              jnp.bfloat16)
        positions = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))

        def fwd(params, x):
            with sc.scope("attn"):
                y, _ = attention(params, x, cfg=cfg, qcfg=quant,
                                 qkey=jax.random.PRNGKey(2),
                                 positions=positions, mode="train")
            return y.astype(jnp.float32).sum()

        ctx = sc.discover_context()
        with sc.activate(ctx):
            jax.eval_shape(jax.grad(fwd), params, x)
        assert set(keys.values()) <= ctx.discovered
        reg = SiteRegistry(ctx.discovered, ctx.discovered_token_sites)
        ds = DelayedScaling(reg, qcfg=quant)
        state = ds.init()

        def step(params, x, tokens):
            def loss(params, x, tokens):
                with ds.collect(state, tokens):
                    out = fwd(params, x)
                    sc.drain_aux()
                return out
            return jax.grad(loss, argnums=(0, 1, 2))(params, x, tokens)

        counts = _count_prims(jax.make_jaxpr(step)(
            params, x, ds.zero_tokens()).jaxpr)
        # 4 projection qeinsums x 3 fused GEMMs + attention fwd/bwd kernels.
        assert counts["pallas"] == 14, counts
        assert counts["outside_dot"] == 0, counts

    def test_fuse_attention_predicate(self):
        cfg = _cfg("hybrid")
        assert fuse_attention(cfg)
        assert not fuse_attention(dataclasses.replace(cfg, backend="xla"))
        assert not fuse_attention(dataclasses.replace(cfg, scaling="none"))
        assert not fuse_attention(
            dataclasses.replace(cfg, fuse_attention=False))
        assert not fuse_attention(
            dataclasses.replace(cfg, quantize_attention=False))

    def test_fuse_attention_off_keeps_unfused_sdpa(self):
        """The opt-out knob: fuse_attention=False keeps the qk/pv qeinsum
        composition (its sites re-appear; no flash kernel in the jaxpr)."""
        from repro.core.precision_policy import PrecisionPolicy
        from repro.models.attention import attention, init_attention
        from repro.models.config import ModelConfig
        quant = dataclasses.replace(_cfg("hybrid"), fuse_attention=False)
        cfg = ModelConfig(arch="t", n_layers=1, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=64,
                          max_seq_len=32,
                          policy=PrecisionPolicy(quant=quant), remat=False)
        params = init_attention(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64),
                              jnp.bfloat16)
        positions = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        ctx = sc.discover_context()
        with sc.activate(ctx):
            jax.eval_shape(
                lambda p, x: attention(p, x, cfg=cfg, qcfg=quant,
                                       qkey=jax.random.PRNGKey(2),
                                       positions=positions,
                                       mode="train")[0], params, x)
        assert not any("sdpa" in k for k in ctx.discovered)
        assert any("qk#" in k for k in ctx.discovered)


# ---------------------------------------------------------------------------
# 2. bit parity with the unfused composition; observations == fp8_amax_bits
# ---------------------------------------------------------------------------

class TestFusedParity:
    @pytest.mark.parametrize("recipe", ["paper_e5m2", "hybrid"])
    def test_bit_matches_unfused_composition(self, recipe):
        """Fused fwd output, dq/dk/dv, and ALL amax observations bit-match
        the unfused composition built from the same operands, per-site
        scales and SR draws — after a warmup step so every site quantizes
        with a real history-derived scale."""
        cfg = _cfg(recipe)
        keys, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv()
        key = jax.random.PRNGKey(7)

        state = ds.init()
        _, _, obs0 = _run_step(ds, state, cfg, q, k, v, key)
        state = ds.update(state, obs0)
        o, (dq, dk, dv), obs = _run_step(ds, state, cfg, q, k, v, key)
        scales = ds.scales_dict(state)

        o_ref, (dq_r, dk_r, dv_r), pay, scal = _ref_composition(
            cfg, scales, keys, q, k, v, key)
        np.testing.assert_array_equal(_bits(o), _bits(o_ref))
        np.testing.assert_array_equal(_bits(dq),
                                      _bits(dq_r.astype(q.dtype)))
        np.testing.assert_array_equal(_bits(dk),
                                      _bits(dk_r.astype(k.dtype)))
        np.testing.assert_array_equal(_bits(dv),
                                      _bits(dv_r.astype(v.dtype)))

        # Observations == the bit-pattern reduction over the materialized
        # payloads of the unfused composition. Exact f32 equality.
        s = scal["scales"]
        expect = {
            keys["q"]: fp8_amax_bits(pay["q8"].data) * pay["q8"].scale,
            keys["k"]: fp8_amax_bits(pay["k8"].data) * pay["k8"].scale,
            keys["v"]: fp8_amax_bits(pay["v8"].data) * pay["v8"].scale,
            keys["s"]: fp8_amax_bits(pay["s8"]) * s[3],
            keys["p"]: fp8_amax_bits(pay["p8"]) * s[4],
            keys["do"]: fp8_amax_bits(pay["qdo"].data) * pay["qdo"].scale,
            keys["dp"]: fp8_amax_bits(pay["dp8"]) * s[6],
            keys["ds"]: fp8_amax_bits(pay["ds8"]) * s[7],
        }
        for kk, want in expect.items():
            assert np.float32(obs[kk]).tobytes() \
                == np.float32(want).tobytes(), kk
        # ... and agree with the ref-side fused epilogue amaxes.
        assert float(obs[keys["s"]]) == float(scal["amax_s"] * s[3])
        assert float(obs[keys["p"]]) == float(scal["amax_p"] * s[4])
        assert float(obs[keys["dp"]]) == float(scal["amax_dp"] * s[6])
        assert float(obs[keys["ds"]]) == float(scal["amax_ds"] * s[7])

    def test_sliding_window_parity(self):
        """Causal + sliding-window masking (local attention layers)."""
        cfg = _cfg("hybrid")
        keys, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv(s=64)
        key = jax.random.PRNGKey(3)
        state = ds.init()
        o, (dq, dk, dv), _ = _run_step(ds, state, cfg, q, k, v, key,
                                       window=16)
        o_ref, (dq_r, dk_r, dv_r), _, _ = _ref_composition(
            cfg, ds.scales_dict(state), keys, q, k, v, key, window=16)
        np.testing.assert_array_equal(_bits(o), _bits(o_ref))
        np.testing.assert_array_equal(_bits(dq),
                                      _bits(dq_r.astype(q.dtype)))
        np.testing.assert_array_equal(_bits(dk),
                                      _bits(dk_r.astype(k.dtype)))
        np.testing.assert_array_equal(_bits(dv),
                                      _bits(dv_r.astype(v.dtype)))

    def test_full_mask_parity(self):
        """Bidirectional (encoder / cross-attention) mode."""
        cfg = _cfg("paper_e5m2")
        keys, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv(s=64)
        key = jax.random.PRNGKey(4)
        state = ds.init()
        o, grads, _ = _run_step(ds, state, cfg, q, k, v, key,
                                mask_mode="full")
        o_ref, grads_r, _, _ = _ref_composition(
            cfg, ds.scales_dict(state), keys, q, k, v, key,
            mask_mode="full")
        np.testing.assert_array_equal(_bits(o), _bits(o_ref))
        for g, gr, prim in zip(grads, grads_r, (q, k, v)):
            np.testing.assert_array_equal(_bits(g),
                                          _bits(gr.astype(prim.dtype)))


# ---------------------------------------------------------------------------
# 3. tiling invariance: GQA groups, head dims, block sizes, ragged lengths
# ---------------------------------------------------------------------------

class TestTilingInvariance:
    @pytest.mark.parametrize("h,hkv,s,d", [
        (4, 4, 128, 64),    # MHA, divisible
        (4, 2, 100, 64),    # GQA 2, ragged seq
        (4, 1, 130, 40),    # GQA 4, ragged seq + ragged head dim
        (2, 2, 64, 128),    # full-lane head dim
    ])
    @pytest.mark.parametrize("rounding", ["rne", "sr"])
    def test_fwd_invariant_to_block_q_and_matches_ref(self, h, hkv, s, d,
                                                      rounding):
        """Outputs and amaxes are bit-identical across query block sizes
        (LANE-stepped reductions + absolute-coordinate SR bits) and to the
        unfused oracle at every block size."""
        dt = jnp.float8_e4m3fn
        q8 = (jax.random.normal(jax.random.PRNGKey(1), (2, h, s, d))
              * 0.3).astype(dt)
        k8 = (jax.random.normal(jax.random.PRNGKey(2), (2, hkv, s, d))
              * 0.3).astype(dt)
        v8 = (jax.random.normal(jax.random.PRNGKey(3), (2, hkv, s, d))
              * 0.3).astype(dt)
        seed = jnp.uint32(42)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s="e4m3", fmt_p="e4m3",
                  rounding_s=rounding, rounding_p=rounding)
        outs = []
        for bq in (128, 32, 8):
            o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                            block_q=bq, interpret=True,
                                            **kw)
            outs.append((_bits(o), float(a_s), float(a_p)))
        for got in outs[1:]:
            np.testing.assert_array_equal(got[0], outs[0][0])
            assert got[1:] == outs[0][1:]
        ro, ra_s, ra_p, _, _ = fp8_attention_fwd_ref(q8, k8, v8, seed, scal,
                                                     **kw)
        np.testing.assert_array_equal(outs[0][0], _bits(ro))
        assert outs[0][1:] == (float(ra_s), float(ra_p))

    @pytest.mark.parametrize("h,hkv,s,d", [
        (4, 2, 100, 40),
        (4, 1, 130, 64),
    ])
    def test_bwd_matches_ref(self, h, hkv, s, d):
        q8 = (jax.random.normal(jax.random.PRNGKey(1), (2, h, s, d))
              * 0.3).astype(jnp.float8_e4m3fn)
        k8 = (jax.random.normal(jax.random.PRNGKey(2), (2, hkv, s, d))
              * 0.3).astype(jnp.float8_e4m3fn)
        v8 = (jax.random.normal(jax.random.PRNGKey(3), (2, hkv, s, d))
              * 0.3).astype(jnp.float8_e4m3fn)
        do8 = (jax.random.normal(jax.random.PRNGKey(4), (2, h, s, d))
               * 0.2).astype(jnp.float8_e5m2)
        seed = jnp.uint32(9)
        scal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                          0.05], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s="e4m3", fmt_p="e4m3",
                  fmt_e="e5m2", rounding_s="sr", rounding_p="sr",
                  rounding_e="sr", saturate_e=False)
        dq, dk, dv, adp, ads = fp8_attention_bwd(q8, k8, v8, do8, seed,
                                                 scal, interpret=True, **kw)
        rdq, rdk, rdv, radp, rads, _, _ = fp8_attention_bwd_ref(
            q8, k8, v8, do8, seed, scal, **kw)
        np.testing.assert_array_equal(np.asarray(dq), np.asarray(rdq))
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(rdk))
        np.testing.assert_array_equal(np.asarray(dv), np.asarray(rdv))
        assert (float(adp), float(ads)) == (float(radp), float(rads))

    def test_padding_invariance(self):
        """A ragged sequence gives bitwise the same logical results as the
        same data embedded in a longer zero-padded buffer would: padding
        contributions are exactly 0.0 and masked out of observations."""
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                         (1, 2, 100, 64)) * 0.3).astype(
            jnp.float8_e5m2) for i in range(3)]
        seed = jnp.uint32(5)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s="e5m2", fmt_p="e5m2",
                  rounding_s="sr", rounding_p="sr")
        o1, s1, p1 = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                       interpret=True, **kw)
        # ref pads to the next LANE multiple internally; a different
        # (larger) padding must not change logical results
        ro, rs, rp, _, _ = fp8_attention_fwd_ref(q8, k8, v8, seed, scal,
                                                 block_q=64, **kw)
        np.testing.assert_array_equal(_bits(o1), _bits(ro))
        assert (float(s1), float(p1)) == (float(rs), float(rp))


# ---------------------------------------------------------------------------
# decode ('kv' mask) + frozen-KV serving through the kernel
# ---------------------------------------------------------------------------

class TestDecode:
    def test_kv_mask_parity(self):
        """Decode-style ('kv' validity mask) forward matches the oracle."""
        q8 = (jax.random.normal(jax.random.PRNGKey(1), (2, 4, 1, 64))
              * 0.3).astype(jnp.float8_e5m2)
        k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i), (2, 2, 40, 64))
                   * 0.3).astype(jnp.float8_e5m2) for i in (2, 3)]
        valid = (jnp.arange(40)[None, :] < jnp.array([[17], [31]]))
        seed = jnp.uint32(11)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="kv", fmt_s="e5m2", fmt_p="e5m2",
                  rounding_s="rne", rounding_p="rne")
        o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                        kv_mask=valid.astype(jnp.int8),
                                        interpret=True, **kw)
        ro, rs, rp, _, _ = fp8_attention_fwd_ref(
            q8, k8, v8, seed, scal, kv_mask=valid.astype(jnp.int8), **kw)
        np.testing.assert_array_equal(_bits(o), _bits(ro))
        assert (float(a_s), float(a_p)) == (float(rs), float(rp))

    def test_frozen_serving_refuses_uncalibrated_attention_sites(self):
        """A frozen-scales file that predates the fused path (or was
        calibrated with fuse_attention=False) lacks the sdpa sites; frozen
        serving must refuse instead of burning silent unit scales into the
        in-kernel S/P Q nodes — the same failure class _kv_scales refuses
        for the FP8 KV cache."""
        cfg = _cfg("hybrid")
        q, k, v = _qkv(s=16)
        ctx = sc.frozen_context({"other#a.A": 0.5})
        with sc.activate(ctx):
            with pytest.raises(ValueError, match="sdpa#qk.A"):
                fp8_sdpa(q, k, v, key=jax.random.PRNGKey(0),
                         cfg=cfg.eval_mode(), sm_scale=SM, site="sdpa")
        good = {f"sdpa#{n}": 0.5 for n in
                ("q.A", "k.A", "v.A", "qk.A", "p.A")}
        with sc.activate(sc.frozen_context(good)):
            o = fp8_sdpa(q, k, v, key=jax.random.PRNGKey(0),
                         cfg=cfg.eval_mode(), sm_scale=SM, site="sdpa")
        assert np.isfinite(np.asarray(o, np.float32)).all()

    def test_serve_engine_fused_decode(self):
        """ServeEngine with a Pallas backend + calibrated frozen scales
        serves from the fused kernel: the FP8 KV cache payloads feed it
        directly (no dequantize->requantize), decode lowers to pallas_call,
        and generation is bitwise deterministic."""
        from repro.core.precision_policy import PrecisionPolicy
        from repro.models.config import ModelConfig
        from repro.models.transformer import init_lm
        from repro.scaling.calibrate import calibrate, freeze
        from repro.serve.engine import ServeConfig, ServeEngine
        quant = _cfg("hybrid")
        pol = PrecisionPolicy(quant=quant, kv_cache_format="e5m2")
        cfg = ModelConfig(arch="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=64,
                          max_seq_len=48, policy=pol, remat=False,
                          scan_layers=False)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        batches = [{"tokens": jnp.asarray(rng.integers(0, 64, (2, 12)),
                                          jnp.int32)} for _ in range(2)]
        ds, state = calibrate(params, cfg, batches,
                              scaling_cfg=ScalingConfig(margin=1.0))
        frozen = freeze(ds, state)
        assert any(k.endswith("sdpa#qk.A") for k in frozen)
        assert any(k.endswith("sdpa#p.A") for k in frozen)

        def generate():
            eng = ServeEngine(cfg, params,
                              ServeConfig(max_batch=2, max_len=32),
                              frozen_scales=frozen)
            uid = eng.add_request(np.array([3, 5, 7], np.int32),
                                  max_new_tokens=4)
            return eng.run_to_completion()[uid], eng
        first, eng = generate()
        second, _ = generate()
        assert first == second and len(first) == 4
        jaxpr = str(jax.make_jaxpr(
            lambda p, b, s: eng._decode.__wrapped__(p, b, s))(
            eng.params,
            {"tokens": jnp.zeros((2, 1), jnp.int32),
             "positions": jnp.zeros((2, 1), jnp.int32)}, eng.states))
        assert "pallas_call" in jaxpr


# ---------------------------------------------------------------------------
# slow property tests (hypothesis; nightly)
# ---------------------------------------------------------------------------

def _row_sums(seed_int, s, scale_p):
    q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(seed_int + i),
                                     (1, 2, s, 32)) * 0.4).astype(
        jnp.float8_e4m3fn) for i in range(3)]
    scal = jnp.array([1.0, 1.0, 1.0 / scale_p, scale_p], jnp.float32)
    _, _, _, _, p8 = fp8_attention_fwd_ref(
        q8, k8, v8, jnp.uint32(seed_int), scal, mask_mode="causal",
        fmt_s="e4m3", fmt_p="e4m3", rounding_s="sr", rounding_p="sr")
    p = np.asarray(p8, np.float32) * scale_p
    return p.sum(axis=-1)


@pytest.mark.slow
class TestProperties:
    @given(st.integers(0, 2 ** 16), st.sampled_from([64, 100]))
    @settings(deadline=None, max_examples=10)
    def test_softmax_rows_sum_to_one_within_fp8_error(self, seed, s):
        """Dequantized fused-attention P rows sum to 1 within the FP8
        quantization error (each of <= s terms is off by at most half an
        e4m3 ulp of its magnitude; SR keeps the sum unbiased)."""
        sums = _row_sums(seed, s, 1.0 / 8.0)
        assert np.all(np.abs(sums - 1.0) < 0.15), \
            (sums.min(), sums.max())

    @given(st.integers(0, 2 ** 16))
    @settings(deadline=None, max_examples=5)
    def test_sr_on_p_is_unbiased(self, base_seed):
        """The in-kernel hash-bit SR is unbiased on the P tensor: averaging
        the quantized values over many seeds recovers the exact values to
        within CLT noise (reusing sr_fp8_via_f16 — already proven unbiased
        for uniform bits in test_formats — the property under test is that
        the COUNTER-HASH bits behave as uniform)."""
        from repro.core.fp8_formats import get_format
        from repro.core.quantize import sr_fp8_via_f16
        fmt = get_format("e4m3")
        p = jnp.linspace(0.003, 0.97, 64, dtype=jnp.float32)[None, :]
        rows = jnp.zeros((1, 1), jnp.int32)
        cols = jnp.arange(64, dtype=jnp.int32)[None, :]
        n = 400
        acc = np.zeros((1, 64), np.float64)
        for i in range(n):
            bits = sr_hash_bits(jnp.uint32(base_seed + i), attn_ref.SALT_P,
                                0, rows, cols)
            acc += np.asarray(sr_fp8_via_f16(p, bits, fmt),
                              np.float32).astype(np.float64)
        mean = acc / n
        # e4m3 ulp at |x|<1 is <= 2^-3 * x; CLT noise ~ ulp/sqrt(n)
        tol = np.maximum(np.asarray(p[0]) * 2.0 ** -3, 2.0 ** -9) \
            / np.sqrt(n) * 4.0
        assert np.all(np.abs(mean[0] - np.asarray(p)[0]) < tol)

    @given(st.integers(0, 2 ** 10))
    @settings(deadline=None, max_examples=5)
    def test_chunked_causal_equals_full_composition(self, seed):
        """Chunk-sequential causal softmax == a naive full-matrix masked
        composition (independent jnp implementation; RNE so the comparison
        is deterministic). Tolerance covers f32 reduction-order noise only.
        """
        s, d = 100, 32
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(seed + i),
                                         (1, 1, s, d)) * 0.4).astype(
            jnp.float8_e4m3fn) for i in range(3)]
        scal = jnp.array([1.0, 1.0, 8.0, 0.125], jnp.float32)
        o, _, _, s8, p8 = fp8_attention_fwd_ref(
            q8, k8, v8, jnp.uint32(0), scal, mask_mode="causal",
            fmt_s="e4m3", fmt_p="e4m3", rounding_s="rne", rounding_p="rne")
        # naive: full S8 -> masked f32 softmax -> quantized P -> PV
        from repro.core.quantize import quantize_rne
        from repro.core.fp8_formats import get_format
        fmt = get_format("e4m3")
        sf = jnp.einsum("bhqd,bhkd->bhqk", q8.astype(jnp.bfloat16),
                        k8.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        s8_naive = quantize_rne(sf * scal[0], fmt)
        mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        x = jnp.where(mask, s8_naive.astype(jnp.float32) * scal[1], -1e30)
        p = jax.nn.softmax(x, axis=-1)
        p8_naive = quantize_rne(p * scal[2], fmt)
        o_naive = jnp.einsum("bhqk,bhkd->bhqd",
                             p8_naive.astype(jnp.bfloat16),
                             v8.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32) * scal[3]
        np.testing.assert_array_equal(_bits(s8), _bits(s8_naive))
        mismatch = (_bits(p8) != _bits(p8_naive)).mean()
        assert mismatch < 0.01, mismatch   # boundary flips only
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o_naive, np.float32),
            rtol=0.1, atol=0.02)
