"""Differential attention-parity suite for the fused FP8 flash-attention
Pallas path (kernels/fp8_attention + core.qattention).

Locks the three guarantees of the fused path (backend="pallas*" + delayed
scaling + QuantConfig.fuse_attention):

  1. Routing: the whole attention block lowers to Pallas calls — the S/P
     path never falls back to an XLA dot_general.
  2. Numerics: fused forward outputs, all three input grads, and every amax
     observation bit-match the unfused quantize -> matmul -> softmax ->
     quantize -> matmul composition (the `_sdpa` dataflow with the S/P Q
     nodes made explicit — kernels.fp8_attention.ref) under BOTH recipes.
  3. Invariance: outputs/grads/observations are invariant to the query
     block size, to GQA group counts, head dims, and non-divisible sequence
     lengths (zero-padding is exactly invisible; SR bits are drawn from
     absolute coordinates).

Plus: decode-mode ('kv' mask) parity, frozen-KV serving through the kernel,
and slow property tests (softmax row sums within FP8 quantization error, SR
unbiasedness of the in-kernel hash bits, chunked-vs-full causal
equivalence).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyputil import given, settings, st

from repro.core.precision_policy import ACT, ERROR, QuantConfig
from repro.core.qattention import (_bwd_factors, _fwd_factors, fp8_sdpa,
                                   fuse_attention)
from repro.core.qlinear import _quant_operand
from repro.core.quantize import fp8_amax_bits
from repro.kernels.fp8_attention import (fp8_attention_bwd,
                                         fp8_attention_bwd_ref,
                                         fp8_attention_fwd,
                                         fp8_attention_fwd_ref,
                                         sr_hash_bits)
from repro.kernels.fp8_attention import ref as attn_ref
from repro.scaling import context as sc
from repro.scaling.state import (DelayedScaling, ScalingConfig, SiteRegistry,
                                 split_observations)

jax.config.update("jax_platform_name", "cpu")

SM = 0.125


def _cfg(recipe):
    return QuantConfig(recipe=recipe, scaling="delayed",
                       backend="pallas_interpret")


def _site_bundle(cfg):
    keys = sc.attention_keys("s")
    reg = SiteRegistry(list(keys.values()), ("s",))
    ds = DelayedScaling(reg, ScalingConfig(), qcfg=cfg)
    return keys, reg, ds


def _qkv(b=2, h=4, hkv=2, s=100, d=64, dtype=jnp.bfloat16):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), dtype)
    return q, k, v


def _run_step(ds, state, cfg, q, k, v, key, **kw):
    """One fused step through fp8_sdpa; returns (o, (dq, dk, dv), obs)."""
    def loss(q, k, v, tokens):
        with ds.collect(state, tokens):
            o = fp8_sdpa(q, k, v, key=key, cfg=cfg, sm_scale=SM, site="s",
                         **kw)
            aux = sc.drain_aux()
        return o.astype(jnp.float32).sum(), (o, aux)

    (_, (o, aux)), grads = jax.value_and_grad(
        loss, argnums=(0, 1, 2, 3), has_aux=True)(q, k, v, ds.zero_tokens())
    obs = split_observations(dict(aux), grads[3], ds.registry)
    return o, grads[:3], obs


def _ref_composition(cfg, scales_dict, keys, q, k, v, key, *,
                     mask_mode="causal", window=0, block_q=128):
    """The unfused `_sdpa` composition with explicit S/P/dP/dS Q nodes,
    built from the same operands, per-site scales and SR draws as the fused
    path. Returns outputs, grads, and the materialized FP8 payloads the
    fused kernel never writes."""
    order = ("q", "k", "v", "s", "p", "do", "dp", "ds")
    scales = jnp.stack([jnp.float32(scales_dict[keys[n]]) for n in order])
    k_q, k_k, k_v, k_seed, k_bwd = jax.random.split(key, 5)
    q8 = _quant_operand(q, ACT, cfg, k_q, scale=scales[0])
    k8 = _quant_operand(k, ACT, cfg, k_k, scale=scales[1])
    v8 = _quant_operand(v, ACT, cfg, k_v, scale=scales[2])
    seed = jax.random.bits(k_seed, (), jnp.uint32)
    fmt_a, rnd_a = cfg.format_for(ACT), cfg.rounding_for(ACT)
    sat_a = cfg.saturate_for(ACT)
    o, amax_s, amax_p, s8, p8 = fp8_attention_fwd_ref(
        q8.data, k8.data, v8.data, seed, _fwd_factors(scales, SM),
        mask_mode=mask_mode, window=window, block_q=block_q,
        fmt_s=fmt_a, fmt_p=fmt_a, rounding_s=rnd_a, rounding_p=rnd_a,
        saturate_s=sat_a, saturate_p=sat_a)
    dy = jnp.ones(o.shape, jnp.bfloat16)   # cotangent of .sum()
    qdo = _quant_operand(dy, ERROR, cfg, k_bwd, scale=scales[5])
    dq, dk, dv, amax_dp, amax_ds, dp8, ds8 = fp8_attention_bwd_ref(
        q8.data, k8.data, v8.data, qdo.data, seed,
        _bwd_factors(scales, SM), mask_mode=mask_mode, window=window,
        block_q=block_q, fmt_s=fmt_a, fmt_p=fmt_a,
        fmt_e=cfg.format_for(ERROR), rounding_s=rnd_a, rounding_p=rnd_a,
        rounding_e=cfg.rounding_for(ERROR), saturate_s=sat_a,
        saturate_p=sat_a, saturate_e=cfg.saturate_for(ERROR))
    payloads = dict(q8=q8, k8=k8, v8=v8, qdo=qdo, s8=s8, p8=p8,
                    dp8=dp8, ds8=ds8)
    scalars = dict(amax_s=amax_s, amax_p=amax_p, amax_dp=amax_dp,
                   amax_ds=amax_ds, scales=scales)
    return o, (dq, dk, dv), payloads, scalars


def _bits(x):
    return np.asarray(x).view(np.uint8)


# ---------------------------------------------------------------------------
# 1. routing: the attention block lowers to Pallas, no XLA dots
# ---------------------------------------------------------------------------

# The canonical traversal lives in repro.analysis.jaxpr_walk; the lint
# passes and these tests assert through the same walker.
from repro.analysis.jaxpr_walk import count_prims as _count_prims


class TestFusedLowering:
    @pytest.mark.parametrize("recipe", ["paper_e5m2", "hybrid"])
    def test_fwd_bwd_lower_to_pallas_no_xla_dots(self, recipe):
        cfg = _cfg(recipe)
        _, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv(s=32)
        state = ds.init()

        def step(q, k, v, tokens):
            def loss(q, k, v, tokens):
                with ds.collect(state, tokens):
                    o = fp8_sdpa(q, k, v, key=jax.random.PRNGKey(2),
                                 cfg=cfg, sm_scale=SM, site="s")
                    sc.drain_aux()
                return o.astype(jnp.float32).sum()
            return jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, tokens)

        counts = _count_prims(jax.make_jaxpr(step)(
            q, k, v, ds.zero_tokens()).jaxpr)
        # One fused forward kernel + the two streamed backward kernels
        # (stats+dQ, then dK/dV stripes); every inner product (QK^T, PV,
        # dP, dQ, dK, dV) lives inside them.
        assert counts["pallas"] == 3, counts
        assert counts["outside_dot"] == 0, counts

    def test_attention_block_has_no_xla_dots(self):
        """The full attention block (projection qeinsums through the fused
        GEMM kernels + the flash kernel pair) leaves NO dot_general on the
        XLA side — the last FP32-bandwidth hot path is closed."""
        from repro.core.precision_policy import PrecisionPolicy
        from repro.models.attention import attention, init_attention
        from repro.models.config import ModelConfig
        quant = _cfg("hybrid")
        cfg = ModelConfig(arch="t", n_layers=1, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=64,
                          max_seq_len=32,
                          policy=PrecisionPolicy(quant=quant), remat=False)
        params = init_attention(jax.random.PRNGKey(0), cfg)
        keys = sc.attention_keys("attn/sdpa")
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64),
                              jnp.bfloat16)
        positions = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))

        def fwd(params, x):
            with sc.scope("attn"):
                y, _ = attention(params, x, cfg=cfg, qcfg=quant,
                                 qkey=jax.random.PRNGKey(2),
                                 positions=positions, mode="train")
            return y.astype(jnp.float32).sum()

        ctx = sc.discover_context()
        with sc.activate(ctx):
            jax.eval_shape(jax.grad(fwd), params, x)
        assert set(keys.values()) <= ctx.discovered
        reg = SiteRegistry(ctx.discovered, ctx.discovered_token_sites)
        ds = DelayedScaling(reg, qcfg=quant)
        state = ds.init()

        def step(params, x, tokens):
            def loss(params, x, tokens):
                with ds.collect(state, tokens):
                    out = fwd(params, x)
                    sc.drain_aux()
                return out
            return jax.grad(loss, argnums=(0, 1, 2))(params, x, tokens)

        counts = _count_prims(jax.make_jaxpr(step)(
            params, x, ds.zero_tokens()).jaxpr)
        # 4 projection qeinsums x 3 fused GEMMs + the attention fwd kernel
        # + the two streamed backward kernels (stats+dQ, dK/dV).
        assert counts["pallas"] == 15, counts
        assert counts["outside_dot"] == 0, counts

    def test_fuse_attention_predicate(self):
        cfg = _cfg("hybrid")
        assert fuse_attention(cfg)
        assert not fuse_attention(dataclasses.replace(cfg, backend="xla"))
        assert not fuse_attention(dataclasses.replace(cfg, scaling="none"))
        assert not fuse_attention(
            dataclasses.replace(cfg, fuse_attention=False))
        assert not fuse_attention(
            dataclasses.replace(cfg, quantize_attention=False))

    def test_fuse_attention_off_keeps_unfused_sdpa(self):
        """The opt-out knob: fuse_attention=False keeps the qk/pv qeinsum
        composition (its sites re-appear; no flash kernel in the jaxpr)."""
        from repro.core.precision_policy import PrecisionPolicy
        from repro.models.attention import attention, init_attention
        from repro.models.config import ModelConfig
        quant = dataclasses.replace(_cfg("hybrid"), fuse_attention=False)
        cfg = ModelConfig(arch="t", n_layers=1, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=64,
                          max_seq_len=32,
                          policy=PrecisionPolicy(quant=quant), remat=False)
        params = init_attention(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64),
                              jnp.bfloat16)
        positions = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        ctx = sc.discover_context()
        with sc.activate(ctx):
            jax.eval_shape(
                lambda p, x: attention(p, x, cfg=cfg, qcfg=quant,
                                       qkey=jax.random.PRNGKey(2),
                                       positions=positions,
                                       mode="train")[0], params, x)
        assert not any("sdpa" in k for k in ctx.discovered)
        assert any("qk#" in k for k in ctx.discovered)


# ---------------------------------------------------------------------------
# 2. bit parity with the unfused composition; observations == fp8_amax_bits
# ---------------------------------------------------------------------------

class TestFusedParity:
    @pytest.mark.parametrize("recipe", ["paper_e5m2", "hybrid"])
    def test_bit_matches_unfused_composition(self, recipe):
        """Fused fwd output, dq/dk/dv, and ALL amax observations bit-match
        the unfused composition built from the same operands, per-site
        scales and SR draws — after a warmup step so every site quantizes
        with a real history-derived scale."""
        cfg = _cfg(recipe)
        keys, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv()
        key = jax.random.PRNGKey(7)

        state = ds.init()
        _, _, obs0 = _run_step(ds, state, cfg, q, k, v, key)
        state = ds.update(state, obs0)
        o, (dq, dk, dv), obs = _run_step(ds, state, cfg, q, k, v, key)
        scales = ds.scales_dict(state)

        o_ref, (dq_r, dk_r, dv_r), pay, scal = _ref_composition(
            cfg, scales, keys, q, k, v, key)
        np.testing.assert_array_equal(_bits(o), _bits(o_ref))
        np.testing.assert_array_equal(_bits(dq),
                                      _bits(dq_r.astype(q.dtype)))
        np.testing.assert_array_equal(_bits(dk),
                                      _bits(dk_r.astype(k.dtype)))
        np.testing.assert_array_equal(_bits(dv),
                                      _bits(dv_r.astype(v.dtype)))

        # Observations == the bit-pattern reduction over the materialized
        # payloads of the unfused composition. Exact f32 equality.
        s = scal["scales"]
        expect = {
            keys["q"]: fp8_amax_bits(pay["q8"].data) * pay["q8"].scale,
            keys["k"]: fp8_amax_bits(pay["k8"].data) * pay["k8"].scale,
            keys["v"]: fp8_amax_bits(pay["v8"].data) * pay["v8"].scale,
            keys["s"]: fp8_amax_bits(pay["s8"]) * s[3],
            keys["p"]: fp8_amax_bits(pay["p8"]) * s[4],
            keys["do"]: fp8_amax_bits(pay["qdo"].data) * pay["qdo"].scale,
            keys["dp"]: fp8_amax_bits(pay["dp8"]) * s[6],
            keys["ds"]: fp8_amax_bits(pay["ds8"]) * s[7],
        }
        for kk, want in expect.items():
            assert np.float32(obs[kk]).tobytes() \
                == np.float32(want).tobytes(), kk
        # ... and agree with the ref-side fused epilogue amaxes.
        assert float(obs[keys["s"]]) == float(scal["amax_s"] * s[3])
        assert float(obs[keys["p"]]) == float(scal["amax_p"] * s[4])
        assert float(obs[keys["dp"]]) == float(scal["amax_dp"] * s[6])
        assert float(obs[keys["ds"]]) == float(scal["amax_ds"] * s[7])

    def test_sliding_window_parity(self):
        """Causal + sliding-window masking (local attention layers)."""
        cfg = _cfg("hybrid")
        keys, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv(s=64)
        key = jax.random.PRNGKey(3)
        state = ds.init()
        o, (dq, dk, dv), _ = _run_step(ds, state, cfg, q, k, v, key,
                                       window=16)
        o_ref, (dq_r, dk_r, dv_r), _, _ = _ref_composition(
            cfg, ds.scales_dict(state), keys, q, k, v, key, window=16)
        np.testing.assert_array_equal(_bits(o), _bits(o_ref))
        np.testing.assert_array_equal(_bits(dq),
                                      _bits(dq_r.astype(q.dtype)))
        np.testing.assert_array_equal(_bits(dk),
                                      _bits(dk_r.astype(k.dtype)))
        np.testing.assert_array_equal(_bits(dv),
                                      _bits(dv_r.astype(v.dtype)))

    def test_full_mask_parity(self):
        """Bidirectional (encoder / cross-attention) mode."""
        cfg = _cfg("paper_e5m2")
        keys, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv(s=64)
        key = jax.random.PRNGKey(4)
        state = ds.init()
        o, grads, _ = _run_step(ds, state, cfg, q, k, v, key,
                                mask_mode="full")
        o_ref, grads_r, _, _ = _ref_composition(
            cfg, ds.scales_dict(state), keys, q, k, v, key,
            mask_mode="full")
        np.testing.assert_array_equal(_bits(o), _bits(o_ref))
        for g, gr, prim in zip(grads, grads_r, (q, k, v)):
            np.testing.assert_array_equal(_bits(g),
                                          _bits(gr.astype(prim.dtype)))


# ---------------------------------------------------------------------------
# 3. tiling invariance: GQA groups, head dims, block sizes, ragged lengths
# ---------------------------------------------------------------------------

class TestTilingInvariance:
    @pytest.mark.parametrize("h,hkv,s,d", [
        (4, 4, 128, 64),    # MHA, divisible
        (4, 2, 100, 64),    # GQA 2, ragged seq
        (4, 1, 130, 40),    # GQA 4, ragged seq + ragged head dim
        (2, 2, 64, 128),    # full-lane head dim
    ])
    @pytest.mark.parametrize("rounding", ["rne", "sr"])
    def test_fwd_invariant_to_block_q_and_matches_ref(self, h, hkv, s, d,
                                                      rounding):
        """Outputs and amaxes are bit-identical across query block sizes
        (LANE-stepped reductions + absolute-coordinate SR bits) and to the
        unfused oracle at every block size."""
        dt = jnp.float8_e4m3fn
        q8 = (jax.random.normal(jax.random.PRNGKey(1), (2, h, s, d))
              * 0.3).astype(dt)
        k8 = (jax.random.normal(jax.random.PRNGKey(2), (2, hkv, s, d))
              * 0.3).astype(dt)
        v8 = (jax.random.normal(jax.random.PRNGKey(3), (2, hkv, s, d))
              * 0.3).astype(dt)
        seed = jnp.uint32(42)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s="e4m3", fmt_p="e4m3",
                  rounding_s=rounding, rounding_p=rounding)
        outs = []
        for bq in (128, 32, 8):
            o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                            block_q=bq, interpret=True,
                                            **kw)
            outs.append((_bits(o), float(a_s), float(a_p)))
        for got in outs[1:]:
            np.testing.assert_array_equal(got[0], outs[0][0])
            assert got[1:] == outs[0][1:]
        ro, ra_s, ra_p, _, _ = fp8_attention_fwd_ref(q8, k8, v8, seed, scal,
                                                     **kw)
        np.testing.assert_array_equal(outs[0][0], _bits(ro))
        assert outs[0][1:] == (float(ra_s), float(ra_p))

    @pytest.mark.parametrize("h,hkv,s,d", [
        (4, 2, 100, 40),
        (4, 1, 130, 64),
    ])
    def test_bwd_matches_ref(self, h, hkv, s, d):
        q8 = (jax.random.normal(jax.random.PRNGKey(1), (2, h, s, d))
              * 0.3).astype(jnp.float8_e4m3fn)
        k8 = (jax.random.normal(jax.random.PRNGKey(2), (2, hkv, s, d))
              * 0.3).astype(jnp.float8_e4m3fn)
        v8 = (jax.random.normal(jax.random.PRNGKey(3), (2, hkv, s, d))
              * 0.3).astype(jnp.float8_e4m3fn)
        do8 = (jax.random.normal(jax.random.PRNGKey(4), (2, h, s, d))
               * 0.2).astype(jnp.float8_e5m2)
        seed = jnp.uint32(9)
        scal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                          0.05], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s="e4m3", fmt_p="e4m3",
                  fmt_e="e5m2", rounding_s="sr", rounding_p="sr",
                  rounding_e="sr", saturate_e=False)
        dq, dk, dv, adp, ads = fp8_attention_bwd(q8, k8, v8, do8, seed,
                                                 scal, interpret=True, **kw)
        rdq, rdk, rdv, radp, rads, _, _ = fp8_attention_bwd_ref(
            q8, k8, v8, do8, seed, scal, **kw)
        np.testing.assert_array_equal(np.asarray(dq), np.asarray(rdq))
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(rdk))
        np.testing.assert_array_equal(np.asarray(dv), np.asarray(rdv))
        assert (float(adp), float(ads)) == (float(radp), float(rads))

    def test_padding_invariance(self):
        """A ragged sequence gives bitwise the same logical results as the
        same data embedded in a longer zero-padded buffer would: padding
        contributions are exactly 0.0 and masked out of observations."""
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                         (1, 2, 100, 64)) * 0.3).astype(
            jnp.float8_e5m2) for i in range(3)]
        seed = jnp.uint32(5)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s="e5m2", fmt_p="e5m2",
                  rounding_s="sr", rounding_p="sr")
        o1, s1, p1 = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                       interpret=True, **kw)
        # ref pads to the next LANE multiple internally; a different
        # (larger) padding must not change logical results
        ro, rs, rp, _, _ = fp8_attention_fwd_ref(q8, k8, v8, seed, scal,
                                                 block_q=64, **kw)
        np.testing.assert_array_equal(_bits(o1), _bits(ro))
        assert (float(s1), float(p1)) == (float(rs), float(rp))


# ---------------------------------------------------------------------------
# streamed-KV grid: stripe-skip proofs + block-size invariance
# ---------------------------------------------------------------------------

def _brute_span(row0, bq, bkv, nk, mask_mode, window, s_len):
    """Ground truth for kv_stripe_span: the stripes with >= 1 valid cell
    for any row of the tile, from the mask definition itself."""
    active = []
    for j in range(nk):
        hit = False
        for r in range(row0, row0 + bq):
            for c in range(j * bkv, (j + 1) * bkv):
                ok = c < s_len
                if mask_mode == "causal":
                    ok = ok and c <= r
                    if window:
                        ok = ok and c > r - window
                if ok:
                    hit = True
                    break
            if hit:
                break
        if hit:
            active.append(j)
    return active


class TestStripeSkip:
    @pytest.mark.parametrize("bq,bkv,s,window", [
        (128, 128, 1024, 0),
        (128, 256, 1024, 200),
        (256, 128, 1280, 384),
        (128, 512, 2048, 512),
    ])
    def test_kv_stripe_span_matches_mask(self, bq, bkv, s, window):
        """The static skip range is EXACTLY the set of stripes with any
        attended cell — skipping is never lossy, and never visits a fully
        masked stripe (the block-index-map contract)."""
        nk = s // bkv
        nq = s // bq
        for iq in range(nq):
            jmin, jmax = attn_ref.kv_stripe_span(
                iq * bq, bq, block_kv=bkv, n_kv=nk, mask_mode="causal",
                window=window)
            want = _brute_span(iq * bq, bq, bkv, nk, "causal", window, s)
            assert list(range(jmin, jmax + 1)) == want, (iq, jmin, jmax)

    @pytest.mark.parametrize("bq,bkv,s,window", [
        (128, 256, 1024, 200),
        (256, 128, 1280, 384),
    ])
    def test_q_tile_span_is_inverse(self, bq, bkv, s, window):
        """q_tile_span (the dK/dV kernel's clamp range) is the exact
        inverse relation of kv_stripe_span."""
        nk, nq = s // bkv, s // bq
        for j in range(nk):
            imin, imax = attn_ref.q_tile_span(
                j, block_q=bq, block_kv=bkv, n_q=nq, mask_mode="causal",
                window=window)
            want = [i for i in range(nq)
                    if attn_ref.kv_stripe_span(
                        i * bq, bq, block_kv=bkv, n_kv=nk,
                        mask_mode="causal", window=window)[0] <= j
                    <= attn_ref.kv_stripe_span(
                        i * bq, bq, block_kv=bkv, n_kv=nk,
                        mask_mode="causal", window=window)[1]]
            assert list(range(imin, imax + 1)) == want, (j, imin, imax)

    def test_skipped_stripes_never_touched(self):
        """NaN-poisoning proof: fill every fully-masked (future) stripe of
        K/V with FP8 NaN payloads — forward outputs/amaxes and backward
        grads are bit-identical to the zero-filled run, so the kernels
        provably never feed those stripes to compute (a single read would
        poison the running max and every downstream value)."""
        s, q_len, d = 2048, 256, 64
        dt = jnp.float8_e4m3fn
        q8 = (jax.random.normal(jax.random.PRNGKey(0), (1, 2, q_len, d))
              * 0.3).astype(dt)
        k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i), (1, 1, s, d))
                   * 0.3).astype(dt) for i in (1, 2)]

        def poison(x):
            raw = np.asarray(x).view(np.uint8).copy()
            raw[:, :, q_len:, :] = 0x7F            # e4m3fn NaN
            return jnp.asarray(raw).view(dt)

        seed = jnp.uint32(5)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="causal", block_q=256, block_kv=256,
                  fmt_s="e4m3", fmt_p="e4m3", rounding_s="sr",
                  rounding_p="sr", interpret=True)
        clean = fp8_attention_fwd(q8, k8, v8, seed, scal, **kw)
        dirty = fp8_attention_fwd(q8, poison(k8), poison(v8), seed, scal,
                                  **kw)
        np.testing.assert_array_equal(_bits(clean[0]), _bits(dirty[0]))
        assert float(clean[1]) == float(dirty[1])
        assert float(clean[2]) == float(dirty[2])
        assert np.isfinite(np.asarray(clean[0], np.float32)).all()

        do8 = (jax.random.normal(jax.random.PRNGKey(3), (1, 2, q_len, d))
               * 0.2).astype(jnp.float8_e5m2)
        bscal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                           0.05], jnp.float32)
        bkw = dict(mask_mode="causal", block_q=256, block_kv=256,
                   fmt_s="e4m3", fmt_p="e4m3", fmt_e="e5m2",
                   rounding_s="sr", rounding_p="sr", rounding_e="sr",
                   saturate_e=False, interpret=True)
        cb = fp8_attention_bwd(q8, k8, v8, do8, seed, bscal, **bkw)
        db = fp8_attention_bwd(q8, poison(k8), poison(v8), do8, seed,
                               bscal, **bkw)
        for a, b in zip(cb, db):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # ... and the skipped stripes' dK/dV are exactly zero.
        assert not np.asarray(cb[1])[:, :, q_len:, :].any()
        assert not np.asarray(cb[2])[:, :, q_len:, :].any()

    def test_fwd_grid_has_kv_stripe_dimension(self):
        """Jaxpr grid check: the forward pallas_call carries the
        (B, H, nq, nk) streamed ONE-pass grid — one step per kv stripe
        (the two-pass kernel's 3*nk phase dimension is gone), not the
        PR-4 (B, H, nq) one."""
        s, bkv = 1024, 256
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                         (1, 2, s, 64)) * 0.3).astype(
            jnp.float8_e5m2) for i in range(3)]
        jaxpr = jax.make_jaxpr(
            lambda q, k, v: fp8_attention_fwd(
                q, k, v, jnp.uint32(0),
                jnp.ones((4,), jnp.float32), block_q=128, block_kv=bkv,
                fmt_s="e5m2", fmt_p="e5m2", rounding_s="rne",
                rounding_p="rne", interpret=True))(q8, k8, v8)
        grids = [eqn.params["grid_mapping"].grid
                 for eqn in _all_eqns(jaxpr.jaxpr)
                 if eqn.primitive.name == "pallas_call"]
        assert (1, 2, s // 128, s // bkv) in grids, grids


from repro.analysis.jaxpr_walk import all_eqns as _all_eqns


class TestStreamedInvariance:
    def test_fwd_invariant_to_block_kv(self):
        """Outputs and amaxes are bit-identical across kv stripe sizes
        (carries cross stripe boundaries; the LANE-step chain is the same
        however it is cut) and match the oracle at every size."""
        s = 640
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                         (1, 2, s, 64)) * 0.3).astype(
            jnp.float8_e4m3fn) for i in range(3)]
        seed = jnp.uint32(7)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        for window in (0, 200):
            kw = dict(mask_mode="causal", window=window, fmt_s="e4m3",
                      fmt_p="e4m3", rounding_s="sr", rounding_p="sr")
            outs = []
            for bkv in (128, 256, 640):
                o, a_s, a_p = fp8_attention_fwd(
                    q8, k8, v8, seed, scal, block_q=128, block_kv=bkv,
                    interpret=True, **kw)
                outs.append((_bits(o), float(a_s), float(a_p)))
            for got in outs[1:]:
                np.testing.assert_array_equal(got[0], outs[0][0])
                assert got[1:] == outs[0][1:]
            ro, rs, rp, _, _ = fp8_attention_fwd_ref(
                q8, k8, v8, seed, scal, block_kv=256, **kw)
            np.testing.assert_array_equal(outs[0][0], _bits(ro))
            assert outs[0][1:] == (float(rs), float(rp))

    def test_one_pass_matches_two_pass_baseline(self):
        """The one-pass online-softmax forward is semantically the same
        attention as the retained two-pass baseline: the S chain (and so
        amax_s) is BIT-identical, and the outputs agree to within the P
        re-quantization difference (one-pass quantizes probs unnormalized
        against the running max; two-pass quantizes them normalized by the
        final l — both are Q_A envelopes of the same softmax rows)."""
        s, bq, bkv = 256, 128, 128
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                         (1, 2, s, 64)) * 0.3).astype(
            jnp.float8_e4m3fn) for i in range(3)]
        seed = jnp.uint32(7)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="causal", window=0, q_len=s, s_len=s,
                  fmt_s="e4m3", fmt_p="e4m3", rounding_s="sr",
                  rounding_p="sr", saturate_s=True, saturate_p=True,
                  block_kv=bkv)
        o1, a_s1, _ = fp8_attention_fwd(
            q8, k8, v8, seed, scal, block_q=bq, block_kv=bkv,
            mask_mode="causal", fmt_s="e4m3", fmt_p="e4m3",
            rounding_s="sr", rounding_p="sr", interpret=True)
        o2 = np.zeros((1, 2, s, 64), np.float32)
        a_s2 = jnp.float32(0.0)
        for h in range(2):
            for iq in range(s // bq):
                qt = q8[0, h, iq * bq:(iq + 1) * bq]
                ot, a_t, _ = attn_ref.fwd_q_tile_two_pass(
                    qt, k8[0, h], v8[0, h], None, seed=seed, bh=h,
                    row0=iq * bq, scal=scal, **kw)
                a_s2 = jnp.maximum(a_s2, a_t)
                o2[0, h, iq * bq:(iq + 1) * bq] = np.asarray(
                    ot, np.float32)
        np.testing.assert_allclose(np.asarray(o1, np.float32), o2,
                                   rtol=0.08, atol=0.08 * np.abs(o2).max())
        assert float(a_s1) == float(a_s2)

    def test_bwd_bit_equal_across_block_configs(self):
        """The FMA-fusion parity pin (PR-4's documented hazard) extended
        to the streamed grid: the backward compiled at different
        (block_q, block_kv) configs — including the single-stripe config
        equivalent to the PR-4 kernel — produces BIT-EQUAL dQ/dK/dV and
        amaxes, and matches the oracle. A raw-accumulation + scale-once
        regression (or any reduction regrouping) breaks this."""
        s = 512
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                         (1, 4, s, 64)) * 0.3).astype(
            jnp.float8_e4m3fn) for i in range(3)]
        k8, v8 = k8[:, :2], v8[:, :2]          # GQA group of 2
        do8 = (jax.random.normal(jax.random.PRNGKey(4), (1, 4, s, 64))
               * 0.2).astype(jnp.float8_e5m2)
        seed = jnp.uint32(11)
        scal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                          0.05], jnp.float32)
        for window in (0, 160):
            kw = dict(mask_mode="causal", window=window, fmt_s="e4m3",
                      fmt_p="e4m3", fmt_e="e5m2", rounding_s="sr",
                      rounding_p="sr", rounding_e="sr", saturate_e=False)
            outs = []
            for bq, bkv in ((128, 128), (256, 256), (128, 512)):
                outs.append(fp8_attention_bwd(
                    q8, k8, v8, do8, seed, scal, block_q=bq, block_kv=bkv,
                    interpret=True, **kw))
            for got in outs[1:]:
                for a, b in zip(outs[0][:3], got[:3]):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
                assert (float(got[3]), float(got[4])) \
                    == (float(outs[0][3]), float(outs[0][4]))
            refs = fp8_attention_bwd_ref(q8, k8, v8, do8, seed, scal,
                                         block_kv=128, **kw)
            for a, r in zip(outs[0][:3], refs[:3]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(r))

    def test_sdpa_invariant_to_attn_block_knobs(self):
        """End to end through fp8_sdpa: the QuantConfig streamed-KV knobs
        change only the grid — outputs, all three grads, and every
        observation are bit-identical across them."""
        cfg = _cfg("hybrid")
        keys, reg, ds = _site_bundle(cfg)
        q, k, v = _qkv(b=1, h=2, hkv=1, s=300)
        key = jax.random.PRNGKey(5)
        state = ds.init()
        base = _run_step(ds, state, cfg, q, k, v, key)
        small = dataclasses.replace(cfg, attn_block_q=128,
                                    attn_block_kv=128)
        got = _run_step(ds, state, small, q, k, v, key)
        np.testing.assert_array_equal(_bits(base[0]), _bits(got[0]))
        for a, b in zip(base[1], got[1]):
            np.testing.assert_array_equal(_bits(a), _bits(b))
        for kk in base[2]:
            assert np.float32(base[2][kk]).tobytes() \
                == np.float32(got[2][kk]).tobytes(), kk


# ---------------------------------------------------------------------------
# decode ('kv' mask) + frozen-KV serving through the kernel
# ---------------------------------------------------------------------------

class TestDecode:
    def test_kv_mask_parity(self):
        """Decode-style ('kv' validity mask) forward matches the oracle."""
        q8 = (jax.random.normal(jax.random.PRNGKey(1), (2, 4, 1, 64))
              * 0.3).astype(jnp.float8_e5m2)
        k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i), (2, 2, 40, 64))
                   * 0.3).astype(jnp.float8_e5m2) for i in (2, 3)]
        valid = (jnp.arange(40)[None, :] < jnp.array([[17], [31]]))
        seed = jnp.uint32(11)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="kv", fmt_s="e5m2", fmt_p="e5m2",
                  rounding_s="rne", rounding_p="rne")
        o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                        kv_mask=valid.astype(jnp.int8),
                                        interpret=True, **kw)
        ro, rs, rp, _, _ = fp8_attention_fwd_ref(
            q8, k8, v8, seed, scal, kv_mask=valid.astype(jnp.int8), **kw)
        np.testing.assert_array_equal(_bits(o), _bits(ro))
        assert (float(a_s), float(a_p)) == (float(rs), float(rp))

    def test_frozen_serving_refuses_uncalibrated_attention_sites(self):
        """A frozen-scales file that predates the fused path (or was
        calibrated with fuse_attention=False) lacks the sdpa sites; frozen
        serving must refuse instead of burning silent unit scales into the
        in-kernel S/P Q nodes — the same failure class _kv_scales refuses
        for the FP8 KV cache."""
        cfg = _cfg("hybrid")
        q, k, v = _qkv(s=16)
        ctx = sc.frozen_context({"other#a.A": 0.5})
        with sc.activate(ctx):
            with pytest.raises(ValueError, match="sdpa#qk.A"):
                fp8_sdpa(q, k, v, key=jax.random.PRNGKey(0),
                         cfg=cfg.eval_mode(), sm_scale=SM, site="sdpa")
        good = {f"sdpa#{n}": 0.5 for n in
                ("q.A", "k.A", "v.A", "qk.A", "p.A")}
        with sc.activate(sc.frozen_context(good)):
            o = fp8_sdpa(q, k, v, key=jax.random.PRNGKey(0),
                         cfg=cfg.eval_mode(), sm_scale=SM, site="sdpa")
        assert np.isfinite(np.asarray(o, np.float32)).all()

    def test_serve_engine_fused_decode(self):
        """ServeEngine with a Pallas backend + calibrated frozen scales
        serves from the fused kernel: the FP8 KV cache payloads feed it
        directly (no dequantize->requantize), decode lowers to pallas_call,
        and generation is bitwise deterministic."""
        from repro.core.precision_policy import PrecisionPolicy
        from repro.models.config import ModelConfig
        from repro.models.transformer import init_lm
        from repro.scaling.calibrate import calibrate, freeze
        from repro.serve.engine import ServeConfig, ServeEngine
        quant = _cfg("hybrid")
        pol = PrecisionPolicy(quant=quant, kv_cache_format="e5m2")
        cfg = ModelConfig(arch="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=64,
                          max_seq_len=48, policy=pol, remat=False,
                          scan_layers=False)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        batches = [{"tokens": jnp.asarray(rng.integers(0, 64, (2, 12)),
                                          jnp.int32)} for _ in range(2)]
        ds, state = calibrate(params, cfg, batches,
                              scaling_cfg=ScalingConfig(margin=1.0))
        frozen = freeze(ds, state)
        assert any(k.endswith("sdpa#qk.A") for k in frozen)
        assert any(k.endswith("sdpa#p.A") for k in frozen)

        def generate():
            eng = ServeEngine(cfg, params,
                              ServeConfig(max_batch=2, max_len=32),
                              frozen_scales=frozen)
            uid = eng.add_request(np.array([3, 5, 7], np.int32),
                                  max_new_tokens=4)
            return eng.run_to_completion()[uid], eng
        first, eng = generate()
        second, _ = generate()
        assert first == second and len(first) == 4
        jaxpr = str(jax.make_jaxpr(
            lambda p, b, s: eng._decode.__wrapped__(p, b, s))(
            eng.params,
            {"tokens": jnp.zeros((2, 1), jnp.int32),
             "positions": jnp.zeros((2, 1), jnp.int32)}, eng.states))
        assert "pallas_call" in jaxpr


# ---------------------------------------------------------------------------
# ring-buffer (sliding-window) decode through the fused kernel
# ---------------------------------------------------------------------------

class TestRingDecode:
    def test_prefill_ring_layout_keeps_append_invariant(self):
        """Regression for the ring-desync bug: a prompt longer than the
        ring wrote its tail sequentially to slots 0..cap-1, while appends
        use slot = pos % cap — so unless s % cap == 0 the next append
        overwrote an IN-WINDOW entry and left the truly-oldest one alive,
        silently dropping a valid key from local attention. Prefill must
        place position p at slot p % cap."""
        from repro.models.attention import _append_cache, _prefill_cache
        cap, s, hkv, dh = 4, 6, 2, 8
        cache = {"k": jnp.zeros((1, cap, hkv, dh), jnp.bfloat16),
                 "v": jnp.zeros((1, cap, hkv, dh), jnp.bfloat16),
                 "slot_pos": jnp.full((1, cap), -1, jnp.int32),
                 "length": jnp.zeros((1,), jnp.int32)}
        k = jnp.arange(s, dtype=jnp.float32)[None, :, None, None] \
            * jnp.ones((1, s, hkv, dh), jnp.float32)
        pos = jnp.arange(s)[None]
        c = _prefill_cache(cache, k.astype(jnp.bfloat16),
                           k.astype(jnp.bfloat16), pos)
        # positions 2..5 live at slots pos % cap = [2, 3, 0, 1]
        np.testing.assert_array_equal(np.asarray(c["slot_pos"][0]),
                                      [4, 5, 2, 3])
        np.testing.assert_array_equal(
            np.asarray(c["k"][0, :, 0, 0], np.float32), [4, 5, 2, 3])
        # the next append (pos 6 -> slot 2) evicts EXACTLY the oldest (2)
        k1 = jnp.full((1, 1, hkv, dh), 6.0, jnp.bfloat16)
        c1 = _append_cache(c, k1, k1, jnp.array([[6]]))
        np.testing.assert_array_equal(np.asarray(c1["slot_pos"][0]),
                                      [4, 5, 6, 3])
        cur, window = 6, cap
        valid = (np.asarray(c1["slot_pos"][0]) >= 0) \
            & (np.asarray(c1["slot_pos"][0]) > cur - window)
        assert sorted(np.asarray(c1["slot_pos"][0])[valid]) == [3, 4, 5, 6]

    def test_wrapped_ring_permutation_invariance_through_kernel(self):
        """The module-docstring claim, proven through the fused kernel: a
        ring cache whose slot_pos wraps across the stripe boundary (out of
        position order) decodes (a) bit-identically to the oracle fed the
        SAME slot order, and (b) numerically identically to the same
        logical window served in sorted order (softmax permutation
        invariance; f32 tolerance covers the reduction-order change)."""
        cap, hkv, h, dh = 320, 2, 4, 64
        q8 = (jax.random.normal(jax.random.PRNGKey(0), (1, h, 1, dh))
              * 0.3).astype(jnp.float8_e5m2)
        k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                     (1, hkv, cap, dh)) * 0.3).astype(
            jnp.float8_e5m2) for i in (1, 2)]
        # wrapped ring: slots [0, cap) hold positions out of order, with
        # a few stale (invalid) entries sprinkled in
        slot_pos = np.roll(np.arange(cap), 131)
        slot_pos[7] = -1
        valid = jnp.asarray((slot_pos >= 0)[None], jnp.int8)
        seed = jnp.uint32(13)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="kv", fmt_s="e5m2", fmt_p="e5m2",
                  rounding_s="rne", rounding_p="rne")
        o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                        kv_mask=valid, block_kv=128,
                                        interpret=True, **kw)
        ro, rs, rp, _, _ = fp8_attention_fwd_ref(
            q8, k8, v8, seed, scal, kv_mask=valid, block_kv=128, **kw)
        np.testing.assert_array_equal(_bits(o), _bits(ro))
        assert (float(a_s), float(a_p)) == (float(rs), float(rp))
        # permutation to sorted position order == same logical attention
        order = np.argsort(np.where(slot_pos < 0, 10 ** 9, slot_pos))
        o_sorted, _, _ = fp8_attention_fwd(
            q8, k8[:, :, order], v8[:, :, order], seed, scal,
            kv_mask=valid[:, order], block_kv=128, interpret=True, **kw)
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o_sorted, np.float32),
            rtol=0.05, atol=0.05)

    def test_bf16_ring_decode_routes_through_fused_kernel(self):
        """attention(mode='decode') with a bf16 ring cache under a fused
        config goes through fp8_sdpa_decode's validity-mask path (no
        `_sdpa` fallback: the jaxpr has a pallas_call and no XLA
        dot_general), across the wrap-around boundary."""
        from repro.core.precision_policy import PrecisionPolicy
        from repro.models.attention import attention, init_attention
        from repro.models.config import ModelConfig
        quant = _cfg("hybrid")
        window = 8
        cfg = ModelConfig(arch="t", n_layers=1, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=64,
                          max_seq_len=64, window=window,
                          policy=PrecisionPolicy(quant=quant), remat=False)
        params = init_attention(jax.random.PRNGKey(0), cfg)
        from repro.models.attention import init_cache
        cache = jax.tree_util.tree_map(
            lambda x: x[0], init_cache(cfg, 1, 64, n_layers=1,
                                       window=window))
        assert cache["k"].dtype == jnp.bfloat16
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 64),
                              jnp.bfloat16)
        pos = jnp.arange(12)[None]
        _, cache = attention(params, x, cfg=cfg, qcfg=quant,
                             qkey=jax.random.PRNGKey(2), positions=pos,
                             mode="prefill", cache_layer=cache,
                             window=window)
        # decode across the ring wrap: positions 12..14, cap == window == 8
        def decode(params, xt, cache, p):
            return attention(params, xt, cfg=cfg, qcfg=quant,
                             qkey=jax.random.PRNGKey(3),
                             positions=p, mode="decode",
                             cache_layer=cache, window=window)
        for t in range(12, 15):
            xt = jax.random.normal(jax.random.fold_in(
                jax.random.PRNGKey(4), t), (1, 1, 64), jnp.bfloat16)
            y, cache = decode(params, xt, cache, jnp.array([[t]]))
            assert np.isfinite(np.asarray(y, np.float32)).all()
            assert int(jnp.max(cache["slot_pos"])) == t
        jaxpr = jax.make_jaxpr(
            lambda *a: decode(*a)[0])(params, xt, cache,
                                      jnp.array([[15]]))
        counts = _count_prims(jaxpr.jaxpr)
        assert counts["pallas"] >= 1, counts
        assert counts["outside_dot"] == 0, counts


# ---------------------------------------------------------------------------
# 32k streamed long-context smoke (nightly)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestLongContext32k:
    @pytest.mark.parametrize("recipe", ["paper_e5m2", "hybrid"])
    def test_32k_windowed_fwd_bwd_parity(self, recipe):
        """S=32k sliding-window training step through the streamed grid:
        fwd outputs + amaxes and bwd grads + amaxes bit-match the
        (payload-free) oracle. Ragged length (not a block multiple), GQA,
        and a window that crosses stripe boundaries; large blocks keep the
        interpret-mode grid small while VMEM-sized blocks on hardware only
        change the grid (bit-invariance locked by the fast tests)."""
        s_len, q_len, d, window = 32640, 32640, 64, 1536
        bq = bkv = 4096
        fmt_a = "e4m3" if recipe == "hybrid" else "e5m2"
        dt = jnp.float8_e4m3fn if recipe == "hybrid" else jnp.float8_e5m2
        q8 = (jax.random.normal(jax.random.PRNGKey(0), (1, 2, q_len, d))
              * 0.3).astype(dt)
        k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i),
                                     (1, 1, s_len, d)) * 0.3).astype(dt)
                  for i in (1, 2)]
        seed = jnp.uint32(17)
        scal = jnp.array([0.5, 2.0, 8.0, 0.25], jnp.float32)
        kw = dict(mask_mode="causal", window=window, fmt_s=fmt_a,
                  fmt_p=fmt_a, rounding_s="sr", rounding_p="sr")
        o, a_s, a_p = fp8_attention_fwd(q8, k8, v8, seed, scal,
                                        block_q=bq, block_kv=bkv,
                                        interpret=True, **kw)
        ro, rs, rp, _, _ = fp8_attention_fwd_ref(
            q8, k8, v8, seed, scal, block_q=bq, block_kv=bkv,
            payload=False, **kw)
        np.testing.assert_array_equal(_bits(o), _bits(ro))
        assert (float(a_s), float(a_p)) == (float(rs), float(rp))

        do8 = (jax.random.normal(jax.random.PRNGKey(3), (1, 2, q_len, d))
               * 0.2).astype(jnp.float8_e5m2)
        bscal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                           0.05], jnp.float32)
        bkw = dict(mask_mode="causal", window=window, fmt_s=fmt_a,
                   fmt_p=fmt_a, fmt_e="e5m2", rounding_s="sr",
                   rounding_p="sr", rounding_e="sr", saturate_e=False)
        outs = fp8_attention_bwd(q8, k8, v8, do8, seed, bscal,
                                 block_q=bq, block_kv=bkv, interpret=True,
                                 **bkw)
        refs = fp8_attention_bwd_ref(q8, k8, v8, do8, seed, bscal,
                                     block_q=bq, block_kv=bkv,
                                     payload=False, **bkw)
        for a, r in zip(outs[:3], refs[:3]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(r))
        assert (float(outs[3]), float(outs[4])) \
            == (float(refs[3]), float(refs[4]))


# ---------------------------------------------------------------------------
# slow property tests (hypothesis; nightly)
# ---------------------------------------------------------------------------

def _row_sums(seed_int, s, scale_p):
    """Dequantized P-payload row sums NORMALIZED by the softmax
    normalizer recomputed from the S8 payload. The one-pass forward
    stores its probs unnormalized against the RUNNING row max; at these
    single-LANE-block sequence lengths (s <= 128) the running max IS the
    final max, so sum(dequant(E8)) / l must recover 1 exactly up to
    quantization error."""
    q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(seed_int + i),
                                     (1, 2, s, 32)) * 0.4).astype(
        jnp.float8_e4m3fn) for i in range(3)]
    scal = jnp.array([1.0, 1.0, 1.0 / scale_p, scale_p], jnp.float32)
    _, _, _, s8, p8 = fp8_attention_fwd_ref(
        q8, k8, v8, jnp.uint32(seed_int), scal, mask_mode="causal",
        fmt_s="e4m3", fmt_p="e4m3", rounding_s="sr", rounding_p="sr")
    e = np.asarray(p8, np.float32) * scale_p
    x = np.asarray(s8, np.float32)  # s_s = 1.0
    rows = np.arange(s)[:, None]
    cols = np.arange(s)[None, :]
    x = np.where(cols <= rows, x, -np.inf)
    m = x.max(axis=-1, keepdims=True)
    l = np.exp(x - m).sum(axis=-1)
    return e.sum(axis=-1) / l


@pytest.mark.slow
class TestProperties:
    @given(st.integers(0, 2 ** 16), st.sampled_from([64, 100]))
    @settings(deadline=None, max_examples=10)
    def test_softmax_rows_sum_to_one_within_fp8_error(self, seed, s):
        """Dequantized fused-attention P-payload rows recover the exact
        softmax normalizer within the FP8 quantization error (each of
        <= s terms is off by at most half an e4m3 ulp of its magnitude;
        SR keeps the sum unbiased)."""
        sums = _row_sums(seed, s, 1.0 / 8.0)
        assert np.all(np.abs(sums - 1.0) < 0.15), \
            (sums.min(), sums.max())

    @given(st.integers(0, 2 ** 16))
    @settings(deadline=None, max_examples=5)
    def test_sr_on_p_is_unbiased(self, base_seed):
        """The in-kernel hash-bit SR is unbiased on the P tensor: averaging
        the quantized values over many seeds recovers the exact values to
        within CLT noise (reusing sr_fp8_via_f16 — already proven unbiased
        for uniform bits in test_formats — the property under test is that
        the COUNTER-HASH bits behave as uniform)."""
        from repro.core.fp8_formats import get_format
        from repro.core.quantize import sr_fp8_via_f16
        fmt = get_format("e4m3")
        p = jnp.linspace(0.003, 0.97, 64, dtype=jnp.float32)[None, :]
        rows = jnp.zeros((1, 1), jnp.int32)
        cols = jnp.arange(64, dtype=jnp.int32)[None, :]
        n = 400
        acc = np.zeros((1, 64), np.float64)
        for i in range(n):
            bits = sr_hash_bits(jnp.uint32(base_seed + i), attn_ref.SALT_P,
                                0, rows, cols)
            acc += np.asarray(sr_fp8_via_f16(p, bits, fmt),
                              np.float32).astype(np.float64)
        mean = acc / n
        # e4m3 ulp at |x|<1 is <= 2^-3 * x; CLT noise ~ ulp/sqrt(n)
        tol = np.maximum(np.asarray(p[0]) * 2.0 ** -3, 2.0 ** -9) \
            / np.sqrt(n) * 4.0
        assert np.all(np.abs(mean[0] - np.asarray(p)[0]) < tol)

    @given(st.integers(0, 2 ** 10))
    @settings(deadline=None, max_examples=5)
    def test_chunked_causal_equals_full_composition(self, seed):
        """Chunk-sequential causal softmax == a naive full-matrix masked
        composition (independent jnp implementation; RNE so the comparison
        is deterministic). Tolerance covers f32 reduction-order noise only.
        """
        s, d = 100, 32
        q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(seed + i),
                                         (1, 1, s, d)) * 0.4).astype(
            jnp.float8_e4m3fn) for i in range(3)]
        scal = jnp.array([1.0, 1.0, 8.0, 0.125], jnp.float32)
        o, _, _, s8, p8 = fp8_attention_fwd_ref(
            q8, k8, v8, jnp.uint32(0), scal, mask_mode="causal",
            fmt_s="e4m3", fmt_p="e4m3", rounding_s="rne", rounding_p="rne")
        # naive: full S8 -> masked f32 softmax -> quantized P -> PV
        from repro.core.quantize import quantize_rne
        from repro.core.fp8_formats import get_format
        fmt = get_format("e4m3")
        sf = jnp.einsum("bhqd,bhkd->bhqk", q8.astype(jnp.bfloat16),
                        k8.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        s8_naive = quantize_rne(sf * scal[0], fmt)
        mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        x = jnp.where(mask, s8_naive.astype(jnp.float32) * scal[1], -1e30)
        p = jax.nn.softmax(x, axis=-1)
        p8_naive = quantize_rne(p * scal[2], fmt)
        o_naive = jnp.einsum("bhqk,bhkd->bhqd",
                             p8_naive.astype(jnp.bfloat16),
                             v8.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32) * scal[3]
        # The oracle materializes its payloads masked to the attended
        # region (stripe-skip observation semantics) — mask the naive
        # side the same way before comparing.
        s8_naive = jnp.where(mask[None, None], s8_naive,
                             jnp.zeros_like(s8_naive))
        np.testing.assert_array_equal(_bits(s8), _bits(s8_naive))
        mismatch = (_bits(p8) != _bits(p8_naive)).mean()
        assert mismatch < 0.01, mismatch   # boundary flips only
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o_naive, np.float32),
            rtol=0.1, atol=0.02)
