"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fp8_matmul import fp8_matmul, fp8_matmul_ref
from repro.kernels.fp8_matmul.kernel import fp8_matmul_kernel
from repro.kernels.fused_quant_matmul import (fused_quant_matmul,
                                              fused_quant_matmul_ref)
from repro.kernels.stochastic_round import (stochastic_round_e5m2,
                                            stochastic_round_e5m2_ref)
from repro.kernels.stochastic_round.kernel import sr_quantize_kernel


class TestStochasticRoundKernel:
    @pytest.mark.parametrize("shape,block", [
        ((32, 128), (32, 128)),
        ((64, 256), (32, 128)),
        ((128, 384), (64, 128)),
    ])
    @pytest.mark.parametrize("in_dtype", [jnp.float32, jnp.bfloat16])
    def test_bit_exact_vs_ref(self, shape, block, in_dtype):
        x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 8).astype(
            in_dtype)
        rand8 = jax.random.bits(jax.random.PRNGKey(1), shape, jnp.uint8)
        scale = jnp.ones((1,), jnp.float32)
        out_k = sr_quantize_kernel(x, rand8, scale, block=block,
                                   interpret=True)
        out_r = stochastic_round_e5m2_ref(x, rand8, scale)
        np.testing.assert_array_equal(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32))

    @pytest.mark.parametrize("scale", [0.5, 4.0])
    def test_scale_applied(self, scale):
        x = jnp.full((16, 128), 2.0, jnp.float32)
        rand8 = jnp.zeros((16, 128), jnp.uint8)
        out = sr_quantize_kernel(x, rand8, jnp.array([scale], jnp.float32),
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   2.0 / scale, rtol=0.13)

    def test_wrapper_any_rank(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 128))
        out = stochastic_round_e5m2(x, jax.random.PRNGKey(1), interpret=True)
        assert out.shape == x.shape and out.dtype == jnp.float8_e5m2


class TestFP8Matmul:
    @pytest.mark.parametrize("m,k,n", [
        (32, 128, 128), (64, 256, 128), (128, 512, 256), (100, 300, 130),
    ])
    def test_matches_ref(self, m, k, n):
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(
            jnp.float8_e5m2)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(
            jnp.float8_e5m2)
        y = fp8_matmul(a, b, bm=32, bk=128, bn=128, interpret=True)
        ref = fp8_matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
    def test_out_dtypes(self, out_dtype):
        a = jax.random.normal(jax.random.PRNGKey(0), (32, 128)).astype(
            jnp.float8_e5m2)
        b = jax.random.normal(jax.random.PRNGKey(1), (128, 128)).astype(
            jnp.float8_e5m2)
        y = fp8_matmul(a, b, bm=32, bk=128, bn=128, out_dtype=out_dtype,
                       interpret=True)
        assert y.dtype == out_dtype

    def test_e4m3_inputs(self):
        a = (jax.random.normal(jax.random.PRNGKey(0), (32, 128)) * 0.5
             ).astype(jnp.float8_e4m3fn)
        b = (jax.random.normal(jax.random.PRNGKey(1), (128, 128)) * 0.5
             ).astype(jnp.float8_e4m3fn)
        y = fp8_matmul(a, b, bm=32, bk=128, bn=128, interpret=True)
        ref = fp8_matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    def test_k_accumulation_order(self):
        """Multiple K blocks accumulate exactly in f32."""
        a = jnp.ones((8, 512), jnp.float8_e5m2)
        b = jnp.ones((512, 128), jnp.float8_e5m2)
        y = fp8_matmul_kernel(a, b, bm=8, bk=128, bn=128, interpret=True)
        np.testing.assert_array_equal(np.asarray(y), 512.0)


class TestFusedQuantMatmul:
    @pytest.mark.parametrize("rounding", ["rne", "sr"])
    def test_matches_ref(self, rounding):
        m, k, n = 32, 256, 128
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(
            jnp.float8_e5m2)
        b = (jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1).astype(
            jnp.float8_e5m2)
        key = jax.random.PRNGKey(2)
        y = fused_quant_matmul(a, b, key, jnp.array([2.0]), bm=32, bk=128,
                               bn=128, rounding=rounding, interpret=True)
        rand8 = jax.random.bits(key, (m, n), jnp.uint8) if rounding == "sr" \
            else jnp.zeros((m, n), jnp.uint8)
        ref = fused_quant_matmul_ref(a, b, rand8, jnp.array([2.0]),
                                     rounding=rounding)
        np.testing.assert_array_equal(np.asarray(y, np.float32),
                                      np.asarray(ref, np.float32))

    def test_output_is_fp8(self):
        a = jnp.ones((8, 128), jnp.float8_e5m2)
        b = jnp.ones((128, 128), jnp.float8_e5m2)
        y = fused_quant_matmul(a, b, jax.random.PRNGKey(0), rounding="rne",
                               bm=8, bk=128, bn=128, interpret=True)
        assert y.dtype == jnp.float8_e5m2
        np.testing.assert_array_equal(np.asarray(y, np.float32), 128.0)
