"""End-to-end behaviour tests for the paper's system: the FP8 training
recipe actually trains, matches its FP32 baseline, and reproduces the
paper's qualitative ablations at reduced scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.loss_scale import LossScaler, convnet_scaler
from repro.core.precision_policy import (BASELINE_POLICY, PAPER_FP8,
                                         PAPER_FP8_RNE, PAPER_POLICY,
                                         PrecisionPolicy)
from repro.data import DataConfig, synthetic_lm_batches
from repro.models.registry import build_config
from repro.models.transformer import init_lm, lm_loss
from repro.train.step import make_optimizer_for, make_train_step

VOCAB = 128


def _train(policy, steps=40, seed=0, init_scale=512.0, lr=3e-3):
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=VOCAB, remat=False, policy=policy)
    opt = make_optimizer_for(cfg, name="adam", learning_rate=lr,
                             scaler=LossScaler(mode="dynamic",
                                               init_scale=init_scale))
    step = jax.jit(make_train_step(cfg, opt))
    data = synthetic_lm_batches(DataConfig(vocab_size=VOCAB, seq_len=32,
                                           batch_size=8, seed=seed))
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    state = opt.init(params)
    losses = []
    for i in range(steps):
        state, m = step(state, next(data),
                        jax.random.fold_in(jax.random.PRNGKey(7), i))
        losses.append(float(m["loss"]))
    return np.array(losses)


def test_fp8_training_converges():
    losses = _train(PAPER_POLICY)
    assert losses[-1] < np.log(VOCAB) * 0.9
    assert losses[-1] < losses[0]


def test_fp8_tracks_fp32_baseline():
    """Paper Tables 2/4: FP8 final quality ~ FP32 baseline."""
    l8 = _train(PAPER_POLICY, steps=60)
    l32 = _train(BASELINE_POLICY, steps=60)
    # mean of last 10 losses within 15% of each other
    m8, m32 = l8[-10:].mean(), l32[-10:].mean()
    assert m8 < m32 * 1.15, (m8, m32)


def test_fp16_master_weights_match_fp32_master():
    pol16 = PAPER_POLICY
    pol32 = dataclasses.replace(PAPER_POLICY, master_weight_dtype="float32")
    l16 = _train(pol16, steps=40)
    l32 = _train(pol32, steps=40)
    assert l16[-5:].mean() < l32[-5:].mean() * 1.15


def test_microbatched_step_matches_full_batch_loss():
    cfg = build_config("qwen2-1.5b", smoke=True).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=VOCAB, remat=False, policy=BASELINE_POLICY)
    opt = make_optimizer_for(cfg, learning_rate=1e-3,
                             scaler=convnet_scaler(128.0))
    data = synthetic_lm_batches(DataConfig(vocab_size=VOCAB, seq_len=32,
                                           batch_size=8, seed=0))
    batch = next(data)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    s1 = opt.init(params)
    s2 = opt.init(params)
    f1 = jax.jit(make_train_step(cfg, opt, n_microbatches=1))
    f4 = jax.jit(make_train_step(cfg, opt, n_microbatches=4))
    _, m1 = f1(s1, batch, jax.random.PRNGKey(1))
    _, m4 = f4(s2, batch, jax.random.PRNGKey(1))
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=0.05)
