"""Block-size autotuner: winners-table round-trip, resolution precedence
(explicit > table > defaults), and bit-parity of every candidate block
config against the unfused oracles — tuning must only ever move wall-clock,
never a single bit of any observation site."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels.fp8_attention import (fp8_attention_bwd,
                                         fp8_attention_bwd_ref,
                                         fp8_attention_fwd,
                                         fp8_attention_fwd_ref)
from repro.kernels.fused_quant_matmul import (fused_quant_matmul,
                                              fused_quant_matmul_ref)


def _gemm_operands(m, k, n, fmt=jnp.float8_e5m2):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(fmt)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(fmt)
    return a, b, jax.random.PRNGKey(2)


def _attn_operands(s, d, b=1, h=1):
    q8, k8, v8 = [(jax.random.normal(jax.random.PRNGKey(i), (b, h, s, d))
                   * 0.3).astype(jnp.float8_e5m2) for i in range(3)]
    do8 = (jax.random.normal(jax.random.PRNGKey(4), (b, h, s, d))
           * 0.2).astype(jnp.float8_e5m2)
    return q8, k8, v8, do8


# ---------------------------------------------------------------------------
# winners table: keys, persistence, cache
# ---------------------------------------------------------------------------

class TestTable:
    def test_bucket_keys_pow2(self):
        # Shapes bucket to the next power of two (min 8) so near-miss
        # shapes share an entry instead of each missing the table.
        assert at.gemm_key("nn", 100, 300, 130, "e5m2") == \
            at.gemm_key("nn", 128, 512, 256, "e5m2")
        assert at.attn_key("fwd", "causal", 200, 200, 64) == \
            at.attn_key("fwd", "causal", 256, 256, 64)
        assert at.gemm_key("nn", 64, 128, 128, "e5m2") != \
            at.gemm_key("nt", 64, 128, 128, "e5m2")

    def test_save_load_round_trip(self, tmp_path):
        p = tmp_path / "table.json"
        table = {at.gemm_key("nn", 64, 128, 128, "e5m2"):
                 {"bm": 32, "bk": 128, "bn": 128}}
        at.save_table(p, table)
        assert at.load_table(p) == table
        # save invalidates the mtime cache: a second save is visible.
        table2 = dict(table)
        table2[at.attn_key("fwd", "causal", 256, 256, 64)] = \
            {"block_q": 64, "block_kv": 128}
        at.save_table(p, table2)
        assert at.load_table(p) == table2

    def test_malformed_table_ignored(self, tmp_path):
        p = tmp_path / "broken.json"
        p.write_text("{not json")
        assert at.load_table(p) == {}
        bm, bk, bn = at.resolve_gemm_blocks(
            "nn", 64, 128, 128, out_format="e5m2",
            autotune=str(p), defaults=(256, 512, 256))
        assert (bm, bk, bn) == (256, 512, 256)

    def test_env_var_points_resolution_at_table(self, tmp_path,
                                                monkeypatch):
        p = tmp_path / "env_table.json"
        at.save_table(p, {at.gemm_key("nn", 64, 128, 128, "e5m2"):
                          {"bm": 32, "bk": 128, "bn": 128}})
        monkeypatch.setenv(at.ENV_VAR, str(p))
        assert at.table_path("table") == p
        assert at.resolve_gemm_blocks(
            "nn", 64, 128, 128, out_format="e5m2", autotune="table",
            defaults=(256, 512, 256)) == (32, 128, 128)


# ---------------------------------------------------------------------------
# resolution precedence: explicit > table > defaults, per knob
# ---------------------------------------------------------------------------

class TestResolvePrecedence:
    def test_gemm_explicit_beats_table(self, tmp_path):
        p = tmp_path / "t.json"
        at.save_table(p, {at.gemm_key("nn", 64, 128, 128, "e5m2"):
                          {"bm": 32, "bk": 128, "bn": 128}})
        # Explicit bm wins; unset bk/bn still come from the table.
        assert at.resolve_gemm_blocks(
            "nn", 64, 128, 128, out_format="e5m2", bm=64,
            autotune=str(p), defaults=(256, 512, 256)) == (64, 128, 128)

    def test_gemm_off_pins_defaults(self, tmp_path):
        p = tmp_path / "t.json"
        at.save_table(p, {at.gemm_key("nn", 64, 128, 128, "e5m2"):
                          {"bm": 32, "bk": 128, "bn": 128}})
        assert at.resolve_gemm_blocks(
            "nn", 64, 128, 128, out_format="e5m2", autotune="off",
            defaults=(256, 512, 256)) == (256, 512, 256)

    def test_gemm_invalid_table_entry_ignored(self, tmp_path):
        p = tmp_path / "t.json"
        at.save_table(p, {at.gemm_key("nn", 64, 128, 128, "e5m2"):
                          {"bm": "huge", "bk": -4, "bn": 128}})
        assert at.resolve_gemm_blocks(
            "nn", 64, 128, 128, out_format="e5m2", autotune=str(p),
            defaults=(256, 512, 256)) == (256, 512, 128)

    def test_gemm_explicit_invalid_raises(self):
        with pytest.raises(ValueError):
            at.resolve_gemm_blocks("nn", 64, 128, 128, out_format="e5m2",
                                   bm=0, autotune="off",
                                   defaults=(256, 512, 256))

    def test_attn_fwd_table_consulted(self, tmp_path):
        p = tmp_path / "t.json"
        at.save_table(p, {at.attn_key("fwd", "causal", 256, 256, 64):
                          {"block_q": 64, "block_kv": 128}})
        assert at.resolve_attn_blocks(
            "fwd", "causal", 256, 256, 64, autotune=str(p)) == (64, 128)
        # Explicit knobs beat the table per-knob.
        assert at.resolve_attn_blocks(
            "fwd", "causal", 256, 256, 64, block_q=128,
            autotune=str(p)) == (128, 128)

    def test_attn_bwd_invalid_table_entry_ignored(self, tmp_path):
        # A table entry the bwd kernel cannot honor (block_q not a TQ
        # multiple) silently falls back to the default — table contents
        # must never make a launch raise.
        p = tmp_path / "t.json"
        at.save_table(p, {at.attn_key("bwd", "causal", 256, 256, 64):
                          {"block_q": 192, "block_kv": 128}})
        bq, bkv = at.resolve_attn_blocks("bwd", "causal", 256, 256, 64,
                                         autotune=str(p))
        assert bq == at.TQ and bkv == 128

    def test_attn_bwd_explicit_sub_tq_raises(self):
        # The silent `max(TQ, block_q)` clamp is gone: an explicit
        # request the kernel cannot honor is an error.
        with pytest.raises(ValueError, match="multiple of TQ"):
            at.resolve_attn_blocks("bwd", "causal", 256, 256, 64,
                                   block_q=64, autotune="off")

    def test_attn_fwd_explicit_invalid_raises(self):
        with pytest.raises(ValueError):
            at.resolve_attn_blocks("fwd", "causal", 256, 256, 64,
                                   block_q=192, autotune="off")


# ---------------------------------------------------------------------------
# ops consult the table; explicit knobs win; results are bit-invariant
# ---------------------------------------------------------------------------

class TestOpsConsultTable:
    def test_gemm_table_blocks_bit_match_explicit(self, tmp_path):
        p = tmp_path / "t.json"
        at.save_table(p, {at.gemm_key("nn", 64, 128, 128, "e5m2"):
                          {"bm": 32, "bk": 128, "bn": 128}})
        a, b, key = _gemm_operands(64, 128, 128)
        y_t, am_t = fused_quant_matmul(a, b, key, autotune=str(p),
                                       with_amax=True, interpret=True)
        y_e, am_e = fused_quant_matmul(a, b, key, bm=32, bk=128, bn=128,
                                       autotune="off", with_amax=True,
                                       interpret=True)
        y_d, am_d = fused_quant_matmul(a, b, key, autotune="off",
                                       with_amax=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(y_t).view(np.uint8),
                                      np.asarray(y_e).view(np.uint8))
        np.testing.assert_array_equal(np.asarray(y_t).view(np.uint8),
                                      np.asarray(y_d).view(np.uint8))
        assert float(am_t) == float(am_e) == float(am_d)

    def test_attention_table_blocks_bit_match_default(self, tmp_path):
        p = tmp_path / "t.json"
        at.save_table(p, {at.attn_key("fwd", "causal", 256, 256, 64):
                          {"block_q": 64, "block_kv": 128}})
        q8, k8, v8, _ = _attn_operands(256, 64)
        scal = jnp.array([1.0, 1.0, 1.0, 1.0], jnp.float32)
        o_t, as_t, ap_t = fp8_attention_fwd(q8, k8, v8, 7, scal,
                                            autotune=str(p),
                                            interpret=True)
        o_d, as_d, ap_d = fp8_attention_fwd(q8, k8, v8, 7, scal,
                                            autotune="off",
                                            interpret=True)
        np.testing.assert_array_equal(np.asarray(o_t).view(np.uint16),
                                      np.asarray(o_d).view(np.uint16))
        assert float(as_t) == float(as_d) and float(ap_t) == float(ap_d)

    def test_sweep_winner_feeds_ops(self, tmp_path):
        # End to end: a (synthetic) sweep result saved via save_table is
        # what resolve hands the ops layer on the next call.
        p = tmp_path / "t.json"
        table = dict(at.load_table(p))
        table[at.gemm_key("nn", 256, 256, 256, "e5m2")] = \
            {"bm": 128, "bk": 256, "bn": 128, "wall_us": 1.0}
        at.save_table(p, table)
        assert at.resolve_gemm_blocks(
            "nn", 256, 256, 256, out_format="e5m2", autotune=str(p),
            defaults=(256, 512, 256)) == (128, 256, 128)


# ---------------------------------------------------------------------------
# parity sweep: every candidate bit-matches the oracle at every
# observation site (out/amax/health x fwd/bwd), both recipes
# ---------------------------------------------------------------------------

class TestCandidateParity:
    @pytest.mark.parametrize("out_format", ["e5m2", "e4m3"])
    def test_gemm_candidates_bit_match_oracle(self, out_format):
        m, k, n = 256, 256, 256
        a, b, key = _gemm_operands(m, k, n)
        scale = jnp.asarray([2.0], jnp.float32)
        rand8 = jax.random.bits(key, (m, n), jnp.uint8)
        ref, ref_amax = fused_quant_matmul_ref(
            a, b, rand8, scale, out_format=out_format, with_amax=True)
        cands = at.gemm_candidates(m, k, n, defaults=(256, 512, 256),
                                   smoke=True)
        assert len(cands) >= 2
        for bm, bk, bn in cands:
            out, amax, health = fused_quant_matmul(
                a, b, key, scale, bm=bm, bk=bk, bn=bn, autotune="off",
                out_format=out_format, with_amax=True, with_counts=True,
                interpret=True)
            np.testing.assert_array_equal(
                np.asarray(out).view(np.uint8),
                np.asarray(ref).view(np.uint8),
                err_msg=f"blocks ({bm},{bk},{bn})")
            assert float(amax) == pytest.approx(float(ref_amax) * 2.0)
            assert health.shape == (2,) and float(health[0]) >= 0.0

    @pytest.mark.parametrize("fmt", ["e5m2", "e4m3"])
    def test_attn_fwd_candidates_bit_match_oracle(self, fmt):
        s, d = 256, 64
        q8, k8, v8, _ = _attn_operands(s, d)
        scal = jnp.array([0.5, 2.0, 8.0, 0.125], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s=fmt, fmt_p=fmt,
                  rounding_s="sr", rounding_p="sr")
        ro, ras, rap, _, _ = fp8_attention_fwd_ref(q8, k8, v8, 7, scal,
                                                   **kw)
        cands = at.attn_candidates("fwd", s, s, smoke=True)
        assert len(cands) >= 2
        for bq, bkv in cands:
            o, a_s, a_p, hs, hp = fp8_attention_fwd(
                q8, k8, v8, 7, scal, block_q=bq, block_kv=bkv,
                autotune="off", with_counts=True, interpret=True, **kw)
            np.testing.assert_array_equal(
                np.asarray(o).view(np.uint16),
                np.asarray(ro).view(np.uint16),
                err_msg=f"blocks (q={bq}, kv={bkv})")
            assert float(a_s) == float(ras) and float(a_p) == float(rap)
            assert hs.shape == (2,) and hp.shape == (2,)

    @pytest.mark.parametrize("fmt", ["e5m2", "e4m3"])
    def test_attn_bwd_candidates_bit_match_oracle(self, fmt):
        s, d = 256, 64
        q8, k8, v8, do8 = _attn_operands(s, d)
        scal = jnp.array([0.5, 2.0, 8.0, 0.125, 0.7, 1.5, 0.3, 0.8, 0.9,
                          0.05], jnp.float32)
        kw = dict(mask_mode="causal", fmt_s=fmt, fmt_p=fmt, fmt_e="e5m2",
                  rounding_s="sr", rounding_p="sr", rounding_e="sr",
                  saturate_e=False)
        refs = fp8_attention_bwd_ref(q8, k8, v8, do8, 7, scal, **kw)
        cands = at.attn_candidates("bwd", s, s, smoke=True)
        assert len(cands) >= 1
        for bq, bkv in cands:
            outs = fp8_attention_bwd(
                q8, k8, v8, do8, 7, scal, block_q=bq, block_kv=bkv,
                autotune="off", with_counts=True, interpret=True, **kw)
            for g, r, name in zip(outs[:3], refs[:3], ("dq", "dk", "dv")):
                np.testing.assert_array_equal(
                    np.asarray(g), np.asarray(r),
                    err_msg=f"{name} blocks (q={bq}, kv={bkv})")
            assert float(outs[3]) == float(refs[3])
            assert float(outs[4]) == float(refs[4])
            assert outs[5].shape == (2,) and outs[6].shape == (2,)


# ---------------------------------------------------------------------------
# policy knob + launch meta
# ---------------------------------------------------------------------------

class TestPolicyWiring:
    def test_quantconfig_autotune_off_bit_matches_table(self):
        # The policy-level autotune knob reaches the attention kernel and
        # never changes bits — only schedule.
        import dataclasses

        from repro.core.precision_policy import QuantConfig
        cfg = QuantConfig(recipe="paper_e5m2")
        assert cfg.autotune == "table"
        off = dataclasses.replace(cfg, autotune="off")
        assert off.attn_block_q is None and off.attn_block_kv is None

    def test_build_cell_meta_records_resolved_blocks(self, monkeypatch):
        import repro.launch.specs as S
        import repro.models.registry as R
        from repro.launch.mesh import enter_mesh, make_mesh
        orig = R.build_config
        monkeypatch.setattr(
            R, "build_config",
            lambda a, smoke=False, **kw: orig(a, smoke=True, **kw))
        monkeypatch.setattr(S, "build_config", R.build_config)
        monkeypatch.setitem(S.SHAPES, "tiny_train",
                            dict(seq=64, batch=8, mode="train"))
        S._cfg_for_cell.cache_clear()
        try:
            mesh = make_mesh((1, 1), ("data", "model"))
            with enter_mesh(mesh):
                cell = S.build_cell("qwen2-1.5b", "tiny_train", mesh)
                cell_off = S.build_cell(
                    "qwen2-1.5b", "tiny_train", mesh,
                    overrides={"policy.quant.autotune": "off"})
        finally:
            S._cfg_for_cell.cache_clear()
        # Resolved schedule is visible in the launch meta for both paths.
        assert cell["meta"]["autotune"] == "table"
        assert cell["meta"]["attn_block_q"] >= 1
        assert cell["meta"]["attn_block_kv"] % 128 == 0
        assert cell_off["meta"]["autotune"] == "off"
        assert cell_off["meta"]["attn_block_q"] >= 1
