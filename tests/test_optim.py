"""Optimizers + FP16-master mixed precision (paper Fig. 1b)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.loss_scale import LossScaler, convnet_scaler
from repro.core.master_weights import MixedPrecisionOptimizer
from repro.optim import make_optimizer
from repro.optim.optimizers import l2_regularization_loss, make_leafwise


def _mp(name="momentum", scaler=None, fused=False, **kw):
    init, update = make_optimizer(name, **kw)
    extra = {}
    if fused:
        names, leaf = make_leafwise(name, **kw)
        extra = dict(accum_names=names, leaf_update=leaf)
    return MixedPrecisionOptimizer(
        inner_init=init, inner_update=update,
        scaler=scaler or convnet_scaler(1024.0), **extra)


class TestOptimizers:
    def test_momentum_trajectory(self):
        init, update = make_optimizer("momentum", learning_rate=0.1,
                                      momentum=0.9)
        p = {"w": jnp.array([1.0])}
        s = init(p)
        g = {"w": jnp.array([1.0])}
        upd, s = update(g, s, p)
        np.testing.assert_allclose(np.asarray(upd["w"]), -0.1)
        upd, s = update(g, s, p)
        np.testing.assert_allclose(np.asarray(upd["w"]), -0.19)  # 0.9*1+1

    def test_adam_first_step_is_lr(self):
        init, update = make_optimizer("adam", learning_rate=0.01)
        p = {"w": jnp.array([1.0])}
        s = init(p)
        upd, _ = update({"w": jnp.array([0.5])}, s, p)
        np.testing.assert_allclose(np.asarray(upd["w"]), -0.01, rtol=1e-4)

    def test_l2_loss_eq1(self):
        p = {"a": jnp.array([1.0, 2.0]), "b": jnp.array([3.0])}
        assert float(l2_regularization_loss(p, 0.1)) == pytest.approx(1.4)


class TestMixedPrecision:
    def test_master_stored_fp16(self):
        opt = _mp()
        state = opt.init({"w": jnp.ones((3,), jnp.float32)})
        assert state.master["w"].dtype == jnp.float16

    def test_unscale_and_update(self):
        opt = _mp(learning_rate=0.1, momentum=0.0)
        state = opt.init({"w": jnp.ones((2,), jnp.float32)})
        grads = {"w": jnp.full((2,), 1024.0 * 0.5)}     # loss-scaled
        state, m = jax.jit(opt.apply_gradients)(state, grads)
        np.testing.assert_allclose(np.asarray(state.master["w"],
                                              np.float32), 0.95, rtol=1e-3)
        assert bool(m["grads_finite"])

    def test_overflow_skips_step(self):
        opt = _mp()
        state = opt.init({"w": jnp.ones((2,), jnp.float32)})
        state2, m = jax.jit(opt.apply_gradients)(
            state, {"w": jnp.array([jnp.inf, 1.0])})
        np.testing.assert_array_equal(np.asarray(state2.master["w"]),
                                      np.asarray(state.master["w"]))
        assert not bool(m["grads_finite"])

    @pytest.mark.parametrize("name", ["momentum", "adam"])
    def test_fused_matches_generic(self, name):
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (16,)),
                  "b": jax.random.normal(jax.random.PRNGKey(1), (4,))}
        grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (16,)) * 512,
                 "b": jax.random.normal(jax.random.PRNGKey(3), (4,)) * 512}
        scaler = convnet_scaler(512.0)
        o_gen = _mp(name, scaler, fused=False, learning_rate=0.05)
        o_fus = _mp(name, scaler, fused=True, learning_rate=0.05)
        s_gen = o_gen.init(params)
        s_fus = o_fus.init(params)
        for _ in range(3):
            s_gen, _ = jax.jit(o_gen.apply_gradients)(s_gen, grads)
            s_fus, _ = jax.jit(o_fus.apply_gradients)(s_fus, grads)
        for k in params:
            np.testing.assert_allclose(
                np.asarray(s_gen.master[k], np.float32),
                np.asarray(s_fus.master[k], np.float32), rtol=2e-3,
                atol=2e-4)

    def test_dynamic_scale_backs_off_then_steps(self):
        opt = _mp(scaler=LossScaler(mode="dynamic", init_scale=1024.0))
        state = opt.init({"w": jnp.ones((2,))})
        state, m = jax.jit(opt.apply_gradients)(
            state, {"w": jnp.array([jnp.nan, 1.0])})
        assert float(m["loss_scale"]) == 512.0
        state, m = jax.jit(opt.apply_gradients)(
            state, {"w": jnp.array([512.0, 512.0])})
        assert bool(m["grads_finite"])
