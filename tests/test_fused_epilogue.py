"""Fused quantize-in-epilogue GEMM path: lowering + bit-for-bit parity.

Locks the three guarantees of the fused qeinsum path (backend="pallas*" +
delayed scaling):

  1. Routing: fwd, dgrad and wgrad all lower to Pallas calls — no silent
     XLA fallback (the bug this PR fixes: the adjoint specs were rejected
     by _pallas_matmul_spec and fell back to jnp.einsum, plus a separate
     _fake_quant_grad pass over HBM).
  2. Numerics: fused output + grads bit-match the unfused
     quantize->matmul composition (the ref oracle) under both recipes.
  3. Observations: the fused-epilogue amaxes bit-match the `_observe`
     bit-pattern reduction over the (identical) materialized payloads, and
     are invariant to the (bm, bk, bn) tiling choice.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import ACT, ERROR, GRAD, WEIGHT, QuantConfig
from repro.core.qlinear import (N_SCALES, _fused_epilogue, _quant_operand,
                                qeinsum)
from repro.core.quantize import fp8_amax_bits
from repro.kernels.fused_quant_matmul import (fused_quant_matmul,
                                              fused_quant_matmul_ref)
from repro.scaling import context as sc
from repro.scaling.state import (DelayedScaling, ScalingConfig, SiteRegistry,
                                 split_observations)

jax.config.update("jax_platform_name", "cpu")


def _cfg(recipe):
    return QuantConfig(recipe=recipe, scaling="delayed",
                       backend="pallas_interpret")


def _site_bundle(cfg, classes=("act", "weight")):
    keys = sc.operand_keys("s", classes)
    fkeys = sc.fused_output_keys("s", classes)
    reg = SiteRegistry(list(keys.values()) + list(fkeys.values()), ("s",))
    ds = DelayedScaling(reg, ScalingConfig(), qcfg=cfg)
    return keys, fkeys, reg, ds


def _run_step(ds, cfg, a, b, key, *, spec="bsk,kn->bsn"):
    """One fused training step through qeinsum; returns (y, grads,
    observations)."""
    def loss(a, b, tokens):
        with ds.collect(ds_state, tokens):
            y = qeinsum(spec, a, b, key=key, cfg=cfg, site="s")
            aux = sc.drain_aux()
        return y.astype(jnp.float32).sum(), (y, aux)

    ds_state = _run_step.state
    (_, (y, aux)), grads = jax.value_and_grad(
        loss, argnums=(0, 1, 2), has_aux=True)(a, b, ds.zero_tokens())
    obs = split_observations(dict(aux), grads[2], ds.registry)
    return y, grads[:2], obs


# ---------------------------------------------------------------------------
# 1. routing: all three GEMMs lower to Pallas, none to XLA dots
# ---------------------------------------------------------------------------

# The canonical traversal lives in repro.analysis.jaxpr_walk; the lint
# passes and these tests assert through the same walker.
from repro.analysis.jaxpr_walk import count_prims as _count_prims


class TestFusedLowering:
    @pytest.mark.parametrize("recipe", ["paper_e5m2", "hybrid"])
    def test_three_pallas_calls_no_xla_dots(self, recipe):
        cfg = _cfg(recipe)
        _, _, reg, ds = _site_bundle(cfg)
        a = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32))
        b = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1
        state = ds.init()

        def step(a, b, tokens):
            def loss(a, b, tokens):
                with ds.collect(state, tokens):
                    y = qeinsum("bsk,kn->bsn", a, b,
                                key=jax.random.PRNGKey(2), cfg=cfg, site="s")
                    sc.drain_aux()
                return y.astype(jnp.float32).sum()
            return jax.grad(loss, argnums=(0, 1, 2))(a, b, tokens)

        counts = _count_prims(jax.make_jaxpr(step)(
            a, b, ds.zero_tokens()).jaxpr)
        assert counts["pallas"] == 3, counts   # fwd nn + dgrad nt + wgrad tn
        assert counts["outside_dot"] == 0, counts

    def test_unfused_delayed_pallas_falls_back_for_adjoints(self):
        """With fuse_epilogue=False the fwd GEMM still runs the plain
        fp8_matmul kernel but both adjoints fall back to XLA dots — the
        regression this PR fixes; kept as documentation of the off switch."""
        cfg = dataclasses.replace(_cfg("paper_e5m2"), fuse_epilogue=False)
        _, _, reg, ds = _site_bundle(cfg)
        a = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32))
        b = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1
        state = ds.init()

        def step(a, b, tokens):
            def loss(a, b, tokens):
                with ds.collect(state, tokens):
                    y = qeinsum("bsk,kn->bsn", a, b,
                                key=jax.random.PRNGKey(2), cfg=cfg, site="s")
                    sc.drain_aux()
                return y.astype(jnp.float32).sum()
            return jax.grad(loss, argnums=(0, 1, 2))(a, b, tokens)

        counts = _count_prims(jax.make_jaxpr(step)(
            a, b, ds.zero_tokens()).jaxpr)
        assert counts["pallas"] == 1, counts
        assert counts["outside_dot"] >= 2, counts

    def test_attention_specs_not_fused(self):
        cfg = _cfg("paper_e5m2")
        assert not _fused_epilogue("bhqd,bhkd->bhqk", ("act", "act"), cfg)
        assert _fused_epilogue("bsk,kn->bsn", ("act", "weight"), cfg)
        assert not _fused_epilogue(
            "bsk,kn->bsn", ("act", "weight"),
            dataclasses.replace(cfg, scaling="none"))
        assert not _fused_epilogue(
            "bsk,kn->bsn", ("act", "weight"),
            dataclasses.replace(cfg, backend="xla"))


# ---------------------------------------------------------------------------
# 2 + 3. bit parity with the unfused composition; observations == _observe
# ---------------------------------------------------------------------------

def _bits(x):
    return np.asarray(x).view(np.uint8)


class TestFusedParity:
    @pytest.mark.parametrize("recipe", ["paper_e5m2", "hybrid"])
    def test_qeinsum_bit_matches_unfused_composition(self, recipe):
        """Fused fwd/dgrad/wgrad outputs, grads and amax observations all
        bit-match the quantize->matmul composition (ref oracle) built from
        the same operands, scales and SR bits."""
        cfg = _cfg(recipe)
        keys_, fkeys, reg, ds = _site_bundle(cfg)
        a = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 32))
        b = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1
        key = jax.random.PRNGKey(7)

        state = ds.init()
        _run_step.state = state
        _, _, obs0 = _run_step(ds, cfg, a, b, key)   # warmup: scales <- amax
        state = ds.update(state, obs0)
        _run_step.state = state
        y, (ga, gb), obs = _run_step(ds, cfg, a, b, key)
        scales = ds.scales_dict(state)

        # ---- reference: unfused quantize -> matmul -> quantize composition
        k_a, k_b, k_bwd, k_y = jax.random.split(key, 4)
        k_e, k_da, k_db = jax.random.split(k_bwd, 3)
        s_a, s_b = scales[keys_["a"]], scales[keys_["b"]]
        s_e, s_g = scales[keys_["E"]], scales[keys_["G"]]
        s_y, s_err = scales[fkeys["y"]], scales[fkeys["err"]]
        qa = _quant_operand(a, ACT, cfg, k_a, scale=jnp.float32(s_a))
        qb = _quant_operand(b, WEIGHT, cfg, k_b, scale=jnp.float32(s_b))
        a2 = qa.data.reshape((-1, 32))
        m = a2.shape[0]

        def ref_gemm(x8, w8, sx, sw, s_out, rkey, cls, dims, mn):
            kscale = jnp.float32(s_out) / (sx * sw).astype(jnp.float32)
            rand8 = jax.random.bits(rkey, mn, jnp.uint8) \
                if cfg.rounding_for(cls) == "sr" \
                else jnp.zeros(mn, jnp.uint8)
            q, amax = fused_quant_matmul_ref(
                x8, w8, rand8, kscale.reshape((1,)), dims=dims,
                out_format=cfg.format_for(cls),
                rounding=cfg.rounding_for(cls),
                saturate=cfg.saturate_for(cls), with_amax=True)
            deq = (q.astype(jnp.float32) * jnp.float32(s_out)) \
                .astype(jnp.bfloat16)
            return q, deq, amax * jnp.float32(s_out)

        # fwd: Y = Q_A(A.W)
        y8, y_ref, amax_y = ref_gemm(a2, qb.data, qa.scale, qb.scale, s_y,
                                     k_y, ACT, "nn", (m, 16))
        np.testing.assert_array_equal(
            _bits(y), _bits(y_ref.reshape(y.shape)))
        # bwd: dy = ones (cotangent of .sum()); E-quantized as usual
        dy = jnp.ones((3, 8, 16), jnp.bfloat16)
        qdy = _quant_operand(dy, ERROR, cfg, k_e, scale=jnp.float32(s_e))
        dy2 = qdy.data.reshape((-1, 16))
        # dgrad: dA = Q_E(dY . W^T)
        da8, da_ref, amax_da = ref_gemm(dy2, qb.data, qdy.scale, qb.scale,
                                        s_err, k_da, ERROR, "nt", (m, 32))
        np.testing.assert_array_equal(
            _bits(ga), _bits(da_ref.reshape(a.shape).astype(a.dtype)))
        # wgrad: dW = Q_G(A^T . dY)
        db8, db_ref, amax_g = ref_gemm(a2, dy2, qa.scale, qdy.scale, s_g,
                                       k_db, GRAD, "tn", (32, 16))
        np.testing.assert_array_equal(
            _bits(gb), _bits(db_ref.astype(b.dtype)))

        # ---- observations: fused epilogue == _observe bit-pattern reduce
        # over the (bit-identical) materialized payloads. Exact f32 equality.
        expect = {
            fkeys["y"]: fp8_amax_bits(y8) * jnp.float32(s_y),
            fkeys["err"]: fp8_amax_bits(da8) * jnp.float32(s_err),
            keys_["G"]: fp8_amax_bits(db8) * jnp.float32(s_g),
            keys_["E"]: fp8_amax_bits(qdy.data) * qdy.scale,
            keys_["a"]: fp8_amax_bits(qa.data) * qa.scale,
            keys_["b"]: fp8_amax_bits(qb.data) * qb.scale,
        }
        for k, v in expect.items():
            assert np.float32(obs[k]).tobytes() == np.float32(v).tobytes(), k
        # and the fused-epilogue amaxes agree with the ref-side epilogue
        for got, want in [(obs[fkeys["y"]], amax_y),
                          (obs[fkeys["err"]], amax_da),
                          (obs[keys_["G"]], amax_g)]:
            assert float(got) == float(want)

    def test_weight_on_lhs(self):
        """classes=(weight, act): the error output flows to operand b
        ("#db.E") and the weight grad to operand a."""
        cfg = _cfg("hybrid")
        classes = ("weight", "act")
        fkeys = sc.fused_output_keys("s", classes)
        assert fkeys["err"] == "s#db.E"
        keys_ = sc.operand_keys("s", classes)
        reg = SiteRegistry(list(keys_.values()) + list(fkeys.values()),
                           ("s",))
        ds = DelayedScaling(reg, ScalingConfig(), qcfg=cfg)
        w = jax.random.normal(jax.random.PRNGKey(0), (8, 32)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
        state = ds.init()

        def loss(w, x, tokens):
            with ds.collect(state, tokens):
                y = qeinsum("mk,kn->mn", w, x, key=jax.random.PRNGKey(2),
                            cfg=cfg, classes=classes, site="s")
                aux = sc.drain_aux()
            return y.astype(jnp.float32).sum(), aux

        (_, aux), grads = jax.value_and_grad(
            loss, argnums=(0, 1, 2), has_aux=True)(w, x, ds.zero_tokens())
        obs = split_observations(dict(aux), grads[2], reg)
        assert "s#db.E" in obs and "s#G" in obs and "s#y.A" in obs
        assert all(np.isfinite(np.asarray(v)).all() for v in obs.values())


# ---------------------------------------------------------------------------
# ops-level: tiling invariance of SR bits + masked amax (padding bugfix)
# ---------------------------------------------------------------------------

class TestTilingInvariance:
    @pytest.mark.parametrize("dims,ash,bsh", [
        ("nn", (40, 200), (200, 130)),
        ("nt", (40, 200), (130, 200)),
        ("tn", (200, 40), (200, 130)),
    ])
    @pytest.mark.parametrize("rounding", ["rne", "sr"])
    def test_output_and_amax_invariant_to_blocks(self, dims, ash, bsh,
                                                 rounding):
        """Padding used to draw SR bits over the PADDED shape and scan dead
        tiles in the amax epilogue, making results depend on (bm, bk, bn).
        Now rand bits are drawn on the logical (m, n) and padding is masked
        out of the amax."""
        a = (jax.random.normal(jax.random.PRNGKey(0), ash) * 0.25).astype(
            jnp.float8_e5m2)
        b = (jax.random.normal(jax.random.PRNGKey(1), bsh) * 0.1).astype(
            jnp.float8_e5m2)
        key = jax.random.PRNGKey(2)
        outs = []
        for blocks in [(32, 128, 128), (64, 256, 256), (8, 512, 128)]:
            bm, bk, bn = blocks
            y, amax = fused_quant_matmul(
                a, b, key, jnp.array([2.0]), dims=dims, bm=bm, bk=bk, bn=bn,
                rounding=rounding, with_amax=True, amax_units="grid",
                interpret=True)
            outs.append((np.asarray(y).view(np.uint8), float(amax)))
        for o, am in outs[1:]:
            np.testing.assert_array_equal(o, outs[0][0])
            assert am == outs[0][1]

    def test_sr_bits_match_logical_draw(self):
        """The SR bits consumed for logical cells are exactly
        jax.random.bits(key, (m, n)) — independent of padding — so the
        fused output bit-matches the ref composition on awkward shapes."""
        m, k, n = 36, 130, 70
        a = (jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.25).astype(
            jnp.float8_e5m2)
        b = (jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1).astype(
            jnp.float8_e5m2)
        key = jax.random.PRNGKey(3)
        y = fused_quant_matmul(a, b, key, jnp.array([1.5]), bm=32, bk=128,
                               bn=128, rounding="sr", interpret=True)
        rand8 = jax.random.bits(key, (m, n), jnp.uint8)
        ref = fused_quant_matmul_ref(a, b, rand8, jnp.array([1.5]),
                                     rounding="sr")
        np.testing.assert_array_equal(np.asarray(y).view(np.uint8),
                                      np.asarray(ref).view(np.uint8))
