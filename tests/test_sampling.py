"""On-device sampling: exact top-k/top-p masks, greedy == host argmax,
batch-layout-invariant PRNG streams."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.sampling import (NEG_INF, row_keys, sample, top_k_mask,
                                  top_p_mask)

jax.config.update("jax_platform_name", "cpu")


class TestGreedy:
    def test_temperature_zero_is_host_argmax(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(size=(5, 97)), jnp.float32)
        keys = row_keys(jnp.arange(5, dtype=jnp.int32),
                        jnp.zeros(5, jnp.int32))
        got = np.asarray(sample(logits, keys, temperature=0.0))
        ref = np.asarray(logits).argmax(-1)
        np.testing.assert_array_equal(got, ref)

    def test_top_k_one_is_greedy(self):
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.normal(size=(4, 33)), jnp.float32)
        keys = row_keys(jnp.arange(4, dtype=jnp.int32),
                        jnp.zeros(4, jnp.int32))
        got = np.asarray(sample(logits, keys, temperature=1.0, top_k=1))
        np.testing.assert_array_equal(got, np.asarray(logits).argmax(-1))


class TestMasks:
    def test_top_k_exact(self):
        logits = jnp.asarray([[5.0, 1.0, 3.0, 4.0, 2.0]])
        out = np.asarray(top_k_mask(logits, 2))
        np.testing.assert_array_equal(
            out, [[5.0, NEG_INF, NEG_INF, 4.0, NEG_INF]])
        # ties at the threshold are all kept
        tied = jnp.asarray([[3.0, 3.0, 1.0, 0.0]])
        out = np.asarray(top_k_mask(tied, 2))
        np.testing.assert_array_equal(out, [[3.0, 3.0, NEG_INF, NEG_INF]])
        # k <= 0 and k >= vocab disable
        np.testing.assert_array_equal(np.asarray(top_k_mask(logits, 0)),
                                      np.asarray(logits))
        np.testing.assert_array_equal(np.asarray(top_k_mask(logits, 99)),
                                      np.asarray(logits))

    def test_top_p_exact(self):
        # probs = [0.5, 0.25, 0.125, 0.125] by construction
        logits = jnp.log(jnp.asarray([[0.5, 0.25, 0.125, 0.125]]))
        # p = 0.6: 0.5 alone misses 0.6, so the crossing token (0.25) is
        # kept; the tail is cut
        out = np.asarray(top_p_mask(logits, 0.6))
        keep = out > NEG_INF / 2
        np.testing.assert_array_equal(keep, [[True, True, False, False]])
        # p smaller than the top prob: top-1 always survives
        out = np.asarray(top_p_mask(logits, 0.1))
        keep = out > NEG_INF / 2
        np.testing.assert_array_equal(keep, [[True, False, False, False]])
        # p >= 1 disables
        np.testing.assert_array_equal(np.asarray(top_p_mask(logits, 1.0)),
                                      np.asarray(logits))

    def test_top_p_keeps_unmasked_values(self):
        rng = np.random.default_rng(2)
        logits = jnp.asarray(rng.normal(size=(3, 17)), jnp.float32)
        out = np.asarray(top_p_mask(logits, 0.8))
        keep = out > NEG_INF / 2
        # surviving entries carry their original logits
        np.testing.assert_array_equal(out[keep], np.asarray(logits)[keep])
        assert keep.any(axis=-1).all()


class TestLayoutInvariance:
    def test_row_keys_depend_only_on_seed_and_step(self):
        k1 = np.asarray(row_keys(jnp.asarray([7, 9], jnp.int32),
                                 jnp.asarray([3, 0], jnp.int32)))
        k2 = np.asarray(row_keys(jnp.asarray([1, 7, 5], jnp.int32),
                                 jnp.asarray([0, 3, 2], jnp.int32)))
        np.testing.assert_array_equal(k1[0], k2[1])   # same (7, 3) pair
        assert not np.array_equal(k1[0], k1[1])

    def test_same_key_same_sample_across_batch_layouts(self):
        """A request's sampled token is a function of (seed, step, logits
        row) only — not of its batch row or of which rows share the step."""
        rng = np.random.default_rng(3)
        row = rng.normal(size=(1, 64)).astype(np.float32)
        noise = rng.normal(size=(7, 64)).astype(np.float32)

        def draw(batch_rows, position):
            logits = np.concatenate([noise[:position], row,
                                     noise[position:batch_rows - 1]])
            seeds = np.arange(100, 100 + batch_rows, dtype=np.int32)
            seeds[position] = 42
            steps = np.arange(batch_rows, dtype=np.int32)
            steps[position] = 5
            toks = sample(jnp.asarray(logits),
                          row_keys(jnp.asarray(seeds), jnp.asarray(steps)),
                          temperature=0.9, top_k=20, top_p=0.95)
            return int(np.asarray(toks)[position])

        draws = {draw(1, 0), draw(4, 0), draw(4, 3), draw(8, 5)}
        assert len(draws) == 1

    def test_different_steps_decorrelate(self):
        logits = jnp.zeros((1, 1024))        # uniform: draws expose the key
        toks = [int(np.asarray(sample(
            logits, row_keys(jnp.asarray([1], jnp.int32),
                             jnp.asarray([s], jnp.int32)),
            temperature=1.0))[0]) for s in range(8)]
        assert len(set(toks)) > 1
